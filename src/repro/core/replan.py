"""The unified elastic-membership contract (DESIGN.md §16).

A membership change used to be four ad-hoc code paths that each knew a
slice of the story: the lifecycle's ``tick().resize_to``, the sim
federation's ``resize``/``resize_peer_axis``, the pipeline's
``with_plan`` + per-stage ``resize_state`` hooks, and the transport's
``resize`` — with placement/controller ``rebind`` patched in after the
fact. This module replaces the seam with **one event**: a
:class:`MembershipChange` carries everything any layer needs to react
(old/new fleet size, the survivor index map, the re-planned
:class:`~repro.core.moshpit.GridPlan`), and every consumer — the sim
backend through :meth:`Federation.apply_membership`, the device backend
through :func:`repro.core.fl_device.apply_membership` — applies the
same change the same way:

* **survivors are bit-exact**: their state leaves are gathered (a pure
  reindex — the contiguous-prefix default is a no-copy slice);
* **joiners bootstrap from the group mean** (MAR's mixing makes any
  subset representative), with per-stage exceptions routed through
  :func:`resize_state_tree` (EF residuals and DP bot-markers start at
  zero);
* the grid re-factorizes via ``runtime.fault.elastic_replan`` and
  plan-holding layers (pipeline, controller, placement, transport,
  address book) re-bind to ``change.new_plan``.

A same-N change (``old_n == new_n``, different dims/placement) is the
adaptive-M / placement *regroup* — the identical contract with an
identity survivor map.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.moshpit import GridPlan

PyTree = Any


# ---------------------------------------------------------------------------
# peer-axis primitives (moved here from core/aggregation.py, which
# re-exports them — this module is the only home of the raw primitive;
# everything else consumes it through the MembershipChange contract)
# ---------------------------------------------------------------------------

def resize_peer_axis(tree: PyTree, old_n: int, new_n: int,
                     fill: str = "mean") -> PyTree:
    """Grow/shrink the stacked peer axis of a pytree *in place* (no
    checkpoint round-trip) — the elastic-membership primitive.

    Leaves whose leading dim is ``old_n`` are resized; everything else
    (scalars, shared state) passes through. Shrinking slices the first
    ``new_n`` peers (each already holds a near-global average — MAR's
    mixing makes any subset representative, same rule as
    ``Checkpointer.restore_elastic``); survivors are bit-exact.
    Growing appends peers bootstrapped from the current group mean
    (``fill="mean"``) or zeros (``fill="zero"`` — for error-feedback
    residuals and indicator state that must start empty).
    """
    if old_n == new_n:
        return tree

    def leaf(x):
        if x.ndim == 0 or x.shape[0] != old_n:
            return x
        if new_n < old_n:
            return x[:new_n]
        if fill == "zero":
            pad = jnp.zeros((new_n - old_n,) + x.shape[1:], x.dtype)
        else:
            mean = jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True)
            pad = jnp.broadcast_to(
                mean.astype(x.dtype), (new_n - old_n,) + x.shape[1:])
        return jnp.concatenate([x, pad], axis=0)

    return jax.tree.map(leaf, tree)


def resize_state_tree(own: PyTree, old_n: int, new_n: int,
                      zero_keys: Tuple[str, ...] = ()) -> PyTree:
    """The per-``WireStage`` elastic hook body: resize a stage's state
    slice, mean-bootstrapping joiners except for the named dict keys,
    which start at zero (EF residuals, DP bot-markers — state a joiner
    must not inherit). Non-dict stage state mean-bootstraps wholesale.
    """
    if old_n == new_n:
        return own
    if isinstance(own, dict):
        return {k: resize_peer_axis(v, old_n, new_n,
                                    "zero" if k in zero_keys else "mean")
                for k, v in own.items()}
    return resize_peer_axis(own, old_n, new_n, "mean")


def select_survivors(tree: PyTree, old_n: int,
                     survivors: Sequence[int]) -> PyTree:
    """Gather the surviving peers' slices (new order) out of an
    ``old_n``-peer tree — a pure reindex, bit-exact per survivor. The
    contiguous-prefix map (the default every shrink produces) is the
    historical ``x[:k]`` slice and short-circuits to it."""
    idx = np.asarray(tuple(survivors), np.int64)
    k = idx.size
    if k == old_n and np.array_equal(idx, np.arange(old_n)):
        return tree
    contiguous = np.array_equal(idx, np.arange(k))

    def leaf(x):
        if x.ndim == 0 or x.shape[0] != old_n:
            return x
        return x[:k] if contiguous else x[idx]

    return jax.tree.map(leaf, tree)


# ---------------------------------------------------------------------------
# the contract
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MembershipChange:
    """One membership event, fully planned: what every layer consumes.

    ``survivors`` maps new-fleet order to old peer ids — entry ``i`` is
    the old id of new-peer ``i``; new ids past ``len(survivors)`` are
    joiners. The default (built by :func:`plan_membership_change`) is
    the contiguous prefix ``range(min(old_n, new_n))``: tail peers
    leave, joiners append — the historical slice semantics, bit-exact.
    """

    old_n: int
    new_n: int
    new_plan: GridPlan
    survivors: Tuple[int, ...]
    iteration: Optional[int] = None

    def __post_init__(self):
        if self.new_n < 1:
            raise ValueError(f"cannot resize to {self.new_n} peers")
        if self.new_plan.n_peers != self.new_n:
            raise ValueError(
                f"plan is for {self.new_plan.n_peers} peers, change "
                f"targets {self.new_n}")
        s = self.survivors
        if len(s) > min(self.old_n, self.new_n) or \
                any(not 0 <= i < self.old_n for i in s) or \
                len(set(s)) != len(s):
            raise ValueError(
                f"survivors must be <= {min(self.old_n, self.new_n)} "
                f"distinct old peer ids in [0, {self.old_n}); got {s}")

    @property
    def same_n(self) -> bool:
        """A membership-preserving regroup (adaptive-M / placement)."""
        return self.old_n == self.new_n

    @property
    def n_joiners(self) -> int:
        return self.new_n - len(self.survivors)

    @property
    def contiguous(self) -> bool:
        return self.survivors == tuple(range(len(self.survivors)))

    def apply_to_tree(self, tree: PyTree, fill: str = "mean") -> PyTree:
        """Map one peer-stacked pytree through this change: gather
        survivors (bit-exact), then bootstrap joiners (``fill``)."""
        kept = select_survivors(tree, self.old_n, self.survivors)
        return resize_peer_axis(kept, len(self.survivors), self.new_n,
                                fill)


def plan_membership_change(old_plan: GridPlan, new_n: int, *,
                           iteration: Optional[int] = None,
                           survivors: Optional[Sequence[int]] = None,
                           exact_only: bool = False) -> MembershipChange:
    """Plan a permanent join/leave: re-factorize the grid
    (``elastic_replan`` — the old uniform M is kept when it still
    factors ``new_n``) and fix the survivor map (contiguous prefix by
    default). ``exact_only`` rejects targets whose replanned grid pads
    virtual slots — the device backend's constraint
    (``mar_aggregate_device`` needs ``capacity == n_peers``)."""
    # lazy: runtime.fault depends on core.moshpit, a module-level import
    # here would cycle when repro.runtime is imported first
    from repro.runtime.fault import elastic_replan
    if new_n < 1:
        raise ValueError(f"cannot resize to {new_n} peers")
    old_n = old_plan.n_peers
    new_plan = old_plan if new_n == old_n else \
        elastic_replan(old_plan, new_n)
    if exact_only and not new_plan.is_exact:
        raise ValueError(
            f"no exact grid for {new_n} peers (best factorization "
            f"{new_plan.dims} has capacity {new_plan.capacity}); the "
            f"device backend needs capacity == N — target a peer count "
            f"with an exact factorization (e.g. 6, 8, 9, 12, 16)")
    if survivors is None:
        survivors = tuple(range(min(old_n, new_n)))
    return MembershipChange(old_n=old_n, new_n=new_n, new_plan=new_plan,
                            survivors=tuple(int(i) for i in survivors),
                            iteration=iteration)


def regroup_change(old_plan: GridPlan, new_plan: GridPlan,
                   iteration: Optional[int] = None) -> MembershipChange:
    """A same-N membership change: new dims and/or placement for the
    same fleet — what adaptive-M proposals and placement permutations
    become before entering ``apply_membership``."""
    if new_plan.n_peers != old_plan.n_peers:
        raise ValueError(
            f"regroup keeps membership: old plan has "
            f"{old_plan.n_peers} peers, proposal {new_plan.n_peers} "
            f"(permanent join/leave goes through "
            f"plan_membership_change)")
    n = old_plan.n_peers
    return MembershipChange(old_n=n, new_n=n, new_plan=new_plan,
                            survivors=tuple(range(n)),
                            iteration=iteration)


def validate_membership_schedule(plan: GridPlan,
                                 planned: Sequence[Tuple[int, int]],
                                 exact_only: bool = True) -> None:
    """Pre-flight a schedule of ``(iteration, new_n)`` resizes (from
    ``PeerLifecycle.planned_resizes``): every target must admit a grid
    the backend can execute. Raises at launch — naming the offending
    step — instead of burning compute until the tick fires."""
    cur = plan
    for t, n in planned:
        try:
            cur = plan_membership_change(
                cur, n, iteration=t, exact_only=exact_only).new_plan
        except ValueError as e:
            raise ValueError(
                f"planned resize at step {t} ({cur.n_peers} -> {n} "
                f"peers) cannot run: {e}") from None
