"""Adaptive group sizing from measured round times (ROADMAP "Adaptive M").

MAR-FL's O(N log N) communication hinges on the group size M, but the
grid was factorized once up front (``plan_grid``) and never revisited —
even though the transport layer now *measures* exactly the signal
needed to tune it: per-iteration :class:`~repro.runtime.transport_base.
Transcript` objects carry per-round completion times (``round_s``) and
per-peer finish times (``peer_finish_s``) for both the discrete-event
simulator and the real socket transport. The wireless-FL literature
(PAPERS.md: Zhou et al. "Towards Scalable Wireless Federated Learning";
Chen et al. "CFL") argues group/cluster structure must track
heterogeneous, time-varying conditions rather than stay static; this
module is that feedback loop:

* :class:`GroupSizeController` — a registry of controllers, each
  consuming one backend-agnostic transcript per FL iteration
  (``observe(t, transcript, plan)``) and proposing a new
  :class:`~repro.core.moshpit.GridPlan` for the *same* peer count (or
  ``None`` to keep the grid). Built-ins:

  - ``static`` — never regroups; the fixed-M baseline as a controller,
    so ``adaptive_m="static"`` exercises the full hook path with zero
    behavioral effect.
  - ``tail_aware`` — shrinks M when the slowest peer's finish time
    dominates the iteration (a slow uplink serializes ``(M-1)`` sends
    per round, so smaller groups cut the tail's airtime and the number
    of peers blocked behind it), and grows M back toward the planner's
    traffic-optimal factorization when the tail clears (fewer rounds,
    fewer latency barriers). It never exceeds the initial plan: past
    the planner's choice, larger M only adds per-round sends.
  - ``schedule`` — scripted ``(iteration, dims)`` regroups for tests
    and ablations.

* The *regroup* the proposals trigger is membership-preserving: the
  federation swaps grid dims mid-run via the same elastic machinery
  permanent join/leave uses (pipeline rebuild + per-``WireStage``
  ``resize_state`` with ``old_n == new_n``), so peer state passes
  through bit-exact — ``Federation.regroup`` (sim) and the
  ``--adaptive-m`` path of ``launch/train.py`` (device backend, which
  needs ``exact_only`` grids: capacity == N).

Controllers read only the Transcript contract
(``runtime/transport_base.py``), so the same controller tunes M over
modeled links and over real loopback TCP.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple, Type

import numpy as np

from repro.core.moshpit import GridPlan, plan_grid

CONTROLLERS: Dict[str, Type["GroupSizeController"]] = {}


def register_controller(cls: Type["GroupSizeController"]
                        ) -> Type["GroupSizeController"]:
    CONTROLLERS[cls.name] = cls
    return cls


def build_controller(name: str, plan: GridPlan,
                     **params: Any) -> "GroupSizeController":
    """Build a registered group-size controller by name."""
    if name not in CONTROLLERS:
        raise ValueError(f"unknown group-size controller {name!r}; "
                         f"registered: {sorted(CONTROLLERS)}")
    return CONTROLLERS[name](plan, **params)


def candidate_grids(n_peers: int, m_min: int = 2, m_max: int = 8,
                    exact_only: bool = False,
                    max_waste: float = 2.0) -> List[GridPlan]:
    """The uniform-M grid ladder for ``n_peers``, ordered by group size.

    One plan per distinct ``dims`` for M in ``[m_min, m_max]``, each the
    shallowest uniform grid with capacity >= N. ``exact_only`` keeps
    only ``M^d == N`` factorizations (the device backend's constraint —
    ``mar_aggregate_device`` asserts capacity == N); otherwise plans
    whose virtual padding exceeds ``max_waste * n_peers`` capacity are
    dropped (mask machinery handles padding, but a mostly-virtual grid
    wastes schedule rounds). Falls back to ``plan_grid(n_peers)`` when
    nothing qualifies.
    """
    out: List[GridPlan] = []
    seen = set()
    for m in range(m_min, max(min(m_max, n_peers), m_min) + 1):
        p = plan_grid(n_peers, group_size=m)
        if exact_only and not p.is_exact:
            continue
        if not exact_only and p.capacity > max_waste * n_peers:
            continue
        if p.dims in seen:
            continue
        seen.add(p.dims)
        out.append(p)
    if not out:
        out = [plan_grid(n_peers)]
    return out


def carry_placement(old: GridPlan, new: GridPlan) -> GridPlan:
    """Carry the live plan's peer ordering onto a proposed grid.

    A dims proposal is built placement-blind, so applying it would
    scatter a clustered permutation until the placement policy's next
    observe — one iteration of re-mixed regions, which also costs the
    superpeer engine its closed-form (region-pure) intra-cluster tiers
    right when the fleet regroups. Slots don't transfer across dims,
    but the peer *order* does: peers are re-packed into the new grid
    in their old slot order, so contiguous clusters stay contiguous
    through the regroup. Identity placements pass through untouched
    (``with_placement`` normalizes the identity permutation away, so
    this cannot turn an unplaced plan into a placed one)."""
    if old.placement is None or new.placement is not None:
        return new
    n = old.n_peers
    order = np.argsort(old.slot_of(np.arange(n)), kind="stable")
    perm = np.empty(n, np.int64)
    perm[order] = np.arange(n)
    return new.with_placement(perm)


def validate_proposal(plan: GridPlan, n_peers: int,
                      exact_only: bool = False) -> GridPlan:
    """Reject proposals the runtime cannot execute: wrong peer count,
    capacity below N, or (device backend) padded grids."""
    if plan.n_peers != n_peers:
        raise ValueError(
            f"group-size controllers regroup, they do not resize: "
            f"proposed plan is for {plan.n_peers} peers, fleet has "
            f"{n_peers} (permanent join/leave goes through the "
            f"lifecycle/Federation.resize)")
    if plan.capacity < n_peers:
        raise ValueError(f"proposed grid {plan.dims} has capacity "
                         f"{plan.capacity} < {n_peers} peers")
    if exact_only and not plan.is_exact:
        raise ValueError(f"the device backend needs exact grids: "
                         f"{plan.dims} has capacity {plan.capacity} "
                         f"!= {n_peers} peers")
    return plan


class GroupSizeController:
    """One M-tuning policy over measured transcripts.

    Contract: ``observe(t, transcript, plan)`` is called once per FL
    iteration with the iteration index, the backend-agnostic transcript
    of the traffic that just ran (controllers read only ``round_s`` /
    ``peer_finish_s`` / ``lost_senders`` — the shared
    :class:`~repro.runtime.transport_base.Transcript` fields, so sim
    and socket transports feed the same policy), and the
    :class:`GridPlan` that produced it. It returns a new plan for the
    *same* peer count (the runtime regroups in place before the next
    iteration) or ``None`` to keep the grid. ``rebind(plan)``
    re-anchors the controller after an externally-driven change
    (elastic membership resize).
    """

    name: str = "?"

    def __init__(self, plan: GridPlan, exact_only: bool = False):
        self.plan = plan
        #: the device backend regroups only onto exact factorizations
        self.exact_only = exact_only

    def observe(self, t: int, transcript: Any,
                plan: GridPlan) -> Optional[GridPlan]:
        raise NotImplementedError

    def rebind(self, plan: GridPlan) -> None:
        """Re-anchor after a membership change (new N, fresh ladder)."""
        self.plan = plan


@register_controller
class StaticController(GroupSizeController):
    """Never regroups — the fixed-M baseline behind the same hook."""

    name = "static"

    def observe(self, t, transcript, plan):
        return None


@register_controller
class TailAwareController(GroupSizeController):
    """Shrink/grow M from the measured finish-time tail.

    Signal: per iteration, the *tail ratio* ``max(peer_finish_s) /
    median(peer_finish_s)`` over peers that moved traffic. A dominant
    tail (ratio above ``hi``, averaged over ``window`` iterations)
    means the slowest peer's uplink chain bounds the iteration —
    shrinking M cuts both its per-round sends (``M-1`` serialized over
    its uplink) and the group waiting on it. A flat distribution
    (ratio below ``lo``) means round barriers/latency dominate — grow
    M back toward the planner's choice (fewer rounds), but never past
    it: on a flat profile the controller therefore converges to (and
    stays at) the static ``plan_grid`` behavior. ``cooldown``
    iterations are skipped after each regroup so the new grid's
    transcripts, not the old grid's tail, drive the next decision.
    Churn couples in through the transcript itself: a demoted peer
    (lost sends) moves no traffic and drops out of the finish-time
    statistics, so a churn-thinned tail reads as flat and lets M grow
    back.
    """

    name = "tail_aware"

    def __init__(self, plan: GridPlan, exact_only: bool = False,
                 window: int = 4, hi: float = 1.6, lo: float = 1.15,
                 cooldown: int = 2, m_min: int = 2, m_max: int = 8):
        super().__init__(plan, exact_only=exact_only)
        if not window >= 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if not hi > lo:
            raise ValueError(f"need hi > lo, got hi={hi} lo={lo}")
        self.window = window
        self.hi = hi
        self.lo = lo
        self.cooldown = cooldown
        self.m_min = m_min
        self.m_max = m_max
        self._ratios: List[float] = []
        self._cool = 0
        self._build_ladder(plan)

    def _build_ladder(self, plan: GridPlan) -> None:
        self.candidates = candidate_grids(
            plan.n_peers, m_min=self.m_min, m_max=self.m_max,
            exact_only=self.exact_only)
        self._home = self._index(plan)
        self._ratios.clear()
        self._cool = 0

    def _index(self, plan: GridPlan) -> int:
        """Ladder position of ``plan`` (nearest by group size when the
        dims are not on the ladder, e.g. heterogeneous mesh grids)."""
        for i, c in enumerate(self.candidates):
            if c.dims == plan.dims:
                return i
        m = max(plan.dims)
        return int(np.argmin([abs(c.dims[0] - m) for c in self.candidates]))

    @staticmethod
    def tail_ratio(transcript: Any) -> Optional[float]:
        """max/median of positive per-peer finish times
        (``Transcript.tail_stats`` is the canonical computation); None
        when fewer than two peers moved traffic — no tail to measure."""
        f = np.asarray(transcript.peer_finish_s, float)
        if int((f > 0).sum()) < 2:
            return None
        med, mx = transcript.tail_stats()
        return mx / max(med, 1e-12)

    def observe(self, t, transcript, plan):
        if plan.n_peers != self.plan.n_peers:
            self.rebind(plan)
        self.plan = plan
        r = self.tail_ratio(transcript)
        if r is not None:
            self._ratios.append(r)
        if self._cool > 0:
            self._cool -= 1
            return None
        if len(self._ratios) < self.window:
            return None
        mean_ratio = float(np.mean(self._ratios[-self.window:]))
        self._ratios.clear()
        i = self._index(plan)
        if mean_ratio > self.hi and i > 0:
            j = i - 1                      # tail dominates: shrink M
        elif mean_ratio < self.lo and i < self._home:
            j = i + 1                      # tail cleared: grow toward home
        else:
            return None
        self._cool = self.cooldown
        return validate_proposal(
            carry_placement(plan, self.candidates[j]), plan.n_peers,
            exact_only=self.exact_only)

    def rebind(self, plan):
        super().rebind(plan)
        self._build_ladder(plan)


@register_controller
class ScheduleController(GroupSizeController):
    """Scripted regroups: ``schedule = ((iteration, dims), ...)``.

    After iteration ``t`` completes, the grid regroups to ``dims``
    (applied before iteration ``t + 1``). Deterministic by
    construction — the test/ablation controller.
    """

    name = "schedule"

    def __init__(self, plan: GridPlan, exact_only: bool = False,
                 schedule: Sequence[Tuple[int, Sequence[int]]] = ()):
        super().__init__(plan, exact_only=exact_only)
        self.schedule: Dict[int, Tuple[int, ...]] = {
            int(t): tuple(int(d) for d in dims) for t, dims in schedule}

    def observe(self, t, transcript, plan):
        dims = self.schedule.get(t)
        if dims is None or dims == tuple(plan.dims):
            return None
        return validate_proposal(
            carry_placement(plan, GridPlan(plan.n_peers, dims)),
            plan.n_peers, exact_only=self.exact_only)
