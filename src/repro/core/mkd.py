"""Moshpit Knowledge Distillation (paper Alg. 2 + Alg. 3).

MKD reuses MAR's group formation: in MKD round ``g`` each peer's
candidate teachers ``C_g`` are its round-``g`` MAR group mates. The peer
(1) rates every candidate by the KL divergence between the candidate's
and its own *softened* output distributions on its local minibatches
(Alg. 3 — the Shao et al. 2024 non-iid guard), (2) keeps the top-l
(l = ceil(rho_l * |C_g|)) lowest-KL teachers, (3) averages their logits
and distills for E epochs with the Hinton loss

    L = (1 - alpha) CE(y, softmax(s)) + alpha tau^2 KL(p_z || p_s),
    alpha = lambda = max(0, 1 - (t-1)/K)   (linear anneal, §A.1).

Implementation: the sim backend stacks peers on the leading axis, so
"collecting teacher models" is a gather of group-mates' params — [N, M,
...] — and teacher logits come from a double vmap. Dropped peers
(a_mask = 0) are excluded from candidate sets but still distill (they
did run their local update; Alg. 1 gates aggregation, and MKD precedes
MAR within the iteration).
"""
from __future__ import annotations

import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
PyTree = Any


def softened(logits: Array, tau: float) -> Array:
    return jax.nn.softmax(logits / tau, axis=-1)


def kl_divergence(p: Array, q: Array, eps: float = 1e-9) -> Array:
    """KL(p || q) over the last axis."""
    p = jnp.clip(p, eps, 1.0)
    q = jnp.clip(q, eps, 1.0)
    return jnp.sum(p * (jnp.log(p) - jnp.log(q)), axis=-1)


def student_loss(student_logits: Array, teacher_logits: Array, labels: Array,
                 tau: float, alpha: Array) -> Array:
    """Alg. 2 line 8: weighted CE + tau^2-scaled KL to the teacher mix."""
    ce = -jnp.mean(jnp.take_along_axis(
        jax.nn.log_softmax(student_logits), labels[:, None], 1))
    p_z = softened(teacher_logits, tau)
    p_s = softened(student_logits, tau)
    lkl = jnp.mean(kl_divergence(p_z, p_s))
    return (1.0 - alpha) * ce + alpha * (tau ** 2) * lkl


def select_teachers(my_logits: Array, cand_logits: Array, cand_mask: Array,
                    tau: float, rho: float) -> Array:
    """Alg. 3: weights [M] — 1/l for the top-l lowest-KL candidates.

    my_logits: [B*, C]; cand_logits: [M, B*, C]; cand_mask: [M] (0 = the
    candidate dropped or is the student itself).
    """
    m = cand_logits.shape[0]
    p_s = softened(my_logits, tau)
    p_c = softened(cand_logits, tau)
    div = jnp.mean(kl_divergence(p_c, p_s[None]), axis=-1)       # [M]
    div = jnp.where(cand_mask > 0, div, jnp.inf)
    n_avail = jnp.sum(cand_mask > 0)
    l = jnp.clip(jnp.ceil(rho * n_avail).astype(jnp.int32), 1, m)
    order = jnp.argsort(div)                                      # asc
    rank = jnp.argsort(order)                                     # rank of each
    chosen = (rank < l) & (cand_mask > 0)
    denom = jnp.maximum(jnp.sum(chosen), 1)
    return chosen.astype(jnp.float32) / denom                     # [M]


def mkd_rounds(fed, params: PyTree, momentum: PyTree, a_mask: Array,
               rng: Array, kd_lambda: Array) -> Tuple[PyTree, PyTree]:
    """All G MKD rounds of one FL iteration (sim backend).

    ``fed`` is the :class:`~repro.core.federation.Federation` (gives the
    grid plan, apply_fn, data and hyperparameters).
    """
    cfg = fed.cfg
    plan = fed.plan
    n = cfg.n_peers
    tau, rho = cfg.kd_temperature, cfg.kd_selection_ratio

    # fixed per-iteration distillation minibatch per peer (B ⋅ batch)
    k_data, rng = jax.random.split(rng)
    nbatch = cfg.local_batches * cfg.batch_size
    idx = jax.random.randint(k_data, (n, nbatch), 0, fed.data_x.shape[1])
    bx = jnp.take_along_axis(
        fed.data_x, idx[..., None], axis=1)                      # [N, B*, D]
    by = jnp.take_along_axis(fed.data_y, idx, axis=1)            # [N, B*]

    rounds = cfg.mar_rounds if cfg.mar_rounds is not None else plan.depth
    for g in range(rounds):
        params, momentum = _mkd_one_round(
            fed, params, momentum, a_mask, bx, by, g % plan.depth,
            tau, rho, kd_lambda)
    return params, momentum


def _mkd_one_round(fed, params, momentum, a_mask, bx, by, g, tau, rho,
                   kd_lambda):
    cfg = fed.cfg
    plan = fed.plan
    n = cfg.n_peers

    # candidate teachers = round-g MAR group mates (incl. virtual slots)
    partners = np.asarray(plan.partner_matrix(g))                # [cap, M]
    partners = partners[:n]
    virtual = partners >= n                                       # pad slots
    self_col = partners == np.arange(n)[:, None]
    partners_c = np.where(virtual, 0, partners)
    pmat = jnp.asarray(partners_c)

    # candidate mask: group mate participates in aggregation, is real,
    # and is not the student itself
    cand_mask = (a_mask[pmat] *
                 jnp.asarray(~virtual, jnp.float32) *
                 jnp.asarray(~self_col, jnp.float32))             # [N, M]

    # teacher logits: gather group-mates' params -> [N, M, ...]
    t_params = jax.tree.map(lambda x: x[pmat], params)

    def peer_round(p, m, tp, cmask, x, y):
        my_logits = fed.apply_fn(p, x)                            # [B*, C]
        cand_logits = jax.vmap(lambda q: fed.apply_fn(q, x))(tp)  # [M, B*, C]
        w = select_teachers(my_logits, cand_logits, cmask, tau, rho)
        zbar = jnp.einsum("m,mbc->bc", w, cand_logits)            # [B*, C]

        def epoch(carry, _):
            p, m = carry

            def loss_fn(pp):
                s = fed.apply_fn(pp, x)
                return student_loss(s, zbar, y, tau, kd_lambda)

            grads = jax.grad(loss_fn)(p)
            from repro.optim.sgdm import momentum_sgd_step
            p, m = momentum_sgd_step(p, m, grads, cfg.lr, cfg.momentum)
            return (p, m), None

        (p, m), _ = jax.lax.scan(epoch, (p, m), None,
                                 length=cfg.kd_epochs)
        return p, m

    new_p, new_m = jax.vmap(peer_round)(params, momentum, t_params,
                                        cand_mask, bx, by)
    # a peer with zero available teachers keeps its pre-MKD state
    has_teacher = (jnp.sum(cand_mask, axis=1) > 0).astype(jnp.float32)
    mix = lambda a, b: jax.tree.map(
        lambda u, v: jnp.where(
            has_teacher.reshape((-1,) + (1,) * (u.ndim - 1)) > 0, u, v),
        a, b)
    return mix(new_p, params), mix(new_m, momentum)
