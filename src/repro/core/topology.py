"""Analytic communication-cost models for every technique (paper §2.2, §3).

Since the discrete-event network layer (DESIGN.md §9) these closed
forms are the cross-checked *oracles*: the ledger is fed from measured
transport transcripts (``core/transport.py`` + ``runtime/network.py``),
and ``tests/test_network.py`` pins transcript bytes equal to these
formulas in the no-loss case for every registered technique.

Byte accounting per FL iteration with ``n`` *aggregating* peers and model
state of ``model_bytes`` (theta + momentum, both averaged by Alg. 1):

* ``fedavg`` — upload + download per peer: ``2 n B``            (O(N))
* ``ar``     — all-to-all, every peer sends to every other:
               ``n (n-1) B``                                    (O(N^2))
* ``rdfl``   — Galaxy-style ring circulation of full models:
               every model traverses the ring: ``n (n-1) B``    (O(N^2));
               differs from AR-FL in latency (n-1 sequential hops vs 1)
* ``mar``    — G rounds, group size M, naive within-group exchange
               (each peer sends its state to M-1 group mates):
               ``n G (M-1) B``                                  (O(N log N))
* ``gossip`` — push-sum ring, one partner per round over
               ceil(log2 n) rounds: ``n ceil(log2 n) B``        (O(N log N))
* ``hierarchical`` — two-tier FedAvg over the leaf MAR groups
               (peers <-> group leader, leaders <-> rendezvous):
               ``2 (n + ceil(n/M)) B``                          (O(N))

The MAR constant reproduces the paper's headline numbers: at N=125
(M=5, G=3): 125*3*4 = 1500 model-units vs AR's 125*124 = 15500 — the
"up to 10x" of Fig. 1 — and the Fig. 11 approximate-aggregation setting
(M=3, G=4) gives 125*4*2 = 1000, the reported 33% reduction. A
``butterfly`` mode (reduce-scatter + all-gather inside each group,
2(M-1)/M per peer per round — what Moshpit-SGD itself implements) is the
beyond-paper option benchmarked in EXPERIMENTS.md §Perf.

MKD adds, per KD-enabled iteration, G rounds of *model-only* exchange
(students pull candidate-teacher weights; Alg. 3) plus logit traffic.

Control plane: DHT coordination is O(N log N) small messages/iteration
(§2.2) — tracked separately, negligible vs data plane.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.moshpit import GridPlan

PyTree = Any

DHT_MSG_BYTES = 64  # one Kademlia get/store record (key+value+routing)


def pytree_bytes(tree: PyTree) -> int:
    return int(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)))


def mar_bytes(n: int, plan: GridPlan, model_bytes: int,
              num_rounds: Optional[int] = None,
              mode: str = "naive",
              mask: Optional[np.ndarray] = None) -> int:
    """Data-plane bytes for one MAR aggregation over ``n`` active peers.

    Mask-aware: an active peer only exchanges with the *active* members
    of its round-``g`` group — a churned mate receives no send. With
    ``mask`` given the accounting is exact per group (``sum_g
    k_g (k_g - 1)`` naive-mode sends), byte-identical to the transport
    transcript in the no-loss case (``tests/test_network.py``). With
    only the count ``n`` the per-group split is unknown, so the
    active-pair expectation ``(n-1)/(N-1)`` scales the full-grid
    formula (the old code billed every sender for ``M-1`` mates even
    when the caller passed a churn-reduced ``n`` — overcounting sends
    to dropped peers). At full participation both paths reduce to the
    paper's ``n G (M-1) B``.
    """
    rounds = plan.depth if num_rounds is None else num_rounds
    total = 0.0
    if mask is not None:
        mask = np.asarray(mask)[:plan.n_peers] > 0
        for g in range(rounds):
            for group in plan.groups_for_round(g % plan.depth):
                real = group[group < plan.n_peers]
                k = int(mask[real].sum())
                if k < 2:
                    continue
                if mode == "butterfly":
                    total += 2.0 * (k - 1) * model_bytes
                else:
                    total += k * (k - 1) * model_bytes
        return int(total)
    n_total = plan.n_peers
    pair_frac = 1.0 if n >= n_total or n_total <= 1 else \
        max(n - 1, 0) / (n_total - 1)
    for g in range(rounds):
        m = plan.dims[g % plan.depth]
        if mode == "butterfly":
            per_peer = 2.0 * (m - 1) / m
        else:
            per_peer = float(m - 1)
        total += n * per_peer * pair_frac * model_bytes
    return int(total)


def hierarchical_bytes(n: int, plan: GridPlan, model_bytes: int,
                       mask: Optional[np.ndarray] = None) -> int:
    """Two-tier FedAvg bytes: ``2 (n + #groups) B``.

    The measured transcript bills the leaf groups that are *actually
    nonempty* under the churn mask (an active member anywhere keeps its
    group's leader <-> rendezvous hop alive). With ``mask`` given the
    count is exact — byte-identical to the transport transcript. With
    only the active count ``n`` the per-group split is unknown, and no
    count-only formula can be exact: ``ceil(n / M)`` is the *minimum*
    possible nonempty-group count (actives packed into as few leaf
    groups as possible), so the count-only path is a documented lower
    bound on the measured bytes — pinned by the inequality test in
    ``tests/test_transport.py``. At full participation both paths
    coincide (every group nonempty, ``ceil(N / M)`` of them).
    """
    if mask is not None:
        active = np.asarray(mask)[:plan.n_peers] > 0
        n_act, n_groups = 0, 0
        for group in plan.groups_for_round(plan.depth - 1):
            k = int(active[group[group < plan.n_peers]].sum())
            if k:
                n_groups += 1
                n_act += k
        return int(2 * (n_act + n_groups) * model_bytes)
    n_groups = max(1, math.ceil(n / plan.dims[-1]))
    return int(2 * (n + n_groups) * model_bytes)


def iteration_bytes(technique: str, n: int, model_bytes: int,
                    plan: Optional[GridPlan] = None,
                    num_rounds: Optional[int] = None,
                    use_kd: bool = False, kd_logit_bytes: int = 0,
                    mode: str = "naive",
                    mask: Optional[np.ndarray] = None) -> int:
    """Total data-plane bytes of one FL iteration.

    ``mask`` (the aggregation mask A_t) makes the MAR and hierarchical
    entries exact per group under churn; the remaining techniques'
    formulas depend only on the active count ``n``.
    """
    if technique == "fedavg":
        data = 2 * n * model_bytes
    elif technique in ("ar", "rdfl"):
        data = n * max(n - 1, 0) * model_bytes
    elif technique == "mar":
        assert plan is not None
        data = mar_bytes(n, plan, model_bytes, num_rounds, mode, mask)
    elif technique == "gossip":
        rounds = (num_rounds if num_rounds is not None
                  else max(1, math.ceil(math.log2(max(n, 2)))))
        data = rounds * n * model_bytes
    elif technique == "hierarchical":
        assert plan is not None
        data = hierarchical_bytes(n, plan, model_bytes, mask)
    else:
        raise ValueError(technique)
    if use_kd and technique == "mar":
        # students pull group-mates' thetas (half the (theta, m) state)
        data += mar_bytes(n, plan, model_bytes // 2, num_rounds, "naive",
                          mask)
        rounds = plan.depth if num_rounds is None else num_rounds
        data += n * rounds * kd_logit_bytes
    return int(data)


def iteration_latency_rounds(technique: str, n: int,
                             plan: Optional[GridPlan] = None,
                             num_rounds: Optional[int] = None) -> int:
    """Sequential communication rounds per iteration (latency proxy)."""
    if technique == "fedavg":
        return 2                      # upload, download
    if technique == "ar":
        return 1                      # fully parallel exchange
    if technique == "rdfl":
        return max(n - 1, 1)          # ring circulation
    if technique == "mar":
        return plan.depth if num_rounds is None else num_rounds
    if technique == "gossip":
        return (num_rounds if num_rounds is not None
                else max(1, math.ceil(math.log2(max(n, 2)))))
    if technique == "hierarchical":
        return 4                      # up/down within groups, up/down leaders
    raise ValueError(technique)


def control_plane_bytes(n: int) -> int:
    """DHT coordination per iteration: O(N log N) lookups (§2.2)."""
    return int(n * max(math.log2(max(n, 2)), 1.0) * DHT_MSG_BYTES)


def complexity_table(model_bytes: int, peer_counts=(16, 64, 125, 512, 4096)
                     ) -> "list[dict]":
    """Fig. 1-style scaling table across techniques."""
    from repro.core.moshpit import plan_grid
    rows = []
    for n in peer_counts:
        plan = plan_grid(n)
        for tech in ("fedavg", "hierarchical", "mar", "gossip", "rdfl",
                     "ar"):
            rows.append(dict(
                technique=tech, n_peers=n,
                bytes=iteration_bytes(tech, n, model_bytes, plan),
                rounds=iteration_latency_rounds(tech, n, plan),
                control_bytes=control_plane_bytes(n) if tech == "mar" else 0,
            ))
    return rows
