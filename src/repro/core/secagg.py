"""Secure aggregation for the DP clipping indicator (paper §A.2).

Alg. 4's clipping-bound update consumes the *average* of per-peer binary
indicators b_i = 1{||delta_i|| <= C_t}. A plain average leaks every
b_i to its group mates; the paper notes "a privacy-preserving mechanism
(e.g., Secure Aggregation) has to be deployed for global binary
indicator computation". This module implements the classic
pairwise-additive-mask construction (Bonawitz et al., 2017) specialized
to MAR groups:

For each aggregating pair (i, j) in a group, both derive a shared mask
m_ij = PRF(k_ij, t) from a pairwise key; peer i submits
``b_i + sum_{j>i} m_ij - sum_{j<i} m_ij``. Masks cancel in the group
sum, so the aggregation path learns only the sum — the property tests
assert individual submissions are uninformative while group sums are
exact. Dropouts: a pair's masks are only applied when both endpoints
are alive (the sim resolves this from the shared mask table; a
production deployment uses the secret-shared mask-recovery protocol of
the original paper — noted, not implemented).

Pairwise keys are keyed-hash stand-ins (`jax.random.fold_in` chains) —
swap for X25519 key agreement in a real deployment; the *protocol
structure* (who masks what, when masks cancel, what leaks) is what this
module pins down. Everything is jit-traceable (vectorized mask table,
static partner matrices) so it composes with the jitted DP iteration.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.moshpit import GridPlan

Array = jax.Array

MASK_RANGE = 100.0


def _pair_mask_table(root: Array, lo: Array, hi: Array, t: int) -> Array:
    """Vectorized PRF(k_{lo,hi}, t) over same-shape integer arrays."""
    def one(lo_, hi_):
        k = jax.random.fold_in(jax.random.fold_in(
            jax.random.fold_in(root, lo_), hi_), t)
        return jax.random.uniform(k, (), jnp.float32,
                                  -MASK_RANGE, MASK_RANGE)
    flat = jax.vmap(one)(lo.reshape(-1), hi.reshape(-1))
    return flat.reshape(lo.shape)


def masked_submissions(values: Array, plan: GridPlan, rnd: int,
                       root: Array, t: int,
                       alive: Optional[Array] = None) -> Array:
    """Each peer's masked indicator for MAR round ``rnd``.

    values: [N] f32; returns [N] masked submissions whose *group sums*
    over alive peers equal the group sums of ``values``. jit-safe.
    """
    n = plan.n_peers
    partners = np.asarray(plan.partner_matrix(rnd))[:n]     # [N, M] static
    I = np.repeat(np.arange(n)[:, None], partners.shape[1], axis=1)
    J = partners
    valid = (J != I) & (J < n)
    lo = np.minimum(I, J)
    hi = np.maximum(I, J)
    sign = np.where(I < J, 1.0, -1.0).astype(np.float32)

    masks = _pair_mask_table(root, jnp.asarray(lo), jnp.asarray(hi), t)
    alive_v = jnp.ones((n,), jnp.float32) if alive is None \
        else alive.astype(jnp.float32)
    j_safe = np.where(valid, J, 0)
    gate = (jnp.asarray(valid, jnp.float32)
            * alive_v[:, None] * alive_v[jnp.asarray(j_safe)])
    total = jnp.sum(masks * jnp.asarray(sign) * gate, axis=1)
    return values.astype(jnp.float32) + total


def secure_group_sum(values: Array, plan: GridPlan, rnd: int, root: Array,
                     t: int, alive: Optional[Array] = None
                     ) -> Tuple[Array, Array]:
    """(group sums scattered back to peers [N], alive counts [N])."""
    n = plan.n_peers
    alive_v = jnp.ones((n,), jnp.float32) if alive is None \
        else alive.astype(jnp.float32)
    masked = masked_submissions(values, plan, rnd, root, t, alive) * alive_v
    seg = jnp.asarray(plan.group_key(np.arange(plan.capacity), rnd),
                      jnp.int32)[:n]
    ngroups = plan.capacity // plan.dims[rnd]
    sums = jax.ops.segment_sum(masked, seg, num_segments=ngroups)
    cnts = jax.ops.segment_sum(alive_v, seg, num_segments=ngroups)
    return sums[seg], cnts[seg]


def secure_indicator_average(values: Array, plan: GridPlan, root: Array,
                             t: int, alive: Optional[Array] = None
                             ) -> Array:
    """Full-depth secure averaging of clipping indicators: the MAR
    schedule over secure group sums; returns the per-peer global average
    (Alg. 4 line 15's b-bar) with no peer revealing its own b_i."""
    cur = values.astype(jnp.float32)
    cur_alive = alive
    for rnd in range(plan.depth):
        s, c = secure_group_sum(cur, plan, rnd,
                                jax.random.fold_in(root, rnd), t,
                                cur_alive)
        cur = s / jnp.maximum(c, 1.0)
        cur_alive = None   # from round 1 on, every peer carries a mean
    return cur
