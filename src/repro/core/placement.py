"""Topology-aware grid placement: align MAR groups to the network.

``plan_grid`` assigns grid coordinates by raw peer index, so on
structured-heterogeneous links (the ``regions`` profile's WAN-separated
blocks) every one of the d aggregation rounds pays cross-region
bandwidth caps and latency. The cluster-FL literature (PAPERS.md: CFL;
SNIPPETS.md Snippet 1's location-clustered D2D hierarchy, ~76% traffic
reduction from locality alone) shows the next constant factor lives in
*who groups with whom*. This module learns that from measured link
evidence and expresses it as a peer→slot permutation on
:class:`~repro.core.moshpit.GridPlan`:

* :class:`LinkQualityEstimator` — accumulates per-link seconds-per-byte
  from transcripts: ``Transcript.link_time_stats`` when the engines
  measured it, else derived from ``bytes_by_link`` + ``peer_finish_s``
  (a sender's finish time apportioned over its outgoing links by byte
  share).
* :class:`ClusteredPlacement` — regular MAR transcripts only ever cover
  each peer's ~d·(M-1) grid partners, so when accumulated evidence is
  too sparse the policy falls back to landmark probe rounds (tiny
  broadcast/gather messages through the *live* transport via
  :meth:`PlacementPolicy.bind_prober`), k-means-clusters peers on their
  log cost-to-landmark rows, and packs each cluster into contiguous
  slots. Contiguous low-axis packing means cross-cluster traffic lands
  in the *high* coordinate axes — exactly one of the d rounds for
  cluster counts ≤ dims[0] — the same trick ``mesh_grid_plan`` plays
  with the pod axis (DESIGN.md §2).
* a registry (``identity`` / ``random`` / ``clustered``) mirroring
  ``core/adaptive.py``'s controllers: policies observe each iteration's
  transcript and propose a full :class:`GridPlan` (same dims, new
  ``placement``) that ``Federation.regroup`` applies as a
  membership-preserving regroup — composing with the
  ``GroupSizeController`` (placement re-emitted via :meth:`rebind`
  after an adaptive-M dims change or an elastic resize).

Placement changes *when* traffic crosses the WAN, never *how much*:
any permutation preserves per-round byte totals
(``topology.mar_bytes`` stays the oracle — asserted in
``tests/test_placement.py`` and ``benchmarks/placement.py``).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple, Type

import numpy as np

from repro.core.moshpit import GridPlan
from repro.core.transport import Message, MessagePlan

__all__ = ["PLACEMENTS", "PlacementPolicy", "IdentityPlacement",
           "RandomPlacement", "ClusteredPlacement",
           "LinkQualityEstimator", "build_placement",
           "cluster_permutation", "probe_plan", "register_placement"]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

PLACEMENTS: Dict[str, Type["PlacementPolicy"]] = {}


def register_placement(cls: Type["PlacementPolicy"]
                       ) -> Type["PlacementPolicy"]:
    PLACEMENTS[cls.name] = cls
    return cls


def build_placement(name: str, plan: GridPlan, seed: int = 0,
                    **params: Any) -> "PlacementPolicy":
    if name not in PLACEMENTS:
        raise ValueError(f"unknown placement policy {name!r}; "
                         f"registered: {sorted(PLACEMENTS)}")
    return PLACEMENTS[name](plan, seed=seed, **params)


# ---------------------------------------------------------------------------
# link-quality evidence
# ---------------------------------------------------------------------------

class LinkQualityEstimator:
    """Per-link seconds-per-byte accumulated across transcripts.

    Evidence order of preference, per transcript: measured
    ``link_time_stats`` (the modeled engines fill it exactly); else a
    derivation from ``bytes_by_link`` + ``peer_finish_s`` — each
    sender's finish time apportioned over its outgoing links by byte
    share (an upper-bound effective time that preserves the *ordering*
    of slow vs fast destinations a sender saw, which is all clustering
    needs). Loopbacks and infrastructure endpoints carry no link
    information and are skipped.
    """

    def __init__(self, n_peers: int):
        self.n_peers = n_peers
        self._secs: Dict[Tuple[int, int], float] = {}
        self._bytes: Dict[Tuple[int, int], float] = {}
        self._baseline: Dict[Tuple[int, int], float] = {}

    @property
    def n_links(self) -> int:
        return len(self._bytes)

    def _add(self, key: Tuple[int, int], secs: float,
             nbytes: float) -> None:
        self._secs[key] = self._secs.get(key, 0.0) + secs
        self._bytes[key] = self._bytes.get(key, 0.0) + nbytes

    def update(self, transcript: Any) -> None:
        n = self.n_peers
        stats = getattr(transcript, "link_time_stats", None) or {}
        if stats:
            for (s, d), sec in stats.items():
                if s < n and d < n and s != d:
                    b = transcript.bytes_by_link.get((s, d), 0.0)
                    if b > 0:
                        self._add((s, d), sec, b)
            return
        links = getattr(transcript, "bytes_by_link", None) or {}
        fin = np.asarray(getattr(transcript, "peer_finish_s",
                                 np.zeros(0)), float)
        out_bytes: Dict[int, float] = {}
        for (s, d), b in links.items():
            if s < n and s != d:
                out_bytes[s] = out_bytes.get(s, 0.0) + b
        for (s, d), b in links.items():
            if (s < n and d < n and s != d and b > 0
                    and s < fin.size and out_bytes[s] > 0):
                self._add((s, d), fin[s] * (b / out_bytes[s]), b)

    def cost_to(self, landmarks: np.ndarray) -> np.ndarray:
        """[n_peers, len(landmarks)] seconds-per-byte to/from each
        landmark (mean of the two directions where both are observed);
        NaN where no evidence exists. A landmark's own row entry is
        NaN (no self-link) — callers impute."""
        n, lm = self.n_peers, np.asarray(landmarks)
        out = np.full((n, lm.size), np.nan)
        for j, l in enumerate(lm.tolist()):
            for i in range(n):
                if i == l:
                    continue
                vals = []
                for key in ((l, i), (i, l)):
                    b = self._bytes.get(key, 0.0)
                    if b > 0:
                        vals.append(self._secs[key] / b)
                if vals:
                    out[i, j] = float(np.mean(vals))
        return out

    def coverage(self, landmarks: np.ndarray) -> float:
        """Fraction of (peer, landmark) pairs with any evidence."""
        c = self.cost_to(landmarks)
        lm = np.asarray(landmarks)
        mask = np.ones((self.n_peers, lm.size), bool)
        mask[lm, np.arange(lm.size)] = False      # self entries
        denom = int(mask.sum())
        return float(np.isfinite(c[mask]).sum()) / denom if denom \
            else 0.0

    def rates(self) -> Dict[Tuple[int, int], float]:
        """Current per-link seconds-per-byte estimates."""
        return {k: self._secs[k] / b
                for k, b in self._bytes.items() if b > 0}

    def mark(self) -> None:
        """Snapshot current rates as the drift baseline — call when a
        clustering was produced from (and therefore reflects) them."""
        self._baseline = self.rates()

    def drift(self) -> float:
        """Median relative change in per-link seconds-per-byte since
        the last :meth:`mark` (0.0 without a baseline or overlap).

        The statistic clustered placement watches between scheduled
        re-cluster ticks: link quality moving by, say, 2x on half the
        observed links means the permutation was computed for a
        network that no longer exists. Median, not max — one link
        blipping shouldn't trigger a fleet-wide regroup."""
        if not self._baseline:
            return 0.0
        cur = self.rates()
        rel = [abs(cur[k] - v) / v
               for k, v in self._baseline.items()
               if k in cur and v > 0]
        return float(np.median(rel)) if rel else 0.0

    def resize(self, new_n: int) -> None:
        """Elastic membership invalidates link identities past the
        survivor range; drop evidence touching departed peers."""
        if new_n < self.n_peers:
            self._secs = {k: v for k, v in self._secs.items()
                          if k[0] < new_n and k[1] < new_n}
            self._bytes = {k: v for k, v in self._bytes.items()
                           if k[0] < new_n and k[1] < new_n}
            self._baseline = {k: v for k, v in self._baseline.items()
                              if k[0] < new_n and k[1] < new_n}
        self.n_peers = new_n


def probe_plan(n_peers: int, landmarks: np.ndarray,
               probe_bytes: float = 250_000.0) -> MessagePlan:
    """Landmark broadcast/gather probe rounds.

    Two rounds per landmark — landmark→all then all→landmark — give a
    complete [n_peers, landmarks] cost matrix in both directions from
    one plan. Probe messages ride the live transport, so their
    ``link_time_stats`` reflect whatever the real links do; the modeled
    engines bill seconds even for lost messages, so loss cannot blind
    the estimator.
    """
    rounds: List[Tuple[Message, ...]] = []
    for l in np.asarray(landmarks).tolist():
        rounds.append(tuple(Message(int(l), i, float(probe_bytes))
                            for i in range(n_peers) if i != l))
        rounds.append(tuple(Message(i, int(l), float(probe_bytes))
                            for i in range(n_peers) if i != l))
    return MessagePlan("placement_probe", n_peers, n_peers,
                       tuple(rounds))


# ---------------------------------------------------------------------------
# clustering (pure numpy — no sklearn in the image)
# ---------------------------------------------------------------------------

def _kmeans(X: np.ndarray, k: int, seed: int,
            iters: int = 64) -> Tuple[np.ndarray, np.ndarray]:
    """Seeded k-means++ returning (labels, centers)."""
    rng = np.random.default_rng(seed * 7919 + k)
    n = X.shape[0]
    centers = [X[int(rng.integers(n))]]
    for _ in range(1, k):
        d2 = np.min(np.stack([((X - c) ** 2).sum(-1)
                              for c in centers]), axis=0)
        tot = d2.sum()
        pick = (int(rng.integers(n)) if tot <= 0
                else int(rng.choice(n, p=d2 / tot)))
        centers.append(X[pick])
    C = np.stack(centers)
    labels = np.full(n, -1, np.int64)
    for _ in range(iters):
        d = ((X[:, None, :] - C[None]) ** 2).sum(-1)
        new = d.argmin(1)
        if np.array_equal(new, labels):
            break
        labels = new
        for j in range(k):
            m = labels == j
            if m.any():
                C[j] = X[m].mean(0)
    return labels, C


def _silhouette(X: np.ndarray, labels: np.ndarray,
                C: np.ndarray) -> float:
    """Simplified (centroid-based) silhouette — enough to pick k.
    Only live (non-empty) clusters' centers count: a stale empty
    center sits on a data point and would poison ``other``."""
    live = np.unique(labels)
    d = np.sqrt(((X[:, None, :] - C[None, live]) ** 2).sum(-1))
    pos = np.searchsorted(live, labels)
    own = d[np.arange(X.shape[0]), pos]
    d_masked = d.copy()
    d_masked[np.arange(X.shape[0]), pos] = np.inf
    other = d_masked.min(1)
    denom = np.maximum(np.maximum(own, other), 1e-300)
    return float(np.mean((other - own) / denom))


def cluster_labels(features: np.ndarray, k: Optional[int] = None,
                   seed: int = 0, k_max: int = 8) -> np.ndarray:
    """Cluster peers on their feature rows; auto-k by silhouette when
    ``k`` is None. Labels are renumbered by first appearance so equal
    evidence always yields identical labels (stability under the
    re-cluster cadence)."""
    n = features.shape[0]
    if k is not None:
        labels, _ = _kmeans(features, min(k, n), seed)
    else:
        best, labels = -np.inf, np.zeros(n, np.int64)
        for kk in range(2, min(k_max, n - 1) + 1):
            cand, C = _kmeans(features, kk, seed)
            if np.unique(cand).size < 2:
                continue
            score = _silhouette(features, cand, C)
            if score > best:
                best, labels = score, cand
    # renumber by first appearance
    remap: Dict[int, int] = {}
    out = np.empty(n, np.int64)
    for i, c in enumerate(labels.tolist()):
        out[i] = remap.setdefault(c, len(remap))
    return out


def cluster_permutation(labels: np.ndarray,
                        capacity: Optional[int] = None,
                        align: Optional[int] = None) -> np.ndarray:
    """peer→slot: clusters pack contiguous slot ranges, largest
    cluster first (ties broken by lowest member index); within a
    cluster peers keep relative order.

    Largest-first matters on mixed-radix grids: equal-size clusters
    land on aligned sub-block boundaries and any remainder cluster
    packs last against the virtual-slot tail, so a stray small cluster
    cannot shift every later cluster off its block boundary (which
    would re-mix regions inside low-axis blocks and forfeit the
    placement win). Stable: re-clustering to the same labels is the
    identity update.

    With ``capacity`` (> n_peers) the returned permutation covers the
    whole grid, assigning the virtual entities explicitly instead of
    leaving :meth:`GridPlan.with_placement` to fill leftover slots
    blindly: each cluster is padded with virtuals up to the next
    multiple of ``align`` (the grid's sub-block size) while spare
    capacity lasts, so a churn-shrunk cluster absorbs its own padding
    rather than pulling the next cluster across a sub-block boundary.
    Remaining virtuals fill the tail. ``capacity=None`` (the default)
    is the historical peer-only permutation, bit-for-bit."""
    labels = np.asarray(labels)
    n = labels.size
    cap = n if capacity is None else int(capacity)
    if cap < n:
        raise ValueError(f"capacity {cap} < {n} peers")
    perm = np.empty(cap, np.int64)
    order = sorted(
        np.unique(labels).tolist(),
        key=lambda c: (-int((labels == c).sum()),
                       int(np.flatnonzero(labels == c)[0])))
    slot = 0
    virt = n                      # next virtual entity id
    for c in order:
        members = np.flatnonzero(labels == c)
        perm[members] = np.arange(slot, slot + members.size)
        slot += members.size
        if align and align > 1 and virt < cap:
            pad = min((-slot) % align, cap - virt)
            if pad:
                perm[virt:virt + pad] = np.arange(slot, slot + pad)
                virt += pad
                slot += pad
    if virt < cap:                # tail virtuals, in order
        perm[virt:cap] = np.arange(slot, cap)
    return perm if capacity is not None else perm[:n]


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------

class PlacementPolicy:
    """Observe transcripts, propose placed :class:`GridPlan`\\ s.

    Mirrors ``core/adaptive.py``'s ``GroupSizeController`` contract:
    :meth:`observe` consumes each iteration's transcript and returns a
    full proposed plan (same dims, new ``placement``) or ``None``;
    ``Federation.regroup`` / ``launch/train.py`` apply proposals as
    membership-preserving regroups. :meth:`rebind` re-anchors the
    policy after an adaptive-M dims change or elastic resize — the
    policy re-emits its permutation for the new plan on the next
    observe. :meth:`bind_prober` hands policies that need active
    measurement (``clustered``) a ``MessagePlan -> Transcript``
    callable bound to the live transport.
    """

    name: str = "?"

    def __init__(self, plan: GridPlan, seed: int = 0):
        self.plan = plan
        self.seed = seed
        self._prober: Optional[Callable[[MessagePlan], Any]] = None

    def bind_prober(self, prober: Callable[[MessagePlan], Any]) -> None:
        self._prober = prober

    def observe(self, t: int, transcript: Any,
                plan: GridPlan) -> Optional[GridPlan]:
        raise NotImplementedError

    def rebind(self, plan: GridPlan) -> None:
        self.plan = plan


@register_placement
class IdentityPlacement(PlacementPolicy):
    """Raw-index coordinates — today's behavior, and the baseline every
    benchmark compares against. Clears any stray placement."""

    name = "identity"

    def observe(self, t, transcript, plan):
        self.plan = plan
        if plan.placement is not None:
            return plan.with_placement(None)
        return None


@register_placement
class RandomPlacement(PlacementPolicy):
    """One seeded random permutation, held fixed — the control arm
    that shows *where* peers sit matters, not just that they moved."""

    name = "random"

    def _perm(self, n: int) -> np.ndarray:
        return np.random.default_rng(self.seed * 60013 + 29) \
            .permutation(n)

    def observe(self, t, transcript, plan):
        self.plan = plan
        target = plan.with_placement(self._perm(plan.n_peers))
        return target if target != plan else None


@register_placement
class ClusteredPlacement(PlacementPolicy):
    """Learn network regions from link evidence; pack each into
    contiguous grid slots.

    Every ``interval`` iterations the policy turns its accumulated
    :class:`LinkQualityEstimator` evidence into a [n_peers, landmarks]
    seconds-per-byte matrix. MAR transcripts only cover each peer's
    grid partners, so when landmark coverage is below ``min_coverage``
    the policy sends :func:`probe_plan` through the bound prober
    instead (the fallback the issue names: transcript evidence first,
    ``LinkModel``-timed probe rounds when that is too sparse). Peers
    are k-means-clustered on log10 cost rows (log because bandwidths
    span decades; pairwise WAN terms separate same-tier regions that
    per-peer parameters cannot), and :func:`cluster_permutation` packs
    clusters contiguously — for cluster counts ≤ dims[0] all
    cross-cluster traffic lands in the round-0 axis alone.

    Proposals are stable: identical evidence reproduces identical
    labels, and a permutation equal to the live plan's proposes
    nothing. After a dims change (:meth:`rebind`) cached labels re-emit
    the permutation for the new grid without re-probing.
    """

    name = "clustered"

    def __init__(self, plan: GridPlan, seed: int = 0,
                 interval: int = 8, k: Optional[int] = None,
                 landmarks: int = 8, probe_bytes: float = 250_000.0,
                 min_coverage: float = 0.9,
                 drift_threshold: float = 0.5,
                 drift_min_interval: int = 2):
        super().__init__(plan, seed)
        self.interval = interval
        self.k = k
        self.n_landmarks = landmarks
        self.probe_bytes = probe_bytes
        self.min_coverage = min_coverage
        self.drift_threshold = drift_threshold
        self.drift_min_interval = drift_min_interval
        self.estimator = LinkQualityEstimator(plan.n_peers)
        self.labels: Optional[np.ndarray] = None
        self._last_cluster_t: Optional[int] = None

    # -- evidence → labels ----------------------------------------------
    def _landmarks(self, n: int) -> np.ndarray:
        l = min(self.n_landmarks, n)
        return np.unique(np.linspace(0, n - 1, l).round()
                         .astype(np.int64))

    def _features(self, cost: np.ndarray,
                  landmarks: np.ndarray) -> np.ndarray:
        """log10 cost rows. A landmark's own entry (no self-link) is
        imputed with the column minimum — a landmark is maximally
        close to itself, and the median would drag it toward whichever
        region holds the most peers; other gaps take the column
        median."""
        X = np.log10(cost)
        lm = np.asarray(landmarks)
        for j in range(X.shape[1]):
            col = X[:, j]
            finite = col[np.isfinite(col)]
            if finite.size:
                col[~np.isfinite(col)] = float(np.median(finite))
                X[lm[j], j] = float(finite.min())
            else:
                col[~np.isfinite(col)] = 0.0
        return X

    def _recluster(self, n: int) -> Optional[np.ndarray]:
        lm = self._landmarks(n)
        if self.estimator.coverage(lm) < self.min_coverage:
            if self._prober is None:
                return None
            tr = self._prober(probe_plan(n, lm, self.probe_bytes))
            self.estimator.update(tr)
            if self.estimator.coverage(lm) < self.min_coverage:
                return None
        X = self._features(self.estimator.cost_to(lm), lm)
        return cluster_labels(X, k=self.k, seed=self.seed)

    # -- policy surface -------------------------------------------------
    def observe(self, t, transcript, plan):
        self.plan = plan
        n = plan.n_peers
        if transcript is not None:
            self.estimator.update(transcript)
        since = (None if self._last_cluster_t is None
                 else t - self._last_cluster_t)
        due = since is None or since >= self.interval
        if not due and since >= self.drift_min_interval \
                and self.estimator.drift() > self.drift_threshold:
            # link quality moved enough that the current permutation
            # reflects a stale network — re-cluster ahead of cadence,
            # but never faster than drift_min_interval (the same
            # rate-limit contract the probe path honors)
            due = True
        if due:
            labels = self._recluster(n)
            if labels is not None:
                self.labels = labels
                self._last_cluster_t = t
                self.estimator.mark()
        if self.labels is None or self.labels.size != n:
            return None
        target = plan.with_placement(cluster_permutation(
            self.labels, capacity=plan.capacity,
            align=(plan.capacity // plan.dims[0]
                   if plan.depth else None)))
        return target if target != plan else None

    def rebind(self, plan):
        if plan.n_peers != self.plan.n_peers:
            self.estimator.resize(plan.n_peers)
            self.labels = None
            self._last_cluster_t = None     # re-probe promptly
        self.plan = plan
