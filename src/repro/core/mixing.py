"""Mixing dynamics of MAR (paper §2.3, Eq. 1) — theory + estimators.

For peers randomly partitioned each iteration into ``r`` groups that
average locally, the expected distortion from the global mean contracts
per averaging iteration by

    factor(N, r) = (r - 1) / N + r / N^2                       (Eq. 1)

so after T iterations:  E[dist_T] = factor^T * dist_0, where
dist = (1/N) sum_i ||theta_i - theta_bar||^2. The bound is independent
of any communication graph's spectral gap. Our deterministic key
schedule mixes *faster* (exact in d rounds when N = M^d) — the tests
verify both the random-grouping rate and the deterministic exactness.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
PyTree = Any


def contraction_factor(n_peers: int, n_groups: int) -> float:
    """Eq. 1 per-iteration contraction of expected average distortion."""
    r, n = n_groups, n_peers
    return (r - 1) / n + r / (n * n)


def predicted_distortion(dist0: float, n_peers: int, n_groups: int,
                         iterations: int) -> float:
    return dist0 * contraction_factor(n_peers, n_groups) ** iterations


def distortion(values: Array) -> float:
    """(1/N) sum_i ||x_i - x_bar||^2 for stacked peer values [N, ...]."""
    mean = jnp.mean(values, axis=0, keepdims=True)
    return float(jnp.sum(jnp.square(values - mean)) / values.shape[0])


def random_group_average(values: Array, n_groups: int,
                         rng: np.random.Generator) -> Array:
    """One iteration of the random-partition averaging model behind Eq. 1."""
    n = values.shape[0]
    perm = rng.permutation(n)
    groups = np.array_split(perm, n_groups)
    out = np.array(values)
    for g in groups:
        out[g] = np.mean(out[g], axis=0)
    return jnp.asarray(out)


def empirical_contraction(n_peers: int, n_groups: int, iterations: int,
                          dim: int = 64, trials: int = 32, seed: int = 0
                          ) -> Tuple[float, float]:
    """(empirical mean factor, Eq.1 prediction) per-iteration."""
    rng = np.random.default_rng(seed)
    factors = []
    for _ in range(trials):
        x = jnp.asarray(rng.normal(size=(n_peers, dim)).astype(np.float32))
        d0 = distortion(x)
        for _ in range(iterations):
            x = random_group_average(x, n_groups, rng)
        dt = distortion(x)
        factors.append((dt / max(d0, 1e-30)) ** (1.0 / iterations))
    return float(np.mean(factors)), contraction_factor(n_peers, n_groups)
