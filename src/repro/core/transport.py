"""Message plans: who sends what to whom, per round, per technique.

``topology.py`` answers "how many bytes should one FL iteration cost" in
closed form; this module answers "which concrete messages make up that
iteration". A :class:`MessagePlan` is the bridge between the aggregation
strategies (``aggregation.py``) and the discrete-event network simulator
(``runtime/network.py``): every registered technique can be *unrolled*
into per-round ``(src, dst, nbytes)`` messages over the
:class:`~repro.core.moshpit.GridPlan` schedule, the simulator times and
possibly drops them, and the resulting transcript feeds the
``CommLedger`` — measured traffic replacing the analytic formulas
(which remain as cross-checked oracles; see ``tests/test_network.py``).

Conventions, chosen so the no-loss transcript reproduces ``topology.py``
exactly at full participation:

* Node ids ``0..n_peers-1`` are real peers. Ids ``>= n_peers`` are
  *infrastructure* (the FedAvg parameter server, the hierarchical
  rendezvous) — modeled by the simulator as infinitely provisioned
  (unbounded bandwidth, zero latency, lossless), so client links stay
  the bottleneck.
* Only **active** peers (``mask > 0``) send. Masked peers are
  receiver-only — the paper §3.1 semantics where a dropped peer
  contributes to no group mean but rejoins with the averaged model;
  the mean delivery rides the next iteration's exchange and is not
  billed separately, matching the analytic model's accounting.
* Self-messages (a hierarchical group leader "uploading" to itself)
  are loopback: bytes are counted (keeping parity with the analytic
  ``2 (n + #groups)`` convention) but transfer time is zero.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.moshpit import GridPlan


@dataclasses.dataclass(frozen=True)
class Message:
    """One data-plane transfer of ``nbytes`` from ``src`` to ``dst``."""

    src: int
    dst: int
    nbytes: float


@dataclasses.dataclass(frozen=True)
class MessagePlan:
    """One FL iteration's traffic, unrolled into rounds of messages.

    Rounds are sequential dependency steps: a round-``r+1`` send leaves
    as soon as *its sender* has finished round ``r`` (received all its
    round-``r`` messages and drained its uplink) — there is no global
    barrier, so group/ring/hierarchy timing emerges from the message
    structure alone.
    """

    technique: str
    n_peers: int                                 # real peers
    n_nodes: int                                 # peers + infrastructure
    rounds: Tuple[Tuple[Message, ...], ...]
    # MKD prefix: the first ``kd_rounds`` entries of ``rounds`` are
    # distillation traffic (teacher pulls + logit exchanges) prepended
    # by :func:`with_mkd_traffic`; transports split their bytes back
    # out into ``Transcript.kd_bytes`` for per-source accounting
    kd_rounds: int = 0

    @property
    def n_messages(self) -> int:
        return sum(len(r) for r in self.rounds)

    @property
    def total_bytes(self) -> float:
        return float(sum(m.nbytes for r in self.rounds for m in r))


def _active_ids(mask: Optional[np.ndarray], n: int) -> np.ndarray:
    if mask is None:
        return np.arange(n)
    mask = np.asarray(mask)
    return np.flatnonzero(mask[:n] > 0)


def _group_members(group: np.ndarray, active: np.ndarray,
                   n_real: int) -> List[int]:
    """Active real peers of one grid group (virtual padding slots and
    masked peers drop out)."""
    act = set(int(a) for a in active)
    return [int(p) for p in group if int(p) < n_real and int(p) in act]


# ---------------------------------------------------------------------------
# per-technique planners
# ---------------------------------------------------------------------------

def mar_plan(plan: GridPlan, mask: Optional[np.ndarray],
             model_bytes: float, num_rounds: Optional[int] = None,
             mode: str = "naive") -> MessagePlan:
    """MAR: ``G`` rounds of within-group exchange over the grid schedule.

    ``naive`` — every active member sends its full state to every other
    active member of its round-``g`` group (the paper's accounting).
    ``butterfly`` — reduce-scatter + all-gather on the active members'
    ring: ``2 (k-1)`` chunks of ``B/k`` per member (what Moshpit-SGD
    itself implements in-group); chunk hops are billed inside one MAR
    round, so uplink serialization models their cost while the round
    count stays the paper's ``G``.
    """
    rounds = plan.depth if num_rounds is None else num_rounds
    active = _active_ids(mask, plan.n_peers)
    out: List[Tuple[Message, ...]] = []
    for g in range(rounds):
        msgs: List[Message] = []
        for group in plan.groups_for_round(g % plan.depth):
            members = _group_members(group, active, plan.n_peers)
            k = len(members)
            if k < 2:
                continue
            if mode == "butterfly":
                chunk = model_bytes / k
                for hop in range(2 * (k - 1)):
                    for i, s in enumerate(members):
                        msgs.append(Message(s, members[(i + 1) % k], chunk))
            else:
                for s in members:
                    for d in members:
                        if d != s:
                            msgs.append(Message(s, d, model_bytes))
        out.append(tuple(msgs))
    return MessagePlan("mar", plan.n_peers, plan.n_peers, tuple(out))


def fedavg_plan(plan: GridPlan, mask: Optional[np.ndarray],
                model_bytes: float) -> MessagePlan:
    """Client-server FedAvg: uploads to the rendezvous, then downloads."""
    n = plan.n_peers
    server = n
    active = _active_ids(mask, n)
    ups = tuple(Message(int(p), server, model_bytes) for p in active)
    downs = tuple(Message(server, int(p), model_bytes) for p in active)
    return MessagePlan("fedavg", n, n + 1, (ups, downs))


def ar_plan(plan: GridPlan, mask: Optional[np.ndarray],
            model_bytes: float) -> MessagePlan:
    """All-to-all AR-FL: one round, every active peer to every other."""
    n = plan.n_peers
    active = _active_ids(mask, n)
    msgs = tuple(Message(int(s), int(d), model_bytes)
                 for s in active for d in active if s != d)
    return MessagePlan("ar", n, n, (msgs,))


def rdfl_plan(plan: GridPlan, mask: Optional[np.ndarray],
              model_bytes: float) -> MessagePlan:
    """RDFL ring circulation: ``k-1`` sequential hops over the active
    ring; each hop every active peer forwards a full model to its
    successor, so a hop cannot leave before the previous one arrived."""
    n = plan.n_peers
    active = _active_ids(mask, n)
    k = len(active)
    if k < 2:
        return MessagePlan("rdfl", n, n, ())
    rounds = tuple(
        tuple(Message(int(active[i]), int(active[(i + 1) % k]), model_bytes)
              for i in range(k))
        for _ in range(k - 1))
    return MessagePlan("rdfl", n, n, rounds)


def gossip_plan(plan: GridPlan, mask: Optional[np.ndarray],
                model_bytes: float,
                num_rounds: Optional[int] = None) -> MessagePlan:
    """Push-sum ring gossip with doubling shifts: in round ``r`` active
    peer ``i`` pushes to peer ``(i + 2^r) mod N`` on the fixed ring over
    *all* N slots (matching ``gossip_aggregate_sim``'s rolls — the ring
    covers peers whether or not they participate)."""
    n = plan.n_peers
    if num_rounds is None:
        num_rounds = max(1, int(math.ceil(math.log2(max(n, 2)))))
    active = _active_ids(mask, n)
    rounds = tuple(
        tuple(Message(int(p), int((p + (1 << r)) % n), model_bytes)
              for p in active)
        for r in range(num_rounds))
    return MessagePlan("gossip", n, n, rounds)


def hierarchical_plan(plan: GridPlan, mask: Optional[np.ndarray],
                      model_bytes: float) -> MessagePlan:
    """Two-tier FedAvg over the leaf MAR groups: members -> leader,
    leaders -> rendezvous, rendezvous -> leaders, leader -> members.
    The leader is each group's first active member; its own up/down
    "transfers" are loopback messages (counted, instant) so measured
    bytes reproduce the analytic ``2 (n + #groups)`` convention."""
    n = plan.n_peers
    rendezvous = n
    active = _active_ids(mask, n)
    groups = [
        _group_members(g, active, n)
        for g in plan.groups_for_round(plan.depth - 1)
    ]
    groups = [g for g in groups if g]
    leaders = [g[0] for g in groups]
    up = tuple(Message(p, lead, model_bytes)
               for g, lead in zip(groups, leaders) for p in g)
    mid_up = tuple(Message(lead, rendezvous, model_bytes)
                   for lead in leaders)
    mid_down = tuple(Message(rendezvous, lead, model_bytes)
                     for lead in leaders)
    down = tuple(Message(lead, p, model_bytes)
                 for g, lead in zip(groups, leaders) for p in g)
    return MessagePlan("hierarchical", n, n + 1,
                       (up, mid_up, mid_down, down))


# ---------------------------------------------------------------------------
# MKD traffic (Alg. 2/3 — rides the same transport as aggregation)
# ---------------------------------------------------------------------------

def mkd_message_rounds(plan: GridPlan, mask: Optional[np.ndarray],
                       model_bytes: float, kd_logit_bytes: float,
                       num_rounds: Optional[int] = None
                       ) -> Tuple[Tuple[Message, ...], ...]:
    """Unroll one iteration's MKD rounds into messages.

    MKD round ``g`` reuses the round-``g`` MAR groups (``core/mkd.py``):

    * **teacher pulls** — every active member sends its theta (half the
      ``(theta, m)`` state, Alg. 3's candidate-model transfer) to every
      other active member of its group: ``sum_g k_g (k_g - 1)`` sends
      of ``model_bytes // 2`` — exactly the mask-aware
      ``topology.mar_bytes`` accounting at half size;
    * **logit exchange** — each active student receives one mixed
      teacher-logit message (``kd_logit_bytes``) from its first active
      group mate, or as a loopback when its group has no other active
      member (billed, instant — the degenerate-group convention), so
      each round bills exactly ``n_active`` logit messages, matching
      the analytic ``n * G * kd_logit_bytes`` add-on.
    """
    rounds = plan.depth if num_rounds is None else num_rounds
    half = model_bytes // 2
    active = _active_ids(mask, plan.n_peers)
    out: List[Tuple[Message, ...]] = []
    for g in range(rounds):
        msgs: List[Message] = []
        for group in plan.groups_for_round(g % plan.depth):
            members = _group_members(group, active, plan.n_peers)
            for t in members:
                for s in members:
                    if s != t:
                        msgs.append(Message(t, s, half))
            for s in members:
                mates = [t for t in members if t != s]
                msgs.append(Message(mates[0] if mates else s, s,
                                    kd_logit_bytes))
        out.append(tuple(msgs))
    return tuple(out)


def with_mkd_traffic(mplan: MessagePlan, plan: GridPlan,
                     mask: Optional[np.ndarray], model_bytes: float,
                     kd_logit_bytes: float,
                     num_rounds: Optional[int] = None) -> MessagePlan:
    """Prepend an iteration's MKD rounds to an aggregation plan (MKD
    precedes aggregation within the iteration). KD sizes are the *raw*
    model bytes — distillation doesn't ride the compressed delta wire
    format — while the aggregation rounds keep their post-stage sizes.
    """
    kd = mkd_message_rounds(plan, mask, model_bytes, kd_logit_bytes,
                            num_rounds=num_rounds)
    return dataclasses.replace(mplan, rounds=kd + mplan.rounds,
                               kd_rounds=len(kd))


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

_PLANNERS = {
    "mar": mar_plan,
    "fedavg": fedavg_plan,
    "ar": ar_plan,
    "rdfl": rdfl_plan,
    "gossip": gossip_plan,
    "hierarchical": hierarchical_plan,
}


def build_message_plan(technique: str, plan: GridPlan,
                       mask: Optional[np.ndarray], model_bytes: float,
                       num_rounds: Optional[int] = None,
                       mode: str = "naive") -> MessagePlan:
    """Unroll one FL iteration of ``technique`` into timed-able messages.

    ``mask`` is the aggregation mask A_t over real peers (None = full
    participation); ``model_bytes`` is the *wire* size of one state
    transfer (post compression-stage transforms).
    """
    if technique not in _PLANNERS:
        raise ValueError(
            f"no message planner for technique {technique!r}; "
            f"known: {sorted(_PLANNERS)}")
    if technique == "mar":
        return mar_plan(plan, mask, model_bytes, num_rounds, mode)
    if technique == "gossip":
        return gossip_plan(plan, mask, model_bytes, num_rounds)
    return _PLANNERS[technique](plan, mask, model_bytes)
