"""Message plans: who sends what to whom, per round, per technique.

``topology.py`` answers "how many bytes should one FL iteration cost" in
closed form; this module answers "which concrete messages make up that
iteration". A :class:`MessagePlan` is the bridge between the aggregation
strategies (``aggregation.py``) and the discrete-event network simulator
(``runtime/network.py``): every registered technique can be *unrolled*
into per-round ``(src, dst, nbytes)`` messages over the
:class:`~repro.core.moshpit.GridPlan` schedule, the simulator times and
possibly drops them, and the resulting transcript feeds the
``CommLedger`` — measured traffic replacing the analytic formulas
(which remain as cross-checked oracles; see ``tests/test_network.py``).

Conventions, chosen so the no-loss transcript reproduces ``topology.py``
exactly at full participation:

* Node ids ``0..n_peers-1`` are real peers. Ids ``>= n_peers`` are
  *infrastructure* (the FedAvg parameter server, the hierarchical
  rendezvous) — modeled by the simulator as infinitely provisioned
  (unbounded bandwidth, zero latency, lossless), so client links stay
  the bottleneck.
* Only **active** peers (``mask > 0``) send. Masked peers are
  receiver-only — the paper §3.1 semantics where a dropped peer
  contributes to no group mean but rejoins with the averaged model;
  the mean delivery rides the next iteration's exchange and is not
  billed separately, matching the analytic model's accounting.
* Self-messages (a hierarchical group leader "uploading" to itself)
  are loopback: bytes are counted (keeping parity with the analytic
  ``2 (n + #groups)`` convention) but transfer time is zero.

Two plan representations share these conventions:

* :class:`MessagePlan` — per-round tuples of :class:`Message` objects,
  the original per-message form every transport accepts.
* :class:`ArrayMessagePlan` — the same iteration as flat ``src`` /
  ``dst`` / ``nbytes`` numpy arrays with CSR-style ``round_ptr``
  boundaries, built *directly* by vectorized planners
  (:func:`build_array_plan`) without ever materializing Python message
  objects. Conversion between the two is lossless and order-preserving
  (``from_plan`` / ``to_plan``), and the vectorized builders emit
  messages in exactly the per-round order of the list planners — the
  invariant that makes the batched simulator
  (``runtime/vector_network.py``) byte-exact *and* time-equal against
  the heap-ordered :class:`~repro.runtime.network.NetworkSim`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.moshpit import GridPlan


@dataclasses.dataclass(frozen=True)
class Message:
    """One data-plane transfer of ``nbytes`` from ``src`` to ``dst``."""

    src: int
    dst: int
    nbytes: float


@dataclasses.dataclass(frozen=True)
class MessagePlan:
    """One FL iteration's traffic, unrolled into rounds of messages.

    Rounds are sequential dependency steps: a round-``r+1`` send leaves
    as soon as *its sender* has finished round ``r`` (received all its
    round-``r`` messages and drained its uplink) — there is no global
    barrier, so group/ring/hierarchy timing emerges from the message
    structure alone.
    """

    technique: str
    n_peers: int                                 # real peers
    n_nodes: int                                 # peers + infrastructure
    rounds: Tuple[Tuple[Message, ...], ...]
    # MKD prefix: the first ``kd_rounds`` entries of ``rounds`` are
    # distillation traffic (teacher pulls + logit exchanges) prepended
    # by :func:`with_mkd_traffic`; transports split their bytes back
    # out into ``Transcript.kd_bytes`` for per-source accounting
    kd_rounds: int = 0

    @property
    def n_messages(self) -> int:
        return sum(len(r) for r in self.rounds)

    @property
    def total_bytes(self) -> float:
        return float(sum(m.nbytes for r in self.rounds for m in r))


def _active_ids(mask: Optional[np.ndarray], n: int) -> np.ndarray:
    if mask is None:
        return np.arange(n)
    mask = np.asarray(mask)
    return np.flatnonzero(mask[:n] > 0)


def _group_members(group: np.ndarray, active: np.ndarray,
                   n_real: int) -> List[int]:
    """Active real peers of one grid group (virtual padding slots and
    masked peers drop out)."""
    act = set(int(a) for a in active)
    return [int(p) for p in group if int(p) < n_real and int(p) in act]


# ---------------------------------------------------------------------------
# per-technique planners
# ---------------------------------------------------------------------------

def mar_plan(plan: GridPlan, mask: Optional[np.ndarray],
             model_bytes: float, num_rounds: Optional[int] = None,
             mode: str = "naive") -> MessagePlan:
    """MAR: ``G`` rounds of within-group exchange over the grid schedule.

    ``naive`` — every active member sends its full state to every other
    active member of its round-``g`` group (the paper's accounting).
    ``butterfly`` — reduce-scatter + all-gather on the active members'
    ring: ``2 (k-1)`` chunks of ``B/k`` per member (what Moshpit-SGD
    itself implements in-group); chunk hops are billed inside one MAR
    round, so uplink serialization models their cost while the round
    count stays the paper's ``G``.
    """
    rounds = plan.depth if num_rounds is None else num_rounds
    active = _active_ids(mask, plan.n_peers)
    out: List[Tuple[Message, ...]] = []
    for g in range(rounds):
        msgs: List[Message] = []
        for group in plan.groups_for_round(g % plan.depth):
            members = _group_members(group, active, plan.n_peers)
            k = len(members)
            if k < 2:
                continue
            if mode == "butterfly":
                chunk = model_bytes / k
                for hop in range(2 * (k - 1)):
                    for i, s in enumerate(members):
                        msgs.append(Message(s, members[(i + 1) % k], chunk))
            else:
                for s in members:
                    for d in members:
                        if d != s:
                            msgs.append(Message(s, d, model_bytes))
        out.append(tuple(msgs))
    return MessagePlan("mar", plan.n_peers, plan.n_peers, tuple(out))


def fedavg_plan(plan: GridPlan, mask: Optional[np.ndarray],
                model_bytes: float) -> MessagePlan:
    """Client-server FedAvg: uploads to the rendezvous, then downloads."""
    n = plan.n_peers
    server = n
    active = _active_ids(mask, n)
    ups = tuple(Message(int(p), server, model_bytes) for p in active)
    downs = tuple(Message(server, int(p), model_bytes) for p in active)
    return MessagePlan("fedavg", n, n + 1, (ups, downs))


def ar_plan(plan: GridPlan, mask: Optional[np.ndarray],
            model_bytes: float) -> MessagePlan:
    """All-to-all AR-FL: one round, every active peer to every other."""
    n = plan.n_peers
    active = _active_ids(mask, n)
    msgs = tuple(Message(int(s), int(d), model_bytes)
                 for s in active for d in active if s != d)
    return MessagePlan("ar", n, n, (msgs,))


def rdfl_plan(plan: GridPlan, mask: Optional[np.ndarray],
              model_bytes: float) -> MessagePlan:
    """RDFL ring circulation: ``k-1`` sequential hops over the active
    ring; each hop every active peer forwards a full model to its
    successor, so a hop cannot leave before the previous one arrived."""
    n = plan.n_peers
    active = _active_ids(mask, n)
    k = len(active)
    if k < 2:
        return MessagePlan("rdfl", n, n, ())
    rounds = tuple(
        tuple(Message(int(active[i]), int(active[(i + 1) % k]), model_bytes)
              for i in range(k))
        for _ in range(k - 1))
    return MessagePlan("rdfl", n, n, rounds)


def gossip_plan(plan: GridPlan, mask: Optional[np.ndarray],
                model_bytes: float,
                num_rounds: Optional[int] = None) -> MessagePlan:
    """Push-sum ring gossip with doubling shifts: in round ``r`` active
    peer ``i`` pushes to peer ``(i + 2^r) mod N`` on the fixed ring over
    *all* N slots (matching ``gossip_aggregate_sim``'s rolls — the ring
    covers peers whether or not they participate)."""
    n = plan.n_peers
    if num_rounds is None:
        num_rounds = max(1, int(math.ceil(math.log2(max(n, 2)))))
    active = _active_ids(mask, n)
    rounds = tuple(
        tuple(Message(int(p), int((p + (1 << r)) % n), model_bytes)
              for p in active)
        for r in range(num_rounds))
    return MessagePlan("gossip", n, n, rounds)


def hierarchical_plan(plan: GridPlan, mask: Optional[np.ndarray],
                      model_bytes: float) -> MessagePlan:
    """Two-tier FedAvg over the leaf MAR groups: members -> leader,
    leaders -> rendezvous, rendezvous -> leaders, leader -> members.
    The leader is each group's first active member; its own up/down
    "transfers" are loopback messages (counted, instant) so measured
    bytes reproduce the analytic ``2 (n + #groups)`` convention."""
    n = plan.n_peers
    rendezvous = n
    active = _active_ids(mask, n)
    groups = [
        _group_members(g, active, n)
        for g in plan.groups_for_round(plan.depth - 1)
    ]
    groups = [g for g in groups if g]
    leaders = [g[0] for g in groups]
    up = tuple(Message(p, lead, model_bytes)
               for g, lead in zip(groups, leaders) for p in g)
    mid_up = tuple(Message(lead, rendezvous, model_bytes)
                   for lead in leaders)
    mid_down = tuple(Message(rendezvous, lead, model_bytes)
                     for lead in leaders)
    down = tuple(Message(lead, p, model_bytes)
                 for g, lead in zip(groups, leaders) for p in g)
    return MessagePlan("hierarchical", n, n + 1,
                       (up, mid_up, mid_down, down))


# ---------------------------------------------------------------------------
# MKD traffic (Alg. 2/3 — rides the same transport as aggregation)
# ---------------------------------------------------------------------------

def mkd_message_rounds(plan: GridPlan, mask: Optional[np.ndarray],
                       model_bytes: float, kd_logit_bytes: float,
                       num_rounds: Optional[int] = None
                       ) -> Tuple[Tuple[Message, ...], ...]:
    """Unroll one iteration's MKD rounds into messages.

    MKD round ``g`` reuses the round-``g`` MAR groups (``core/mkd.py``):

    * **teacher pulls** — every active member sends its theta (half the
      ``(theta, m)`` state, Alg. 3's candidate-model transfer) to every
      other active member of its group: ``sum_g k_g (k_g - 1)`` sends
      of ``model_bytes // 2`` — exactly the mask-aware
      ``topology.mar_bytes`` accounting at half size;
    * **logit exchange** — each active student receives one mixed
      teacher-logit message (``kd_logit_bytes``) from its first active
      group mate, or as a loopback when its group has no other active
      member (billed, instant — the degenerate-group convention), so
      each round bills exactly ``n_active`` logit messages, matching
      the analytic ``n * G * kd_logit_bytes`` add-on.
    """
    rounds = plan.depth if num_rounds is None else num_rounds
    half = model_bytes // 2
    active = _active_ids(mask, plan.n_peers)
    out: List[Tuple[Message, ...]] = []
    for g in range(rounds):
        msgs: List[Message] = []
        for group in plan.groups_for_round(g % plan.depth):
            members = _group_members(group, active, plan.n_peers)
            for t in members:
                for s in members:
                    if s != t:
                        msgs.append(Message(t, s, half))
            for s in members:
                mates = [t for t in members if t != s]
                msgs.append(Message(mates[0] if mates else s, s,
                                    kd_logit_bytes))
        out.append(tuple(msgs))
    return tuple(out)


def with_mkd_traffic(mplan: MessagePlan, plan: GridPlan,
                     mask: Optional[np.ndarray], model_bytes: float,
                     kd_logit_bytes: float,
                     num_rounds: Optional[int] = None) -> MessagePlan:
    """Prepend an iteration's MKD rounds to an aggregation plan (MKD
    precedes aggregation within the iteration). KD sizes are the *raw*
    model bytes — distillation doesn't ride the compressed delta wire
    format — while the aggregation rounds keep their post-stage sizes.
    """
    kd = mkd_message_rounds(plan, mask, model_bytes, kd_logit_bytes,
                            num_rounds=num_rounds)
    return dataclasses.replace(mplan, rounds=kd + mplan.rounds,
                               kd_rounds=len(kd))


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

_PLANNERS = {
    "mar": mar_plan,
    "fedavg": fedavg_plan,
    "ar": ar_plan,
    "rdfl": rdfl_plan,
    "gossip": gossip_plan,
    "hierarchical": hierarchical_plan,
}


def build_message_plan(technique: str, plan: GridPlan,
                       mask: Optional[np.ndarray], model_bytes: float,
                       num_rounds: Optional[int] = None,
                       mode: str = "naive") -> MessagePlan:
    """Unroll one FL iteration of ``technique`` into timed-able messages.

    ``mask`` is the aggregation mask A_t over real peers (None = full
    participation); ``model_bytes`` is the *wire* size of one state
    transfer (post compression-stage transforms).
    """
    if technique not in _PLANNERS:
        raise ValueError(
            f"no message planner for technique {technique!r}; "
            f"known: {sorted(_PLANNERS)}")
    if technique == "mar":
        return mar_plan(plan, mask, model_bytes, num_rounds, mode)
    if technique == "gossip":
        return gossip_plan(plan, mask, model_bytes, num_rounds)
    return _PLANNERS[technique](plan, mask, model_bytes)


# ---------------------------------------------------------------------------
# array-form plans (the large-N hot path)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArrayMessagePlan:
    """One FL iteration's traffic as flat per-message arrays.

    ``src`` / ``dst`` / ``nbytes`` concatenate every round's messages
    in round order; ``round_ptr`` (length ``n_rounds + 1``) holds the
    CSR boundaries, so round ``r`` is the slice
    ``round_ptr[r]:round_ptr[r+1]``. Message order *within* each round
    is exactly the list planners' emission order — per-sender uplink
    serialization and seeded loss draws depend on it, so preserving it
    is what keeps the vectorized simulator time-equal and
    drop-identical to the heap engine.
    """

    technique: str
    n_peers: int
    n_nodes: int
    src: np.ndarray                     # int64 [n_messages]
    dst: np.ndarray                     # int64 [n_messages]
    nbytes: np.ndarray                  # float64 [n_messages]
    round_ptr: np.ndarray               # int64 [n_rounds + 1]
    kd_rounds: int = 0

    @property
    def n_rounds(self) -> int:
        return len(self.round_ptr) - 1

    @property
    def n_messages(self) -> int:
        return int(self.src.size)

    @property
    def total_bytes(self) -> float:
        return float(self.nbytes.sum())

    def round_arrays(self, r: int) -> Tuple[np.ndarray, np.ndarray,
                                            np.ndarray]:
        lo, hi = int(self.round_ptr[r]), int(self.round_ptr[r + 1])
        return self.src[lo:hi], self.dst[lo:hi], self.nbytes[lo:hi]

    # -- lossless conversion -------------------------------------------
    @classmethod
    def from_plan(cls, mplan: MessagePlan) -> "ArrayMessagePlan":
        counts = [len(r) for r in mplan.rounds]
        ptr = np.zeros(len(counts) + 1, np.int64)
        np.cumsum(counts, out=ptr[1:])
        n = int(ptr[-1])
        src = np.empty(n, np.int64)
        dst = np.empty(n, np.int64)
        nb = np.empty(n, np.float64)
        i = 0
        for r in mplan.rounds:
            for m in r:
                src[i], dst[i], nb[i] = m.src, m.dst, m.nbytes
                i += 1
        return cls(mplan.technique, mplan.n_peers, mplan.n_nodes,
                   src, dst, nb, ptr, kd_rounds=mplan.kd_rounds)

    def to_plan(self) -> MessagePlan:
        rounds = tuple(
            tuple(Message(int(s), int(d), float(b))
                  for s, d, b in zip(*self.round_arrays(r)))
            for r in range(self.n_rounds))
        return MessagePlan(self.technique, self.n_peers, self.n_nodes,
                           rounds, kd_rounds=self.kd_rounds)


def _concat_rounds(technique: str, n_peers: int, n_nodes: int,
                   rounds: List[Tuple[np.ndarray, np.ndarray,
                                      np.ndarray]],
                   kd_rounds: int = 0) -> ArrayMessagePlan:
    counts = [r[0].size for r in rounds]
    ptr = np.zeros(len(counts) + 1, np.int64)
    np.cumsum(counts, out=ptr[1:])
    if rounds:
        src = np.concatenate([r[0] for r in rounds])
        dst = np.concatenate([r[1] for r in rounds])
        nb = np.concatenate([r[2] for r in rounds])
    else:
        src = np.empty(0, np.int64)
        dst = np.empty(0, np.int64)
        nb = np.empty(0, np.float64)
    return ArrayMessagePlan(technique, n_peers, n_nodes,
                            src.astype(np.int64), dst.astype(np.int64),
                            nb.astype(np.float64), ptr,
                            kd_rounds=kd_rounds)


def _group_rows(plan: GridPlan, rnd: int) -> np.ndarray:
    """[n_groups, m] peer ids of round ``rnd``'s groups, rows in
    ``groups_for_round`` order, members in within-group order."""
    peers = np.arange(plan.capacity)
    keys = plan.group_key(peers, rnd)
    order = np.argsort(keys, kind="stable")
    return order.reshape(-1, plan.dims[rnd])


def _valid_slots(plan: GridPlan, active: np.ndarray) -> np.ndarray:
    """Boolean over grid slots: real peer and active under the mask."""
    valid = np.zeros(plan.capacity, bool)
    valid[active] = True
    return valid


def _mar_round_arrays(rows: np.ndarray, vrows: np.ndarray,
                      model_bytes: float
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """All active intra-group pairs of one MAR round, flattened
    group-major then sender-major — the list planner's order."""
    g, m = rows.shape
    pair_ok = vrows[:, :, None] & vrows[:, None, :]
    pair_ok &= ~np.eye(m, dtype=bool)[None]
    src = np.broadcast_to(rows[:, :, None], (g, m, m))[pair_ok]
    dst = np.broadcast_to(rows[:, None, :], (g, m, m))[pair_ok]
    return (src, dst, np.full(src.size, float(model_bytes)))


def mar_plan_arrays(plan: GridPlan, mask: Optional[np.ndarray],
                    model_bytes: float,
                    num_rounds: Optional[int] = None) -> ArrayMessagePlan:
    """Vectorized :func:`mar_plan` (``naive`` mode) — identical message
    order without materializing ``Message`` objects."""
    rounds = plan.depth if num_rounds is None else num_rounds
    active = _active_ids(mask, plan.n_peers)
    valid = _valid_slots(plan, active)
    out = []
    for g in range(rounds):
        rows = _group_rows(plan, g % plan.depth)
        out.append(_mar_round_arrays(rows, valid[rows], model_bytes))
    return _concat_rounds("mar", plan.n_peers, plan.n_peers, out)


def fedavg_plan_arrays(plan: GridPlan, mask: Optional[np.ndarray],
                       model_bytes: float) -> ArrayMessagePlan:
    n = plan.n_peers
    active = _active_ids(mask, n).astype(np.int64)
    server = np.full(active.size, n, np.int64)
    nb = np.full(active.size, float(model_bytes))
    return _concat_rounds("fedavg", n, n + 1,
                          [(active, server, nb), (server, active, nb)])


def ar_plan_arrays(plan: GridPlan, mask: Optional[np.ndarray],
                   model_bytes: float) -> ArrayMessagePlan:
    n = plan.n_peers
    active = _active_ids(mask, n).astype(np.int64)
    k = active.size
    off_diag = ~np.eye(k, dtype=bool)
    src = np.broadcast_to(active[:, None], (k, k))[off_diag]
    dst = np.broadcast_to(active[None, :], (k, k))[off_diag]
    return _concat_rounds(
        "ar", n, n, [(src, dst, np.full(src.size, float(model_bytes)))])


def rdfl_plan_arrays(plan: GridPlan, mask: Optional[np.ndarray],
                     model_bytes: float) -> ArrayMessagePlan:
    n = plan.n_peers
    active = _active_ids(mask, n).astype(np.int64)
    k = active.size
    if k < 2:
        return _concat_rounds("rdfl", n, n, [])
    dst = np.roll(active, -1)
    nb = np.full(k, float(model_bytes))
    return _concat_rounds("rdfl", n, n,
                          [(active, dst, nb)] * (k - 1))


def gossip_plan_arrays(plan: GridPlan, mask: Optional[np.ndarray],
                       model_bytes: float,
                       num_rounds: Optional[int] = None
                       ) -> ArrayMessagePlan:
    n = plan.n_peers
    if num_rounds is None:
        num_rounds = max(1, int(math.ceil(math.log2(max(n, 2)))))
    active = _active_ids(mask, n).astype(np.int64)
    nb = np.full(active.size, float(model_bytes))
    out = [(active, (active + (1 << r)) % n, nb)
           for r in range(num_rounds)]
    return _concat_rounds("gossip", n, n, out)


def _leaf_groups(plan: GridPlan, active: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(rows, vrows, leaders) of the last-round groups: member matrix,
    validity, and each group's first active member (leaders only
    meaningful where a group has any active member)."""
    rows = _group_rows(plan, plan.depth - 1)
    vrows = _valid_slots(plan, active)[rows]
    first_pos = np.argmax(vrows, axis=1)
    leaders = rows[np.arange(rows.shape[0]), first_pos]
    return rows, vrows, leaders


def hierarchical_plan_arrays(plan: GridPlan, mask: Optional[np.ndarray],
                             model_bytes: float) -> ArrayMessagePlan:
    n = plan.n_peers
    rendezvous = n
    active = _active_ids(mask, n)
    rows, vrows, leaders = _leaf_groups(plan, active)
    nonempty = vrows.any(axis=1)
    # member-matrix flattening is group-major then member-major — the
    # list planner's nested-loop order; empty groups drop out of the
    # boolean mask naturally
    members = rows[vrows]
    member_lead = np.broadcast_to(leaders[:, None], rows.shape)[vrows]
    glead = leaders[nonempty]
    nb_m = np.full(members.size, float(model_bytes))
    nb_g = np.full(glead.size, float(model_bytes))
    rv = np.full(glead.size, rendezvous, np.int64)
    return _concat_rounds(
        "hierarchical", n, n + 1,
        [(members, member_lead, nb_m), (glead, rv, nb_g),
         (rv, glead, nb_g), (member_lead, members, nb_m)])


def mkd_round_arrays(plan: GridPlan, mask: Optional[np.ndarray],
                     model_bytes: float, kd_logit_bytes: float,
                     num_rounds: Optional[int] = None
                     ) -> List[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Vectorized :func:`mkd_message_rounds`: per group, the teacher
    pulls (all active pairs at half state) then the logit messages
    (first active mate -> student, loopback for singleton groups),
    blocks interleaved per group exactly like the list builder."""
    rounds = plan.depth if num_rounds is None else num_rounds
    half = model_bytes // 2
    active = _active_ids(mask, plan.n_peers)
    valid = _valid_slots(plan, active)
    out = []
    for g in range(rounds):
        rows = _group_rows(plan, g % plan.depth)
        vrows = valid[rows]
        ng, m = rows.shape
        p_src, p_dst, _ = _mar_round_arrays(rows, vrows, half)
        k = vrows.sum(axis=1)                      # active per group
        # logit messages: student s <- its group's first active member
        # (second if s *is* the first; itself when alone)
        first_pos = np.argmax(vrows, axis=1)
        first = rows[np.arange(ng), first_pos]
        v2 = vrows.copy()
        v2[np.arange(ng), first_pos] = False
        second_pos = np.argmax(v2, axis=1)
        second = rows[np.arange(ng), second_pos]
        students = rows[vrows]
        gid_l = np.broadcast_to(np.arange(ng)[:, None], rows.shape)[vrows]
        mate = np.where(students == first[gid_l], second[gid_l],
                        first[gid_l])
        mate = np.where(k[gid_l] < 2, students, mate)
        # interleave per group: [pulls_g, logits_g] blocks in group order
        p_cnt = k * (k - 1)
        tot = p_cnt + k
        goff = np.zeros(ng + 1, np.int64)
        np.cumsum(tot, out=goff[1:])
        gid_p = np.broadcast_to(
            np.arange(ng)[:, None, None], (ng, m, m))[
                vrows[:, :, None] & vrows[:, None, :]
                & ~np.eye(m, dtype=bool)[None]]
        poff = np.zeros(ng + 1, np.int64)
        np.cumsum(p_cnt, out=poff[1:])
        idx_p = goff[gid_p] + (np.arange(p_src.size) - poff[gid_p])
        loff = np.zeros(ng + 1, np.int64)
        np.cumsum(k, out=loff[1:])
        idx_l = goff[gid_l] + p_cnt[gid_l] + \
            (np.arange(students.size) - loff[gid_l])
        n_msg = int(tot.sum())
        src = np.empty(n_msg, np.int64)
        dst = np.empty(n_msg, np.int64)
        nb = np.empty(n_msg, np.float64)
        src[idx_p], dst[idx_p], nb[idx_p] = p_src, p_dst, float(half)
        src[idx_l], dst[idx_l], nb[idx_l] = \
            mate, students, float(kd_logit_bytes)
        out.append((src, dst, nb))
    return out


def with_mkd_traffic_arrays(aplan: ArrayMessagePlan, plan: GridPlan,
                            mask: Optional[np.ndarray],
                            model_bytes: float, kd_logit_bytes: float,
                            num_rounds: Optional[int] = None
                            ) -> ArrayMessagePlan:
    """Array-form :func:`with_mkd_traffic`: prepend the MKD rounds."""
    kd = mkd_round_arrays(plan, mask, model_bytes, kd_logit_bytes,
                          num_rounds=num_rounds)
    agg = [aplan.round_arrays(r) for r in range(aplan.n_rounds)]
    return _concat_rounds(aplan.technique, aplan.n_peers, aplan.n_nodes,
                          kd + agg, kd_rounds=len(kd))


_ARRAY_PLANNERS = {
    "mar": mar_plan_arrays,
    "fedavg": fedavg_plan_arrays,
    "ar": ar_plan_arrays,
    "rdfl": rdfl_plan_arrays,
    "gossip": gossip_plan_arrays,
    "hierarchical": hierarchical_plan_arrays,
}


def build_array_plan(technique: str, plan: GridPlan,
                     mask: Optional[np.ndarray], model_bytes: float,
                     num_rounds: Optional[int] = None,
                     mode: str = "naive") -> ArrayMessagePlan:
    """Array-native :func:`build_message_plan` — same messages, same
    order, no per-message Python objects. ``mar`` ``butterfly`` mode
    falls back to converting the list plan (its variable-length chunk
    hops aren't on the large-N hot path)."""
    if technique not in _ARRAY_PLANNERS:
        raise ValueError(
            f"no array message planner for technique {technique!r}; "
            f"known: {sorted(_ARRAY_PLANNERS)}")
    if technique == "mar":
        if mode != "naive":
            return ArrayMessagePlan.from_plan(
                mar_plan(plan, mask, model_bytes, num_rounds, mode))
        return mar_plan_arrays(plan, mask, model_bytes, num_rounds)
    if technique == "gossip":
        return gossip_plan_arrays(plan, mask, model_bytes, num_rounds)
    return _ARRAY_PLANNERS[technique](plan, mask, model_bytes)


# ---------------------------------------------------------------------------
# symbolic superpeer plans (the N=10^6 tier)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class SuperMessagePlan:
    """One FL iteration's traffic as a *recipe*, not messages.

    Where :class:`ArrayMessagePlan` materializes every ``(src, dst,
    nbytes)`` tuple, this plan stores only what generated them — the
    technique, the grid (with placement), the active mask and the byte
    sizes — O(N) state independent of message count. The superpeer
    engine (``runtime/super_network.py``) walks the same per-technique
    round structure the array planners would emit, timing structured
    rounds with the closed-form recurrences of
    ``runtime/vector_network.py`` and materializing only the rounds
    that need the full vector path (pairwise WAN terms, loss). Because
    the recipe *determines* the array plan, :meth:`to_array_plan`
    rebuilds the exact messages on demand — the engine's fallback, and
    the parity tests' oracle.

    ``use_kd`` prepends the MKD prefix rounds at ``raw_model_bytes``
    (distillation rides uncompressed state, as
    ``AggregationPipeline.message_plan`` bills it) with
    ``kd_logit_bytes`` logits.
    """

    technique: str
    plan: GridPlan
    model_bytes: float                   # wire bytes per agg message
    mask: Optional[np.ndarray] = None
    num_rounds: Optional[int] = None
    mode: str = "naive"
    use_kd: bool = False
    raw_model_bytes: float = 0.0
    kd_logit_bytes: float = 0.0

    @property
    def n_peers(self) -> int:
        return self.plan.n_peers

    @property
    def n_nodes(self) -> int:
        return self.plan.n_peers + (
            1 if self.technique in ("fedavg", "hierarchical") else 0)

    @property
    def kd_rounds(self) -> int:
        if not self.use_kd:
            return 0
        return (self.plan.depth if self.num_rounds is None
                else self.num_rounds)

    def n_messages_estimate(self) -> int:
        """Upper-ish bound on materialized message count — the
        engine's per-link-tracking budget check."""
        n = self.plan.n_peers
        k = _active_ids(self.mask, n).size
        depth = self.plan.depth
        rounds = depth if self.num_rounds is None else self.num_rounds
        m = max(self.plan.dims)
        est = {
            "mar": rounds * k * (m - 1),
            "gossip": rounds * k,
            "fedavg": 2 * k,
            "hierarchical": 2 * k + 2 * (k // max(
                self.plan.dims[-1], 1) + 1),
            "ar": k * (k - 1),
            "rdfl": k * (k - 1),
        }.get(self.technique, k * rounds)
        if self.use_kd:
            est += self.kd_rounds * k * m
        return int(est)

    def to_array_plan(self) -> ArrayMessagePlan:
        """Materialize the exact messages this recipe stands for."""
        aplan = build_array_plan(self.technique, self.plan, self.mask,
                                 self.model_bytes,
                                 num_rounds=self.num_rounds,
                                 mode=self.mode)
        if self.use_kd:
            aplan = with_mkd_traffic_arrays(
                aplan, self.plan, self.mask, self.raw_model_bytes,
                self.kd_logit_bytes, num_rounds=self.num_rounds)
        return aplan


def build_super_plan(technique: str, plan: GridPlan,
                     mask: Optional[np.ndarray], model_bytes: float,
                     num_rounds: Optional[int] = None,
                     mode: str = "naive",
                     use_kd: bool = False,
                     raw_model_bytes: float = 0.0,
                     kd_logit_bytes: float = 0.0) -> SuperMessagePlan:
    """Symbolic counterpart of :func:`build_array_plan` — validates the
    technique and freezes the recipe; no messages are materialized."""
    if technique not in _ARRAY_PLANNERS:
        raise ValueError(
            f"no superpeer plan recipe for technique {technique!r}; "
            f"known: {sorted(_ARRAY_PLANNERS)}")
    if mask is not None:
        mask = np.asarray(mask).copy()
        mask.setflags(write=False)
    return SuperMessagePlan(technique, plan, float(model_bytes),
                            mask=mask, num_rounds=num_rounds, mode=mode,
                            use_kd=use_kd,
                            raw_model_bytes=float(raw_model_bytes),
                            kd_logit_bytes=float(kd_logit_bytes))
