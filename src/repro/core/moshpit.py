"""Moshpit All-Reduce grid math and deterministic group-key schedule.

The paper (§2.2) arranges N peers on a virtual d-dimensional grid
``N = M^d``. In MAR round ``g`` a peer's *group key* is its grid
coordinate vector with coordinate ``g`` struck out, so the ``M`` peers
that differ only in coordinate ``g`` share a key and average together.
After ``d`` rounds every peer holds the exact global mean (when
``N = M^d`` and no dropouts). This module is pure index arithmetic —
the TPU-native replacement for Hivemind DHT matchmaking (DESIGN.md §2);
``mar_allreduce.py`` executes the schedule.

Also provides ``plan_grid`` for general N (elastic peer counts): picks
(M, d) with M^d >= N and minimal per-iteration traffic, padding virtual
slots with a participation mask (the same mask mechanism that models
churn), so restarts with a different peer count re-factorize cleanly.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class GridPlan:
    """A d-dimensional MAR grid for N peers.

    ``dims`` may be heterogeneous (e.g. (2, 4, 4) for a 2-pod mesh whose
    DP axes factor as 4x4) — the paper's M^d is the uniform special case.

    ``placement`` optionally permutes peers onto grid slots:
    ``placement[peer] = slot`` over all ``capacity`` entities (real
    peers first, then virtual padding). Every coordinate/key/group query
    routes through it, so list planners, the vectorized builders, the
    analytic oracles and both sim engines see one consistent schedule —
    the hook topology-aware placement (``core/placement.py``) uses to
    park each network cluster on contiguous low-axis coordinates, the
    same way ``mesh_grid_plan`` isolates DCN traffic on the pod axis.
    ``None`` (and the identity permutation, which normalizes to
    ``None``) is bit-exact with the historical index == coordinate
    behavior.
    """

    n_peers: int               # real peers (<= capacity)
    dims: Tuple[int, ...]      # group size per round; capacity = prod(dims)
    placement: Optional[Tuple[int, ...]] = None   # entity -> slot

    def __post_init__(self):
        if self.placement is None:
            return
        cap = int(np.prod(self.dims))
        p = tuple(int(s) for s in self.placement)
        if len(p) != cap or sorted(p) != list(range(cap)):
            raise ValueError(
                f"placement must be a permutation of range({cap}) "
                f"(entity -> slot over the full grid capacity); got "
                f"length {len(p)}")
        if p == tuple(range(cap)):
            p = None               # identity is the no-placement plan
        object.__setattr__(self, "placement", p)

    @property
    def depth(self) -> int:
        return len(self.dims)

    @property
    def capacity(self) -> int:
        return int(np.prod(self.dims))

    @property
    def is_exact(self) -> bool:
        """Exact global average after ``depth`` rounds (no virtual slots)."""
        return self.capacity == self.n_peers

    # -- placement ------------------------------------------------------
    @functools.cached_property
    def _slot_of(self) -> np.ndarray:
        return np.asarray(self.placement, np.int64)

    @functools.cached_property
    def _entity_at(self) -> np.ndarray:
        inv = np.empty(self.capacity, np.int64)
        inv[self._slot_of] = np.arange(self.capacity)
        return inv

    def with_placement(self, perm) -> "GridPlan":
        """This grid with a peer→slot permutation applied.

        ``perm`` maps each real peer (length ``n_peers``) — or every
        capacity entity (length ``capacity``) — to a grid slot; with
        the short form, virtual entities fill the leftover slots in
        ascending order. ``None`` clears the placement. The identity
        permutation normalizes to ``placement=None``, so a cleared and
        an identity-placed plan compare equal.
        """
        if perm is None:
            return dataclasses.replace(self, placement=None)
        perm = np.asarray(perm, np.int64)
        cap = self.capacity
        if perm.shape == (cap,):
            full = perm
        elif perm.shape == (self.n_peers,):
            full = np.empty(cap, np.int64)
            full[:self.n_peers] = perm
            used = np.zeros(cap, bool)
            used[perm] = True
            full[self.n_peers:] = np.flatnonzero(~used)
        else:
            raise ValueError(
                f"placement permutation must cover the {self.n_peers} "
                f"real peers or all {cap} capacity slots; got shape "
                f"{perm.shape}")
        return dataclasses.replace(
            self, placement=tuple(int(s) for s in full))

    def slot_of(self, peer: np.ndarray | int) -> np.ndarray:
        """Grid slot of each entity (identity without a placement)."""
        peer = np.asarray(peer)
        return peer if self.placement is None else self._slot_of[peer]

    # -- coordinates ----------------------------------------------------
    def coords(self, peer: np.ndarray | int) -> np.ndarray:
        """Mixed-radix coordinates of peer index; last dim fastest."""
        peer = np.asarray(peer)
        if self.placement is not None:
            peer = self._slot_of[peer]
        out = np.empty(peer.shape + (self.depth,), np.int64)
        rem = peer
        for axis in range(self.depth - 1, -1, -1):
            out[..., axis] = rem % self.dims[axis]
            rem = rem // self.dims[axis]
        return out

    def index(self, coords: np.ndarray) -> np.ndarray:
        """Inverse of :meth:`coords`."""
        coords = np.asarray(coords)
        idx = np.zeros(coords.shape[:-1], np.int64)
        for axis in range(self.depth):
            idx = idx * self.dims[axis] + coords[..., axis]
        if self.placement is not None:
            idx = self._entity_at[idx]
        return idx

    # -- the group-key schedule ------------------------------------------
    def group_key(self, peer: np.ndarray | int, rnd: int) -> np.ndarray:
        """Round-``rnd`` group key: coordinates with axis ``rnd`` struck out.

        Peers sharing a key form one group of size ``dims[rnd]``. Keys are
        flattened to a scalar id so they can double as replica-group labels.
        This reproduces the paper's "group key initialization and updates
        that leverage chunk indices from d-1 previous MAR rounds": a peer's
        chunk index in round r *is* its coordinate on axis r, and striking
        a different axis every round guarantees no pair is revisited within
        one FL iteration.
        """
        if not 0 <= rnd < self.depth:
            raise ValueError(f"round {rnd} out of range for depth {self.depth}")
        c = self.coords(peer)
        key = np.zeros(c.shape[:-1], np.int64)
        for axis in range(self.depth):
            if axis == rnd:
                continue
            key = key * self.dims[axis] + c[..., axis]
        return key

    def groups_for_round(self, rnd: int) -> List[np.ndarray]:
        """All replica groups (lists of peer ids) for MAR round ``rnd``."""
        peers = np.arange(self.capacity)
        keys = self.group_key(peers, rnd)
        order = np.argsort(keys, kind="stable")
        m = self.dims[rnd]
        return [order[i * m:(i + 1) * m] for i in range(self.capacity // m)]

    def partner_matrix(self, rnd: int) -> np.ndarray:
        """[capacity, M] peer ids of each peer's round-``rnd`` group
        (including itself), ordered by the struck-out coordinate."""
        peers = np.arange(self.capacity)
        c = self.coords(peers)                       # [P, d]
        m = self.dims[rnd]
        reps = np.repeat(c[:, None, :], m, axis=1)   # [P, M, d]
        reps[:, :, rnd] = np.arange(m)[None, :]
        return self.index(reps)


def plan_grid(n_peers: int, group_size: int | None = None,
              depth: int | None = None) -> GridPlan:
    """Choose a grid for ``n_peers``.

    Priority: (1) honor explicit (group_size, depth) — and *honor*
    means honor: a (g, d) whose capacity ``g**d`` cannot hold N peers
    is a ValueError, never a silently deepened grid; (2) find uniform
    M^d == N exactly with M <= 8 (paper's optimal setup, e.g.
    125 = 5^3; 65536 = 2^16); (3) near-balanced mixed-radix grid: for
    each depth take M = ceil(N^(1/d)) and demote trailing rounds to
    M-1 while capacity still covers N, then keep the (capacity, cost,
    depth)-minimal candidate — e.g. 10 -> (3, 2, 2), 100 -> (5, 5, 4).
    The winner provably pads by less than one grid row; a clear
    ValueError (not a degenerate deep grid) is raised otherwise.
    """
    if depth is not None and depth < 1:
        # 0 is an explicit (invalid) request, not "unset"
        raise ValueError(f"depth must be >= 1, got {depth}")
    if group_size is not None:
        if depth is not None:
            if group_size ** depth < n_peers:
                raise ValueError(
                    f"explicit grid (group_size={group_size}, "
                    f"depth={depth}) has capacity "
                    f"{group_size ** depth} < {n_peers} peers; pass a "
                    f"deeper/wider grid or omit depth to auto-size")
            return GridPlan(n_peers, (group_size,) * depth)
        d = max(1, round(math.log(max(n_peers, 2), group_size)))
        while group_size ** d < n_peers:
            d += 1
        return GridPlan(n_peers, (group_size,) * d)
    if depth is not None:
        m = max(2, math.ceil(n_peers ** (1.0 / depth)))
        return GridPlan(n_peers, (m,) * depth)
    if n_peers < 2:
        return GridPlan(n_peers, (2,))
    # exact factorization M^d == N, prefer smaller M (less per-round traffic)
    for m in range(2, min(n_peers, 8) + 1):
        d = round(math.log(n_peers, m))
        for dd in (d, d + 1):
            if dd >= 1 and m ** dd == n_peers:
                return GridPlan(n_peers, (m,) * dd)
    # no exact power with M <= 8: near-balanced mixed-radix grid.  For
    # each depth d take the smallest M with M^d >= N and demote as many
    # trailing rounds as possible from M to M-1 while capacity still
    # covers N; rank candidates by (capacity, pairwise-exchange cost,
    # depth).  Because M was minimal, at least one round keeps M, so
    # padding < capacity / M — never a full grid row of virtual slots.
    best: GridPlan | None = None
    best_key = None
    for d in range(2, max(2, math.ceil(math.log2(n_peers))) + 1):
        m = 2
        while m ** d < n_peers:
            m += 1
        if m > 8:
            continue
        dims = [m] * d
        if m > 2:
            for k in range(1, d):
                cand = [m] * (d - k) + [m - 1] * k
                if int(np.prod(cand)) < n_peers:
                    break
                dims = cand
        cap = int(np.prod(dims))
        key = (cap, cap * sum(g - 1 for g in dims), d)
        if best_key is None or key < best_key:
            best, best_key = GridPlan(n_peers, tuple(dims)), key
    if best is None or (best.capacity - n_peers
                        >= best.capacity // best.dims[0]):
        raise ValueError(
            f"no auto-sized grid for N={n_peers} pads by less than one "
            f"grid row; pass an explicit (group_size, depth)")
    return best


def mesh_grid_plan(dp_axis_sizes: Sequence[int],
                   factor_hints: dict | None = None) -> GridPlan:
    """Map physical mesh DP axes onto a MAR grid (DESIGN.md §2).

    Each DP mesh axis contributes its factors as MAR rounds; e.g.
    data=16 -> (4, 4); multi-pod (pod=2, data=16) -> (2, 4, 4) with the
    pod axis as the *outermost* round so DCN-crossing traffic happens in
    exactly one of the d rounds.
    """
    factor_hints = factor_hints or {}
    dims: List[int] = []
    for i, size in enumerate(dp_axis_sizes):
        fac = factor_hints.get(i)
        if fac:
            assert int(np.prod(fac)) == size, (fac, size)
            dims.extend(fac)
        else:
            dims.extend(_balanced_factors(size))
    n = int(np.prod(dp_axis_sizes))
    return GridPlan(n, tuple(dims))


def _balanced_factors(n: int) -> List[int]:
    """Factor n into near-equal factors in [2..8], e.g. 16 -> [4, 4]."""
    if n == 1:
        return []
    if n <= 8:
        return [n]
    for m in (4, 5, 6, 7, 8, 3, 2):
        if n % m == 0:
            return [m] + _balanced_factors(n // m)
    return [n]  # prime > 8: single round


# ---------------------------------------------------------------------------
# Communication accounting (per paper §2.2)
# ---------------------------------------------------------------------------

def exchanges_per_iteration(plan: GridPlan) -> int:
    """Total pairwise model exchanges in one FL iteration: each of the
    capacity slots talks to (M_g - 1) peers in round g."""
    return int(sum(plan.capacity * (m - 1) for m in plan.dims))


def bytes_per_iteration(plan: GridPlan, model_bytes: int,
                        allreduce: str = "butterfly") -> int:
    """Data-plane bytes moved per FL iteration.

    ``butterfly``: within a group of M peers, reduce-scatter + all-gather
    moves 2*(M-1)/M * model_bytes per peer per round (bandwidth-optimal,
    what Moshpit/Hivemind does inside a group). ``naive``: every peer
    sends its full model to M-1 peers.
    """
    total = 0
    for m in plan.dims:
        if allreduce == "butterfly":
            per_peer = 2.0 * (m - 1) / m * model_bytes
        else:
            per_peer = (m - 1) * model_bytes
        total += int(plan.capacity * per_peer)
    return total
