"""The MAR-FL training loop (Alg. 1) and its baselines (sim backend).

Peers are the leading axis of every state pytree leaf; local updates are
vmapped Momentum-SGD; aggregation runs through one composable
:class:`~repro.core.aggregation.AggregationPipeline`:

* the **technique** picks the :class:`Aggregator` from the registry —
  ``mar`` (the paper), ``fedavg``, ``rdfl``, ``ar``, plus beyond-paper
  ``gossip`` and ``hierarchical``;
* **wire stages** compose around it from config flags — staleness-1
  async application, DP privatization (with optional secure aggregation
  of the clipping indicator), int8 error-feedback delta compression.
  Any stage combination is legal (DESIGN.md §6); e.g. compress + DP
  quantizes *after* noising, async + compress delays the quantized
  aggregate one iteration.

The exact-mean techniques produce the *same* global average under full
participation (paper Fig. 5 "qualitative identity"); they differ in
communication cost (``topology.py``, tracked per source by the
:class:`CommLedger`) and churn semantics. Partial participation and
dropout follow §3.1: U_t peers run local updates; A_t = U_t minus
dropouts joins aggregation; non-participants carry state forward
(Alg. 1 line 5). Both masks come from a pluggable
:class:`~repro.runtime.lifecycle.PeerLifecycle` (DESIGN.md §7):
``cfg.churn`` picks the availability process (i.i.d. Bernoulli is the
degenerate default, replaying the legacy ``sample_masks`` bit-exact),
and permanent join/leave — from ``cfg.resize_schedule`` or trace
events — becomes a :class:`~repro.core.replan.MembershipChange`
through :meth:`Federation.apply_membership` (the one membership entry
point, DESIGN.md §16): the MAR grid is re-factorized
(``elastic_replan``), the aggregation pipeline rebuilt, and the
stacked peer axis of params/momentum/pipe state grown or shrunk in
place, mid-run, with no checkpoint/restart.

One FL iteration is a single jitted function of (state, masks, rng);
the loop is host-side so benchmarks can interleave evaluation and
communication accounting.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import TYPE_CHECKING, Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import topology
from repro.core.aggregation import (TECHNIQUES, AggregationPipeline,
                                    CommLedger, build_pipeline)
from repro.core.moshpit import GridPlan, plan_grid
from repro.core.replan import (MembershipChange, plan_membership_change,
                               regroup_change)
from repro.data.partition import dirichlet_partition, iid_partition
from repro.data.synthetic import classification_task
from repro.models.small import build_peer_model
from repro.optim.sgdm import momentum_sgd_init, momentum_sgd_step

# repro.runtime.{lifecycle,fault} are imported lazily inside methods:
# they depend on repro.core.moshpit, so a module-level import here would
# cycle when repro.runtime is imported first.
if TYPE_CHECKING:  # pragma: no cover
    from repro.runtime.lifecycle import PeerLifecycle

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class FederationConfig:
    n_peers: int = 125
    technique: str = "mar"
    task: str = "text"               # vision | text
    # MAR grid: default plan_grid(n_peers) -> e.g. 125 = 5^3
    group_size: Optional[int] = None
    mar_rounds: Optional[int] = None  # None -> grid depth (exact)
    # local update (paper §3.1)
    local_batches: int = 1            # B in Alg. 1
    batch_size: int = 16              # 64 for vision, 16 for text per paper
    lr: float = 0.1
    momentum: float = 0.9
    # participation / churn — ``churn`` names a lifecycle scenario from
    # runtime/lifecycle.py ("bernoulli" | "sessions" | "correlated" |
    # "wireless" | "trace"); None keeps the legacy i.i.d. Bernoulli
    # masks (bit-identical replay of pre-lifecycle runs).
    participation_rate: float = 1.0
    dropout_rate: float = 0.0
    churn: Optional[str] = None
    churn_params: Optional[Dict[str, Any]] = None
    # mid-run elastic membership: ((iteration, new_n_peers), ...) —
    # at each listed iteration the fleet permanently grows/shrinks and
    # the runtime regroups in place (no checkpoint/restart)
    resize_schedule: Tuple[Tuple[int, int], ...] = ()
    # adaptive group sizing (core/adaptive.py): a GroupSizeController
    # name ("static" | "tail_aware" | "schedule"). The controller
    # consumes every iteration's transport transcript and may propose a
    # new grid for the SAME peer count; Federation.regroup swaps the
    # dims mid-run through the elastic machinery without touching
    # membership. None disables the hook entirely.
    adaptive_m: Optional[str] = None
    adaptive_m_params: Optional[Dict[str, Any]] = None
    # topology-aware placement (core/placement.py): a PlacementPolicy
    # name ("identity" | "random" | "clustered"). The policy consumes
    # every iteration's transcript and may propose the SAME dims with a
    # new peer->slot permutation; Federation.regroup applies it as a
    # membership-preserving regroup. Composes with adaptive_m: after a
    # dims change the policy rebinds and re-emits its permutation for
    # the new grid. None disables the hook entirely.
    placement: Optional[str] = None
    placement_params: Optional[Dict[str, Any]] = None
    # route the sim MAR masked group mean through the fused Pallas
    # kernel (kernels/group_mean.py) instead of jnp segment sums
    pallas_group_mean: bool = False
    # data heterogeneity
    alpha: Optional[float] = 1.0      # Dirichlet; None -> iid
    # KD (Alg. 2/3)
    use_kd: bool = False
    kd_iterations: int = 6            # K
    kd_temperature: float = 3.0       # tau
    kd_selection_ratio: float = 0.4   # rho_l
    kd_epochs: int = 1                # E
    # DP wire stage (Alg. 4)
    use_dp: bool = False
    noise_multiplier: float = 0.3     # sigma_mult
    dp_clip_init: float = 1.0         # C_0
    use_secagg: bool = False          # pairwise-masked indicator (§A.2)
    # async wire stage: staleness-1 aggregation — the result computed at
    # iteration t is *applied* at t+1, so its collectives overlap the
    # next iteration's compute (delayed averaging; DESIGN.md §5)
    async_aggregation: bool = False
    # compression wire stage: int8 error-feedback delta compression on
    # the wire (core/compression.py) — 4x fewer bytes, bias-free in time
    compress: Optional[str] = None    # None | "int8_ef"
    # discrete-event network layer (runtime/network.py): every
    # aggregation is unrolled into messages and timed over modeled
    # links; the CommLedger is fed from the measured transcript. None
    # -> the lossless "uniform" profile (bytes match the analytic
    # oracles; time is still simulated). "wireless"/"regions" add
    # lognormal heterogeneity, latency, and per-message loss — a peer
    # whose message is lost mid-round is demoted to receiver-only for
    # that aggregation (paper §3.1 churn semantics).
    link_profile: Optional[str] = None
    link_params: Optional[Dict[str, Any]] = None
    # transport backend executing the per-step message plans
    # (runtime/transport_base.py): "sim" models them over the link
    # profile above; "vector_sim" is the batched segment-op engine —
    # byte- and time-identical transcripts, orders of magnitude faster
    # at large N (runtime/vector_network.py); "super_sim" goes one
    # tier further — closed-form intra-cluster rounds plus the vector
    # engine for cross-cluster flows, same transcripts on uniform/
    # wireless, O(rounds) not O(messages), reaching N=2^20
    # (runtime/super_network.py); "socket" runs every peer
    # as an asyncio task on loopback TCP and really transmits
    # int8-serialized update tensors — identical transcript shape, so
    # the ledger, churn demotion and history are backend-agnostic
    # (link_profile/link_params apply to the sims only; "socket" keeps
    # just the loss rate as injection).
    transport: str = "sim"
    seed: int = 0

    def grid(self) -> GridPlan:
        return plan_grid(self.n_peers, self.group_size)


@dataclasses.dataclass
class FederationState:
    params: PyTree                    # [N, ...] stacked peer params
    momentum: PyTree                  # [N, ...]
    iteration: int
    rng: Array
    # wire-stage state keyed by stage name: "dp" (clip bound, smoothed
    # deltas), "async" (pending aggregate), "int8_ef" (ref + EF residual)
    pipe: Dict[str, PyTree] = dataclasses.field(default_factory=dict)
    kd_lambda: float = 1.0

    # -- legacy accessors (pre-pipeline field names) --------------------
    @property
    def dp(self) -> Optional[Dict[str, PyTree]]:
        return self.pipe.get("dp")

    @property
    def pending(self) -> Optional[PyTree]:
        a = self.pipe.get("async")
        return a["pending"] if a else None

    @property
    def ref(self) -> Optional[PyTree]:
        c = self.pipe.get("int8_ef")
        return c["ref"] if c else None

    @property
    def ef_error(self) -> Optional[PyTree]:
        c = self.pipe.get("int8_ef")
        return c["err"] if c else None


class Federation:
    """Owns the task data, the jitted iteration fn, the aggregation
    pipeline, the transport backend, and the comm ledger.

    Communication accounting is *measured*: each step unrolls the
    aggregation (plus any MKD rounds) into a message plan
    (``core/transport.py``) and hands it to the pluggable
    :class:`~repro.runtime.transport_base.Transport`
    (``cfg.transport``): the ``"sim"`` backend times — and, under lossy
    profiles, drops — every message over per-peer modeled links
    (``cfg.link_profile``: "uniform" lossless default, "wireless"
    lognormal heterogeneity, "regions" tiered blocks); the ``"socket"``
    backend really transmits int8-serialized update tensors between
    asyncio peer tasks on loopback TCP. Either way the transcript feeds
    the ledger — bytes plus (simulated or wall-clock) seconds — and
    lost sends demote their peer to receiver-only for the iteration
    (DESIGN.md §9-§10).
    """

    def __init__(self, cfg: FederationConfig,
                 lifecycle: Optional["PeerLifecycle"] = None):
        from repro.runtime.lifecycle import build_lifecycle
        from repro.runtime.transport_base import build_transport
        if cfg.technique not in TECHNIQUES:
            raise ValueError(cfg.technique)
        self.cfg = cfg
        self.plan = cfg.grid()
        self.pipeline = self._build_pipeline(cfg, self.plan)
        self.controller = None
        if cfg.adaptive_m is not None:
            from repro.core.adaptive import build_controller
            self.controller = build_controller(
                cfg.adaptive_m, self.plan, **(cfg.adaptive_m_params or {}))
        # (iteration, old_dims, new_dims) of every adaptive regroup
        self.regroup_log: List[Tuple[int, Tuple[int, ...],
                                     Tuple[int, ...]]] = []
        self.placement_policy = None
        if cfg.placement is not None:
            from repro.core.placement import build_placement
            self.placement_policy = build_placement(
                cfg.placement, self.plan, seed=cfg.seed,
                **(cfg.placement_params or {}))
        # (iteration, peers_moved) of every placement regroup
        self.placement_log: List[Tuple[int, int]] = []
        self.ledger = CommLedger()
        self.network = build_transport(cfg.transport, cfg.n_peers,
                                       profile=cfg.link_profile,
                                       seed=cfg.seed,
                                       link_params=cfg.link_params)
        self.last_transcript = None
        # per-iteration plan memo: (grid, mask, parity, KD) -> built
        # plan. Plans are immutable once built, so identical steps
        # reuse them; regroup/resize clear the cache (the grid id in
        # the key would already miss, clearing just bounds growth).
        self._plan_cache: Dict[Tuple, Any] = {}
        self.plan_cache_hits = 0
        self.plan_cache_misses = 0
        if self.placement_policy is not None:
            self.placement_policy.bind_prober(self._run_probe)
        self.lifecycle = lifecycle if lifecycle is not None else \
            build_lifecycle(cfg.churn, cfg.n_peers, seed=cfg.seed,
                            participation_rate=cfg.participation_rate,
                            dropout_rate=cfg.dropout_rate,
                            churn_params=cfg.churn_params,
                            schedule=cfg.resize_schedule)
        spec, train, test = classification_task(cfg.task, seed=cfg.seed)
        self.spec = spec
        self._train = train
        self.test = {k: jnp.asarray(v) for k, v in test.items()}
        self.init_fn, self.apply_fn = build_peer_model(
            cfg.task, spec.feature_dim, spec.num_classes)

        # --- federated partition (rectangular per-peer arrays) ----------
        xs, ys = self._peer_shards(range(cfg.n_peers), cfg.n_peers)
        self.data_x = jnp.asarray(np.stack(xs))     # [N, P, D]
        self.data_y = jnp.asarray(np.stack(ys))     # [N, P]

        self.model_bytes = topology.pytree_bytes(
            self.init_fn(jax.random.PRNGKey(0))) * 2  # theta + momentum
        self._it_fn = jax.jit(self._iteration,
                              static_argnames=("use_kd", "do_aggregate"))

    @staticmethod
    def _build_pipeline(cfg: FederationConfig,
                        plan: GridPlan) -> AggregationPipeline:
        return build_pipeline(
            cfg.technique, plan, num_rounds=cfg.mar_rounds,
            use_kernel=cfg.pallas_group_mean,
            async_aggregation=cfg.async_aggregation,
            use_dp=cfg.use_dp, noise_multiplier=cfg.noise_multiplier,
            dp_clip_init=cfg.dp_clip_init, use_secagg=cfg.use_secagg,
            compress=cfg.compress)

    def _peer_shards(self, peers, n_peers: int,
                     per_peer: Optional[int] = None):
        """Data rows for the given peer ids out of an ``n_peers``-way
        partition of the training set. Shard *membership* is
        deterministic in (cfg.seed, n_peers); the per-peer row
        subsample is seeded but consumes the rng in loop order, so a
        mid-run joiner's rows differ from the rows it would have drawn
        in a fresh run at the same size (the shard itself matches)."""
        cfg = self.cfg
        if cfg.alpha is None:
            shards = iid_partition(len(self._train["y"]), n_peers,
                                   seed=cfg.seed)
        else:
            shards = dirichlet_partition(self._train["y"], n_peers,
                                         alpha=cfg.alpha, seed=cfg.seed)
        rng = np.random.default_rng(cfg.seed + 1)
        if per_peer is None:
            per_peer = max(cfg.batch_size,
                           int(np.median([len(s) for s in shards])))
        xs, ys = [], []
        for i in peers:
            s = shards[i]
            take = rng.choice(s, size=per_peer, replace=len(s) < per_peer)
            xs.append(self._train["x"][take])
            ys.append(self._train["y"][take])
        return xs, ys

    @property
    def comm_bytes(self) -> float:
        """Total data-plane bytes so far (CommLedger-backed)."""
        return self.ledger.total_bytes

    @property
    def sim_seconds(self) -> float:
        """Cumulative communication seconds from the transport backend
        (simulated for ``"sim"``, measured wall-clock for ``"socket"``)."""
        return self.network.clock

    # ------------------------------------------------------------------
    def init_state(self) -> FederationState:
        key = jax.random.PRNGKey(self.cfg.seed)
        params0 = self.init_fn(key)  # same theta^0 for every peer (Alg. 1)
        stack = lambda x: jnp.broadcast_to(
            x[None], (self.cfg.n_peers,) + x.shape)
        params = jax.tree.map(stack, params0)
        mom = momentum_sgd_init(params)
        pipe = self.pipeline.init_state({"p": params, "m": mom})
        return FederationState(params=params, momentum=mom, iteration=0,
                               rng=jax.random.PRNGKey(self.cfg.seed + 7),
                               pipe=pipe)

    # ------------------------------------------------------------------
    # masks (legacy API — the lifecycle is the pluggable source now)
    # ------------------------------------------------------------------
    def sample_masks(self, rng: np.random.Generator
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """(participates U_t, aggregates A_t) boolean masks, float32.

        Kept for callers that pre-compute masks; ``step()`` itself asks
        ``self.lifecycle`` (whose Bernoulli model replays this exact
        sampling sequence for ``churn=None`` configs).
        """
        n = self.cfg.n_peers
        u = rng.random(n) < self.cfg.participation_rate
        if not u.any():
            u[rng.integers(n)] = True
        drop = rng.random(n) < self.cfg.dropout_rate
        a = u & ~drop
        if not a.any():
            a[np.flatnonzero(u)[0]] = True
        return u.astype(np.float32), a.astype(np.float32)

    # ------------------------------------------------------------------
    # elastic membership (mid-run, no checkpoint/restart)
    # ------------------------------------------------------------------
    def apply_membership(self, state: FederationState,
                         change: MembershipChange) -> FederationState:
        """THE membership entry point (DESIGN.md §16): every layer's
        reaction to one :class:`~repro.core.replan.MembershipChange` —
        lifecycle resizes, adaptive-M regroups and placement
        permutations all arrive here as the same event.

        Same-N change (regroup): the grid dims/placement swap, the
        pipeline re-binds (:meth:`AggregationPipeline.with_plan`), peer
        state is untouched. Different-N change (permanent join/leave):
        survivors' params/momentum/pipe state map through the change
        bit-exact, joiners bootstrap from the group mean (per-stage
        zero rules for wire state), the data shards follow the survivor
        map, and the lifecycle, transport links, controller and
        placement policy all re-bind to ``change.new_plan``. Either
        way the plan cache and jit trace are refreshed.
        """
        if change.old_n != self.cfg.n_peers:
            raise ValueError(
                f"change was planned for {change.old_n} peers, fleet "
                f"has {self.cfg.n_peers}")
        if change.same_n:
            # membership-preserving regroup (adaptive-M / placement)
            from repro.core.adaptive import validate_proposal
            n = self.cfg.n_peers
            validate_proposal(change.new_plan, n)
            # full-plan equality: a placement-only change (same dims,
            # new peer->slot permutation) is a real regroup too
            if change.new_plan == self.plan:
                return state
            self.plan = change.new_plan
            self._plan_cache.clear()
            self.pipeline = self.pipeline.with_plan(change.new_plan)
            pipe = self.pipeline.resize_state(state.pipe, n, n)
            self._it_fn = jax.jit(self._iteration,
                                  static_argnames=("use_kd",
                                                   "do_aggregate"))
            return dataclasses.replace(state, pipe=pipe)

        old_n, new_n = change.old_n, change.new_n
        k = len(change.survivors)
        params = change.apply_to_tree(state.params)
        momentum = change.apply_to_tree(state.momentum)
        # pipe state: survivor gather is a pure reindex; the joiner
        # bootstrap routes through the per-stage hooks (EF residuals
        # start at zero, DP bot markers reset)
        from repro.core.replan import select_survivors
        pipe = select_survivors(state.pipe, old_n, change.survivors)
        pipe = self.pipeline.resize_state(pipe, k, new_n)

        # per-peer data: survivors keep their shard; joiners draw theirs
        # from a new_n-way partition of the same training set
        self.data_x = select_survivors(self.data_x, old_n,
                                       change.survivors)
        self.data_y = select_survivors(self.data_y, old_n,
                                       change.survivors)
        if new_n > k:
            xs, ys = self._peer_shards(range(k, new_n), new_n,
                                       per_peer=self.data_x.shape[1])
            self.data_x = jnp.concatenate(
                [self.data_x, jnp.asarray(np.stack(xs))], axis=0)
            self.data_y = jnp.concatenate(
                [self.data_y, jnp.asarray(np.stack(ys))], axis=0)

        self.cfg = dataclasses.replace(self.cfg, n_peers=new_n)
        self.plan = change.new_plan
        self._plan_cache.clear()
        self.pipeline = self._build_pipeline(self.cfg, change.new_plan)
        if self.lifecycle.n_peers != new_n:
            self.lifecycle.resize(new_n)
        # survivors keep their modeled links (or, in address-book mode,
        # their fixed endpoints); joiners draw/bind fresh ones
        self.network.resize(new_n)
        if self.controller is not None:
            # new fleet, new candidate ladder — the controller re-anchors
            self.controller.rebind(change.new_plan)
        if self.placement_policy is not None:
            # stale link evidence and permutation sizes are dropped; the
            # policy re-learns/re-emits for the new fleet
            self.placement_policy.rebind(change.new_plan)
        # fresh jit cache: the old traces closed over the old data arrays
        self._it_fn = jax.jit(self._iteration,
                              static_argnames=("use_kd", "do_aggregate"))
        return dataclasses.replace(state, params=params,
                                   momentum=momentum, pipe=pipe)

    def resize(self, state: FederationState,
               new_n: int) -> FederationState:
        """Permanent join/leave — thin wrapper: plans the
        :class:`MembershipChange` (``elastic_replan`` grid, contiguous
        survivor prefix) and routes it through
        :meth:`apply_membership`. Surviving peers' state is untouched
        (bit-exact); joining peers bootstrap from the group mean."""
        if new_n == self.cfg.n_peers:
            return state
        return self.apply_membership(
            state, plan_membership_change(self.plan, new_n,
                                          iteration=state.iteration))

    # ------------------------------------------------------------------
    # placement probes (core/placement.py)
    # ------------------------------------------------------------------
    def _run_probe(self, mplan) -> Any:
        """Run a placement probe plan through the live transport and
        ledger its traffic under its own source. Probe rounds advance
        the transport's iteration counter (and thus the loss RNG
        stream) like any other traffic — they are real messages."""
        tr = self.network.run(mplan)
        self.ledger.record("placement_probe", tr.total_bytes)
        self.ledger.record_time(tr.iteration_s)
        return tr

    # ------------------------------------------------------------------
    # adaptive group sizing (same-N regroup, no membership change)
    # ------------------------------------------------------------------
    def regroup(self, state: FederationState,
                new_plan: GridPlan) -> FederationState:
        """Swap the MAR grid dims mid-run *without* touching membership
        — the adaptive-M hook (``core/adaptive.py``). Thin wrapper: a
        same-N :class:`MembershipChange` through
        :meth:`apply_membership` — the aggregation pipeline re-binds
        (:meth:`AggregationPipeline.with_plan`), peer state / data
        shards / links / lifecycle are untouched and survivor state is
        bit-exact; only the jit cache is refreshed (the old trace
        closed over the old pipeline).
        """
        return self.apply_membership(
            state, regroup_change(self.plan, new_plan,
                                  iteration=state.iteration))

    # ------------------------------------------------------------------
    # local update (vmapped Momentum-SGD over B minibatches)
    # ------------------------------------------------------------------
    def _local_update(self, params, momentum, rng):
        cfg = self.cfg

        def peer_update(p, m, x, y, key):
            def one_batch(carry, bkey):
                p, m = carry
                idx = jax.random.randint(bkey, (cfg.batch_size,), 0,
                                         x.shape[0])
                bx, by = x[idx], y[idx]

                def loss_fn(pp):
                    logits = self.apply_fn(pp, bx)
                    logp = jax.nn.log_softmax(logits)
                    return -jnp.mean(
                        jnp.take_along_axis(logp, by[:, None], 1))

                grads = jax.grad(loss_fn)(p)
                p, m = momentum_sgd_step(p, m, grads, cfg.lr, cfg.momentum)
                return (p, m), None

            keys = jax.random.split(key, cfg.local_batches)
            (p, m), _ = jax.lax.scan(one_batch, (p, m), keys)
            return p, m

        keys = jax.random.split(rng, cfg.n_peers)
        return jax.vmap(peer_update)(params, momentum, self.data_x,
                                     self.data_y, keys)

    # ------------------------------------------------------------------
    # one FL iteration (jitted): local update -> (MKD) -> pipeline
    # ------------------------------------------------------------------
    def _iteration(self, params, momentum, pipe, u_mask, a_mask, rng,
                   kd_lambda, use_kd: bool, do_aggregate: bool = True):
        k_local, k_kd, k_agg = jax.random.split(rng, 3)

        new_p, new_m = self._local_update(params, momentum, k_local)
        # Alg. 1 line 5: non-participants keep previous state
        sel = lambda new, old: jax.tree.map(
            lambda a, b: jnp.where(
                u_mask.reshape((-1,) + (1,) * (a.ndim - 1)) > 0, a, b),
            new, old)
        params, momentum = sel(new_p, params), sel(new_m, momentum)

        if use_kd:
            from repro.core.mkd import mkd_rounds
            params, momentum = mkd_rounds(
                self, params, momentum, a_mask, k_kd, kd_lambda)

        if not do_aggregate:
            return params, momentum, pipe
        out, pipe = self.pipeline({"p": params, "m": momentum}, pipe,
                                  a_mask, k_agg)
        return out["p"], out["m"], pipe

    # ------------------------------------------------------------------
    def _build_plan(self, a: np.ndarray, n_active: int,
                    iteration: int, use_kd: bool,
                    kd_logit_bytes: float) -> Any:
        """The iteration's transport plan, in the format the active
        transport negotiates (``Transport.plan_format``): symbolic
        recipes for ``super_sim``, array plans for ``vector_sim``,
        list plans for the heap/socket backends. Memoized on
        (grid, mask bytes, iteration parity, KD shape) — within a
        stable membership window every step rebuilds the identical
        plan, so the cache turns per-step planning time into a dict
        hit. ``regroup``/``resize`` invalidate."""
        fmt = getattr(self.network, "plan_format", "list")
        key = (id(self.plan), a.tobytes(), iteration % 2, fmt,
               use_kd, kd_logit_bytes, n_active)
        plan = self._plan_cache.get(key)
        if plan is not None:
            self.plan_cache_hits += 1
            return plan
        self.plan_cache_misses += 1
        if fmt == "super":
            build = self.pipeline.super_plan
        elif fmt == "array":
            build = self.pipeline.array_plan
        else:
            build = self.pipeline.message_plan
        plan = build(a, self.model_bytes, n_active, use_kd=use_kd,
                     kd_logit_bytes=kd_logit_bytes)
        if len(self._plan_cache) >= 8:   # parity x KD x mask drift
            self._plan_cache.clear()
        self._plan_cache[key] = plan
        return plan

    # ------------------------------------------------------------------
    def step(self, state: FederationState,
             masks: Optional[Tuple[np.ndarray, np.ndarray]] = None
             ) -> FederationState:
        if masks is not None:
            u, a = masks
        else:
            tick = self.lifecycle.tick(state.iteration)
            if tick.resize_to is not None:
                # permanent join/leave: one MembershipChange through the
                # unified entry point, then run the iteration with the
                # already-resized masks
                state = self.apply_membership(
                    state, plan_membership_change(
                        self.plan, tick.resize_to,
                        iteration=state.iteration))
            u, a = tick.u, tick.a
        cfg = self.cfg
        rng, it_rng = jax.random.split(state.rng)
        use_kd = cfg.use_kd and state.iteration < cfg.kd_iterations
        kd_lambda = max(0.0, 1.0 - state.iteration / max(cfg.kd_iterations, 1))

        # run this iteration's traffic *before* aggregating: the
        # transport backend (modeled links or real loopback sockets)
        # produces the transcript that feeds the ledger, and, under
        # loss, demotes peers whose sends were dropped mid-round to
        # receiver-only (paper §3.1 — they rejoin with the group mean).
        # MKD rounds ride the same plan, so distillation bytes cross
        # whichever transport is active.
        from repro.runtime.transport_base import demote_lost_senders
        n_active = int(a.sum())
        mplan = self._build_plan(
            np.asarray(a), n_active, state.iteration, use_kd,
            self._kd_logit_bytes() if use_kd else 0)
        payloads = None
        if self.network.wants_payloads:
            from repro.runtime.socket_transport import \
                encode_state_payloads
            payloads = encode_state_payloads(state.params)
        transcript = self.network.run(mplan, payloads=payloads)
        self.last_transcript = transcript
        a = demote_lost_senders(a, u, transcript)

        params, momentum, pipe = self._it_fn(
            state.params, state.momentum, state.pipe,
            jnp.asarray(u), jnp.asarray(a), it_rng,
            jnp.asarray(kd_lambda, jnp.float32), use_kd=use_kd)

        self.pipeline.record_transcript(
            self.ledger, transcript, n_active, self.model_bytes,
            use_kd=use_kd,
            kd_logit_bytes=self._kd_logit_bytes() if use_kd else 0)
        out = FederationState(params=params, momentum=momentum,
                              iteration=state.iteration + 1, rng=rng,
                              pipe=pipe, kd_lambda=kd_lambda)
        if self.controller is not None:
            # the controller sees every transcript — slow wireless
            # tails and churn-induced demotions (lost_senders) alike —
            # and its proposal regroups before the next iteration
            proposal = self.controller.observe(
                state.iteration, transcript, self.plan)
            if proposal is not None and proposal != self.plan:
                old_dims = tuple(self.plan.dims)
                out = self.apply_membership(
                    out, regroup_change(self.plan, proposal,
                                        iteration=state.iteration))
                self.regroup_log.append(
                    (state.iteration, old_dims, tuple(self.plan.dims)))
                if self.placement_policy is not None:
                    # dims changed: the policy re-emits its permutation
                    # for the new grid on its next observe
                    self.placement_policy.rebind(self.plan)
        if self.placement_policy is not None:
            target = self.placement_policy.observe(
                state.iteration, transcript, self.plan)
            if target is not None and target != self.plan:
                old = self.plan
                out = self.apply_membership(
                    out, regroup_change(self.plan, target,
                                        iteration=state.iteration))
                moved = int(np.sum(
                    old.slot_of(np.arange(old.n_peers))
                    != self.plan.slot_of(np.arange(old.n_peers))))
                self.placement_log.append((state.iteration, moved))
        return out

    def _kd_logit_bytes(self) -> int:
        # per teacher<->student exchange: logits on B local minibatches
        return (self.cfg.local_batches * self.cfg.batch_size
                * self.spec.num_classes * 4)

    # ------------------------------------------------------------------
    # evaluation
    # ------------------------------------------------------------------
    @functools.cached_property
    def _eval_fn(self):
        def acc(params, x, y):
            logits = self.apply_fn(params, x)
            return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
        return jax.jit(acc)

    def evaluate(self, state: FederationState, peer: int = 0) -> float:
        """Test accuracy of one peer's model (post-aggregation they agree
        under full participation)."""
        p = jax.tree.map(lambda x: x[peer], state.params)
        return float(self._eval_fn(p, self.test["x"], self.test["y"]))

    def evaluate_mean_model(self, state: FederationState) -> float:
        p = jax.tree.map(lambda x: jnp.mean(x, 0), state.params)
        return float(self._eval_fn(p, self.test["x"], self.test["y"]))

    def peer_disagreement(self, state: FederationState) -> float:
        """Per-parameter mean squared distance of peers to the global
        mean (Eq. 1 LHS): sum_i ||theta_i - theta-bar||^2 / (N * P)."""
        total, count = 0.0, 0
        for x in jax.tree.leaves(state.params):
            mean = jnp.mean(x, 0, keepdims=True)
            total += float(jnp.sum(jnp.square(x - mean)))
            count += x[0].size
        return total / max(self.cfg.n_peers * count, 1)


def run_federation(cfg: FederationConfig, iterations: int,
                   eval_every: int = 5,
                   verbose: bool = False,
                   lifecycle: Optional["PeerLifecycle"] = None
                   ) -> Dict[str, List[float]]:
    """Train and return the (accuracy, comm) history used by benchmarks.

    Churn scenarios (``cfg.churn``) and mid-run elastic resizes
    (``cfg.resize_schedule``) run through the peer lifecycle inside
    ``Federation.step``; the history tracks the live peer count and the
    cumulative membership-event count alongside the paper metrics.
    """
    fed = Federation(cfg, lifecycle=lifecycle)
    state = fed.init_state()
    hist = {"iteration": [], "accuracy": [], "comm_bytes": [],
            "sim_s": [], "disagreement": [], "n_peers": [], "events": [],
            "grid": [], "regroups": [], "placements": []}
    for t in range(iterations):
        state = fed.step(state)
        if (t + 1) % eval_every == 0 or t == iterations - 1:
            acc = fed.evaluate(state)
            hist["iteration"].append(t + 1)
            hist["accuracy"].append(acc)
            hist["comm_bytes"].append(fed.comm_bytes)
            hist["sim_s"].append(fed.sim_seconds)
            hist["disagreement"].append(fed.peer_disagreement(state))
            hist["n_peers"].append(fed.cfg.n_peers)
            hist["events"].append(len(fed.lifecycle.event_log))
            hist["grid"].append(tuple(fed.plan.dims))
            hist["regroups"].append(len(fed.regroup_log))
            hist["placements"].append(len(fed.placement_log))
            if verbose:
                print(f"  it={t+1:4d} acc={acc:.4f} "
                      f"comm={fed.comm_bytes/1e6:.1f}MB "
                      f"sim={fed.sim_seconds:.2f}s "
                      f"peers={fed.cfg.n_peers} "
                      f"grid={fed.plan.dims}")
    return hist
