"""The MAR-FL training loop (Alg. 1) and its baselines (sim backend).

Peers are the leading axis of every state pytree leaf; local updates are
vmapped Momentum-SGD; aggregation runs through one composable
:class:`~repro.core.aggregation.AggregationPipeline`:

* the **technique** picks the :class:`Aggregator` from the registry —
  ``mar`` (the paper), ``fedavg``, ``rdfl``, ``ar``, plus beyond-paper
  ``gossip`` and ``hierarchical``;
* **wire stages** compose around it from config flags — staleness-1
  async application, DP privatization (with optional secure aggregation
  of the clipping indicator), int8 error-feedback delta compression.
  Any stage combination is legal (DESIGN.md §6); e.g. compress + DP
  quantizes *after* noising, async + compress delays the quantized
  aggregate one iteration.

The exact-mean techniques produce the *same* global average under full
participation (paper Fig. 5 "qualitative identity"); they differ in
communication cost (``topology.py``, tracked per source by the
:class:`CommLedger`) and churn semantics. Partial participation and
dropout follow §3.1: U_t peers run local updates; A_t = U_t minus
dropouts joins aggregation; non-participants carry state forward
(Alg. 1 line 5).

One FL iteration is a single jitted function of (state, masks, rng);
the loop is host-side so benchmarks can interleave evaluation and
communication accounting.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import topology
from repro.core.aggregation import (TECHNIQUES, AggregationPipeline,
                                    CommLedger, build_pipeline)
from repro.core.moshpit import GridPlan, plan_grid
from repro.data.partition import dirichlet_partition, iid_partition
from repro.data.synthetic import classification_task
from repro.models.small import build_peer_model
from repro.optim.sgdm import momentum_sgd_init, momentum_sgd_step

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class FederationConfig:
    n_peers: int = 125
    technique: str = "mar"
    task: str = "text"               # vision | text
    # MAR grid: default plan_grid(n_peers) -> e.g. 125 = 5^3
    group_size: Optional[int] = None
    mar_rounds: Optional[int] = None  # None -> grid depth (exact)
    # local update (paper §3.1)
    local_batches: int = 1            # B in Alg. 1
    batch_size: int = 16              # 64 for vision, 16 for text per paper
    lr: float = 0.1
    momentum: float = 0.9
    # participation / churn
    participation_rate: float = 1.0
    dropout_rate: float = 0.0
    # data heterogeneity
    alpha: Optional[float] = 1.0      # Dirichlet; None -> iid
    # KD (Alg. 2/3)
    use_kd: bool = False
    kd_iterations: int = 6            # K
    kd_temperature: float = 3.0       # tau
    kd_selection_ratio: float = 0.4   # rho_l
    kd_epochs: int = 1                # E
    # DP wire stage (Alg. 4)
    use_dp: bool = False
    noise_multiplier: float = 0.3     # sigma_mult
    dp_clip_init: float = 1.0         # C_0
    use_secagg: bool = False          # pairwise-masked indicator (§A.2)
    # async wire stage: staleness-1 aggregation — the result computed at
    # iteration t is *applied* at t+1, so its collectives overlap the
    # next iteration's compute (delayed averaging; DESIGN.md §5)
    async_aggregation: bool = False
    # compression wire stage: int8 error-feedback delta compression on
    # the wire (core/compression.py) — 4x fewer bytes, bias-free in time
    compress: Optional[str] = None    # None | "int8_ef"
    seed: int = 0

    def grid(self) -> GridPlan:
        return plan_grid(self.n_peers, self.group_size)


@dataclasses.dataclass
class FederationState:
    params: PyTree                    # [N, ...] stacked peer params
    momentum: PyTree                  # [N, ...]
    iteration: int
    rng: Array
    # wire-stage state keyed by stage name: "dp" (clip bound, smoothed
    # deltas), "async" (pending aggregate), "int8_ef" (ref + EF residual)
    pipe: Dict[str, PyTree] = dataclasses.field(default_factory=dict)
    kd_lambda: float = 1.0

    # -- legacy accessors (pre-pipeline field names) --------------------
    @property
    def dp(self) -> Optional[Dict[str, PyTree]]:
        return self.pipe.get("dp")

    @property
    def pending(self) -> Optional[PyTree]:
        a = self.pipe.get("async")
        return a["pending"] if a else None

    @property
    def ref(self) -> Optional[PyTree]:
        c = self.pipe.get("int8_ef")
        return c["ref"] if c else None

    @property
    def ef_error(self) -> Optional[PyTree]:
        c = self.pipe.get("int8_ef")
        return c["err"] if c else None


class Federation:
    """Owns the task data, the jitted iteration fn, the aggregation
    pipeline, and the comm ledger."""

    def __init__(self, cfg: FederationConfig):
        if cfg.technique not in TECHNIQUES:
            raise ValueError(cfg.technique)
        self.cfg = cfg
        self.plan = cfg.grid()
        self.pipeline: AggregationPipeline = build_pipeline(
            cfg.technique, self.plan, num_rounds=cfg.mar_rounds,
            async_aggregation=cfg.async_aggregation,
            use_dp=cfg.use_dp, noise_multiplier=cfg.noise_multiplier,
            dp_clip_init=cfg.dp_clip_init, use_secagg=cfg.use_secagg,
            compress=cfg.compress)
        self.ledger = CommLedger()
        spec, train, test = classification_task(cfg.task, seed=cfg.seed)
        self.spec = spec
        self.test = {k: jnp.asarray(v) for k, v in test.items()}
        self.init_fn, self.apply_fn = build_peer_model(
            cfg.task, spec.feature_dim, spec.num_classes)

        # --- federated partition (rectangular per-peer arrays) ----------
        if cfg.alpha is None:
            shards = iid_partition(len(train["y"]), cfg.n_peers,
                                   seed=cfg.seed)
        else:
            shards = dirichlet_partition(train["y"], cfg.n_peers,
                                         alpha=cfg.alpha, seed=cfg.seed)
        rng = np.random.default_rng(cfg.seed + 1)
        per_peer = max(cfg.batch_size,
                       int(np.median([len(s) for s in shards])))
        xs, ys = [], []
        for s in shards:
            take = rng.choice(s, size=per_peer, replace=len(s) < per_peer)
            xs.append(train["x"][take])
            ys.append(train["y"][take])
        self.data_x = jnp.asarray(np.stack(xs))     # [N, P, D]
        self.data_y = jnp.asarray(np.stack(ys))     # [N, P]

        self.model_bytes = topology.pytree_bytes(
            self.init_fn(jax.random.PRNGKey(0))) * 2  # theta + momentum
        self._it_fn = jax.jit(self._iteration,
                              static_argnames=("use_kd", "do_aggregate"))

    @property
    def comm_bytes(self) -> float:
        """Total data-plane bytes so far (CommLedger-backed)."""
        return self.ledger.total_bytes

    # ------------------------------------------------------------------
    def init_state(self) -> FederationState:
        key = jax.random.PRNGKey(self.cfg.seed)
        params0 = self.init_fn(key)  # same theta^0 for every peer (Alg. 1)
        stack = lambda x: jnp.broadcast_to(
            x[None], (self.cfg.n_peers,) + x.shape)
        params = jax.tree.map(stack, params0)
        mom = momentum_sgd_init(params)
        pipe = self.pipeline.init_state({"p": params, "m": mom})
        return FederationState(params=params, momentum=mom, iteration=0,
                               rng=jax.random.PRNGKey(self.cfg.seed + 7),
                               pipe=pipe)

    # ------------------------------------------------------------------
    # masks
    # ------------------------------------------------------------------
    def sample_masks(self, rng: np.random.Generator
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """(participates U_t, aggregates A_t) boolean masks, float32."""
        n = self.cfg.n_peers
        u = rng.random(n) < self.cfg.participation_rate
        if not u.any():
            u[rng.integers(n)] = True
        drop = rng.random(n) < self.cfg.dropout_rate
        a = u & ~drop
        if not a.any():
            a[np.flatnonzero(u)[0]] = True
        return u.astype(np.float32), a.astype(np.float32)

    # ------------------------------------------------------------------
    # local update (vmapped Momentum-SGD over B minibatches)
    # ------------------------------------------------------------------
    def _local_update(self, params, momentum, rng):
        cfg = self.cfg

        def peer_update(p, m, x, y, key):
            def one_batch(carry, bkey):
                p, m = carry
                idx = jax.random.randint(bkey, (cfg.batch_size,), 0,
                                         x.shape[0])
                bx, by = x[idx], y[idx]

                def loss_fn(pp):
                    logits = self.apply_fn(pp, bx)
                    logp = jax.nn.log_softmax(logits)
                    return -jnp.mean(
                        jnp.take_along_axis(logp, by[:, None], 1))

                grads = jax.grad(loss_fn)(p)
                p, m = momentum_sgd_step(p, m, grads, cfg.lr, cfg.momentum)
                return (p, m), None

            keys = jax.random.split(key, cfg.local_batches)
            (p, m), _ = jax.lax.scan(one_batch, (p, m), keys)
            return p, m

        keys = jax.random.split(rng, cfg.n_peers)
        return jax.vmap(peer_update)(params, momentum, self.data_x,
                                     self.data_y, keys)

    # ------------------------------------------------------------------
    # one FL iteration (jitted): local update -> (MKD) -> pipeline
    # ------------------------------------------------------------------
    def _iteration(self, params, momentum, pipe, u_mask, a_mask, rng,
                   kd_lambda, use_kd: bool, do_aggregate: bool = True):
        k_local, k_kd, k_agg = jax.random.split(rng, 3)

        new_p, new_m = self._local_update(params, momentum, k_local)
        # Alg. 1 line 5: non-participants keep previous state
        sel = lambda new, old: jax.tree.map(
            lambda a, b: jnp.where(
                u_mask.reshape((-1,) + (1,) * (a.ndim - 1)) > 0, a, b),
            new, old)
        params, momentum = sel(new_p, params), sel(new_m, momentum)

        if use_kd:
            from repro.core.mkd import mkd_rounds
            params, momentum = mkd_rounds(
                self, params, momentum, a_mask, k_kd, kd_lambda)

        if not do_aggregate:
            return params, momentum, pipe
        out, pipe = self.pipeline({"p": params, "m": momentum}, pipe,
                                  a_mask, k_agg)
        return out["p"], out["m"], pipe

    # ------------------------------------------------------------------
    def step(self, state: FederationState,
             masks: Optional[Tuple[np.ndarray, np.ndarray]] = None
             ) -> FederationState:
        cfg = self.cfg
        host_rng = np.random.default_rng(cfg.seed * 100003 + state.iteration)
        u, a = masks if masks is not None else self.sample_masks(host_rng)
        rng, it_rng = jax.random.split(state.rng)
        use_kd = cfg.use_kd and state.iteration < cfg.kd_iterations
        kd_lambda = max(0.0, 1.0 - state.iteration / max(cfg.kd_iterations, 1))

        params, momentum, pipe = self._it_fn(
            state.params, state.momentum, state.pipe,
            jnp.asarray(u), jnp.asarray(a), it_rng,
            jnp.asarray(kd_lambda, jnp.float32), use_kd=use_kd)

        self.pipeline.record_iteration(
            self.ledger, int(a.sum()), self.model_bytes, use_kd=use_kd,
            kd_logit_bytes=self._kd_logit_bytes() if use_kd else 0)
        return FederationState(params=params, momentum=momentum,
                               iteration=state.iteration + 1, rng=rng,
                               pipe=pipe, kd_lambda=kd_lambda)

    def _kd_logit_bytes(self) -> int:
        # per teacher<->student exchange: logits on B local minibatches
        return (self.cfg.local_batches * self.cfg.batch_size
                * self.spec.num_classes * 4)

    # ------------------------------------------------------------------
    # evaluation
    # ------------------------------------------------------------------
    @functools.cached_property
    def _eval_fn(self):
        def acc(params, x, y):
            logits = self.apply_fn(params, x)
            return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
        return jax.jit(acc)

    def evaluate(self, state: FederationState, peer: int = 0) -> float:
        """Test accuracy of one peer's model (post-aggregation they agree
        under full participation)."""
        p = jax.tree.map(lambda x: x[peer], state.params)
        return float(self._eval_fn(p, self.test["x"], self.test["y"]))

    def evaluate_mean_model(self, state: FederationState) -> float:
        p = jax.tree.map(lambda x: jnp.mean(x, 0), state.params)
        return float(self._eval_fn(p, self.test["x"], self.test["y"]))

    def peer_disagreement(self, state: FederationState) -> float:
        """Per-parameter mean squared distance of peers to the global
        mean (Eq. 1 LHS): sum_i ||theta_i - theta-bar||^2 / (N * P)."""
        total, count = 0.0, 0
        for x in jax.tree.leaves(state.params):
            mean = jnp.mean(x, 0, keepdims=True)
            total += float(jnp.sum(jnp.square(x - mean)))
            count += x[0].size
        return total / max(self.cfg.n_peers * count, 1)


def run_federation(cfg: FederationConfig, iterations: int,
                   eval_every: int = 5,
                   verbose: bool = False) -> Dict[str, List[float]]:
    """Train and return the (accuracy, comm) history used by benchmarks."""
    fed = Federation(cfg)
    state = fed.init_state()
    hist = {"iteration": [], "accuracy": [], "comm_bytes": [],
            "disagreement": []}
    for t in range(iterations):
        state = fed.step(state)
        if (t + 1) % eval_every == 0 or t == iterations - 1:
            acc = fed.evaluate(state)
            hist["iteration"].append(t + 1)
            hist["accuracy"].append(acc)
            hist["comm_bytes"].append(fed.comm_bytes)
            hist["disagreement"].append(fed.peer_disagreement(state))
            if verbose:
                print(f"  it={t+1:4d} acc={acc:.4f} "
                      f"comm={fed.comm_bytes/1e6:.1f}MB")
    return hist
