"""The MAR-FL training loop (Alg. 1) and its baselines (sim backend).

Peers are the leading axis of every state pytree leaf; local updates are
vmapped Momentum-SGD; aggregation dispatches on ``technique``:

* ``mar``     — Moshpit All-Reduce over a :class:`GridPlan` (the paper)
* ``fedavg``  — client-server mean over participating peers
* ``rdfl``    — ring-decentralized FL (global mean; ring cost model)
* ``ar``      — naive all-to-all All-Reduce FL

All four produce the *same* global average under full participation
(paper Fig. 5 "qualitative identity"); they differ in communication cost
(``topology.py``) and churn semantics. Partial participation and dropout
follow §3.1: U_t peers run local updates; A_t = U_t minus dropouts joins
aggregation; non-participants carry state forward (Alg. 1 line 5).

One FL iteration is a single jitted function of (state, masks, rng);
the loop is host-side so benchmarks can interleave evaluation and
communication accounting.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mar_allreduce as mar
from repro.core import topology
from repro.core.moshpit import GridPlan, plan_grid
from repro.data.partition import dirichlet_partition, iid_partition
from repro.data.synthetic import classification_task
from repro.models.small import build_peer_model
from repro.optim.sgdm import momentum_sgd_init, momentum_sgd_step

Array = jax.Array
PyTree = Any

TECHNIQUES = ("mar", "fedavg", "rdfl", "ar")


@dataclasses.dataclass(frozen=True)
class FederationConfig:
    n_peers: int = 125
    technique: str = "mar"
    task: str = "text"               # vision | text
    # MAR grid: default plan_grid(n_peers) -> e.g. 125 = 5^3
    group_size: Optional[int] = None
    mar_rounds: Optional[int] = None  # None -> grid depth (exact)
    # local update (paper §3.1)
    local_batches: int = 1            # B in Alg. 1
    batch_size: int = 16              # 64 for vision, 16 for text per paper
    lr: float = 0.1
    momentum: float = 0.9
    # participation / churn
    participation_rate: float = 1.0
    dropout_rate: float = 0.0
    # data heterogeneity
    alpha: Optional[float] = 1.0      # Dirichlet; None -> iid
    # KD (Alg. 2/3)
    use_kd: bool = False
    kd_iterations: int = 6            # K
    kd_temperature: float = 3.0       # tau
    kd_selection_ratio: float = 0.4   # rho_l
    kd_epochs: int = 1                # E
    # DP (Alg. 4)
    use_dp: bool = False
    noise_multiplier: float = 0.3     # sigma_mult
    dp_clip_init: float = 1.0         # C_0
    use_secagg: bool = False          # pairwise-masked indicator (§A.2)
    # beyond-paper: staleness-1 aggregation — the MAR result computed at
    # iteration t is *applied* at t+1, so its collectives overlap the
    # next iteration's compute (async/delayed averaging; DESIGN.md §5)
    async_aggregation: bool = False
    # beyond-paper: int8 error-feedback delta compression on the wire
    # (core/compression.py) — 4x fewer MAR bytes, bias-free over time
    compress: Optional[str] = None    # None | "int8_ef"
    seed: int = 0

    def grid(self) -> GridPlan:
        return plan_grid(self.n_peers, self.group_size)


@dataclasses.dataclass
class FederationState:
    params: PyTree                    # [N, ...] stacked peer params
    momentum: PyTree                  # [N, ...]
    iteration: int
    rng: Array
    dp: Optional[Dict[str, PyTree]] = None   # see core/dp.py
    kd_lambda: float = 1.0
    pending: Optional[PyTree] = None  # staleness-1 aggregated state
    ref: Optional[PyTree] = None      # int8_ef shared reference point
    ef_error: Optional[PyTree] = None # int8_ef residual carry


class Federation:
    """Owns the task data, the jitted iteration fns, and the comm ledger."""

    def __init__(self, cfg: FederationConfig):
        if cfg.technique not in TECHNIQUES:
            raise ValueError(cfg.technique)
        self.cfg = cfg
        self.plan = cfg.grid()
        spec, train, test = classification_task(cfg.task, seed=cfg.seed)
        self.spec = spec
        self.test = {k: jnp.asarray(v) for k, v in test.items()}
        self.init_fn, self.apply_fn = build_peer_model(
            cfg.task, spec.feature_dim, spec.num_classes)

        # --- federated partition (rectangular per-peer arrays) ----------
        if cfg.alpha is None:
            shards = iid_partition(len(train["y"]), cfg.n_peers,
                                   seed=cfg.seed)
        else:
            shards = dirichlet_partition(train["y"], cfg.n_peers,
                                         alpha=cfg.alpha, seed=cfg.seed)
        rng = np.random.default_rng(cfg.seed + 1)
        per_peer = max(cfg.batch_size,
                       int(np.median([len(s) for s in shards])))
        xs, ys = [], []
        for s in shards:
            take = rng.choice(s, size=per_peer, replace=len(s) < per_peer)
            xs.append(train["x"][take])
            ys.append(train["y"][take])
        self.data_x = jnp.asarray(np.stack(xs))     # [N, P, D]
        self.data_y = jnp.asarray(np.stack(ys))     # [N, P]

        self.model_bytes = topology.pytree_bytes(
            self.init_fn(jax.random.PRNGKey(0))) * 2  # theta + momentum
        self.comm_bytes = 0.0
        self._it_fn = jax.jit(self._iteration,
                              static_argnames=("use_kd", "use_dp",
                                               "do_aggregate"))

    # ------------------------------------------------------------------
    def init_state(self) -> FederationState:
        key = jax.random.PRNGKey(self.cfg.seed)
        params0 = self.init_fn(key)  # same theta^0 for every peer (Alg. 1)
        stack = lambda x: jnp.broadcast_to(
            x[None], (self.cfg.n_peers,) + x.shape)
        params = jax.tree.map(stack, params0)
        mom = momentum_sgd_init(params)
        state = FederationState(params=params, momentum=mom, iteration=0,
                                rng=jax.random.PRNGKey(self.cfg.seed + 7))
        if self.cfg.use_dp:
            from repro.core.dp import dp_init
            state.dp = dp_init(params, self.cfg.dp_clip_init)
        if self.cfg.compress == "int8_ef":
            state.ref = jax.tree.map(
                lambda x: x.astype(jnp.float32), params)
        return state

    # ------------------------------------------------------------------
    # masks
    # ------------------------------------------------------------------
    def sample_masks(self, rng: np.random.Generator
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """(participates U_t, aggregates A_t) boolean masks, float32."""
        n = self.cfg.n_peers
        u = rng.random(n) < self.cfg.participation_rate
        if not u.any():
            u[rng.integers(n)] = True
        drop = rng.random(n) < self.cfg.dropout_rate
        a = u & ~drop
        if not a.any():
            a[np.flatnonzero(u)[0]] = True
        return u.astype(np.float32), a.astype(np.float32)

    # ------------------------------------------------------------------
    # local update (vmapped Momentum-SGD over B minibatches)
    # ------------------------------------------------------------------
    def _local_update(self, params, momentum, rng):
        cfg = self.cfg

        def peer_update(p, m, x, y, key):
            def one_batch(carry, bkey):
                p, m = carry
                idx = jax.random.randint(bkey, (cfg.batch_size,), 0,
                                         x.shape[0])
                bx, by = x[idx], y[idx]

                def loss_fn(pp):
                    logits = self.apply_fn(pp, bx)
                    logp = jax.nn.log_softmax(logits)
                    return -jnp.mean(
                        jnp.take_along_axis(logp, by[:, None], 1))

                grads = jax.grad(loss_fn)(p)
                p, m = momentum_sgd_step(p, m, grads, cfg.lr, cfg.momentum)
                return (p, m), None

            keys = jax.random.split(key, cfg.local_batches)
            (p, m), _ = jax.lax.scan(one_batch, (p, m), keys)
            return p, m

        keys = jax.random.split(rng, cfg.n_peers)
        return jax.vmap(peer_update)(params, momentum, self.data_x,
                                     self.data_y, keys)

    # ------------------------------------------------------------------
    # one FL iteration (jitted)
    # ------------------------------------------------------------------
    def _iteration(self, params, momentum, dp_state, u_mask, a_mask, rng,
                   kd_lambda, use_kd: bool, use_dp: bool,
                   do_aggregate: bool = True):
        cfg = self.cfg
        k_local, k_kd, k_dp = jax.random.split(rng, 3)

        new_p, new_m = self._local_update(params, momentum, k_local)
        # Alg. 1 line 5: non-participants keep previous state
        sel = lambda new, old: jax.tree.map(
            lambda a, b: jnp.where(
                u_mask.reshape((-1,) + (1,) * (a.ndim - 1)) > 0, a, b),
            new, old)
        params, momentum = sel(new_p, params), sel(new_m, momentum)

        if use_kd:
            from repro.core.mkd import mkd_rounds
            params, momentum = mkd_rounds(
                self, params, momentum, a_mask, k_kd, kd_lambda)

        if not do_aggregate:
            return params, momentum, dp_state
        if use_dp:
            from repro.core.dp import dp_aggregate
            params, momentum, dp_state = dp_aggregate(
                self, params, momentum, dp_state, a_mask, k_dp)
        else:
            state = {"p": params, "m": momentum}
            state = self._aggregate(state, a_mask)
            params, momentum = state["p"], state["m"]
        return params, momentum, dp_state

    def _aggregate(self, state: PyTree, a_mask: Array) -> PyTree:
        cfg = self.cfg
        if cfg.technique == "mar":
            return mar.mar_aggregate_sim(state, self.plan, a_mask,
                                         num_rounds=cfg.mar_rounds)
        if cfg.technique in ("fedavg", "ar"):
            return mar.allreduce_all_to_all_sim(state, a_mask)
        if cfg.technique == "rdfl":
            return mar.ring_allreduce_sim(state, a_mask)
        raise ValueError(cfg.technique)

    # ------------------------------------------------------------------
    def step(self, state: FederationState,
             masks: Optional[Tuple[np.ndarray, np.ndarray]] = None
             ) -> FederationState:
        cfg = self.cfg
        host_rng = np.random.default_rng(cfg.seed * 100003 + state.iteration)
        u, a = masks if masks is not None else self.sample_masks(host_rng)
        rng, it_rng = jax.random.split(state.rng)
        use_kd = cfg.use_kd and state.iteration < cfg.kd_iterations
        kd_lambda = max(0.0, 1.0 - state.iteration / max(cfg.kd_iterations, 1))

        if cfg.async_aggregation:
            return self._step_async(state, u, a, rng, it_rng, use_kd,
                                    kd_lambda)
        if cfg.compress == "int8_ef":
            return self._step_compressed(state, u, a, rng, it_rng,
                                         use_kd, kd_lambda)

        params, momentum, dp_state = self._it_fn(
            state.params, state.momentum, state.dp,
            jnp.asarray(u), jnp.asarray(a), it_rng,
            jnp.asarray(kd_lambda, jnp.float32),
            use_kd=use_kd, use_dp=cfg.use_dp)

        self.comm_bytes += topology.iteration_bytes(
            cfg.technique, int(a.sum()), self.model_bytes, self.plan,
            num_rounds=cfg.mar_rounds, use_kd=use_kd,
            kd_logit_bytes=self._kd_logit_bytes() if use_kd else 0)
        return FederationState(params=params, momentum=momentum,
                               iteration=state.iteration + 1, rng=rng,
                               dp=dp_state, kd_lambda=kd_lambda)

    # ------------------------------------------------------------------
    # staleness-1 aggregation (beyond-paper; DESIGN.md §5): the MAR
    # launched for iteration t's snapshot is applied at t+1 with a local
    # progress correction — x_{t+1} = agg(y_{t-1}) + (y_t - y_{t-1}) —
    # so on real hardware the collective overlaps iteration t+1's
    # compute instead of blocking iteration t.
    # ------------------------------------------------------------------
    def _step_async(self, state, u, a, rng, it_rng, use_kd, kd_lambda):
        cfg = self.cfg
        assert not cfg.use_dp, "async_aggregation + DP not supported"
        y_p, y_m, _ = self._it_fn(
            state.params, state.momentum, None,
            jnp.asarray(u), jnp.asarray(a), it_rng,
            jnp.asarray(kd_lambda, jnp.float32),
            use_kd=use_kd, use_dp=False, do_aggregate=False)

        if state.pending is not None:
            corr = lambda agg, y, snap: jax.tree.map(
                lambda ag, yy, sn: ag + (yy.astype(ag.dtype)
                                         - sn.astype(ag.dtype)),
                agg, y, snap)
            new_p = corr(state.pending["agg_p"], y_p,
                         state.pending["snap_p"])
            new_m = corr(state.pending["agg_m"], y_m,
                         state.pending["snap_m"])
        else:
            new_p, new_m = y_p, y_m

        agg = self._agg_fn({"p": y_p, "m": y_m}, jnp.asarray(a))
        self.comm_bytes += topology.iteration_bytes(
            cfg.technique, int(a.sum()), self.model_bytes, self.plan,
            num_rounds=cfg.mar_rounds)
        return FederationState(
            params=new_p, momentum=new_m,
            iteration=state.iteration + 1, rng=rng, dp=None,
            kd_lambda=kd_lambda,
            pending={"agg_p": agg["p"], "agg_m": agg["m"],
                     "snap_p": y_p, "snap_m": y_m})

    @functools.cached_property
    def _agg_fn(self):
        return jax.jit(self._aggregate)

    # ------------------------------------------------------------------
    # int8 error-feedback compressed aggregation (beyond-paper)
    # ------------------------------------------------------------------
    def _step_compressed(self, state, u, a, rng, it_rng, use_kd,
                         kd_lambda):
        cfg = self.cfg
        assert not cfg.use_dp, "compress + DP: quantize after noising TBD"
        y_p, y_m, _ = self._it_fn(
            state.params, state.momentum, None,
            jnp.asarray(u), jnp.asarray(a), it_rng,
            jnp.asarray(kd_lambda, jnp.float32),
            use_kd=use_kd, use_dp=False, do_aggregate=False)
        new_p, new_m, new_ref, new_err = self._compressed_agg_fn(
            y_p, y_m, state.ref, state.ef_error, jnp.asarray(a))
        from repro.core.compression import INT8_RATIO
        self.comm_bytes += topology.iteration_bytes(
            cfg.technique, int(a.sum()), self.model_bytes, self.plan,
            num_rounds=cfg.mar_rounds) / INT8_RATIO
        return FederationState(
            params=new_p, momentum=new_m,
            iteration=state.iteration + 1, rng=rng, dp=None,
            kd_lambda=kd_lambda, ref=new_ref, ef_error=new_err)

    @functools.cached_property
    def _compressed_agg_fn(self):
        from repro.core.compression import compressed_aggregate

        def fn(params, momentum, ref, error, a_mask):
            return compressed_aggregate(self._aggregate, params, momentum,
                                        ref, error, a_mask)

        return jax.jit(fn)

    def _kd_logit_bytes(self) -> int:
        # per teacher<->student exchange: logits on B local minibatches
        return (self.cfg.local_batches * self.cfg.batch_size
                * self.spec.num_classes * 4)

    # ------------------------------------------------------------------
    # evaluation
    # ------------------------------------------------------------------
    @functools.cached_property
    def _eval_fn(self):
        def acc(params, x, y):
            logits = self.apply_fn(params, x)
            return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
        return jax.jit(acc)

    def evaluate(self, state: FederationState, peer: int = 0) -> float:
        """Test accuracy of one peer's model (post-aggregation they agree
        under full participation)."""
        p = jax.tree.map(lambda x: x[peer], state.params)
        return float(self._eval_fn(p, self.test["x"], self.test["y"]))

    def evaluate_mean_model(self, state: FederationState) -> float:
        p = jax.tree.map(lambda x: jnp.mean(x, 0), state.params)
        return float(self._eval_fn(p, self.test["x"], self.test["y"]))

    def peer_disagreement(self, state: FederationState) -> float:
        """Mean squared distance of peers to the global mean (Eq. 1 LHS)."""
        leaves = jax.tree.leaves(state.params)
        total, count = 0.0, 0
        for x in leaves:
            mean = jnp.mean(x, 0, keepdims=True)
            total += float(jnp.sum(jnp.square(x - mean)))
            count += x[0].size
        return total / max(self.cfg.n_peers, 1)


def run_federation(cfg: FederationConfig, iterations: int,
                   eval_every: int = 5,
                   verbose: bool = False) -> Dict[str, List[float]]:
    """Train and return the (accuracy, comm) history used by benchmarks."""
    fed = Federation(cfg)
    state = fed.init_state()
    hist = {"iteration": [], "accuracy": [], "comm_bytes": [],
            "disagreement": []}
    for t in range(iterations):
        state = fed.step(state)
        if (t + 1) % eval_every == 0 or t == iterations - 1:
            acc = fed.evaluate(state)
            hist["iteration"].append(t + 1)
            hist["accuracy"].append(acc)
            hist["comm_bytes"].append(fed.comm_bytes)
            hist["disagreement"].append(fed.peer_disagreement(state))
            if verbose:
                print(f"  it={t+1:4d} acc={acc:.4f} "
                      f"comm={fed.comm_bytes/1e6:.1f}MB")
    return hist
