"""MAR-FL core: the paper's contribution as composable JAX modules."""
from repro.core.moshpit import GridPlan, plan_grid, mesh_grid_plan
from repro.core.federation import (Federation, FederationConfig,
                                   FederationState, run_federation)
from repro.core import mar_allreduce, topology, mixing
