"""Composable aggregation pipeline: Aggregator x WireStage x CommLedger.

This module is the strategy/wire/accounting spine of the FL system
(DESIGN.md §6). It decomposes one FL aggregation into three orthogonal
pieces so every technique x wire-transform x backend combination is a
*configuration*, not a fork of the step function:

* :class:`Aggregator` — *what* consensus is computed. A registry maps
  technique names (``mar``, ``fedavg``, ``ar``, ``rdfl``, ``gossip``,
  ``hierarchical``) to pure, jit-traceable callables
  ``(state, mask) -> state`` over peer-stacked pytrees. The MAR entry
  spans both execution backends (sim segment-means and the device
  mesh's grid-reshape collectives — ``mar_allreduce.py``).

* :class:`WireStage` — *how* the exchanged tensors are transformed on
  the wire. Stages wrap any aggregator (or another stage): int8
  error-feedback delta compression (:class:`Int8EFStage`), decentralized
  DP with adaptive clipping and optional secure aggregation of the
  clipping indicator (:class:`DPStage`), and staleness-1 delayed
  application (:class:`AsyncStage`). Stage state (EF residuals, DP
  clip bounds, pending aggregates) threads through the pipeline as one
  pytree, so the whole composition stays jittable. Combinations the
  old step-function forks asserted out — compress∘dp ("quantize after
  noising"), async∘compress — are now just stage lists.

* :class:`CommLedger` — *how many bytes (and simulated seconds)* moved.
  Every aggregator can unroll itself into a per-round message plan
  (:meth:`Aggregator.message_plan`, ``core/transport.py``); the
  discrete-event network layer (``runtime/network.py``) times those
  messages over modeled links and the resulting transcript feeds the
  ledger (:meth:`AggregationPipeline.record_transcript`). The analytic
  formulas in ``topology.py`` remain as cross-checked oracles — equal
  to the transcript in the no-loss case — and still drive the legacy
  :meth:`AggregationPipeline.record_iteration` path. Stages transform
  wire sizes either way (e.g. / ``INT8_RATIO``), so compression shrinks
  simulated transfer time, not just the byte total.

Canonical aggregation state is a dict ``{"p": params, "m": momentum}``
with peers on the leading axis of every leaf; stages may grow it with
extra keys (DP adds the smoothed delta ``"sd"`` and clipping indicator
``"b"``) that are averaged alongside and stripped before returning.

Stage order in a pipeline is outermost-first: ``[async, dp, int8_ef]``
means the staleness-1 schedule wraps DP privatization which wraps
quantized exchange — i.e. noising happens *before* quantization, and
both ride the delayed-application schedule.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import topology
from repro.core.moshpit import GridPlan
# the elastic-membership primitives live in core/replan.py (the
# MembershipChange contract, DESIGN.md §16); re-exported here for the
# historical import path
from repro.core.replan import resize_peer_axis  # noqa: F401
from repro.core.replan import resize_state_tree

Array = jax.Array
PyTree = Any
# inner pipeline callable: (agg_state, pipe_state) -> (agg_state, pipe_state)
InnerFn = Callable[[PyTree, Dict[str, PyTree]], Tuple[PyTree, Dict[str, PyTree]]]


# ---------------------------------------------------------------------------
# the shared masked-mean core
# ---------------------------------------------------------------------------

def finalize_masked_mean(num: Array, den: Array, own: Array,
                         floor: float = 1.0) -> Array:
    """Shared epilogue of every masked group mean in the system.

    ``num`` — masked sum (f32), ``den`` — masked contributor count (or
    push-sum weight), ``own`` — the value a peer keeps when its whole
    group dropped (churn semantics, paper §3.1). Broadcasts, so ``num``/
    ``den`` may carry keepdims group axes against a full-shape ``own``.
    Both the sim backend (segment sums) and the device backend (grid
    reshape + axis sums) reduce to this one mean-with-fallback; keeping
    it in one place keeps their churn semantics provably identical.
    ``floor`` guards the division — 1.0 for integer counts, small eps
    for fractional push-sum weights.
    """
    mean = num / jnp.maximum(den, floor)
    empty = (den == 0.0).astype(jnp.float32)
    return mean * (1.0 - empty) + own.astype(jnp.float32) * empty


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CommLedger:
    """Per-source communication accounting, replacing the ad-hoc
    ``topology.iteration_bytes`` calls that used to sit (and disagree)
    at every step-path call site.

    Since the discrete-event network layer (``runtime/network.py``),
    the ledger carries a time axis too: ``total_seconds`` accumulates
    simulated wall-clock from transport transcripts, so benchmarks can
    report *seconds* per technique alongside bytes.
    """

    total_bytes: float = 0.0
    total_seconds: float = 0.0
    by_source: Dict[str, float] = dataclasses.field(default_factory=dict)

    def record(self, source: str, nbytes: float) -> None:
        self.total_bytes += nbytes
        self.by_source[source] = self.by_source.get(source, 0.0) + nbytes

    def record_time(self, seconds: float) -> None:
        self.total_seconds += seconds

    def reset(self) -> None:
        self.total_bytes = 0.0
        self.total_seconds = 0.0
        self.by_source.clear()


# ---------------------------------------------------------------------------
# strategy layer: aggregators + registry
# ---------------------------------------------------------------------------

AGGREGATORS: Dict[str, Type["Aggregator"]] = {}


def register_aggregator(cls: Type["Aggregator"]) -> Type["Aggregator"]:
    AGGREGATORS[cls.name] = cls
    return cls


def make_aggregator(name: str, plan: GridPlan, **kwargs: Any) -> "Aggregator":
    if name not in AGGREGATORS:
        raise ValueError(
            f"unknown aggregation technique {name!r}; "
            f"registered: {sorted(AGGREGATORS)}")
    return AGGREGATORS[name](plan, **kwargs)


class Aggregator:
    """A consensus strategy: pure ``(state, mask) -> state`` plus its
    analytic byte cost. Subclasses set ``name`` (the registry key) and
    ``supports_device`` when the strategy lowers onto mesh collectives."""

    name: str = "?"
    supports_device: bool = False

    def __init__(self, plan: GridPlan, num_rounds: Optional[int] = None,
                 backend: str = "sim", one_shot: bool = False,
                 comm_dtype: Optional[str] = None,
                 use_kernel: bool = False):
        if backend not in ("sim", "device"):
            raise ValueError(backend)
        if backend == "device" and not self.supports_device:
            raise ValueError(f"{self.name!r} has no device backend")
        self.plan = plan
        self.num_rounds = num_rounds
        self.backend = backend
        self.one_shot = one_shot
        self.comm_dtype = comm_dtype
        self.use_kernel = use_kernel

    def __call__(self, state: PyTree, mask: Array) -> PyTree:
        raise NotImplementedError

    def iteration_bytes(self, n_active: int, model_bytes: int,
                        mask: Optional[Any] = None) -> float:
        """Analytic data-plane bytes for one aggregation (topology.py).

        The analytic model is the cross-checked *oracle* now — the
        ledger is fed from measured transport transcripts
        (:meth:`message_plan` + ``runtime/network.py``); ``mask`` makes
        the MAR entry exact per group under churn.
        """
        return topology.iteration_bytes(
            self.name, n_active, model_bytes, self.plan,
            num_rounds=self.num_rounds, mask=mask)

    def message_plan(self, mask: Optional[Any],
                     model_bytes: float) -> "Any":
        """Unroll one aggregation into per-round ``(src, dst, nbytes)``
        messages (``core/transport.py``) — who sends what to whom, the
        input the discrete-event network simulator times and drops."""
        from repro.core import transport
        return transport.build_message_plan(
            self.name, self.plan, mask, model_bytes,
            num_rounds=self.num_rounds)

    def kd_bytes(self, n_active: int, model_bytes: int,
                 kd_logit_bytes: int) -> float:
        """Extra bytes a KD-enabled iteration adds on this topology."""
        full = topology.iteration_bytes(
            self.name, n_active, model_bytes, self.plan,
            num_rounds=self.num_rounds, use_kd=True,
            kd_logit_bytes=kd_logit_bytes)
        return full - self.iteration_bytes(n_active, model_bytes)


@register_aggregator
class MarAggregator(Aggregator):
    """Moshpit All-Reduce over a :class:`GridPlan` (the paper).

    ``backend="sim"`` runs masked segment-means over the stacked peer
    axis; ``backend="device"`` reshapes the (sharded) peer axis onto the
    grid so XLA lowers each round to a replica-grouped all-reduce, with
    ``one_shot`` / ``comm_dtype`` as the beyond-paper perf knobs."""

    name = "mar"
    supports_device = True

    def __call__(self, state: PyTree, mask: Array) -> PyTree:
        from repro.core import mar_allreduce as mar
        if self.backend == "device":
            return mar.mar_aggregate_device(
                state, self.plan, mask, one_shot=self.one_shot,
                comm_dtype=self.comm_dtype)
        return mar.mar_aggregate_sim(state, self.plan, mask,
                                     num_rounds=self.num_rounds,
                                     use_kernel=self.use_kernel)


class _GlobalMeanAggregator(Aggregator):
    """Strategies whose fixed point is the masked global mean; they
    differ only in cost/latency models (topology.py) and churn story."""

    def __call__(self, state: PyTree, mask: Array) -> PyTree:
        from repro.core import mar_allreduce as mar
        return mar.allreduce_all_to_all_sim(state, mask)


@register_aggregator
class FedAvgAggregator(_GlobalMeanAggregator):
    """Client-server mean over participating peers: O(N) bytes, but a
    central rendezvous (the baseline MAR-FL removes)."""
    name = "fedavg"


@register_aggregator
class AllToAllAggregator(_GlobalMeanAggregator):
    """Naive all-to-all All-Reduce FL: O(N^2) bytes, 1 round."""
    name = "ar"


@register_aggregator
class RingAggregator(_GlobalMeanAggregator):
    """RDFL-style ring circulation: O(N^2) bytes, N-1 sequential hops."""
    name = "rdfl"


@register_aggregator
class HierarchicalAggregator(_GlobalMeanAggregator):
    """Two-tier FedAvg (beyond-paper): peers average within their leaf
    MAR group via a group leader, leaders average among themselves, and
    the result is broadcast back down. The fixed point equals the global
    masked mean; the cost model (2(N + #groups) model-units, 4 rounds)
    sits between fedavg and mar — see ``topology.py``."""
    name = "hierarchical"


@register_aggregator
class GossipAggregator(Aggregator):
    """Push-sum ring gossip with doubling shifts (beyond-paper).

    Round r averages each peer's (value, weight) pair with the peer
    ``2^r`` positions behind it on a fixed ring; ``num_rounds`` defaults
    to ceil(log2 N), after which every window covers the ring — exact
    global mean for power-of-two N under full participation, a
    weight-corrected approximation otherwise."""
    name = "gossip"

    def __init__(self, plan: GridPlan, num_rounds: Optional[int] = None,
                 **kwargs: Any):
        if num_rounds is None:
            # pin the default here so execution and byte accounting use
            # the same count: the ring covers all peers, active or not,
            # so rounds depend on total N (not on n_active under churn)
            num_rounds = max(1, int(np.ceil(np.log2(max(plan.n_peers,
                                                        2)))))
        super().__init__(plan, num_rounds=num_rounds, **kwargs)

    def __call__(self, state: PyTree, mask: Array) -> PyTree:
        from repro.core import mar_allreduce as mar
        return mar.gossip_aggregate_sim(state, mask,
                                        rounds=self.num_rounds)


#: registry-backed technique list (import-stable name for configs/tests)
TECHNIQUES: Tuple[str, ...] = tuple(AGGREGATORS)


# ---------------------------------------------------------------------------
# wire-stage layer
# ---------------------------------------------------------------------------

WIRE_STAGES: Dict[str, Type["WireStage"]] = {}


def register_stage(cls: Type["WireStage"]) -> Type["WireStage"]:
    WIRE_STAGES[cls.name] = cls
    return cls


class WireStage:
    """A composable transform around an aggregator (or another stage).

    ``apply`` receives the canonical agg state, the *whole* pipeline
    state dict (its own slice under ``self.name``), the participation
    mask and a stage-unique rng key; it must call ``inner`` exactly once
    and return (agg_state, pipe_state) with its own slice updated.
    ``transform_bytes`` maps the wrapped pipeline's wire bytes to this
    stage's (e.g. a compression ratio); identity by default.
    """

    name: str = "?"

    def init(self, template: PyTree) -> Optional[PyTree]:
        """Initial stage state for an agg-state template; None if
        stateless."""
        return None

    def apply(self, inner: InnerFn, state: PyTree,
              pipe_state: Dict[str, PyTree], mask: Array,
              rng: Array) -> Tuple[PyTree, Dict[str, PyTree]]:
        raise NotImplementedError

    def transform_bytes(self, inner_bytes: float, n_active: int,
                        model_bytes: int) -> float:
        return inner_bytes

    def resize_state(self, own: PyTree, old_n: int, new_n: int) -> PyTree:
        """Elastic membership: remap this stage's state to a new peer
        count (mean-bootstrap by default; stages whose state must start
        empty for new peers name those keys)."""
        return resize_state_tree(own, old_n, new_n)

    def with_plan(self, new_plan: GridPlan) -> "WireStage":
        """Same stage bound to a new grid (adaptive-M regroup). Most
        stages are grid-agnostic; plan-holding stages override."""
        return self


@register_stage
class Int8EFStage(WireStage):
    """int8 error-feedback delta compression (core/compression.py).

    Quantizes each peer's delta against the shared reference point,
    aggregates the dequantized deltas through the wrapped pipeline, and
    re-anchors: ref' = ref + agg(delta). The per-peer quantization
    residual carries into the next iteration (EF-SGD), so the bias
    cancels over time. Only the ``"p"`` entry is compressed — momentum
    (and any stage-added keys) travel exact in sim to isolate the theta
    quantization error; accounting discounts all wire bytes uniformly.
    """

    name = "int8_ef"

    def init(self, template: PyTree) -> PyTree:
        # err starts as zeros (not None) so the stage-state pytree
        # structure is stable across iterations — no retrace on the
        # second step, and checkpoints restore onto a fresh template
        ref = jax.tree.map(lambda x: x.astype(jnp.float32), template["p"])
        return {"ref": ref, "err": jax.tree.map(jnp.zeros_like, ref)}

    def apply(self, inner, state, pipe_state, mask, rng):
        from repro.core.compression import compress_tree
        own = pipe_state[self.name]
        ref = own["ref"]
        delta = jax.tree.map(lambda p, r: p.astype(jnp.float32) - r,
                             state["p"], ref)
        deq, new_err = compress_tree(delta, own["err"])
        out, pipe_state = inner({**state, "p": deq}, pipe_state)
        new_ref = jax.tree.map(lambda r, d: r + d, ref, out["p"])
        new_p = jax.tree.map(lambda nr, p: nr.astype(p.dtype),
                             new_ref, state["p"])
        return ({**out, "p": new_p},
                {**pipe_state, self.name: {"ref": new_ref, "err": new_err}})

    def transform_bytes(self, inner_bytes, n_active, model_bytes):
        from repro.core.compression import INT8_RATIO
        return inner_bytes / INT8_RATIO

    def resize_state(self, own, old_n, new_n):
        # a grown peer anchors at the mean reference but must not
        # inherit another peer's quantization residual
        return resize_state_tree(own, old_n, new_n, zero_keys=("err",))


@register_stage
class DPStage(WireStage):
    """Decentralized DP with adaptive clipping (paper Alg. 4; core/dp.py).

    Clips + noises each peer's local delta, lets the wrapped pipeline
    average the privatized models (plus the smoothed delta and — unless
    ``use_secagg`` routes it through pairwise-masked secure aggregation —
    the clipping indicator), then updates the shared clipping bound.
    Wire bytes are unchanged versus the plain path: the indicator is
    scalar-negligible and the smoothed delta rides the same exchange in
    the analytic model (DESIGN.md §6)."""

    name = "dp"

    def __init__(self, plan: GridPlan, noise_multiplier: float = 0.3,
                 clip_init: float = 1.0, use_secagg: bool = False):
        self.plan = plan
        self.noise_multiplier = noise_multiplier
        self.clip_init = clip_init
        self.use_secagg = use_secagg

    def init(self, template: PyTree) -> PyTree:
        from repro.core.dp import dp_init
        return dp_init(template["p"], self.clip_init)

    def apply(self, inner, state, pipe_state, mask, rng):
        from repro.core.dp import dp_transform
        carried: Dict[str, Any] = {}

        def aggregate_fn(agg_state):
            out, carried["pipe"] = inner(agg_state, pipe_state)
            return out

        out_state, new_dp = dp_transform(
            aggregate_fn, state, pipe_state[self.name], mask, rng,
            noise_multiplier=self.noise_multiplier, plan=self.plan,
            use_secagg=self.use_secagg)
        return out_state, {**carried["pipe"], self.name: new_dp}

    def resize_state(self, own, old_n, new_n):
        # has_delta is a bot marker: a new peer has no smoothed delta yet
        return resize_state_tree(own, old_n, new_n,
                                 zero_keys=("has_delta",))

    def with_plan(self, new_plan):
        # secagg pairwise masks pair within MAR groups — re-bind the grid
        return DPStage(new_plan, noise_multiplier=self.noise_multiplier,
                       clip_init=self.clip_init,
                       use_secagg=self.use_secagg)


@register_stage
class AsyncStage(WireStage):
    """Staleness-1 delayed application (beyond-paper; DESIGN.md §5).

    The aggregate launched for iteration t's snapshot is *applied* at
    t+1 with a local-progress correction —
    ``x_{t+1} = agg(y_{t-1}) + (y_t - y_{t-1})`` — so on real hardware
    the collective overlaps the next iteration's compute instead of
    blocking. Wraps any inner pipeline: whatever the wrapped stages
    produce for snapshot t is what gets applied at t+1."""

    name = "async"

    def init(self, template: PyTree) -> PyTree:
        # zeros placeholders + a has-pending flag keep the stage-state
        # pytree structure identical on every iteration (single jit
        # trace, checkpoint-stable) — same rationale as Int8EFStage
        zeros = jax.tree.map(jnp.zeros_like, template)
        return {"pending": {"agg": zeros, "snap": zeros},
                "has": jnp.zeros((), jnp.float32)}

    def apply(self, inner, state, pipe_state, mask, rng):
        agg_out, pipe_state = inner(state, pipe_state)
        own = pipe_state[self.name]
        pending = own["pending"]
        # first iteration (has=0): no pending aggregate — pass through
        out = jax.tree.map(
            lambda ag, y, sn: jnp.where(
                own["has"] > 0,
                (ag + (y.astype(ag.dtype)
                       - sn.astype(ag.dtype))).astype(y.dtype),
                y),
            pending["agg"], state, pending["snap"])
        new_own = {"pending": {"agg": agg_out, "snap": state},
                   "has": jnp.ones((), jnp.float32)}
        return out, {**pipe_state, self.name: new_own}


# ---------------------------------------------------------------------------
# the pipeline
# ---------------------------------------------------------------------------

class AggregationPipeline:
    """An aggregator wrapped by zero or more wire stages.

    Pure and jit-traceable: ``pipeline(state, pipe_state, mask, rng)``
    returns the aggregated state plus updated stage states. Stage order
    is outermost-first. Byte accounting mirrors the execution nesting:
    the aggregator's analytic bytes pass inner-to-outer through each
    stage's ``transform_bytes``.
    """

    def __init__(self, aggregator: Aggregator,
                 stages: Sequence[WireStage] = ()):
        self.aggregator = aggregator
        self.stages = tuple(stages)
        names = [s.name for s in self.stages]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate wire stages: {names}")

    @property
    def stage_names(self) -> Tuple[str, ...]:
        return tuple(s.name for s in self.stages)

    def init_state(self, template: PyTree) -> Dict[str, PyTree]:
        out: Dict[str, PyTree] = {}
        for stage in self.stages:
            st = stage.init(template)
            if st is not None:
                out[stage.name] = st
        return out

    def resize_state(self, pipe_state: Dict[str, PyTree], old_n: int,
                     new_n: int) -> Dict[str, PyTree]:
        """Elastic membership: each stage remaps its own state slice."""
        out = dict(pipe_state)
        for stage in self.stages:
            if stage.name in out:
                out[stage.name] = stage.resize_state(out[stage.name],
                                                     old_n, new_n)
        return out

    def with_plan(self, new_plan: GridPlan) -> "AggregationPipeline":
        """Same pipeline over a new grid — the adaptive-M regroup
        primitive (``core/adaptive.py``). The aggregator is rebuilt for
        the new dims with its configuration preserved; stages re-bind
        where they hold the plan (DP/secagg pairing) and pass through
        otherwise. Peer-axis state is untouched: a same-N regroup maps
        pipe state through :meth:`resize_state` with ``old_n ==
        new_n``, which is the identity — survivor state stays
        bit-exact.
        """
        a = self.aggregator
        agg = type(a)(new_plan, num_rounds=a.num_rounds,
                      backend=a.backend, one_shot=a.one_shot,
                      comm_dtype=a.comm_dtype, use_kernel=a.use_kernel)
        return AggregationPipeline(
            agg, [s.with_plan(new_plan) for s in self.stages])

    def __call__(self, state: PyTree, pipe_state: Dict[str, PyTree],
                 mask: Array, rng: Array
                 ) -> Tuple[PyTree, Dict[str, PyTree]]:
        def run(i: int, state: PyTree, pipe_state: Dict[str, PyTree]):
            if i == len(self.stages):
                return self.aggregator(state, mask), pipe_state
            inner = lambda s, ps: run(i + 1, s, ps)  # noqa: E731
            return self.stages[i].apply(inner, state, pipe_state, mask,
                                        jax.random.fold_in(rng, i))
        return run(0, state, pipe_state)

    # -- accounting -----------------------------------------------------
    def iteration_bytes(self, n_active: int, model_bytes: int,
                        mask: Optional[Any] = None) -> float:
        """Wire bytes of one aggregation after all stage transforms."""
        b = self.aggregator.iteration_bytes(n_active, model_bytes, mask)
        for stage in reversed(self.stages):      # inner-to-outer
            b = stage.transform_bytes(b, n_active, model_bytes)
        return b

    def wire_model_bytes(self, model_bytes: float,
                         n_active: int) -> float:
        """Per-message wire size of one state transfer after stage
        transforms (e.g. / ``INT8_RATIO``) — the ``nbytes`` messages
        carry in a transport plan, so compression shrinks simulated
        transfer *time*, not just the ledger's byte total."""
        b = float(model_bytes)
        for stage in reversed(self.stages):      # inner-to-outer
            b = stage.transform_bytes(b, n_active, model_bytes)
        return b

    def message_plan(self, mask: Optional[Any], model_bytes: float,
                     n_active: int, use_kd: bool = False,
                     kd_logit_bytes: float = 0) -> Any:
        """The aggregator's message plan at post-stage wire sizes.

        With ``use_kd`` the iteration's MKD rounds (teacher pulls +
        logit exchanges over the same MAR groups) are prepended at
        *raw* sizes — distillation doesn't ride the compressed delta
        wire format — so KD bytes move (and, on real transports, are
        transmitted) through whichever backend is active instead of
        being analytic add-ons.
        """
        mp = self.aggregator.message_plan(
            mask, self.wire_model_bytes(model_bytes, n_active))
        if use_kd and self.aggregator.name == "mar":
            from repro.core import transport
            mp = transport.with_mkd_traffic(
                mp, self.aggregator.plan, mask, model_bytes,
                kd_logit_bytes, num_rounds=self.aggregator.num_rounds)
        return mp

    def array_plan(self, mask: Optional[Any], model_bytes: float,
                   n_active: int, use_kd: bool = False,
                   kd_logit_bytes: float = 0) -> Any:
        """:meth:`message_plan` in array form — same messages, same
        order, no per-message Python objects. What ``plan_format ==
        "array"`` transports (``vector_sim``) consume directly."""
        from repro.core import transport
        ap = transport.build_array_plan(
            self.aggregator.name, self.aggregator.plan, mask,
            self.wire_model_bytes(model_bytes, n_active),
            num_rounds=self.aggregator.num_rounds)
        if use_kd and self.aggregator.name == "mar":
            ap = transport.with_mkd_traffic_arrays(
                ap, self.aggregator.plan, mask, model_bytes,
                kd_logit_bytes, num_rounds=self.aggregator.num_rounds)
        return ap

    def super_plan(self, mask: Optional[Any], model_bytes: float,
                   n_active: int, use_kd: bool = False,
                   kd_logit_bytes: float = 0) -> Any:
        """Symbolic :meth:`message_plan` — the frozen recipe
        ``plan_format == "super"`` transports (``super_sim``) split
        into closed-form and materialized tiers. Wire sizes go through
        the same stage transforms; MKD rounds ride at raw model bytes,
        exactly as in the list/array builders."""
        from repro.core import transport
        return transport.build_super_plan(
            self.aggregator.name, self.aggregator.plan, mask,
            self.wire_model_bytes(model_bytes, n_active),
            num_rounds=self.aggregator.num_rounds,
            use_kd=use_kd and self.aggregator.name == "mar",
            raw_model_bytes=model_bytes,
            kd_logit_bytes=kd_logit_bytes)

    def record_transcript(self, ledger: CommLedger, transcript: Any,
                          n_active: int, model_bytes: int,
                          use_kd: bool = False,
                          kd_logit_bytes: int = 0) -> float:
        """Record one FL iteration from a measured transport transcript
        (``runtime/transport_base.py``) — bytes as transmitted (lost
        messages consumed airtime and are billed) plus seconds. KD
        traffic is split out of the transcript via the plan's MKD
        prefix rounds (``Transcript.kd_bytes``); the analytic KD add-on
        remains only as a fallback for transcripts of plans built
        without :meth:`message_plan`'s ``use_kd`` path."""
        kd_measured = getattr(transcript, "kd_bytes", 0.0)
        ledger.record(f"agg/{self.aggregator.name}",
                      transcript.total_bytes - kd_measured)
        ledger.record_time(transcript.iteration_s)
        total = transcript.total_bytes
        if kd_measured:
            ledger.record("kd", kd_measured)
        elif use_kd:
            kd = self.aggregator.kd_bytes(n_active, model_bytes,
                                          kd_logit_bytes)
            if kd:
                ledger.record("kd", kd)
                total += kd
        return total

    def record_iteration(self, ledger: CommLedger, n_active: int,
                         model_bytes: int, use_kd: bool = False,
                         kd_logit_bytes: int = 0,
                         mask: Optional[Any] = None) -> float:
        """Record one FL iteration's *analytic* bytes (legacy path for
        callers without a network sim); returns the total recorded.

        KD traffic (teacher-model pulls + logits, MKD) is recorded
        separately and untransformed — distillation exchanges don't ride
        the compressed delta wire format.
        """
        data = self.iteration_bytes(n_active, model_bytes, mask)
        ledger.record(f"agg/{self.aggregator.name}", data)
        total = data
        if use_kd:
            kd = self.aggregator.kd_bytes(n_active, model_bytes,
                                          kd_logit_bytes)
            if kd:
                ledger.record("kd", kd)
                total += kd
        return total


def build_pipeline(technique: str, plan: GridPlan, *,
                   num_rounds: Optional[int] = None,
                   backend: str = "sim",
                   one_shot: bool = False,
                   comm_dtype: Optional[str] = None,
                   use_kernel: bool = False,
                   async_aggregation: bool = False,
                   use_dp: bool = False,
                   noise_multiplier: float = 0.3,
                   dp_clip_init: float = 1.0,
                   use_secagg: bool = False,
                   compress: Optional[str] = None) -> AggregationPipeline:
    """Config-driven pipeline assembly (the one place that fixes stage
    order): async wraps DP wraps compression wraps the aggregator, so
    noising precedes quantization and both ride the delayed schedule."""
    aggregator = make_aggregator(technique, plan, num_rounds=num_rounds,
                                 backend=backend, one_shot=one_shot,
                                 comm_dtype=comm_dtype,
                                 use_kernel=use_kernel)
    stages: List[WireStage] = []
    if async_aggregation:
        stages.append(AsyncStage())
    if use_dp:
        stages.append(DPStage(plan, noise_multiplier=noise_multiplier,
                              clip_init=dp_clip_init,
                              use_secagg=use_secagg))
    if compress is not None:
        if compress != "int8_ef":
            raise ValueError(f"unknown compression {compress!r}")
        stages.append(Int8EFStage())
    return AggregationPipeline(aggregator, stages)
