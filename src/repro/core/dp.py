"""Fully decentralized DP with adaptive clipping (paper Alg. 4, §A.2).

DP-FedAvg with adaptive clipping (Andrew et al., 2021) made serverless:
each peer clips + noises its *local delta* against its last-known global
model, smooths it (beta), derives a DP-safe local model, and lets MAR
average privatized models. The clipping bound tracks a target quantile
``gamma`` of the *globally averaged* (noised) clipping indicator.

State per peer (leading peer axis):
  last_global   — theta-bar_i^{t-1}, the peer's last aggregated model
  smooth_delta  — Delta-bar_i^{t-1}  (bot encoded as has_delta = 0)
plus the shared scalar clipping bound C_t.

Noise calibration (Alg. 4 lines 1-3, with the paper's average-vs-sum
rescales): sigma_b = n_t / 20;  z_Delta = (sigma_mult^-2 - (2 sigma_b)^-2)^-1/2;
sigma_Delta = z_Delta * C_t; per-peer delta noise has variance
sigma_Delta^2 / n_t; the averaged indicator gets N(0, sigma_b^2) / n_t.

Privacy loss is estimated with Renyi-DP composition for the Gaussian
mechanism (Mironov, 2017) in :func:`epsilon_estimate`.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
PyTree = Any

BETA = 0.9       # delta smoothing (paper §A.2)
ETA_U = 0.1      # server-lr analogue
GAMMA = 0.5      # target clipping quantile
ETA_C = 0.2      # clipping-bound stepsize


def dp_init(params: PyTree, clip_init: float) -> Dict[str, PyTree]:
    zeros = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)
    n = jax.tree.leaves(params)[0].shape[0]
    return {
        "last_global": jax.tree.map(
            lambda x: x.astype(jnp.float32), params),
        "smooth_delta": zeros,
        "has_delta": jnp.zeros((n,), jnp.float32),      # bot marker
        "clip": jnp.asarray(clip_init, jnp.float32),
    }


def _global_norm(tree: PyTree, axis0: bool = True) -> Array:
    """Per-peer l2 norm over all leaves (leading axis = peers)."""
    sq = [jnp.sum(jnp.square(x.astype(jnp.float32)),
                  axis=tuple(range(1, x.ndim))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(sq))


def dp_transform(aggregate_fn, state: PyTree, dp_state: Dict[str, PyTree],
                 a_mask: Array, rng: Array, *, noise_multiplier: float,
                 plan=None, use_secagg: bool = False
                 ) -> Tuple[PyTree, Dict[str, PyTree]]:
    """Alg. 4 as a wire transform around any aggregation.

    ``aggregate_fn`` is the wrapped pipeline's ``(agg_state) ->
    agg_state`` (in the composable architecture this is the inner
    pipeline — see :class:`~repro.core.aggregation.DPStage`); ``state``
    is the canonical ``{"p": params, "m": momentum}`` dict. Returns the
    privatized, aggregated state (extra keys stripped) and the new DP
    state. ``plan`` (a :class:`GridPlan`) is only needed when
    ``use_secagg`` routes the clipping indicator through
    pairwise-masked secure aggregation.
    """
    params, momentum = state["p"], state["m"]
    n_t = jnp.maximum(jnp.sum(a_mask), 1.0)
    c_t = dp_state["clip"]

    # lines 1-3: noise calibration
    sigma_b = n_t / 20.0
    z_delta = (noise_multiplier ** -2
               - (2.0 * sigma_b) ** -2) ** -0.5
    sigma_delta = z_delta * c_t

    # line 4: local delta vs last-known global model
    delta = jax.tree.map(
        lambda p, g: p.astype(jnp.float32) - g,
        params, dp_state["last_global"])

    # line 5: clipping indicator
    norms = _global_norm(delta)                          # [N]
    b_ind = (norms <= c_t).astype(jnp.float32)

    # line 6: clip + noise
    scale = jnp.minimum(1.0, c_t / jnp.maximum(norms, 1e-12))
    keys = list(jax.random.split(rng, len(jax.tree.leaves(delta))))
    noise_std = sigma_delta / jnp.sqrt(n_t)

    def clip_noise(x, k):
        s = scale.reshape((-1,) + (1,) * (x.ndim - 1))
        return x * s + noise_std * jax.random.normal(k, x.shape, jnp.float32)

    leaves, treedef = jax.tree.flatten(delta)
    tilde = jax.tree.unflatten(
        treedef, [clip_noise(x, k) for x, k in zip(leaves, keys)])

    # line 7: smoothing (bot -> take tilde directly)
    has = dp_state["has_delta"]
    smooth = jax.tree.map(
        lambda sd, td: jnp.where(
            has.reshape((-1,) + (1,) * (td.ndim - 1)) > 0,
            BETA * sd + td, td),
        dp_state["smooth_delta"], tilde)

    # line 8: DP-safe local model
    theta_hat = jax.tree.map(
        lambda g, sd: g + ETA_U * sd, dp_state["last_global"], smooth)

    # lines 10-15: aggregate (theta_hat, momentum, b, smooth_delta).
    # The binary indicator leaks whether a peer clipped, so with
    # use_secagg it travels through pairwise-masked secure aggregation
    # (core/secagg.py; paper §A.2) instead of the plain group mean.
    agg_state = {**state, "p": theta_hat, "sd": smooth}
    if use_secagg:
        from repro.core.secagg import secure_indicator_average
        assert plan is not None, "use_secagg needs a GridPlan"
        b_bar = secure_indicator_average(
            b_ind, plan, jax.random.fold_in(rng, 777),
            t=0, alive=a_mask)
        agg_state = aggregate_fn(agg_state)
    else:
        agg_state["b"] = b_ind
        agg_state = aggregate_fn(agg_state)
        b_bar = agg_state["b"]                           # [N] per-peer view

    new_params = jax.tree.map(
        lambda x, p: x.astype(p.dtype), agg_state["p"], params)
    new_m = agg_state["m"]

    # lines 16-17: noised indicator average -> clipping-bound update.
    # b_bar is already the group/global average; one more shared noise draw
    k_b = jax.random.fold_in(rng, 12345)
    b_tilde = jnp.mean(b_bar) + jax.random.normal(k_b, (), jnp.float32) \
        * sigma_b / n_t
    new_clip = c_t * jnp.exp(-ETA_C * (b_tilde - GAMMA))

    # participants update their last-global / smoothed-delta records
    am = lambda x: a_mask.reshape((-1,) + (1,) * (x.ndim - 1))
    new_last = jax.tree.map(
        lambda old, new: jnp.where(am(old) > 0, new.astype(jnp.float32), old),
        dp_state["last_global"], agg_state["p"])
    new_sd = jax.tree.map(
        lambda old, new: jnp.where(am(old) > 0, new, old),
        dp_state["smooth_delta"], agg_state["sd"])
    new_has = jnp.maximum(has, a_mask)

    out = {k: v for k, v in agg_state.items() if k not in ("sd", "b")}
    out["p"], out["m"] = new_params, new_m
    return out, {
        "last_global": new_last, "smooth_delta": new_sd,
        "has_delta": new_has, "clip": new_clip,
    }


# ---------------------------------------------------------------------------
# Privacy accounting (Renyi DP, Gaussian mechanism, q = sampling rate)
# ---------------------------------------------------------------------------

def epsilon_estimate(iterations: int, noise_multiplier: float,
                     delta: float = 1e-5, sampling_rate: float = 1.0
                     ) -> float:
    """(eps, delta)-DP upper estimate via RDP composition.

    For q = 1 the Gaussian mechanism has RDP(alpha) = alpha / (2 z^2);
    for q < 1 we use the standard subsampling bound
    RDP(alpha) <= q^2 * alpha / z^2 (valid for the alpha range used).
    eps = min_alpha [ T * RDP(alpha) + log(1/delta) / (alpha - 1) ].
    """
    z = noise_multiplier
    if z <= 0:
        return float("inf")
    best = float("inf")
    for alpha in [1.5, 2, 3, 4, 6, 8, 16, 32, 64, 128, 256]:
        if sampling_rate >= 1.0:
            rdp = alpha / (2.0 * z * z)
        else:
            rdp = (sampling_rate ** 2) * alpha / (z * z)
        eps = iterations * rdp + math.log(1.0 / delta) / (alpha - 1.0)
        best = min(best, eps)
    return best
