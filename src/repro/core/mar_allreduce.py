"""Moshpit All-Reduce execution: group means over the MAR grid.

Two backends with identical math (property-tested against each other):

* **sim** — peers stacked on a leading axis ``[N, ...]`` of every pytree
  leaf; one MAR round is a masked segment-mean over that axis grouped by
  the round's group key. Supports arbitrary N, per-peer participation
  masks (churn), and runs fully vectorized under jit/vmap. This is the
  backend for the paper-scale experiments (N = 16/64/125).

* **device** — peers are slices of the production mesh's DP axes
  (``pod`` x ``data``); the leading peer axis is *sharded* over those
  axes and one MAR round is a reshape-to-grid + masked mean + broadcast,
  constrained so XLA GSPMD lowers it to a partial all-reduce whose
  replica groups are exactly the paper's MAR groups. ``one_shot=True``
  replaces the d-round schedule with a single full-mean all-reduce —
  the beyond-paper variant measured in EXPERIMENTS.md §Perf.

Churn semantics (paper §3.1): a dropped peer contributes neither to the
numerator nor to the denominator of its group mean, but *receives* the
group mean (it rejoins with the averaged model next iteration). An empty
group keeps its previous state.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import finalize_masked_mean
from repro.core.moshpit import GridPlan

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# sim backend
# ---------------------------------------------------------------------------

def _segment_mean(x: Array, seg_ids: Array, num_groups: int,
                  mask: Array) -> Array:
    """Masked per-group mean, scattered back to peers.

    x: [N, ...]; seg_ids: [N] int32 group ids; mask: [N] (0/1 float).
    Returns [N, ...] where peer i holds mean over its group's active peers
    (or its own value if the whole group dropped).
    """
    mshape = (-1,) + (1,) * (x.ndim - 1)
    m = mask.reshape(mshape).astype(jnp.float32)
    sums = jax.ops.segment_sum(x.astype(jnp.float32) * m, seg_ids,
                               num_segments=num_groups)
    cnts = jax.ops.segment_sum(mask.astype(jnp.float32), seg_ids,
                               num_segments=num_groups)
    cnt_per_peer = cnts[seg_ids].reshape(mshape)
    return finalize_masked_mean(sums[seg_ids], cnt_per_peer,
                                x).astype(x.dtype)


def _kernel_round(state: PyTree, plan: GridPlan, rnd: int,
                  mask: Array) -> PyTree:
    """The Pallas ``group_mean`` path for one MAR round.

    Permutes peers into round-``rnd`` group order, flattens each leaf to
    [G, M, D] tiles, and runs the fused masked-mean kernel
    (``kernels/group_mean.py`` — one VMEM pass instead of the four
    materialized intermediates of the segment-sum path). Gather/scatter
    indices are host-side numpy on the *static* plan, so the whole round
    stays jit-traceable. Exact math parity with ``_segment_mean`` is
    pinned by ``tests/test_aggregation.py``.
    """
    from repro.kernels.ops import group_mean

    n, cap, m = plan.n_peers, plan.capacity, plan.dims[rnd]
    keys = plan.group_key(np.arange(cap), rnd)
    order = np.argsort(keys, kind="stable")          # [cap] peers by group
    inv = np.argsort(order)
    g = cap // m
    if cap == n:
        mask_g = mask[order].reshape(g, m)
    else:
        mask_g = jnp.concatenate(
            [mask, jnp.zeros((cap - n,), mask.dtype)])[order].reshape(g, m)

    def leaf(x):
        tail = x.shape[1:]
        d = max(1, int(np.prod(tail)))
        xf = x.reshape(n, d)
        if cap != n:
            xf = jnp.concatenate(
                [xf, jnp.zeros((cap - n, d), x.dtype)], axis=0)
        out = group_mean(xf[order].reshape(g, m, d), mask_g)
        return out.reshape(cap, d)[inv][:n].reshape((n,) + tail)

    return jax.tree.map(leaf, state)


def mar_round_sim(state: PyTree, plan: GridPlan, rnd: int,
                  mask: Optional[Array] = None,
                  use_kernel: bool = False) -> PyTree:
    """One MAR round over the leading peer axis (sim backend).

    ``state`` leaves: [N, ...] with N == plan.n_peers. Virtual slots
    (capacity > N) are handled by embedding into capacity internally.
    ``use_kernel`` routes the masked group mean through the fused Pallas
    kernel (jnp segment-sum otherwise — identical semantics).
    """
    n = plan.n_peers
    cap = plan.capacity
    if mask is None:
        mask = jnp.ones((n,), jnp.float32)
    if use_kernel:
        return _kernel_round(state, plan, rnd, mask)
    seg = jnp.asarray(plan.group_key(np.arange(cap), rnd), jnp.int32)
    num_groups = cap // plan.dims[rnd]

    if cap == n:
        def leaf(x):
            return _segment_mean(x, seg, num_groups, mask)
    else:
        # pad with virtual always-dropped slots
        pad_mask = jnp.concatenate(
            [mask, jnp.zeros((cap - n,), mask.dtype)])

        def leaf(x):
            xp = jnp.concatenate(
                [x, jnp.zeros((cap - n,) + x.shape[1:], x.dtype)], axis=0)
            return _segment_mean(xp, seg, num_groups, pad_mask)[:n]

    return jax.tree.map(leaf, state)


def mar_aggregate_sim(state: PyTree, plan: GridPlan,
                      mask: Optional[Array] = None,
                      num_rounds: Optional[int] = None,
                      use_kernel: bool = False) -> PyTree:
    """Full MAR schedule: ``num_rounds`` (default depth) rounds in order.

    With full participation and an exact grid this returns the exact
    global mean in every slot (paper §2.3).
    """
    rounds = plan.depth if num_rounds is None else num_rounds
    for g in range(rounds):
        state = mar_round_sim(state, plan, g % plan.depth, mask,
                              use_kernel=use_kernel)
    return state


def allreduce_all_to_all_sim(state: PyTree,
                             mask: Optional[Array] = None) -> PyTree:
    """AR-FL baseline: every peer averages over all active peers."""
    n = jax.tree.leaves(state)[0].shape[0]
    if mask is None:
        mask = jnp.ones((n,), jnp.float32)
    seg = jnp.zeros((n,), jnp.int32)
    return jax.tree.map(lambda x: _segment_mean(x, seg, 1, mask), state)


# ---------------------------------------------------------------------------
# device backend (production mesh)
# ---------------------------------------------------------------------------

def _grid_reshape_mean(x: Array, dims: Sequence[int], axis: int,
                       mask: Array, comm_dtype=None) -> Array:
    """Masked mean over grid axis ``axis`` of the leading peer dim.

    ``comm_dtype`` (e.g. bf16) sets the dtype of the cross-peer reduce —
    the collective's wire format. The group mean still divides in f32.
    This is the delta-compression hook (EXPERIMENTS.md §Perf C-ladder):
    group sizes are <= 8, so bf16 accumulation loses <1 ulp-of-bf16.
    """
    lead = x.shape[0]
    grid = tuple(dims)
    acc_dt = jnp.float32 if comm_dtype is None else jnp.dtype(comm_dtype)
    xg = x.reshape(grid + x.shape[1:])
    mg = mask.reshape(grid + (1,) * (x.ndim - 1))
    num = jnp.sum(xg.astype(acc_dt) * mg.astype(acc_dt), axis=axis,
                  keepdims=True).astype(jnp.float32)
    den = jnp.sum(mg.astype(jnp.float32), axis=axis, keepdims=True)
    out = finalize_masked_mean(num, den, xg)
    out = jnp.broadcast_to(out, grid + x.shape[1:])
    # broadcast after keepdims-mean: group members all receive the mean
    return out.astype(x.dtype).reshape((lead,) + x.shape[1:])


def mar_round_device(state: PyTree, plan: GridPlan, rnd: int,
                     mask: Optional[Array] = None,
                     comm_dtype=None) -> PyTree:
    """One MAR round on the device backend.

    ``state`` leaves: [P, ...] with P == plan.capacity, leading axis
    sharded over the mesh DP axes. The reshape [P, ...] ->
    [*dims, ...] aligns grid axes with mesh-axis factors so the
    mean+broadcast over axis ``rnd`` lowers to a replica-grouped
    all-reduce touching only that round's groups (the paper's partial
    communication, GSPMD-native).
    """
    assert plan.capacity == plan.n_peers, "device backend needs exact grids"
    if mask is None:
        mask = jnp.ones((plan.capacity,), jnp.float32)
    fn = functools.partial(_grid_reshape_mean, dims=plan.dims, axis=rnd,
                           mask=mask, comm_dtype=comm_dtype)
    return jax.tree.map(fn, state)


def mar_aggregate_device(state: PyTree, plan: GridPlan,
                         mask: Optional[Array] = None,
                         one_shot: bool = False,
                         comm_dtype=None) -> PyTree:
    """Full MAR schedule on the device backend.

    ``one_shot`` fuses the d rounds into a single global masked mean —
    mathematically identical under full participation, lowered by XLA to
    one all-reduce over the whole DP axis set (beyond-paper variant; see
    EXPERIMENTS.md §Perf for the collective-bytes comparison).
    """
    if one_shot:
        n = plan.capacity
        if mask is None:
            mask = jnp.ones((n,), jnp.float32)
        acc_dt = jnp.float32 if comm_dtype is None else jnp.dtype(comm_dtype)

        def leaf(x):
            m = mask.reshape((-1,) + (1,) * (x.ndim - 1))
            num = jnp.sum(x.astype(acc_dt) * m.astype(acc_dt), axis=0,
                          keepdims=True).astype(jnp.float32)
            den = jnp.sum(m.astype(jnp.float32), axis=0, keepdims=True)
            return finalize_masked_mean(num, den, x).astype(x.dtype)

        return jax.tree.map(leaf, state)
    for g in range(plan.depth):
        state = mar_round_device(state, plan, g, mask, comm_dtype)
    return state


# ---------------------------------------------------------------------------
# gossip (push-sum) — sim backend
# ---------------------------------------------------------------------------

def gossip_aggregate_sim(state: PyTree, mask: Optional[Array] = None,
                         rounds: Optional[int] = None) -> PyTree:
    """Push-sum ring gossip with doubling shifts (beyond-paper).

    In round ``r`` every peer averages its (value, weight) pair with the
    peer ``2^r`` positions behind it on a fixed ring; after
    ``ceil(log2 N)`` rounds (the default) each peer's window covers the
    whole ring. For power-of-two N under full participation this is the
    exact global mean; otherwise overlapping windows double-count some
    peers and the push-sum weights turn the result into a consistent
    weighted approximation. A peer whose whole window dropped keeps its
    own state (same churn semantics as MAR). Cost model: one model per
    peer per round — ``topology.py``.
    """
    leaves = jax.tree.leaves(state)
    n = leaves[0].shape[0]
    if mask is None:
        mask = jnp.ones((n,), jnp.float32)
    if rounds is None:
        rounds = max(1, int(np.ceil(np.log2(max(n, 2)))))

    w = mask.astype(jnp.float32)
    for r in range(rounds):
        w = 0.5 * (w + jnp.roll(w, 1 << r, axis=0))

    def leaf(x):
        mshape = (-1,) + (1,) * (x.ndim - 1)
        num = x.astype(jnp.float32) * mask.reshape(mshape)
        for r in range(rounds):
            num = 0.5 * (num + jnp.roll(num, 1 << r, axis=0))
        return finalize_masked_mean(num, w.reshape(mshape), x,
                                    floor=1e-12).astype(x.dtype)

    return jax.tree.map(leaf, state)


# ---------------------------------------------------------------------------
# RDFL (ring) baseline — sim backend
# ---------------------------------------------------------------------------

def ring_allreduce_sim(state: PyTree, mask: Optional[Array] = None) -> PyTree:
    """RDFL-style ring: global average via the closed ring.

    RDFL circulates models around a ring so every peer ends with the
    global average; mathematically the fixed point equals the all-to-all
    mean, so we reuse the masked global mean. Its *cost* model (O(N^2)
    bytes for full-model per-hop circulation, no tolerance to ring
    breaks) lives in ``topology.py``; churn on a ring is modeled as a
    failed iteration for the affected peers by the caller.
    """
    return allreduce_all_to_all_sim(state, mask)
