"""Delta compression for MAR exchanges (beyond-paper; DESIGN.md §5).

Peers exchange model state every iteration; quantizing the *delta since
the last aggregated state* to int8 cuts MAR wire bytes 4x (vs f32) at
<1% relative error — and **error feedback** (Seide et al. / EF-SGD)
carries each peer's quantization residual into its next delta, so the
bias cancels over iterations instead of accumulating.

Protocol (per FL iteration, sim backend):
    delta_i   = theta_i - ref_i + e_i          # e_i = residual carry
    q_i       = Q(delta_i)                     # int8 absmax per tensor
    e_i'      = delta_i - deQ(q_i)             # new residual
    exchange  = MAR group means over deQ(q_i)  # wire format: int8+scale
    theta_i'  = ref' = ref_i + mean(deQ(q))    # all peers re-anchor

``FederationConfig(compress="int8_ef")`` activates it through the
composable :class:`~repro.core.aggregation.Int8EFStage`, which wraps any
aggregator (and composes with DP/async stages); communication accounting
divides data-plane bytes by the compression ratio.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any

INT8_RATIO = 4.0   # vs f32 wire format (scales are negligible)


def quantize_int8(x: Array) -> Tuple[Array, Array]:
    """Per-tensor absmax int8 quantization (leading peer axis kept)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)),
                     axis=tuple(range(1, x.ndim)), keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def compress_tree(tree: PyTree, error: Optional[PyTree]
                  ) -> Tuple[PyTree, PyTree]:
    """Quantize every leaf (plus carried error); returns (dequantized
    values as seen on the wire, new error carry)."""
    def leaf(x, e):
        xe = x.astype(jnp.float32) + (0.0 if e is None else e)
        q, s = quantize_int8(xe)
        deq = dequantize_int8(q, s)
        return deq, xe - deq

    if error is None:
        flat, treedef = jax.tree.flatten(tree)
        outs = [leaf(x, None) for x in flat]
    else:
        flat, treedef = jax.tree.flatten(tree)
        eflat = jax.tree.leaves(error)
        outs = [leaf(x, e) for x, e in zip(flat, eflat)]
    deqs = jax.tree.unflatten(treedef, [o[0] for o in outs])
    errs = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return deqs, errs


