"""Device-backend MAR-FL: the paper's protocol on the production mesh.

A *peer* is a slice of the mesh's DP axes (the whole ``pod`` on the
multi-pod mesh; one ``data`` index on the single-pod mesh — DESIGN.md
§5). Every state leaf carries a leading peer axis sharded over the peer
mesh axes; within a peer, params shard over FSDP/TP axes per
``runtime/sharding.py``.

One FL iteration (Alg. 1, device form):

  1. ``local_steps`` Momentum-SGD steps per peer, each accumulating
     grads over ``n_micro`` microbatches (activation memory control).
     No cross-peer communication — only within-peer FSDP/TP collectives.
  2. Aggregation of (theta, m) through the same composable
     :class:`~repro.core.aggregation.AggregationPipeline` as the sim
     backend: device-backed MAR — ``depth`` masked group-mean rounds
     over the peer grid (``one_shot=True`` fuses them into one global
     all-reduce — beyond-paper variant) — optionally wrapped in wire
     stages (int8-EF compression, ``comm_dtype``), with participation
     masks for churn.

Collective bytes per FL iteration drop by ``local_steps`` x versus
per-step gradient DP — the paper's communication saving, realized on a
TPU mesh as local-SGD cadence (DESIGN.md §2).

``make_serve_step`` / ``make_prefill_step`` cover the inference shapes
(no aggregation — MAR is a training-time protocol).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.aggregation import AggregationPipeline, MarAggregator
from repro.core.moshpit import GridPlan
from repro.core.replan import (MembershipChange, resize_peer_axis,
                               select_survivors)
from repro.models.model import Model
from repro.optim.sgdm import momentum_sgd_step

Array = jax.Array
PyTree = Any


def init_fl_state(model: Model, n_peers: int, key: Array,
                  pipeline: Optional[AggregationPipeline] = None
                  ) -> Dict[str, Any]:
    """Peer-stacked (params, momentum) — every peer starts from the same
    theta^0 (Alg. 1). With a ``pipeline``, its wire-stage state (EF
    residuals etc.) is initialized under ``"pipe"``."""
    params = model.init(key)
    stack = lambda x: jnp.broadcast_to(x[None], (n_peers,) + x.shape)
    params = jax.tree.map(stack, params)
    momentum = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    state = {"params": params, "momentum": momentum,
             "step": jnp.zeros((), jnp.int32)}
    if pipeline is not None:
        state["pipe"] = pipeline.init_state({"p": params, "m": momentum})
    return state


def resize_fl_state(state: Dict[str, Any], new_n: int,
                    pipeline: Optional[AggregationPipeline] = None
                    ) -> Dict[str, Any]:
    """Elastic membership for the device-backend FL state dict.

    Shrinks/grows the stacked peer axis of params/momentum (and, via
    the pipeline's per-stage hooks, any wire-stage state under
    ``"pipe"``) in place — the same no-restart path as
    ``Federation.resize``; survivors are bit-exact, joiners bootstrap
    from the group mean. The caller re-plans the grid
    (``runtime.fault.elastic_replan``) and rebuilds the train step for
    the new plan.
    """
    old_n = jax.tree.leaves(state["params"])[0].shape[0]
    if new_n == old_n:
        return state
    out = dict(state)
    out["params"] = resize_peer_axis(state["params"], old_n, new_n)
    out["momentum"] = resize_peer_axis(state["momentum"], old_n, new_n)
    if "pipe" in state:
        if pipeline is not None:
            out["pipe"] = pipeline.resize_state(state["pipe"], old_n,
                                                new_n)
        else:
            out["pipe"] = resize_peer_axis(state["pipe"], old_n, new_n)
    return out


def apply_membership(state: Dict[str, Any], change: MembershipChange,
                     pipeline: Optional[AggregationPipeline] = None
                     ) -> Tuple[Dict[str, Any],
                                Optional[AggregationPipeline]]:
    """The device backend's consumer of the unified membership contract
    (DESIGN.md §16): apply one
    :class:`~repro.core.replan.MembershipChange` to the FL state dict
    and re-bind the pipeline to ``change.new_plan``.

    Survivors' params/momentum/pipe state map through the change
    bit-exact (the contiguous-prefix default is the historical slice);
    joiners bootstrap from the group mean, with the per-``WireStage``
    zero rules for wire state (EF residuals, DP bot markers). Returns
    ``(state, pipeline)``; the caller re-jits the train step for the
    new plan (``make_fl_train_step(model, change.new_plan, ...)``) —
    the device aggregator needs an exact grid, so plan the change with
    ``exact_only=True``.
    """
    old_n = jax.tree.leaves(state["params"])[0].shape[0]
    if old_n != change.old_n:
        raise ValueError(f"change was planned for {change.old_n} "
                         f"peers, state has {old_n}")
    new_pipeline = pipeline.with_plan(change.new_plan) \
        if pipeline is not None else None
    if change.same_n:
        return dict(state), new_pipeline
    k = len(change.survivors)
    out = dict(state)
    out["params"] = change.apply_to_tree(state["params"])
    out["momentum"] = change.apply_to_tree(state["momentum"])
    if "pipe" in state:
        # survivor gather is a pure reindex; the joiner bootstrap
        # routes through the per-stage hooks
        pipe = select_survivors(state["pipe"], old_n, change.survivors)
        if pipeline is not None:
            out["pipe"] = pipeline.resize_state(pipe, k, change.new_n)
        else:
            out["pipe"] = resize_peer_axis(pipe, k, change.new_n)
    return out, new_pipeline


def fl_state_shape(model: Model, n_peers: int,
                   momentum_dtype: str = "float32") -> Dict[str, Any]:
    """ShapeDtypeStructs of the FL state (dry-run; no allocation)."""
    pshape = model.init_shape()
    lift = lambda x: jax.ShapeDtypeStruct((n_peers,) + x.shape, x.dtype)
    params = jax.tree.map(lift, pshape)
    mom = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.dtype(momentum_dtype)),
        params)
    return {"params": params, "momentum": mom,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------

def make_fl_train_step(model: Model, grid: GridPlan, lr: float = 0.1,
                       mu: float = 0.9, one_shot: bool = False,
                       aggregate: bool = True,
                       comm_dtype: Optional[str] = None,
                       pipeline: Optional[AggregationPipeline] = None
                       ) -> Callable:
    """Returns ``fl_train_step(state, batch, mask=None, agg_mask=None)
    -> (state, metrics)``.

    batch: {"tokens": [P, B, n_micro, mb, s], "labels": ..., optional
    "prefix_embeds": ...} — P peers, B local steps, grad-accumulated
    microbatches.

    ``pipeline`` runs the same composable aggregation as the sim backend
    (device-backed MAR plus wire stages, e.g. ``int8_ef`` compression);
    without one, a plain device-MAR pipeline is built from ``one_shot``
    / ``comm_dtype``. ``mask`` ([P] 0/1 float) is the participation
    mask U_t with the paper's churn semantics: masked peers keep their
    previous state, contribute nothing to their group means, but
    receive them. ``agg_mask`` (default: ``mask``) is the aggregation
    mask A_t — peers in U_t but not A_t keep their local update yet
    miss aggregation (the paper's dropout/straggler path, §3.1).
    When the pipeline carries wire-stage state, build the train state
    with ``init_fl_state(..., pipeline=...)``.
    """
    if pipeline is None and aggregate:
        pipeline = AggregationPipeline(MarAggregator(
            grid, backend="device", one_shot=one_shot,
            comm_dtype=comm_dtype))

    def peer_local_update(params, momentum, peer_batch):
        """One peer: B sequential Momentum-SGD steps."""

        def one_step(carry, step_batch):      # step_batch: [n_micro, mb, ..]
            p, m = carry

            def micro(acc, mb_batch):
                loss, grads = jax.value_and_grad(model.loss)(p, mb_batch)
                acc = (jax.tree.map(jnp.add, acc[0], grads),
                       acc[1] + loss)
                return acc, None

            zeros = jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), p)
            (gsum, lsum), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros((), jnp.float32)), step_batch)
            n_micro = jax.tree.leaves(step_batch)[0].shape[0]
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            p, m = momentum_sgd_step(p, m, grads, lr, mu)
            return (p, m), lsum / n_micro

        (params, momentum), losses = jax.lax.scan(
            one_step, (params, momentum), peer_batch)
        return params, momentum, jnp.mean(losses)

    def fl_train_step(state, batch, mask=None, agg_mask=None):
        params, momentum = state["params"], state["momentum"]
        new_p, new_m, loss = jax.vmap(peer_local_update)(
            params, momentum, batch)
        if mask is not None:
            # churn: masked-out peers carry previous state forward
            sel = lambda new, old: jax.tree.map(
                lambda a, b: jnp.where(
                    mask.reshape((-1,) + (1,) * (a.ndim - 1)) > 0, a, b),
                new, old)
            new_p, new_m = sel(new_p, params), sel(new_m, momentum)
        new_state = {"params": new_p, "momentum": new_m,
                     "step": state["step"] + 1}
        if aggregate:
            if pipeline.stages and "pipe" not in state:
                raise ValueError(
                    "pipeline has wire stages; build the state with "
                    "init_fl_state(..., pipeline=pipeline)")
            m = agg_mask if agg_mask is not None else mask
            if m is None:
                m = jnp.ones((grid.capacity,), jnp.float32)
            key = jax.random.fold_in(jax.random.PRNGKey(0), state["step"])
            agg, new_pipe = pipeline({"p": new_p, "m": new_m},
                                     state.get("pipe", {}), m, key)
            new_state["params"], new_state["momentum"] = agg["p"], agg["m"]
            if "pipe" in state:
                new_state["pipe"] = new_pipe
        metrics = {"loss": jnp.mean(loss)}
        return new_state, metrics

    return fl_train_step


# ---------------------------------------------------------------------------
# serve
# ---------------------------------------------------------------------------

def make_serve_step(model: Model) -> Callable:
    """One greedy decode step over a request batch (no aggregation)."""

    def serve_step(params, cache, token):
        logits, cache = model.decode_step(params, cache, token)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, cache

    return serve_step


def make_prefill_step(model: Model, max_len: Optional[int] = None
                      ) -> Callable:
    """Prefill: forward over the full prompt, emit last-token logits and
    the populated cache (single pass; see transformer.forward).

    With ``max_len`` the cache is returned *decode-ready* — converted to
    the exact ``init_cache(cfg, b, max_len)`` layout via
    ``prefill_cache_to_decode`` — so ``serve_step`` continues from
    position ``s`` directly, with no token-by-token prompt replay."""

    def prefill_step(params, batch):
        tokens = batch["tokens"]
        logits, _, cache = model.forward(
            params, tokens,
            prefix_embeds=batch.get("prefix_embeds"),
            collect_cache=True)
        if max_len is not None:
            cache = model.prefill_cache_to_decode(
                cache, max_len, tokens.shape[1])
        return logits[:, -1], cache

    return prefill_step


def make_paged_serve_step(model: Model) -> Callable:
    """One greedy decode step over the paged serving pool.

    ``paged_serve_step(params, pages, block_tables, pos, token) ->
    (next_token, logits, pages)`` — logits are exposed so the engine can
    apply per-session sampling/stops host-side."""

    def paged_serve_step(params, pages, block_tables, pos, token):
        logits, pages = model.paged_decode_step(params, pages, block_tables,
                                                pos, token)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, pages

    return paged_serve_step
