"""Vectorized large-N event engine: batched round timing in numpy.

The heap-ordered :class:`~repro.runtime.network.NetworkSim` walks one
Python loop iteration and one heap push/pop per message — O(messages ·
log messages) interpreter work that caps every benchmark near N=125.
This module times the *same* plans with numpy segment ops, one batch
per round, and registers the result as the ``"vector_sim"`` transport
backend, scaling the simulation to N=65536 (ROADMAP: three orders of
magnitude past the heap engine).

The timing model is the heap engine's, computed in array form and
bit-for-bit equal on the overlap (``tests/test_vector_network.py``
pins every technique at N <= 125):

* *uplink serialization* — within a round, a sender's transmissions
  drain its uplink in plan order. The per-sender start times are
  seeded sequential prefix sums: messages are stably sorted by sender,
  packed into a ``[senders, max_fanout]`` rectangle whose column 0 is
  the sender's ready time, and one ``np.cumsum(axis=1)`` reproduces
  the heap engine's chain ``ready ⊕ o_1 ⊕ o_2 ...`` exactly (cumsum
  accumulates sequentially; padding zeros are exact no-ops).
* *arrival* — send start + transfer at the slower endpoint + both
  endpoints' propagation, same expression, same evaluation order.
* *loss* — one ``rng.random(k)`` per round consumes the identical
  Generator stream as the heap engine's per-message draws (numpy fills
  batched doubles from the same bit stream), so seeded drops — and the
  ``demote_lost_senders`` masks downstream — match message for
  message.
* *barriers* — per-node ready times advance to max(uplink drain,
  last surviving arrival); rounds chain through those ready times, so
  group waits, ring hops and hierarchy barriers emerge exactly as in
  the heap engine.

For the two techniques whose *plans* are O(N^2) messages (all-to-all
AR-FL, and RDFL's N-1 ring hops) the module also provides closed-form
engines (:func:`all_to_all_seconds`, :func:`ring_seconds`) that apply
the same model without materializing messages — benchmarks use them
past a message budget, cross-checked against the materialized engine
at overlapping sizes.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.transport import (ArrayMessagePlan, Message, MessagePlan,
                                  _group_rows, _leaf_groups, _valid_slots)
from repro.runtime.network import LinkModel, build_link_model
from repro.runtime.transport_base import (LinkAccounting, Transcript,
                                          Transport, register_transport)

__all__ = ["VectorNetworkSim", "all_to_all_seconds", "ring_seconds",
           "mar_group_seconds", "group_gather_seconds",
           "group_broadcast_seconds"]


def _extended_links(links: LinkModel, n_nodes: int
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                               np.ndarray]:
    """Per-node link arrays with infrastructure rows appended:
    unbounded bandwidth, zero latency, lossless."""
    n_real = links.n_peers
    up = np.full(n_nodes, np.inf)
    down = np.full(n_nodes, np.inf)
    lat = np.zeros(n_nodes)
    loss = np.zeros(n_nodes)
    up[:n_real] = links.up
    down[:n_real] = links.down
    lat[:n_real] = links.lat
    loss[:n_real] = links.loss
    return up, down, lat, loss


@register_transport
class VectorNetworkSim(Transport):
    """Array-native message timing over a :class:`LinkModel` — the
    ``"vector_sim"`` transport backend.

    Accepts :class:`ArrayMessagePlan` directly (the large-N hot path)
    or any :class:`MessagePlan` (converted once, losslessly). The
    transcript schema, clock accumulation, resize semantics and
    ``from_config`` surface are identical to the heap ``"sim"``
    backend, so ``FederationConfig(transport="vector_sim")`` drops in —
    the ``GroupSizeController``, ``CommLedger`` and
    ``record_transcript`` consumers run unchanged.
    """

    name = "vector_sim"
    plan_format = "array"

    def __init__(self, n_peers: int, profile: str = "uniform",
                 seed: int = 0,
                 link_params: Optional[Dict[str, Any]] = None,
                 links: Optional[LinkModel] = None):
        self.links = links if links is not None else build_link_model(
            profile, n_peers, seed=seed, **(link_params or {}))
        self.seed = seed
        self.clock = 0.0
        self.iterations = 0

    @classmethod
    def from_config(cls, n_peers, *, profile=None, seed=0,
                    link_params=None, **kwargs):
        return cls(n_peers, profile=profile or "uniform", seed=seed,
                   link_params=link_params, **kwargs)

    @property
    def n_peers(self) -> int:
        return self.links.n_peers

    @property
    def lossless(self) -> bool:
        return not self.links.loss.any()

    def resize(self, new_n: int) -> None:
        self.links.resize(new_n)

    # ------------------------------------------------------------------
    def run(self, plan: Any,
            compute_s: Optional[np.ndarray] = None,
            payloads: Optional[Any] = None) -> Transcript:
        """Simulate one iteration's plan, one vector batch per round."""
        if not isinstance(plan, ArrayMessagePlan):
            plan = ArrayMessagePlan.from_plan(plan)
        links = self.links
        n_real = links.n_peers
        n_nodes = max(plan.n_nodes, n_real)
        rng = np.random.default_rng(
            (self.seed + 1) * 48611 + self.iterations)
        up, down, lat, loss = _extended_links(links, n_nodes)

        ready = np.zeros(n_nodes)
        if compute_s is not None:
            ready[:min(n_real, len(compute_s))] = compute_s[:n_real]
        tr = Transcript(technique=plan.technique,
                        lost_senders=np.zeros(n_real, bool))
        acct = LinkAccounting(n_nodes, n_real)

        pairwise = getattr(links, "has_pair_terms", False)

        for r in range(plan.n_rounds):
            src, dst, nb = plan.round_arrays(r)
            tr.n_messages += src.size
            rbytes = float(nb.sum())
            tr.total_bytes += rbytes
            nz = src != dst                  # loopback: billed, instant
            s, d, b = src[nz], dst[nz], nb[nz]
            if s.size == 0:
                acct.add_batch(src, dst, nb)
                tr.bytes_by_round.append(rbytes)
                tr.round_s.append(float(ready.max()))
                continue
            # pairwise WAN terms (regions profile): bandwidth cap +
            # extra latency on cross-region real-peer pairs; the
            # neutral (inf, 0.0) fill keeps every other profile's
            # arithmetic — and transcript — bit-identical
            cap = np.full(s.size, np.inf)
            xlat = np.zeros(s.size)
            if pairwise:
                both = (s < n_real) & (d < n_real)
                pc, pl = links.pair_terms(s[both], d[both])
                cap[both] = pc
                xlat[both] = pl
            # seeded Bernoulli loss, one batch on the heap engine's
            # exact draw stream (message order, loopbacks skipped)
            p_loss = 1.0 - (1.0 - loss[s]) * (1.0 - loss[d])
            lost = rng.random(s.size) < p_loss
            senders, drain, arr_plan_order, start_plan_order = \
                _timed_round(ready, s, d, b, up, down, lat, cap, xlat)
            # drain: every node advances to max(ready, uplink busy);
            # survivors' arrivals then lift their receiver
            new_ready = ready.copy()
            new_ready[senders] = np.maximum(ready[senders], drain)
            kept = ~lost
            np.maximum.at(new_ready, d[kept], arr_plan_order[kept])
            # per-message effective seconds (arrival - send start) in
            # plan order; loopbacks stay 0.0 — same billing as the
            # heap engine's acct.add(..., arrival - start)
            secs = np.zeros(src.size)
            secs[nz] = arr_plan_order - start_plan_order
            acct.add_batch(src, dst, nb, secs)
            ready = new_ready
            tr.bytes_by_round.append(rbytes)
            tr.round_s.append(float(ready.max()))
            if lost.any():
                ls, ld, lb = s[lost], d[lost], b[lost]
                tr.dropped.extend(
                    Message(int(a), int(bb), float(c))
                    for a, bb, c in zip(ls, ld, lb))
                tr.lost_senders[ls[ls < n_real]] = True

        tr.peer_finish_s = ready[:n_real].copy()
        tr.iteration_s = float(ready.max()) if n_nodes else 0.0
        acct.finalize(tr)
        self._split_kd_bytes(tr, plan)
        self.clock += tr.iteration_s
        self.iterations += 1
        return tr


def _timed_round(ready: np.ndarray, s: np.ndarray, d: np.ndarray,
                 b: np.ndarray, up: np.ndarray, down: np.ndarray,
                 lat: np.ndarray, cap: np.ndarray, xlat: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                            np.ndarray]:
    """Time one round of non-loopback messages (plan order) against
    per-node ``ready`` times.

    Uplink serialization: stable sort by sender packs each sender's
    messages (plan order preserved) into one row of a
    ``[senders, fanout+1]`` rectangle seeded with its ready time; a
    single sequential cumsum along the row is the heap engine's
    ``ready ⊕ o_1 ⊕ o_2 ...`` chain, bit for bit.

    Returns ``(senders, drain, arrival, start)`` — the unique sender
    ids with their uplink-busy-until times, and per-message arrival /
    send-start times back in plan order. Callers apply loss masks,
    drains and receiver maxima (see :meth:`VectorNetworkSim.run`); the
    superpeer engine reuses this for its materialized rounds so both
    engines share one arithmetic.
    """
    occ = b / np.minimum(up[s], cap)  # inf uplink -> 0.0
    order = np.argsort(s, kind="stable")
    ss = s[order]
    boundary = np.empty(ss.size, bool)
    boundary[0] = True
    np.not_equal(ss[1:], ss[:-1], out=boundary[1:])
    seg_first = np.flatnonzero(boundary)
    seg_id = np.cumsum(boundary) - 1
    pos = np.arange(ss.size) - seg_first[seg_id]
    n_seg, fan = seg_first.size, int(pos.max()) + 1
    rect = np.zeros((n_seg, fan + 1))
    senders = ss[seg_first]
    rect[:, 0] = ready[senders]
    rect[seg_id, pos + 1] = occ[order]
    chain = np.cumsum(rect, axis=1)
    ds = d[order]
    start = chain[seg_id, pos]           # send start, sorted order
    arrival = start + (b[order] / np.minimum(
        np.minimum(up[ss], down[ds]), cap[order]))
    arrival = arrival + lat[ss]
    arrival = arrival + lat[ds]
    arrival = arrival + xlat[order]      # last, as the heap adds it
    arr_plan_order = np.empty(s.size)
    arr_plan_order[order] = arrival
    start_plan_order = np.empty(s.size)
    start_plan_order[order] = start
    return senders, chain[:, fan], arr_plan_order, start_plan_order


# ---------------------------------------------------------------------------
# closed-form group rounds (the superpeer engine's intra-cluster tier)
# ---------------------------------------------------------------------------
#
# Each ``_closed_*_round`` advances per-node ready times through one
# structured round *without materializing its messages*, reproducing
# ``_timed_round``'s arithmetic term by term on per-peer link
# parameters (no pairwise WAN costs, no loss — the superpeer engine
# checks both and falls back to the materialized path otherwise):
#
# * a sender's k-th transmission starts after k-1 sequential uplink
#   drains from its ready time — reproduced by accumulating ``occ``
#   in the same member order the planners emit (cumsum over identical
#   addends is bitwise the same as the rectangle chain);
# * ``min(x, inf)`` and ``+ 0.0`` are bitwise no-ops, so dropping the
#   neutral pairwise cap/xlat terms changes nothing;
# * drains apply before receiver maxima, receivers take the max over
#   their arrivals — order-independent, so group-vectorizing across
#   lanes is exact.
#
# ``sink(src, dst, secs)`` receives each vector of timed messages
# (arrival - send start, plan semantics) so the engine can feed
# ``LinkAccounting`` without re-deriving anything; loopbacks are the
# caller's to bill (0.0 s, as both event engines do).

def _row_counts(vrows: np.ndarray) -> np.ndarray:
    """Valid members per row, as column adds — numpy's axis-1 bool
    reduction is an order of magnitude slower at 2^16 rows."""
    kk = vrows[:, 0].astype(np.int64)
    for j in range(1, vrows.shape[1]):
        kk = kk + vrows[:, j]
    return kk


def _closed_allpairs_round(ready: np.ndarray, rows: np.ndarray,
                           vrows: np.ndarray, nbytes: float,
                           up: np.ndarray, down: np.ndarray,
                           lat: np.ndarray,
                           sink=None, safe: Optional[np.ndarray] = None,
                           kk: Optional[np.ndarray] = None) -> np.ndarray:
    """One MAR all-pairs group round: every valid member of every row
    sends ``nbytes`` to each other valid member, member order.
    ``safe`` / ``kk`` let callers pass precomputed safe-index rows and
    per-row valid counts (the superpeer engine caches them)."""
    g, m = rows.shape
    if safe is None:
        safe = np.where(vrows, rows, 0)
    new_ready = ready.copy()
    if kk is None:
        kk = _row_counts(vrows)
    # per-receiver-lane running max of arrivals, filled sender by sender
    arr_max = np.full((g, m), -np.inf)
    drain_lanes: List[Tuple[np.ndarray, np.ndarray]] = []
    for i in range(m):
        sends = vrows[:, i] & (kk >= 2)
        if not sends.any():
            continue
        s_idx = safe[:, i]
        up_s, lat_s = up[s_idx], lat[s_idx]
        occ = nbytes / up_s
        acc = ready[s_idx]                  # fancy index -> fresh copy
        for j in range(m):
            if j == i:
                continue
            pair = sends & vrows[:, j]
            if not pair.any():
                continue
            d_idx = safe[:, j]
            arr = acc + (nbytes / np.minimum(up_s, down[d_idx]))
            arr = arr + lat_s
            arr = arr + lat[d_idx]
            if sink is not None:
                sink(s_idx[pair], d_idx[pair], (arr - acc)[pair])
            arr_max[:, j] = np.where(pair, np.maximum(arr_max[:, j],
                                                      arr),
                                     arr_max[:, j])
            acc = np.where(pair, acc + occ, acc)
        drain_lanes.append((s_idx[sends], acc[sends]))
    for s_ids, busy in drain_lanes:         # drains first, as run() does
        new_ready[s_ids] = np.maximum(ready[s_ids], busy)
    for j in range(m):
        got = arr_max[:, j] > -np.inf
        if got.any():
            d_ids = safe[got, j]
            new_ready[d_ids] = np.maximum(new_ready[d_ids],
                                          arr_max[got, j])
    return new_ready


def _closed_leaf_gather_round(ready: np.ndarray, rows: np.ndarray,
                              vrows: np.ndarray, leaders: np.ndarray,
                              nbytes: float, up: np.ndarray,
                              down: np.ndarray, lat: np.ndarray,
                              sink=None) -> np.ndarray:
    """Hierarchical up round: every valid member sends ``nbytes`` to
    its row's leader (the leader's own message is a loopback — billed
    by the caller, never timed)."""
    g, m = rows.shape
    safe = np.where(vrows, rows, 0)
    new_ready = ready.copy()
    lead_max = np.full(g, -np.inf)
    for j in range(m):
        pair = vrows[:, j] & (safe[:, j] != leaders)
        if not pair.any():
            continue
        s_idx = safe[:, j]
        start = ready[s_idx]
        arr = start + (nbytes / np.minimum(up[s_idx], down[leaders]))
        arr = arr + lat[s_idx]
        arr = arr + lat[leaders]
        if sink is not None:
            sink(s_idx[pair], leaders[pair], (arr - start)[pair])
        lead_max = np.where(pair, np.maximum(lead_max, arr), lead_max)
        # single message per sender: drain = ready + occ
        busy = start + nbytes / up[s_idx]
        new_ready[s_idx[pair]] = busy[pair]
    got = lead_max > -np.inf
    if got.any():
        d_ids = leaders[got]
        new_ready[d_ids] = np.maximum(new_ready[d_ids], lead_max[got])
    return new_ready


def _closed_leaf_bcast_round(ready: np.ndarray, rows: np.ndarray,
                             vrows: np.ndarray, leaders: np.ndarray,
                             nbytes: float, up: np.ndarray,
                             down: np.ndarray, lat: np.ndarray,
                             sink=None) -> np.ndarray:
    """Hierarchical down round: each row's leader sends ``nbytes`` to
    every valid member in member order (its own copy is a loopback)."""
    g, m = rows.shape
    safe = np.where(vrows, rows, 0)
    new_ready = ready.copy()
    up_l, lat_l = up[leaders], lat[leaders]
    occ = nbytes / up_l
    acc = ready[leaders]
    sent = np.zeros(g, bool)
    for j in range(m):
        pair = vrows[:, j] & (safe[:, j] != leaders)
        if not pair.any():
            continue
        d_idx = safe[:, j]
        arr = acc + (nbytes / np.minimum(up_l, down[d_idx]))
        arr = arr + lat_l
        arr = arr + lat[d_idx]
        if sink is not None:
            sink(leaders[pair], d_idx[pair], (arr - acc)[pair])
        # member receivers are unique within the round: direct max
        d_ids = d_idx[pair]
        new_ready[d_ids] = np.maximum(new_ready[d_ids], arr[pair])
        acc = np.where(pair, acc + occ, acc)
        sent |= pair
    if sent.any():
        l_ids = leaders[sent]
        new_ready[l_ids] = np.maximum(ready[l_ids], acc[sent])
    return new_ready


def _closed_single_round(ready: np.ndarray, s: np.ndarray,
                         d: np.ndarray, nbytes: float,
                         up: np.ndarray, down: np.ndarray,
                         lat: np.ndarray, sink=None) -> np.ndarray:
    """Unique senders each send one ``nbytes`` message to unique
    receivers (gossip shifts, ring hops); loopbacks pre-filtered."""
    start = ready[s]
    arr = start + (nbytes / np.minimum(up[s], down[d]))
    arr = arr + lat[s]
    arr = arr + lat[d]
    if sink is not None:
        sink(s, d, arr - start)
    new_ready = ready.copy()
    new_ready[s] = start + nbytes / up[s]
    new_ready[d] = np.maximum(new_ready[d], arr)
    return new_ready


def _closed_fan_in_round(ready: np.ndarray, s: np.ndarray, d0: int,
                         nbytes: float, up: np.ndarray,
                         down: np.ndarray, lat: np.ndarray,
                         sink=None) -> np.ndarray:
    """Unique senders each send one ``nbytes`` message to the single
    node ``d0`` (fedavg up, hierarchical rendezvous up)."""
    start = ready[s]
    arr = start + (nbytes / np.minimum(up[s], down[d0]))
    arr = arr + lat[s]
    arr = arr + lat[d0]
    if sink is not None:
        sink(s, np.full(s.size, d0, np.int64), arr - start)
    new_ready = ready.copy()
    new_ready[s] = start + nbytes / up[s]
    if s.size:
        new_ready[d0] = max(new_ready[d0], float(arr.max()))
    return new_ready


def _closed_fan_out_round(ready: np.ndarray, s0: int, d: np.ndarray,
                          nbytes: float, up: np.ndarray,
                          down: np.ndarray, lat: np.ndarray,
                          sink=None) -> np.ndarray:
    """The single node ``s0`` sends ``nbytes`` to each of ``d`` in
    order (fedavg down, rendezvous down); its uplink chain is one
    sequential cumsum, exactly the rectangle row it would occupy."""
    k = d.size
    new_ready = ready.copy()
    if k == 0:
        return new_ready
    buf = np.empty(k + 1)
    buf[0] = ready[s0]
    buf[1:] = nbytes / up[s0]
    chain = np.cumsum(buf)
    start = chain[:k]
    arr = start + (nbytes / np.minimum(up[s0], down[d]))
    arr = arr + lat[s0]
    arr = arr + lat[d]
    if sink is not None:
        sink(np.full(k, s0, np.int64), d, arr - start)
    new_ready[s0] = max(ready[s0], float(chain[k]))
    new_ready[d] = np.maximum(new_ready[d], arr)
    return new_ready


def mar_group_seconds(links: LinkModel, plan, model_bytes: float,
                      mask: Optional[np.ndarray] = None,
                      compute_s: Optional[np.ndarray] = None,
                      num_rounds: Optional[int] = None
                      ) -> Tuple[float, np.ndarray]:
    """One MAR iteration's (iteration_s, peer_finish_s) in closed form
    over ``plan``'s grid — O(depth · m · N/m · m) work, no messages.
    Exact (bitwise vs the materialized engines) on any per-peer link
    profile; raises on loss or pairwise terms like the other closed
    engines."""
    active, ready = _active_ready(links, mask, compute_s)
    valid = _valid_slots(plan, active)
    rounds = plan.depth if num_rounds is None else num_rounds
    up, down, lat = links.up, links.down, links.lat
    for g in range(rounds):
        rows = _group_rows(plan, g % plan.depth)
        ready = _closed_allpairs_round(ready, rows, valid[rows],
                                       float(model_bytes),
                                       up, down, lat)
    return (float(ready.max()) if ready.size else 0.0, ready)


def group_gather_seconds(links: LinkModel, plan, model_bytes: float,
                         mask: Optional[np.ndarray] = None,
                         compute_s: Optional[np.ndarray] = None
                         ) -> Tuple[float, np.ndarray]:
    """One leaf-group gather round (members -> first active member, as
    hierarchical's up phase) in closed form."""
    active, ready = _active_ready(links, mask, compute_s)
    rows, vrows, leaders = _leaf_groups(plan, active)
    ready = _closed_leaf_gather_round(ready, rows, vrows, leaders,
                                      float(model_bytes),
                                      links.up, links.down, links.lat)
    return (float(ready.max()) if ready.size else 0.0, ready)


def group_broadcast_seconds(links: LinkModel, plan, model_bytes: float,
                            mask: Optional[np.ndarray] = None,
                            compute_s: Optional[np.ndarray] = None
                            ) -> Tuple[float, np.ndarray]:
    """One leaf-group broadcast round (first active member -> members,
    as hierarchical's down phase) in closed form."""
    active, ready = _active_ready(links, mask, compute_s)
    rows, vrows, leaders = _leaf_groups(plan, active)
    ready = _closed_leaf_bcast_round(ready, rows, vrows, leaders,
                                     float(model_bytes),
                                     links.up, links.down, links.lat)
    return (float(ready.max()) if ready.size else 0.0, ready)


# ---------------------------------------------------------------------------
# closed-form engines for O(N^2)-message techniques
# ---------------------------------------------------------------------------

def _active_ready(links: LinkModel, mask: Optional[np.ndarray],
                  compute_s: Optional[np.ndarray]
                  ) -> Tuple[np.ndarray, np.ndarray]:
    n = links.n_peers
    if mask is None:
        active = np.arange(n)
    else:
        active = np.flatnonzero(np.asarray(mask)[:n] > 0)
    ready = np.zeros(n)
    if compute_s is not None:
        ready[:min(n, len(compute_s))] = compute_s[:n]
    if links.loss.any():
        raise ValueError(
            "closed-form engines require lossless links (per-message "
            "loss draws need the materialized plan's RNG stream); got "
            "a lossy profile — materialize the plan instead")
    if getattr(links, "has_pair_terms", False):
        raise ValueError(
            "closed-form engines model per-peer link terms only; this "
            "profile carries pairwise (src, dst) costs (e.g. the "
            "regions WAN cap) — materialize the plan instead")
    return active, ready


def all_to_all_seconds(links: LinkModel, model_bytes: float,
                       mask: Optional[np.ndarray] = None,
                       compute_s: Optional[np.ndarray] = None,
                       chunk: int = 256
                       ) -> Tuple[float, np.ndarray]:
    """One AR-FL iteration's (iteration_s, peer_finish_s) without
    materializing its O(N^2) messages.

    Applies the vector engine's model to ``ar_plan``'s structure —
    sender-major message order, so sender ``s``'s k-th transmission
    starts ``k`` uplink drains after its ready time — in sender chunks
    of O(chunk * N) memory. Start offsets use ``k * occupy`` instead of
    a sequential chain (float-associativity differences land at ~1e-12
    relative; cross-checked against the materialized engine in tests).
    """
    active, ready = _active_ready(links, mask, compute_s)
    k = active.size
    finish = ready.copy()
    if k < 2:
        return (float(finish.max()) if finish.size else 0.0,
                finish)
    up, down, lat = links.up, links.down, links.lat
    occ = model_bytes / up[active]
    # receiver index k(s, d): position of d in s's ascending dst scan
    # (self skipped) = rank(d) - (rank(d) > rank(s))
    rank = np.arange(k)
    drain = ready[active] + (k - 1) * occ
    peer_best = np.full(k, -np.inf)
    for lo in range(0, k, chunk):
        sl = slice(lo, min(lo + chunk, k))
        s_ids = active[sl]
        idx = rank[None, :] - (rank[None, :] > rank[sl, None])
        start = ready[s_ids, None] + idx * occ[sl, None]
        tx = model_bytes / np.minimum(up[s_ids, None],
                                      down[active][None, :])
        arr = start + tx + lat[s_ids, None] + lat[active][None, :]
        # a peer never "arrives" to itself
        arr[rank[sl, None] == rank[None, :]] = -np.inf
        np.maximum(peer_best, arr.max(axis=0), out=peer_best)
    finish[active] = np.maximum(drain, peer_best)
    return float(finish.max()), finish


def ring_seconds(links: LinkModel, model_bytes: float,
                 mask: Optional[np.ndarray] = None,
                 compute_s: Optional[np.ndarray] = None
                 ) -> Tuple[float, np.ndarray]:
    """One RDFL iteration's (iteration_s, peer_finish_s) by iterating
    the k-1 ring hops as O(k) vector recurrences instead of O(k^2)
    materialized messages: each hop, every active peer forwards one
    full model to its successor, and a hop cannot leave before the
    previous one arrived."""
    active, ready = _active_ready(links, mask, compute_s)
    k = active.size
    if k < 2:
        return (float(ready.max()) if ready.size else 0.0, ready)
    up, down, lat = (links.up[active], links.down[active],
                     links.lat[active])
    r = ready[active]
    occ = model_bytes / up
    tx = model_bytes / np.minimum(up, np.roll(down, -1))
    hop_lat = lat + np.roll(lat, -1)
    for _ in range(k - 1):
        arrival = r + tx + hop_lat
        r = np.maximum(r + occ, np.roll(arrival, 1))
    finish = ready.copy()
    finish[active] = r
    return float(finish.max()), finish
