"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Per the brief, for each (arch x shape x mesh):

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

``compiled.cost_analysis()`` supplies FLOPs / bytes-accessed of the
*partitioned per-device module* (verified in tests: for an evenly
sharded program it reports global/chips). Collective bytes come from
parsing the optimized HLO (``compiled.as_text()``) and summing operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops — also per device, since the module is the SPMD
per-device program.

Hardware model (TPU v5e-class, per brief): 197 TFLOP/s bf16, 819 GB/s
HBM, 50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Dict, Optional

# one HLO operand parser for both cost models: commas inside shape
# strings (f32[256,256]{1,0}) must not split operand lists
from repro.runtime.hlo_analysis import _operand_names

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link
HBM_PER_CHIP = 16 * 1024 ** 3   # v5e: 16 GiB

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g. f32[128,256] or bf16[4,8,16] or pred[] in type strings
_TYPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
# instruction definition: [ROOT] %name = <type(s)> opcode(
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"      # result name
    r"((?:\([^=]*?\)|\S+?))\s+"                  # result type (may be tuple)
    r"([\w\-]+)\(")                              # opcode


def _type_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _type_str_bytes(type_str: str) -> int:
    return sum(_type_bytes(d, s) for d, s in _TYPE_RE.findall(type_str))


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum *operand* bytes of every collective op in optimized HLO.

    This XLA's HLO printer emits operands as bare names, so we first
    build a name -> result-type-bytes table, then resolve each
    collective's operand list against it. ``-done`` ops are skipped
    (bytes counted at ``-start``).
    """
    sizes: Dict[str, int] = {}
    pending = []  # (op, operand_names) resolved after the full pass
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, opcode = m.groups()
        sizes[name] = _type_str_bytes(type_str)
        base = opcode
        for suffix in ("-start", "-done"):
            if base.endswith(suffix):
                base = base[: -len(suffix)]
        if base in _COLLECTIVES and not opcode.endswith("-done"):
            pending.append((base, _operand_names(line, m.end() - 1)))

    per_op: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    counts: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for op, operands in pending:
        per_op[op] += sum(sizes.get(n, 0) for n in operands)
        counts[op] += 1
    return {
        "bytes_by_op": per_op,
        "counts": counts,
        "total_bytes": sum(per_op.values()),
        "total_count": sum(counts.values()),
    }


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    collective_bytes_per_chip: float
    collective_detail: Dict[str, Any]
    model_flops: float               # 6*N*D (active params for MoE)
    memory_per_chip: Dict[str, float]

    @property
    def compute_s(self) -> float:
        return self.hlo_flops_per_chip / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes_per_chip / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_chip / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline-model step time: max of the three terms (perfect
        overlap assumption; the no-overlap sum is the pessimistic bound)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs — remat/redundancy waste catch."""
        total = self.hlo_flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline step time."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * t)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_chip": self.hlo_flops_per_chip,
            "hlo_bytes_per_chip": self.hlo_bytes_per_chip,
            "collective_bytes_per_chip": self.collective_bytes_per_chip,
            "collective_detail": self.collective_detail,
            "model_flops": self.model_flops,
            "memory_per_chip": self.memory_per_chip,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_time_s": self.step_time_s,
            "useful_flops_fraction": self.useful_flops_fraction,
            "mfu": self.mfu,
        }


def memory_analysis_dict(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    out["total_bytes"] = (out.get("argument_size_in_bytes", 0.0)
                          + out.get("output_size_in_bytes", 0.0)
                          + out.get("temp_size_in_bytes", 0.0)
                          - out.get("alias_size_in_bytes", 0.0))
    out["hbm_fraction"] = out["total_bytes"] / HBM_PER_CHIP
    return out


def analyze(compiled, *, arch: str, shape: str, mesh: str, chips: int,
            model_flops: float) -> RooflineReport:
    """Scan-aware roofline terms from the compiled module.

    ``cost_analysis()`` counts while-loop bodies once (verified in
    tests/test_roofline.py), so the primary numbers come from
    ``hlo_analysis.analyze_text`` — an HLO-text cost model with
    trip-count multiplication. The raw cost_analysis numbers are kept in
    ``collective_detail["raw_cost_analysis"]`` for reference.
    """
    from repro.runtime.hlo_analysis import analyze_text
    text = compiled.as_text()
    scan_aware = analyze_text(text)
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        raw = {"flops": float(cost.get("flops", 0.0)),
               "bytes_accessed": float(cost.get("bytes accessed", 0.0))}
    except Exception:
        raw = {}
    detail = {
        "bytes_by_op": scan_aware["collective_by_op"],
        "counts": scan_aware["collective_counts"],
        "total_bytes": scan_aware["collective_bytes"],
        "layout_bytes_per_chip": scan_aware["layout_bytes"],
        "unknown_trip_whiles": scan_aware["unknown_trip_whiles"],
        "raw_cost_analysis": raw,
    }
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        hlo_flops_per_chip=float(scan_aware["flops"]),
        hlo_bytes_per_chip=float(scan_aware["bytes"]),
        collective_bytes_per_chip=float(scan_aware["collective_bytes"]),
        collective_detail=detail,
        model_flops=model_flops,
        memory_per_chip=memory_analysis_dict(compiled),
    )


def model_flops_estimate(cfg, shape, kind: str) -> float:
    """6*N*D for training, 2*N*D for inference, N = active params."""
    n = cfg.active_param_count()
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
