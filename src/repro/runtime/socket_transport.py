"""Real loopback TCP transport: the ``"socket"`` MessagePlan backend.

Where :class:`~repro.runtime.network.NetworkSim` *models* a
:class:`~repro.core.transport.MessagePlan`, this backend *executes* it:
every node of the plan (real peers and infrastructure ids alike) runs
as an asyncio task with its own TCP server on 127.0.0.1, and every
non-loopback message becomes an actual framed ``send``/``recv`` between
two of those tasks. The per-round dependency semantics are the plan's
own — a node sends its round-``r`` messages once it has received all
its round-``r-1`` frames; there is no global barrier — so group
waits, ring hops, and hierarchy structure shape real wall-clock the
same way they shape simulated time.

Transcript contract (the sim-vs-real calibration story, DESIGN.md §10):

* **Bytes are measured off received frame headers.** Each frame bills
  the plan's scheduled ``nbytes`` (carried as a float64 so fractional
  butterfly chunks round-trip exactly) and additionally moves a payload
  of ``ceil(nbytes)`` real octets, counted into ``payload_bytes``. A
  no-loss socket transcript is therefore *byte-identical* to the
  simulator's — same ``total_bytes``, ``bytes_by_round``,
  ``bytes_by_link`` — which
  ``benchmarks/transport_calibration.py`` asserts exactly.
* **Seconds are wall-clock**, not modeled: ``round_s`` stamps when the
  last frame of each round landed, ``peer_finish_s`` when each peer
  task completed its schedule. Reported, never asserted — loopback
  timing is the calibration *input*, not a claim.
* **Loss is injected, not suffered**: per-message Bernoulli at
  ``loss`` (seeded like the simulator's draw) and/or an explicit
  ``fail_sends={(round, src, dst), ...}`` set. A "lost" frame is still
  transmitted — flagged in its header so the receiver bills its
  airtime, counts it for round progression, but records it dropped and
  flags the sender — keeping ``demote_lost_senders`` semantics
  identical across backends without deadlocking the schedule.

Payloads are real update tensors: :func:`encode_state_payloads`
serializes each peer's stacked state leaves through the int8 absmax
wire format of ``core/compression.py`` (int8 codes + f32 scales), and
each frame's payload window cycles through the sender's blob. Peers
whose blob is shorter than their scheduled bytes pad with zeros;
infrastructure nodes (which own no model) always send zeros.
"""
from __future__ import annotations

import asyncio
import math
import struct
import time
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.transport import Message, MessagePlan
from repro.runtime.transport_base import (Transcript, Transport,
                                          register_transport)

#: frame header: round, src, dst, billed nbytes (f64), lost flag,
#: payload length in real octets
_HEADER = struct.Struct("!IIIdBI")
_READ_CHUNK = 1 << 20


class _Collector:
    """Shared accounting for one run: receivers record every frame here
    (single event loop — no locking needed), peer tasks await their
    per-round arrival counts, and the transcript falls out at the end."""

    def __init__(self, plan: MessagePlan, n_nodes: int, n_real: int):
        self.t0 = time.perf_counter()
        self.n_real = n_real
        n_rounds = len(plan.rounds)
        self.tr = Transcript(technique=plan.technique,
                             lost_senders=np.zeros(n_real, bool))
        self.tr.bytes_by_round = [0.0] * n_rounds
        self.tr.peer_finish_s = np.zeros(n_real)
        # all billed events per round (loopbacks included) -> round_s
        self.round_total = [len(msgs) for msgs in plan.rounds]
        self.round_seen = [0] * n_rounds
        self.round_done_t = [0.0] * n_rounds
        # socket frames each node must receive per round (loopbacks are
        # billed at the sender and never hit the wire)
        self.expected = [[0] * n_nodes for _ in range(n_rounds)]
        for r, msgs in enumerate(plan.rounds):
            for m in msgs:
                if m.src != m.dst:
                    self.expected[r][m.dst] += 1
        self.seen = [[0] * n_nodes for _ in range(n_rounds)]
        self.events = [[asyncio.Event() for _ in range(n_nodes)]
                       for _ in range(n_rounds)]
        for r in range(n_rounds):
            for node in range(n_nodes):
                if not self.expected[r][node]:
                    self.events[r][node].set()

    def bill(self, rnd: int, src: int, dst: int, nbytes: float,
             lost: bool, payload_len: int = 0) -> None:
        """Account one frame (or loopback) exactly like the simulator's
        per-message billing: scheduled bytes, link/round split, drops."""
        tr = self.tr
        tr.n_messages += 1
        tr.total_bytes += nbytes
        tr.payload_bytes += payload_len
        tr.bytes_by_round[rnd] += nbytes
        key = (src, dst)
        tr.bytes_by_link[key] = tr.bytes_by_link.get(key, 0.0) + nbytes
        if lost:
            tr.dropped.append(Message(src, dst, nbytes))
            if src < self.n_real:
                tr.lost_senders[src] = True
        self.round_seen[rnd] += 1
        if self.round_seen[rnd] == self.round_total[rnd]:
            self.round_done_t[rnd] = time.perf_counter() - self.t0

    def arrived(self, rnd: int, dst: int) -> None:
        self.seen[rnd][dst] += 1
        if self.seen[rnd][dst] == self.expected[rnd][dst]:
            self.events[rnd][dst].set()

    async def wait_round(self, rnd: int, node: int) -> None:
        await self.events[rnd][node].wait()


@register_transport
class SocketTransport(Transport):
    """Every plan node as an asyncio task over loopback TCP.

    ``run`` is synchronous at the call site (it owns a private event
    loop per iteration), so the federation's per-step traffic path is
    backend-agnostic: ``transport.run(plan, payloads=...)`` either
    simulates or really transmits.
    """

    name = "socket"
    wants_payloads = True

    def __init__(self, n_peers: int, seed: int = 0, loss: float = 0.0,
                 fail_sends: Optional[Set[Tuple[int, int, int]]] = None,
                 host: str = "127.0.0.1", timeout_s: float = 120.0):
        self._n_peers = n_peers
        self.seed = seed
        self.loss = float(loss)
        self.fail_sends = set(fail_sends or ())
        self.host = host
        self.timeout_s = timeout_s
        self.clock = 0.0           # cumulative wall-clock seconds
        self.iterations = 0

    @classmethod
    def from_config(cls, n_peers, *, profile=None, seed=0,
                    link_params=None, **kwargs):
        # loopback links are real — of the link knobs only the loss
        # rate survives, as deterministic send-failure injection
        loss = float((link_params or {}).get("loss", 0.0))
        return cls(n_peers, seed=seed, loss=loss, **kwargs)

    @property
    def n_peers(self) -> int:
        return self._n_peers

    @property
    def lossless(self) -> bool:
        return self.loss <= 0.0 and not self.fail_sends

    def resize(self, new_n: int) -> None:
        """Elastic membership: node identity is positional, so only the
        peer count moves; the cumulative clock carries over."""
        self._n_peers = new_n

    # ------------------------------------------------------------------
    def run(self, plan: MessagePlan,
            compute_s: Optional[np.ndarray] = None,
            payloads: Optional[Sequence[bytes]] = None) -> Transcript:
        """Execute one iteration's plan over real sockets.

        ``compute_s`` is ignored — this backend measures communication
        only; compute/straggler modeling stays with the lifecycle.
        ``payloads`` maps peer id -> serialized update blob
        (:func:`encode_state_payloads`); omitted peers send zeros.
        """
        tr = asyncio.run(self._run(plan, payloads))
        self._split_kd_bytes(tr, plan)
        self.clock += tr.iteration_s
        self.iterations += 1
        return tr

    # ------------------------------------------------------------------
    def _draw_losses(self, plan: MessagePlan) -> List[List[bool]]:
        """Per-message drop decisions, fixed before any task starts so
        the pattern is deterministic in (seed, iterations) regardless of
        socket scheduling. The rng is seeded like the simulator's
        per-iteration stream, but the draws are NOT aligned with it:
        the sim draws per non-loopback message at the combined
        endpoint rate (infrastructure downlinks included), while this
        backend draws only for peer-sourced messages at the flat
        ``loss`` rate — same seed does not mean the same drop pattern
        across backends."""
        rng = np.random.default_rng(
            (self.seed + 1) * 48611 + self.iterations)
        out: List[List[bool]] = []
        for r, msgs in enumerate(plan.rounds):
            row = []
            for m in msgs:
                lost = False
                if m.src != m.dst and m.src < self._n_peers:
                    if self.loss > 0.0:
                        lost = bool(rng.random() < self.loss)
                    lost = lost or (r, m.src, m.dst) in self.fail_sends
                row.append(lost)
            out.append(row)
        return out

    def _payload_for(self, src: int, nbytes: float,
                     payloads: Optional[Sequence[bytes]]) -> bytes:
        size = int(math.ceil(nbytes))
        if size <= 0:
            return b""
        blob: bytes = b""
        if payloads is not None and src < self._n_peers:
            if isinstance(payloads, dict):
                blob = payloads.get(src, b"")
            elif src < len(payloads):
                blob = payloads[src]
        if not blob:
            return bytes(size)
        if len(blob) >= size:
            return blob[:size]
        reps = -(-size // len(blob))
        return (blob * reps)[:size]

    async def _run(self, plan: MessagePlan,
                   payloads: Optional[Sequence[bytes]]) -> Transcript:
        n_real = self._n_peers
        n_nodes = max(plan.n_nodes, n_real)
        col = _Collector(plan, n_nodes, n_real)
        losses = self._draw_losses(plan)

        async def handler(reader: asyncio.StreamReader,
                          writer: asyncio.StreamWriter) -> None:
            try:
                while True:
                    hdr = await reader.readexactly(_HEADER.size)
                    rnd, src, dst, nbytes, lost, plen = _HEADER.unpack(hdr)
                    got = 0
                    while got < plen:           # really pull the octets
                        chunk = await reader.read(
                            min(plen - got, _READ_CHUNK))
                        if not chunk:
                            raise asyncio.IncompleteReadError(b"", plen)
                        got += len(chunk)
                    col.bill(rnd, src, dst, nbytes, bool(lost), plen)
                    col.arrived(rnd, dst)
            except (asyncio.IncompleteReadError, ConnectionResetError):
                pass                            # sender closed its link
            finally:
                writer.close()

        servers = []
        ports: List[int] = []
        for _ in range(n_nodes):
            srv = await asyncio.start_server(handler, self.host, 0)
            servers.append(srv)
            ports.append(srv.sockets[0].getsockname()[1])

        async def node_task(me: int) -> None:
            writers: Dict[int, asyncio.StreamWriter] = {}
            try:
                for r, msgs in enumerate(plan.rounds):
                    for seq, m in enumerate(msgs):
                        if m.src != me:
                            continue
                        if m.src == m.dst:      # loopback: billed, local
                            col.bill(r, m.src, m.dst, m.nbytes, False)
                            continue
                        w = writers.get(m.dst)
                        if w is None:
                            _, w = await asyncio.open_connection(
                                self.host, ports[m.dst])
                            writers[m.dst] = w
                        payload = self._payload_for(me, m.nbytes,
                                                    payloads)
                        w.write(_HEADER.pack(r, m.src, m.dst,
                                             float(m.nbytes),
                                             int(losses[r][seq]),
                                             len(payload)))
                        w.write(payload)
                        await w.drain()
                    await col.wait_round(r, me)
                if me < n_real:
                    col.tr.peer_finish_s[me] = \
                        time.perf_counter() - col.t0
            finally:
                for w in writers.values():
                    w.close()

        try:
            await asyncio.wait_for(
                asyncio.gather(*(node_task(i) for i in range(n_nodes))),
                timeout=self.timeout_s)
        except asyncio.TimeoutError:
            raise RuntimeError(
                f"socket transport stalled past {self.timeout_s}s "
                f"executing a {plan.technique!r} plan "
                f"({plan.n_messages} messages over {n_nodes} nodes)")
        finally:
            for srv in servers:
                srv.close()
            await asyncio.gather(*(s.wait_closed() for s in servers))

        tr = col.tr
        # round completion is monotone like the simulator's cumulative
        # ready times (late rounds can't finish before earlier ones)
        t = 0.0
        for rt in col.round_done_t:
            t = max(t, rt)
            tr.round_s.append(t)
        tr.iteration_s = time.perf_counter() - col.t0
        return tr


# ---------------------------------------------------------------------------
# real-tensor payload serialization (int8 wire format)
# ---------------------------------------------------------------------------

def encode_state_payloads(state: Any) -> List[bytes]:
    """Serialize peer-stacked update tensors into per-peer wire blobs.

    Every leaf of ``state`` must carry peers on its leading axis. Each
    leaf is pushed through the int8 absmax quantizer of
    ``core/compression.py`` (the same wire format the Int8EF stage
    accounts for) and each peer's blob concatenates its int8 codes plus
    the f32 scales — the bytes a frame's payload window cycles through.
    """
    import jax

    from repro.core.compression import quantize_int8

    leaves = jax.tree.leaves(state)
    if not leaves:
        return []
    n = int(leaves[0].shape[0])
    blobs = [bytearray() for _ in range(n)]
    for leaf in leaves:
        q, scale = quantize_int8(leaf)
        qn = np.asarray(q)
        sn = np.asarray(scale, dtype=np.float32)
        for i in range(n):
            blobs[i] += qn[i].tobytes() + sn[i].tobytes()
    return [bytes(b) for b in blobs]
