"""Real TCP transport: the ``"socket"`` MessagePlan backend.

Where :class:`~repro.runtime.network.NetworkSim` *models* a
:class:`~repro.core.transport.MessagePlan`, this backend *executes* it:
every node of the plan (real peers and infrastructure ids alike) runs
as an asyncio task with its own TCP server, and every non-loopback
message becomes an actual framed ``send``/``recv`` between two of
those tasks. The per-round dependency semantics are the plan's
own — a node sends its round-``r`` messages once it has received all
its round-``r-1`` frames; there is no global barrier — so group
waits, ring hops, and hierarchy structure shape real wall-clock the
same way they shape simulated time.

Two deployment modes:

* **Single-process loopback** (the default, no address book): every
  node binds an ephemeral 127.0.0.1 port inside a private per-run
  event loop — the historical behavior, byte-exact vs the sim.
* **Multi-host address book** (``address_book=`` + ``rank=``): a
  config-driven :class:`AddressBook` fixes ``host:port`` per plan node
  and assigns each node an owning rank. Each rank runs only its own
  nodes' tasks, binds persistent servers on its nodes' fixed ports (a
  background event loop thread keeps them — and the outgoing
  connections — alive across iterations), and frames carry an
  iteration tag so a rank that races ahead buffers early frames
  instead of corrupting the previous run's accounting. Each rank's
  transcript bills exactly the events its nodes observe (receptions by
  owned nodes, plus owned loopbacks), so the per-rank transcripts are
  disjoint and :func:`merge_transcripts` reassembles the byte-exact
  whole — what ``benchmarks/transport_calibration.py`` gates with a
  real two-process run (:func:`run_multiprocess`, spawn-based). A
  :class:`~repro.core.replan.MembershipChange` rewires the book
  through ``Transport.resize``: node identity is positional, so
  survivors keep their fixed endpoints and a shrink simply stops
  scheduling the tail entries.

Transcript contract (the sim-vs-real calibration story, DESIGN.md §10):

* **Bytes are measured off received frame headers.** Each frame bills
  the plan's scheduled ``nbytes`` (carried as a float64 so fractional
  butterfly chunks round-trip exactly) and additionally moves a payload
  of ``ceil(nbytes)`` real octets, counted into ``payload_bytes``. A
  no-loss socket transcript is therefore *byte-identical* to the
  simulator's — same ``total_bytes``, ``bytes_by_round``,
  ``bytes_by_link`` — which
  ``benchmarks/transport_calibration.py`` asserts exactly.
* **Seconds are wall-clock**, not modeled: ``round_s`` stamps when the
  last frame of each round landed, ``peer_finish_s`` when each peer
  task completed its schedule. Reported, never asserted — loopback
  timing is the calibration *input*, not a claim.
* **Loss is injected, not suffered**: per-message Bernoulli at
  ``loss`` (seeded like the simulator's draw) and/or an explicit
  ``fail_sends={(round, src, dst), ...}`` set. A "lost" frame is still
  transmitted — flagged in its header so the receiver bills its
  airtime, counts it for round progression, but records it dropped and
  flags the sender — keeping ``demote_lost_senders`` semantics
  identical across backends without deadlocking the schedule.

Payloads are real update tensors: :func:`encode_state_payloads`
serializes each peer's stacked state leaves through the int8 absmax
wire format of ``core/compression.py`` (int8 codes + f32 scales), and
each frame's payload window cycles through the sender's blob. Peers
whose blob is shorter than their scheduled bytes pad with zeros;
infrastructure nodes (which own no model) always send zeros.
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
import math
import socket as _socket
import struct
import threading
import time
from typing import (Any, Dict, List, Optional, Sequence, Set, Tuple,
                    Union)

import numpy as np

from repro.core.transport import Message, MessagePlan
from repro.runtime.transport_base import (Transcript, Transport,
                                          register_transport)

#: frame header: iteration tag, round, src, dst, billed nbytes (f64),
#: lost flag, payload length in real octets. The iteration tag lets a
#: multi-process rank that finished run k and raced into k+1 be
#: buffered by a peer still accounting run k.
_HEADER = struct.Struct("!IIIIdBI")
_READ_CHUNK = 1 << 20


# ---------------------------------------------------------------------------
# the address book (multi-host mode)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AddressBook:
    """Fixed ``(host, port)`` plus owning rank per plan node.

    Node identity is positional — entry ``i`` is plan node ``i`` (real
    peers first, then infrastructure ids) — which is what makes the
    elastic story work: a :class:`~repro.core.replan.MembershipChange`
    that shrinks the fleet keeps survivors on their existing endpoints
    and simply stops scheduling the tail entries; growth past the book
    needs more entries (a config change, surfaced as a clear error).

    JSON form (``--peer-hosts`` in ``launch/train.py``)::

        {"nodes": [{"host": "10.0.0.1", "port": 9101, "rank": 0},
                   {"host": "10.0.0.2", "port": 9101, "rank": 1},
                   ...]}

    Entries may also be compact ``"host:port:rank"`` strings (rank
    defaults to 0 when omitted).
    """

    hosts: Tuple[str, ...]
    ports: Tuple[int, ...]
    ranks: Tuple[int, ...]

    def __post_init__(self):
        if not (len(self.hosts) == len(self.ports) == len(self.ranks)):
            raise ValueError("hosts/ports/ranks must align per node")

    @property
    def n_nodes(self) -> int:
        return len(self.hosts)

    @property
    def world_size(self) -> int:
        return max(self.ranks) + 1 if self.ranks else 0

    def owned(self, rank: int) -> Tuple[int, ...]:
        return tuple(i for i, r in enumerate(self.ranks) if r == rank)

    # -- (de)serialization ----------------------------------------------
    @classmethod
    def from_dict(cls, doc: Dict[str, Any]) -> "AddressBook":
        hosts, ports, ranks = [], [], []
        for entry in doc["nodes"]:
            if isinstance(entry, str):
                parts = entry.split(":")
                if len(parts) not in (2, 3):
                    raise ValueError(
                        f"node entry must be 'host:port[:rank]'; "
                        f"got {entry!r}")
                hosts.append(parts[0])
                ports.append(int(parts[1]))
                ranks.append(int(parts[2]) if len(parts) == 3 else 0)
            else:
                hosts.append(str(entry["host"]))
                ports.append(int(entry["port"]))
                ranks.append(int(entry.get("rank", 0)))
        return cls(tuple(hosts), tuple(ports), tuple(ranks))

    @classmethod
    def from_json(cls, path: str) -> "AddressBook":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    def to_dict(self) -> Dict[str, Any]:
        return {"nodes": [{"host": h, "port": p, "rank": r}
                          for h, p, r in zip(self.hosts, self.ports,
                                             self.ranks)]}

    def to_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1)

    @classmethod
    def loopback(cls, n_nodes: int, world_size: int = 1,
                 host: str = "127.0.0.1") -> "AddressBook":
        """A local book: ``n_nodes`` distinct free ports on ``host``,
        nodes dealt round-robin over ``world_size`` ranks — the
        multi-process driver's default layout (mixing nodes across
        ranks exercises every cross-rank link)."""
        socks, ports = [], []
        for _ in range(n_nodes):
            s = _socket.socket()
            s.setsockopt(_socket.SOL_SOCKET, _socket.SO_REUSEADDR, 1)
            s.bind((host, 0))
            socks.append(s)
            ports.append(s.getsockname()[1])
        for s in socks:
            s.close()
        return cls(tuple(host for _ in range(n_nodes)), tuple(ports),
                   tuple(i % world_size for i in range(n_nodes)))


# ---------------------------------------------------------------------------
# per-run accounting
# ---------------------------------------------------------------------------

class _Collector:
    """Shared accounting for one run: receivers record every frame here
    (single event loop — no locking needed), peer tasks await their
    per-round arrival counts, and the transcript falls out at the end.

    With ``owned`` (multi-host mode) the collector accounts only the
    events this rank observes — frames received by owned nodes plus
    owned-node loopbacks — so per-rank transcripts are disjoint and sum
    to the single-process whole (:func:`merge_transcripts`)."""

    def __init__(self, plan: MessagePlan, n_nodes: int, n_real: int,
                 owned: Optional[Set[int]] = None):
        self.t0 = time.perf_counter()
        self.n_real = n_real
        n_rounds = len(plan.rounds)
        self.tr = Transcript(technique=plan.technique,
                             lost_senders=np.zeros(n_real, bool))
        self.tr.bytes_by_round = [0.0] * n_rounds
        self.tr.peer_finish_s = np.zeros(n_real)
        # all locally-billed events per round (loopbacks included) ->
        # round_s; a loopback bills at its sender, which owns both ends
        self.round_total = [
            sum(1 for m in msgs if owned is None or m.dst in owned)
            for msgs in plan.rounds]
        self.round_seen = [0] * n_rounds
        self.round_done_t = [0.0] * n_rounds
        # socket frames each owned node must receive per round
        # (loopbacks are billed at the sender and never hit the wire)
        self.expected = [[0] * n_nodes for _ in range(n_rounds)]
        for r, msgs in enumerate(plan.rounds):
            for m in msgs:
                if m.src != m.dst and (owned is None or m.dst in owned):
                    self.expected[r][m.dst] += 1
        self.seen = [[0] * n_nodes for _ in range(n_rounds)]
        self.events = [[asyncio.Event() for _ in range(n_nodes)]
                       for _ in range(n_rounds)]
        for r in range(n_rounds):
            for node in range(n_nodes):
                if not self.expected[r][node]:
                    self.events[r][node].set()

    def bill(self, rnd: int, src: int, dst: int, nbytes: float,
             lost: bool, payload_len: int = 0) -> None:
        """Account one frame (or loopback) exactly like the simulator's
        per-message billing: scheduled bytes, link/round split, drops."""
        tr = self.tr
        tr.n_messages += 1
        tr.total_bytes += nbytes
        tr.payload_bytes += payload_len
        tr.bytes_by_round[rnd] += nbytes
        key = (src, dst)
        tr.bytes_by_link[key] = tr.bytes_by_link.get(key, 0.0) + nbytes
        if lost:
            tr.dropped.append(Message(src, dst, nbytes))
            if src < self.n_real:
                tr.lost_senders[src] = True
        self.round_seen[rnd] += 1
        if self.round_seen[rnd] == self.round_total[rnd]:
            self.round_done_t[rnd] = time.perf_counter() - self.t0

    def arrived(self, rnd: int, dst: int) -> None:
        self.seen[rnd][dst] += 1
        if self.seen[rnd][dst] == self.expected[rnd][dst]:
            self.events[rnd][dst].set()

    async def wait_round(self, rnd: int, node: int) -> None:
        await self.events[rnd][node].wait()

    def finish(self) -> Transcript:
        tr = self.tr
        # round completion is monotone like the simulator's cumulative
        # ready times (late rounds can't finish before earlier ones)
        t = 0.0
        for rt in self.round_done_t:
            t = max(t, rt)
            tr.round_s.append(t)
        tr.iteration_s = time.perf_counter() - self.t0
        return tr


@register_transport
class SocketTransport(Transport):
    """Every plan node as an asyncio task over real TCP.

    ``run`` is synchronous at the call site (loopback mode owns a
    private event loop per iteration; book mode submits onto a
    persistent background loop), so the federation's per-step traffic
    path is backend-agnostic: ``transport.run(plan, payloads=...)``
    either simulates or really transmits.
    """

    name = "socket"
    wants_payloads = True

    def __init__(self, n_peers: int, seed: int = 0, loss: float = 0.0,
                 fail_sends: Optional[Set[Tuple[int, int, int]]] = None,
                 host: str = "127.0.0.1", timeout_s: float = 120.0,
                 address_book: Optional[AddressBook] = None,
                 rank: int = 0):
        self._n_peers = n_peers
        self.seed = seed
        self.loss = float(loss)
        self.fail_sends = set(fail_sends or ())
        self.host = host
        self.timeout_s = timeout_s
        self.book = address_book
        self.rank = int(rank)
        self.clock = 0.0           # cumulative wall-clock seconds
        self.iterations = 0
        # book mode: persistent loop thread + servers + writer cache;
        # frames that arrive for a run this rank hasn't started yet
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._servers: Dict[int, Any] = {}
        self._writers: Dict[int, asyncio.StreamWriter] = {}
        self._future: Dict[int, List[Tuple]] = {}
        self._active: Optional[Tuple[int, _Collector]] = None
        if address_book is not None and n_peers > address_book.n_nodes:
            raise ValueError(
                f"address book has {address_book.n_nodes} node "
                f"entries but the fleet has {n_peers} peers — extend "
                f"the book")

    @classmethod
    def from_config(cls, n_peers, *, profile=None, seed=0,
                    link_params=None,
                    address_book: Union[AddressBook, Dict, str,
                                        None] = None,
                    **kwargs):
        # loopback links are real — of the link knobs only the loss
        # rate survives, as deterministic send-failure injection
        loss = float((link_params or {}).get("loss", 0.0))
        if isinstance(address_book, str):
            address_book = AddressBook.from_json(address_book)
        elif isinstance(address_book, dict):
            address_book = AddressBook.from_dict(address_book)
        return cls(n_peers, seed=seed, loss=loss,
                   address_book=address_book, **kwargs)

    @property
    def n_peers(self) -> int:
        return self._n_peers

    @property
    def lossless(self) -> bool:
        return self.loss <= 0.0 and not self.fail_sends

    def resize(self, new_n: int) -> None:
        """Elastic membership: node identity is positional, so only the
        peer count moves; the cumulative clock carries over. In
        address-book mode this IS the rewiring — survivors keep their
        fixed endpoints, a shrink stops scheduling the tail entries,
        and growth past the book's entries raises (the book is config;
        extend it and relaunch the new ranks)."""
        if self.book is not None and new_n > self.book.n_nodes:
            raise ValueError(
                f"address book has {self.book.n_nodes} node entries; "
                f"cannot grow to {new_n} peers — extend the book "
                f"(--peer-hosts) and launch the new ranks")
        self._n_peers = new_n

    # ------------------------------------------------------------------
    def run(self, plan: MessagePlan,
            compute_s: Optional[np.ndarray] = None,
            payloads: Optional[Sequence[bytes]] = None) -> Transcript:
        """Execute one iteration's plan over real sockets.

        ``compute_s`` is ignored — this backend measures communication
        only; compute/straggler modeling stays with the lifecycle.
        ``payloads`` maps peer id -> serialized update blob
        (:func:`encode_state_payloads`); omitted peers send zeros.
        """
        if self.book is None:
            tr = asyncio.run(self._run(plan, payloads))
        else:
            tr = self._submit(self._run_book(plan, payloads))
        self._split_kd_bytes(tr, plan)
        self.clock += tr.iteration_s
        self.iterations += 1
        return tr

    def close(self) -> None:
        """Tear down book-mode servers/connections and the background
        loop (idempotent; loopback mode has nothing persistent)."""
        loop = self._loop
        if loop is None:
            return

        async def _shutdown():
            for w in self._writers.values():
                w.close()
            for srv in self._servers.values():
                srv.close()
                await srv.wait_closed()
            for task in asyncio.all_tasks():    # inbound handlers
                if task is not asyncio.current_task():
                    task.cancel()

        asyncio.run_coroutine_threadsafe(_shutdown(), loop).result(
            timeout=10)
        loop.call_soon_threadsafe(loop.stop)
        if self._thread is not None:
            self._thread.join(timeout=10)
        loop.close()
        self._loop = None
        self._thread = None
        self._servers = {}
        self._writers = {}

    def __del__(self):  # pragma: no cover - best effort
        try:
            self.close()
        except Exception:
            pass

    # ------------------------------------------------------------------
    def _draw_losses(self, plan: MessagePlan) -> List[List[bool]]:
        """Per-message drop decisions, fixed before any task starts so
        the pattern is deterministic in (seed, iterations) regardless of
        socket scheduling — and identical across the ranks of a
        multi-process world, whose transports run in lockstep. The rng
        is seeded like the simulator's per-iteration stream, but the
        draws are NOT aligned with it: the sim draws per non-loopback
        message at the combined endpoint rate (infrastructure downlinks
        included), while this backend draws only for peer-sourced
        messages at the flat ``loss`` rate — same seed does not mean
        the same drop pattern across backends."""
        rng = np.random.default_rng(
            (self.seed + 1) * 48611 + self.iterations)
        out: List[List[bool]] = []
        for r, msgs in enumerate(plan.rounds):
            row = []
            for m in msgs:
                lost = False
                if m.src != m.dst and m.src < self._n_peers:
                    if self.loss > 0.0:
                        lost = bool(rng.random() < self.loss)
                    lost = lost or (r, m.src, m.dst) in self.fail_sends
                row.append(lost)
            out.append(row)
        return out

    def _payload_for(self, src: int, nbytes: float,
                     payloads: Optional[Sequence[bytes]]) -> bytes:
        size = int(math.ceil(nbytes))
        if size <= 0:
            return b""
        blob: bytes = b""
        if payloads is not None and src < self._n_peers:
            if isinstance(payloads, dict):
                blob = payloads.get(src, b"")
            elif src < len(payloads):
                blob = payloads[src]
        if not blob:
            return bytes(size)
        if len(blob) >= size:
            return blob[:size]
        reps = -(-size // len(blob))
        return (blob * reps)[:size]

    # ------------------------------------------------------------------
    # single-process loopback mode (private per-run event loop)
    # ------------------------------------------------------------------
    async def _run(self, plan: MessagePlan,
                   payloads: Optional[Sequence[bytes]]) -> Transcript:
        n_real = self._n_peers
        n_nodes = max(plan.n_nodes, n_real)
        col = _Collector(plan, n_nodes, n_real)
        losses = self._draw_losses(plan)
        it = self.iterations

        async def handler(reader: asyncio.StreamReader,
                          writer: asyncio.StreamWriter) -> None:
            try:
                while True:
                    hdr = await reader.readexactly(_HEADER.size)
                    _, rnd, src, dst, nbytes, lost, plen = \
                        _HEADER.unpack(hdr)
                    got = 0
                    while got < plen:           # really pull the octets
                        chunk = await reader.read(
                            min(plen - got, _READ_CHUNK))
                        if not chunk:
                            raise asyncio.IncompleteReadError(b"", plen)
                        got += len(chunk)
                    col.bill(rnd, src, dst, nbytes, bool(lost), plen)
                    col.arrived(rnd, dst)
            except (asyncio.IncompleteReadError, ConnectionResetError):
                pass                            # sender closed its link
            finally:
                writer.close()

        servers = []
        ports: List[int] = []
        for _ in range(n_nodes):
            srv = await asyncio.start_server(handler, self.host, 0)
            servers.append(srv)
            ports.append(srv.sockets[0].getsockname()[1])

        async def node_task(me: int) -> None:
            writers: Dict[int, asyncio.StreamWriter] = {}
            try:
                for r, msgs in enumerate(plan.rounds):
                    for seq, m in enumerate(msgs):
                        if m.src != me:
                            continue
                        if m.src == m.dst:      # loopback: billed, local
                            col.bill(r, m.src, m.dst, m.nbytes, False)
                            continue
                        w = writers.get(m.dst)
                        if w is None:
                            _, w = await asyncio.open_connection(
                                self.host, ports[m.dst])
                            writers[m.dst] = w
                        payload = self._payload_for(me, m.nbytes,
                                                    payloads)
                        w.write(_HEADER.pack(it, r, m.src, m.dst,
                                             float(m.nbytes),
                                             int(losses[r][seq]),
                                             len(payload)))
                        w.write(payload)
                        await w.drain()
                    await col.wait_round(r, me)
                if me < n_real:
                    col.tr.peer_finish_s[me] = \
                        time.perf_counter() - col.t0
            finally:
                for w in writers.values():
                    w.close()

        try:
            await asyncio.wait_for(
                asyncio.gather(*(node_task(i) for i in range(n_nodes))),
                timeout=self.timeout_s)
        except asyncio.TimeoutError:
            raise RuntimeError(
                f"socket transport stalled past {self.timeout_s}s "
                f"executing a {plan.technique!r} plan "
                f"({plan.n_messages} messages over {n_nodes} nodes)")
        finally:
            for srv in servers:
                srv.close()
            await asyncio.gather(*(s.wait_closed() for s in servers))

        return col.finish()

    # ------------------------------------------------------------------
    # multi-host address-book mode (persistent background loop)
    # ------------------------------------------------------------------
    def _ensure_loop(self) -> asyncio.AbstractEventLoop:
        if self._loop is None:
            self._loop = asyncio.new_event_loop()
            self._thread = threading.Thread(
                target=self._loop.run_forever,
                name=f"socket-transport-rank{self.rank}", daemon=True)
            self._thread.start()
        return self._loop

    def _submit(self, coro) -> Any:
        fut = asyncio.run_coroutine_threadsafe(coro, self._ensure_loop())
        return fut.result()

    async def _book_handler(self, reader: asyncio.StreamReader,
                            writer: asyncio.StreamWriter) -> None:
        """One per inbound connection, shared by every iteration the
        connection spans (senders keep connections open across runs)."""
        try:
            while True:
                hdr = await reader.readexactly(_HEADER.size)
                it, rnd, src, dst, nbytes, lost, plen = \
                    _HEADER.unpack(hdr)
                got = 0
                while got < plen:               # really pull the octets
                    chunk = await reader.read(
                        min(plen - got, _READ_CHUNK))
                    if not chunk:
                        raise asyncio.IncompleteReadError(b"", plen)
                    got += len(chunk)
                self._dispatch(it, rnd, src, dst, nbytes, bool(lost),
                               plen)
        except (asyncio.IncompleteReadError, ConnectionResetError):
            pass                                # sender closed its link
        finally:
            try:
                writer.close()
            except RuntimeError:
                pass                            # loop already torn down

    def _dispatch(self, it: int, rnd: int, src: int, dst: int,
                  nbytes: float, lost: bool, plen: int) -> None:
        if self._active is not None and self._active[0] == it:
            col = self._active[1]
            col.bill(rnd, src, dst, nbytes, lost, plen)
            col.arrived(rnd, dst)
        elif it >= self.iterations:
            # a peer rank raced into a run this rank hasn't started:
            # buffer, drained when the matching run begins
            self._future.setdefault(it, []).append(
                (rnd, src, dst, nbytes, lost, plen))
        # frames for past iterations would be duplicates — drop

    async def _book_writer(self, dst: int) -> asyncio.StreamWriter:
        w = self._writers.get(dst)
        if w is not None:
            return w
        host, port = self.book.hosts[dst], self.book.ports[dst]
        deadline = time.perf_counter() + self.timeout_s
        delay = 0.02
        while True:
            try:
                _, w = await asyncio.open_connection(host, port)
                break
            except OSError:
                # the owning rank may still be starting up
                if time.perf_counter() >= deadline:
                    raise RuntimeError(
                        f"could not reach node {dst} at {host}:{port} "
                        f"within {self.timeout_s}s — is rank "
                        f"{self.book.ranks[dst]} running?")
                await asyncio.sleep(delay)
                delay = min(delay * 2, 0.5)
        self._writers[dst] = w
        return w

    async def _book_node_task(self, me: int, plan: MessagePlan,
                              losses: List[List[bool]],
                              payloads: Optional[Sequence[bytes]],
                              it: int, col: _Collector,
                              n_real: int) -> None:
        for r, msgs in enumerate(plan.rounds):
            for seq, m in enumerate(msgs):
                if m.src != me:
                    continue
                if m.src == m.dst:              # loopback: billed, local
                    col.bill(r, m.src, m.dst, m.nbytes, False)
                    continue
                w = await self._book_writer(m.dst)
                payload = self._payload_for(me, m.nbytes, payloads)
                w.write(_HEADER.pack(it, r, m.src, m.dst,
                                     float(m.nbytes),
                                     int(losses[r][seq]),
                                     len(payload)))
                w.write(payload)
                await w.drain()
            await col.wait_round(r, me)
        if me < n_real:
            col.tr.peer_finish_s[me] = time.perf_counter() - col.t0

    async def _run_book(self, plan: MessagePlan,
                        payloads: Optional[Sequence[bytes]]
                        ) -> Transcript:
        book = self.book
        n_real = self._n_peers
        n_nodes = max(plan.n_nodes, n_real)
        if n_nodes > book.n_nodes:
            raise ValueError(
                f"address book covers {book.n_nodes} nodes but the "
                f"{plan.technique!r} plan spans {n_nodes} — extend "
                f"the book")
        owned = {i for i in range(n_nodes)
                 if book.ranks[i] == self.rank}
        # bind owned nodes' servers once, on their fixed ports; they
        # persist across iterations (and across elastic resizes)
        for node in sorted(owned):
            if node not in self._servers:
                self._servers[node] = await asyncio.start_server(
                    self._book_handler, book.hosts[node],
                    book.ports[node])
        col = _Collector(plan, n_nodes, n_real, owned=owned)
        losses = self._draw_losses(plan)
        it = self.iterations
        self._active = (it, col)
        # frames that raced ahead of this run (no await between setting
        # _active and draining, so none can slip past both paths)
        for frame in self._future.pop(it, ()):
            col.bill(*frame[:3], frame[3], frame[4], frame[5])
            col.arrived(frame[0], frame[2])
        try:
            await asyncio.wait_for(
                asyncio.gather(*(
                    self._book_node_task(me, plan, losses, payloads,
                                         it, col, n_real)
                    for me in sorted(owned))),
                timeout=self.timeout_s)
        except asyncio.TimeoutError:
            raise RuntimeError(
                f"socket transport (rank {self.rank}) stalled past "
                f"{self.timeout_s}s executing a {plan.technique!r} "
                f"plan ({plan.n_messages} messages over {n_nodes} "
                f"nodes, {len(owned)} owned)")
        finally:
            self._active = None
        return col.finish()


# ---------------------------------------------------------------------------
# multi-process composition
# ---------------------------------------------------------------------------

def merge_transcripts(parts: Sequence[Transcript]) -> Transcript:
    """Reassemble one iteration's transcript from per-rank parts.

    Each rank bills a disjoint slice of the plan's events (receptions
    by its owned nodes + owned loopbacks), so byte fields *sum*; the
    time axes take elementwise maxima (a round completes when its last
    rank saw its last frame — ranks' clocks share only approximate
    epochs, and seconds are reported, never asserted); ``lost_senders``
    ORs and ``peer_finish_s`` takes each peer's owning rank's stamp.
    """
    parts = [p for p in parts if p is not None]
    if not parts:
        raise ValueError("no transcripts to merge")
    out = Transcript(technique=parts[0].technique)
    n_rounds = max(len(p.bytes_by_round) for p in parts)
    out.bytes_by_round = [0.0] * n_rounds
    out.round_s = [0.0] * n_rounds
    n_fin = max(len(p.peer_finish_s) for p in parts)
    out.peer_finish_s = np.zeros(n_fin)
    out.lost_senders = np.zeros(n_fin, bool)
    for p in parts:
        out.n_messages += p.n_messages
        out.total_bytes += p.total_bytes
        out.payload_bytes += p.payload_bytes
        out.kd_bytes += p.kd_bytes
        for r, b in enumerate(p.bytes_by_round):
            out.bytes_by_round[r] += b
        for r, s in enumerate(p.round_s):
            out.round_s[r] = max(out.round_s[r], s)
        for k, v in p.bytes_by_link.items():
            out.bytes_by_link[k] = out.bytes_by_link.get(k, 0.0) + v
        out.dropped.extend(p.dropped)
        ls = np.asarray(p.lost_senders, bool)
        out.lost_senders[:ls.size] |= ls
        pf = np.asarray(p.peer_finish_s, float)
        out.peer_finish_s[:pf.size] = np.maximum(
            out.peer_finish_s[:pf.size], pf)
        out.iteration_s = max(out.iteration_s, p.iteration_s)
    return out


def _mp_worker(rank: int, book_doc: Dict[str, Any], n_peers: int,
               plans: List[MessagePlan], seed: int, loss: float,
               timeout_s: float, queue) -> None:
    """One rank of the spawn-based world: runs every plan in sequence
    (iteration tags keep the ranks aligned) and ships its transcripts
    back through the queue. Top-level so the spawn context can pickle
    it."""
    transport = SocketTransport(
        n_peers, seed=seed, loss=loss, timeout_s=timeout_s,
        address_book=AddressBook.from_dict(book_doc), rank=rank)
    try:
        out = [transport.run(plan) for plan in plans]
        queue.put((rank, out))
    except BaseException as e:  # surface the failure, don't hang the parent
        queue.put((rank, RuntimeError(f"rank {rank}: {e!r}")))
    finally:
        transport.close()


def run_multiprocess(n_peers: int, plans: Sequence[MessagePlan], *,
                     world_size: int = 2, seed: int = 0,
                     loss: float = 0.0, host: str = "127.0.0.1",
                     timeout_s: float = 120.0,
                     book: Optional[AddressBook] = None
                     ) -> List[Transcript]:
    """Execute plans across ``world_size`` real OS processes.

    Builds a loopback :class:`AddressBook` over every node the plans
    span (round-robin rank assignment, so every cross-rank link is
    exercised), spawns one :class:`SocketTransport` rank per process
    (``spawn`` context — clean interpreters, the multi-host launch
    shape), runs the plan sequence in lockstep, and returns one
    *merged* transcript per plan — byte-exact vs the single-process
    backends, which ``benchmarks/transport_calibration.py`` gates.
    """
    import multiprocessing as mp

    plans = list(plans)
    if not plans:
        return []
    n_nodes = max(max(p.n_nodes for p in plans), n_peers)
    if book is None:
        book = AddressBook.loopback(n_nodes, world_size=world_size,
                                    host=host)
    elif book.n_nodes < n_nodes:
        raise ValueError(f"address book covers {book.n_nodes} nodes, "
                         f"plans span {n_nodes}")
    ctx = mp.get_context("spawn")
    queue = ctx.Queue()
    procs = [ctx.Process(target=_mp_worker,
                         args=(r, book.to_dict(), n_peers, plans, seed,
                               loss, timeout_s, queue), daemon=True)
             for r in range(book.world_size)]
    for p in procs:
        p.start()
    results: Dict[int, List[Transcript]] = {}
    try:
        for _ in range(len(procs)):
            try:
                rank, out = queue.get(timeout=timeout_s + 60)
            except Exception:
                raise RuntimeError(
                    f"multi-process socket run timed out; worker exit "
                    f"codes: {[p.exitcode for p in procs]}")
            if isinstance(out, BaseException):
                raise out
            results[rank] = out
    finally:
        for p in procs:
            p.join(timeout=10)
            if p.is_alive():
                p.terminate()
    ranks = sorted(results)
    return [merge_transcripts([results[r][i] for r in ranks])
            for i in range(len(plans))]


# ---------------------------------------------------------------------------
# real-tensor payload serialization (int8 wire format)
# ---------------------------------------------------------------------------

def encode_state_payloads(state: Any) -> List[bytes]:
    """Serialize peer-stacked update tensors into per-peer wire blobs.

    Every leaf of ``state`` must carry peers on its leading axis. Each
    leaf is pushed through the int8 absmax quantizer of
    ``core/compression.py`` (the same wire format the Int8EF stage
    accounts for) and each peer's blob concatenates its int8 codes plus
    the f32 scales — the bytes a frame's payload window cycles through.
    """
    import jax

    from repro.core.compression import quantize_int8

    leaves = jax.tree.leaves(state)
    if not leaves:
        return []
    n = int(leaves[0].shape[0])
    blobs = [bytearray() for _ in range(n)]
    for leaf in leaves:
        q, scale = quantize_int8(leaf)
        qn = np.asarray(q)
        sn = np.asarray(scale, dtype=np.float32)
        for i in range(n):
            blobs[i] += qn[i].tobytes() + sn[i].tobytes()
    return [bytes(b) for b in blobs]
