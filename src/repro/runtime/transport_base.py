"""The pluggable transport seam: one MessagePlan executor interface.

A :class:`~repro.core.transport.MessagePlan` says *what* one FL
iteration's traffic is — per-round ``(src, dst, nbytes)`` messages. A
:class:`Transport` says *how* those messages move: the discrete-event
simulator (``runtime/network.py``, backend ``"sim"``) times them over
modeled links; the real loopback transport
(``runtime/socket_transport.py``, backend ``"socket"``) runs every peer
as an asyncio task and pushes the bytes through actual TCP sockets.
Both return the same :class:`Transcript` shape — per-link and per-round
bytes, round completion times, per-peer finish times, dropped
messages — so the ``CommLedger`` (via
``AggregationPipeline.record_transcript``), the churn demotion rule
(:func:`demote_lost_senders`) and the benchmarks consume either backend
unchanged. That shared contract is what makes sim-vs-real calibration
possible (``benchmarks/transport_calibration.py``): the *bytes* of a
no-loss transcript are byte-identical across backends (both bill the
plan's scheduled sizes, the socket backend measuring them off received
frame headers), while the *seconds* axis is modeled on one and
wall-clock-measured on the other.

Backend selection threads through ``FederationConfig(transport=...)``
and ``launch/train.py --transport``; new backends register with
:func:`register_transport` and are built by name via
:func:`build_transport`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple, Type

import numpy as np

from repro.core.transport import Message, MessagePlan


# ---------------------------------------------------------------------------
# the transcript — the one shape every backend emits
# ---------------------------------------------------------------------------

#: above this peer count, per-(src, dst) link accounting is aggregated
#: into per-peer totals + a top-k heavy-link dict — the dense dict is
#: O(N^2) entries and dominates memory long before the event engine does
LINK_DETAIL_MAX_PEERS = 512


@dataclasses.dataclass
class Transcript:
    """What one FL iteration actually did on the wire.

    Byte fields bill the plan's *scheduled* sizes (lost messages
    consumed airtime and are billed), so a no-loss transcript is
    byte-identical across transport backends. ``kd_bytes`` is the
    portion carried by the plan's MKD prefix rounds
    (``MessagePlan.kd_rounds``) — distillation traffic rides the same
    transport as aggregation traffic and is split back out for the
    ledger's per-source accounting. ``payload_bytes`` counts the actual
    octets a real transport moved through its frames (0 for the
    simulator).

    Per-link accounting has two modes (``link_mode``). ``"exact"`` —
    the small-N default — fills ``bytes_by_link`` with every (src, dst)
    pair. Above :data:`LINK_DETAIL_MAX_PEERS` peers the backends switch
    to ``"peer"``: ``tx_bytes_by_peer`` / ``rx_bytes_by_peer`` carry
    exact per-node totals, and ``bytes_by_link`` keeps only the top-k
    heavy links (exact totals unless the deferred link buffer had to be
    compacted — see :class:`LinkAccounting` — in which case per-link
    values are a lower bound, never an overcount).
    """

    technique: str
    n_messages: int = 0
    total_bytes: float = 0.0
    bytes_by_round: List[float] = dataclasses.field(default_factory=list)
    round_s: List[float] = dataclasses.field(default_factory=list)
    iteration_s: float = 0.0
    peer_finish_s: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0))
    bytes_by_link: Dict[Tuple[int, int], float] = dataclasses.field(
        default_factory=dict)
    dropped: List[Message] = dataclasses.field(default_factory=list)
    lost_senders: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, bool))
    kd_bytes: float = 0.0
    payload_bytes: float = 0.0
    link_mode: str = "exact"
    tx_bytes_by_peer: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0))
    rx_bytes_by_peer: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0))
    #: per-link effective seconds: transfer + latency per message
    #: (arrival - send start, queue wait excluded), summed per
    #: (src, dst). Loopbacks contribute 0.0; lost messages are billed
    #: (their airtime was consumed). Follows ``link_mode`` exactly like
    #: the byte fields: ``"peer"`` mode keeps exact per-node totals in
    #: ``tx_seconds_by_peer`` / ``rx_seconds_by_peer`` and restricts
    #: ``link_time_stats`` to the byte top-k's key set. Filled by the
    #: modeled engines (sim / vector_sim); the socket backend leaves it
    #: empty — wall-clock per-message timing isn't observable from the
    #: receiving frame alone. This is the placement layer's evidence
    #: (``core/placement.py``): seconds-per-byte reveals slow links the
    #: byte totals can't.
    link_time_stats: Dict[Tuple[int, int], float] = dataclasses.field(
        default_factory=dict)
    tx_seconds_by_peer: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0))
    rx_seconds_by_peer: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0))

    @property
    def n_dropped(self) -> int:
        return len(self.dropped)

    def tail_stats(self) -> Tuple[float, float]:
        """(median, max) of positive per-peer finish times — the
        adaptive group-size controllers' signal (``core/adaptive.py``
        reads only this contract, so one policy tunes M over modeled
        links and over real sockets alike)."""
        f = np.asarray(self.peer_finish_s, float)
        f = f[f > 0]
        if f.size == 0:
            return 0.0, 0.0
        return float(np.median(f)), float(f.max())


class LinkAccounting:
    """Per-link byte accounting with an automatic large-N mode.

    At or below ``detail_max`` peers (default
    :data:`LINK_DETAIL_MAX_PEERS`) every (src, dst) pair is tracked —
    the exact dict the calibration gates and small-N tests compare.
    Above it, the accounting keeps exact per-node tx/rx totals, and
    per-link detail is deferred: each round appends its raw
    ``(key, bytes)`` arrays and :meth:`finalize` merges them once into
    exact per-link totals before taking the top ``top_k``. Only when
    the deferred buffer exceeds ``compact_at`` entries is it compacted
    down to a bounded candidate set — from then on the reported top-k
    is a per-link lower bound (a link must stay heavy to stay
    tracked), which keeps memory O(bound) on plans whose *distinct
    link count* itself is O(N^2).
    """

    def __init__(self, n_nodes: int, n_peers: int,
                 detail_max: Optional[int] = None, top_k: int = 32,
                 compact_at: int = 4_000_000,
                 track_links: bool = True):
        self.n_nodes = n_nodes
        self.top_k = top_k
        self.compact_at = compact_at
        if detail_max is None:
            detail_max = LINK_DETAIL_MAX_PEERS
        self.exact = n_peers <= detail_max
        #: peer mode only: when False, skip the deferred per-link
        #: (key, bytes) buffers entirely — per-node totals stay exact,
        #: ``bytes_by_link`` / ``link_time_stats`` come back empty. The
        #: superpeer engine disables tracking past a message budget
        #: where even the deferred buffers would dominate memory.
        self.track_links = track_links or self.exact
        self.links: Dict[Tuple[int, int], float] = {}
        self.link_secs: Dict[Tuple[int, int], float] = {}
        if not self.exact:
            self.tx = np.zeros(n_nodes)
            self.rx = np.zeros(n_nodes)
            self.tx_s = np.zeros(n_nodes)
            self.rx_s = np.zeros(n_nodes)
            self._keys: List[np.ndarray] = []
            self._sums: List[np.ndarray] = []
            self._secs: List[np.ndarray] = []
            self._pending = 0

    def add(self, src: int, dst: int, nbytes: float,
            seconds: float = 0.0) -> None:
        """Scalar path (the per-message heap / socket engines)."""
        if self.exact:
            key = (src, dst)
            self.links[key] = self.links.get(key, 0.0) + nbytes
            self.link_secs[key] = self.link_secs.get(key, 0.0) + seconds
        else:
            self.tx[src] += nbytes
            self.rx[dst] += nbytes
            self.tx_s[src] += seconds
            self.rx_s[dst] += seconds
            if not self.track_links:
                return
            self._keys.append(np.asarray([src * self.n_nodes + dst]))
            self._sums.append(np.asarray([float(nbytes)]))
            self._secs.append(np.asarray([float(seconds)]))
            self._pending += 1
            if self._pending > self.compact_at:
                self._compact()

    def add_batch(self, src: np.ndarray, dst: np.ndarray,
                  nbytes: np.ndarray,
                  seconds: Optional[np.ndarray] = None,
                  unique: bool = False) -> None:
        """Array path (the vectorized engine): one call per round.

        ``unique=True`` asserts that ``src`` has no repeated ids and
        ``dst`` has no repeated ids (each node sends and receives at
        most once in this batch) — peer-mode totals then use direct
        indexed adds instead of bincounts. Each per-node total still
        receives exactly one addend, so the result is bitwise the same.
        """
        if src.size == 0:
            return
        if seconds is None:
            seconds = np.zeros(src.size)
        if self.exact:
            keys = src * self.n_nodes + dst
            uniq, inv = np.unique(keys, return_inverse=True)
            sums = np.bincount(inv, weights=nbytes, minlength=uniq.size)
            secs = np.bincount(inv, weights=seconds,
                               minlength=uniq.size)
            links, lsecs = self.links, self.link_secs
            for k, v, s in zip(uniq.tolist(), sums.tolist(),
                               secs.tolist()):
                kk = (k // self.n_nodes, k % self.n_nodes)
                links[kk] = links.get(kk, 0.0) + v
                lsecs[kk] = lsecs.get(kk, 0.0) + s
            return
        if unique:
            self.tx[src] += nbytes
            self.rx[dst] += nbytes
            self.tx_s[src] += seconds
            self.rx_s[dst] += seconds
        else:
            self.tx += np.bincount(src, weights=nbytes,
                                   minlength=self.n_nodes)
            self.rx += np.bincount(dst, weights=nbytes,
                                   minlength=self.n_nodes)
            self.tx_s += np.bincount(src, weights=seconds,
                                     minlength=self.n_nodes)
            self.rx_s += np.bincount(dst, weights=seconds,
                                     minlength=self.n_nodes)
        if not self.track_links:
            return
        self._keys.append(src * self.n_nodes + dst)
        self._sums.append(np.asarray(nbytes, float))
        self._secs.append(np.asarray(seconds, float))
        self._pending += src.size
        if self._pending > self.compact_at:
            self._compact()

    def add_uniform_round(self, src: np.ndarray, dst: np.ndarray,
                          nbytes: float,
                          seconds: np.ndarray) -> None:
        """Round where ``src`` and ``dst`` are each a permutation of
        *all* nodes and every message carries ``nbytes`` bytes (a full
        MAR pair round at exact capacity). Peer-mode byte totals then
        add uniformly — each node gets exactly one ``nbytes`` addend,
        so ``tx += nbytes`` is bitwise the indexed add — and the
        seconds use the unique-indexed adds. Falls back to
        :meth:`add_batch` whenever per-link keys are kept (copying
        ``seconds``, which callers may hand in as a reused scratch
        buffer — the fast path consumes it immediately, but the
        fallback defers it into the per-link key buffers)."""
        if self.exact or self.track_links:
            self.add_batch(src, dst, np.full(src.size, nbytes),
                           seconds.copy(), unique=True)
            return
        self.tx += nbytes
        self.rx += nbytes
        self.tx_s[src] += seconds
        self.rx_s[dst] += seconds

    def _merge(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        keys = np.concatenate(self._keys) if self._keys else \
            np.empty(0, np.int64)
        sums = np.concatenate(self._sums) if self._sums else \
            np.empty(0)
        secs = np.concatenate(self._secs) if self._secs else \
            np.empty(0)
        uniq, inv = np.unique(keys, return_inverse=True)
        return (uniq,
                np.bincount(inv, weights=sums, minlength=uniq.size),
                np.bincount(inv, weights=secs, minlength=uniq.size))

    def _compact(self, bound: int = 65536) -> None:
        uniq, sums, secs = self._merge()
        if uniq.size > bound:
            top = np.argpartition(sums, -bound)[-bound:]
            uniq, sums, secs = uniq[top], sums[top], secs[top]
        self._keys, self._sums = [uniq], [sums]
        self._secs = [secs]
        self._pending = uniq.size

    def finalize(self, tr: "Transcript") -> None:
        if self.exact:
            tr.bytes_by_link = self.links
            tr.link_time_stats = self.link_secs
            return
        tr.link_mode = "peer"
        tr.tx_bytes_by_peer = self.tx
        tr.rx_bytes_by_peer = self.rx
        tr.tx_seconds_by_peer = self.tx_s
        tr.rx_seconds_by_peer = self.rx_s
        uniq, sums, secs = self._merge()
        if uniq.size > self.top_k:
            top = np.argpartition(sums, -self.top_k)[-self.top_k:]
            uniq, sums, secs = uniq[top], sums[top], secs[top]
        # one ranking (by bytes) keys both top-k dicts, so the byte and
        # seconds views of a heavy link stay aligned
        order = np.argsort(-sums, kind="stable")
        tr.bytes_by_link = {
            (int(k) // self.n_nodes, int(k) % self.n_nodes): float(v)
            for k, v in zip(uniq[order], sums[order])}
        tr.link_time_stats = {
            (int(k) // self.n_nodes, int(k) % self.n_nodes): float(s)
            for k, s in zip(uniq[order], secs[order])}


def demote_lost_senders(a: np.ndarray, u: np.ndarray,
                        transcript: Transcript) -> np.ndarray:
    """Fold a transcript's lost senders out of the aggregation mask.

    A peer whose send was dropped mid-round becomes receiver-only for
    this aggregation (paper §3.1 — it still receives the group mean);
    if every aggregator was lost, the first participating peer is kept
    so Alg. 1 always has >= 1 contributor. Returns a new mask; the sim
    federation, the device trainer, and both transport backends share
    this rule.
    """
    if not transcript.n_dropped:
        return a
    a = np.asarray(a) * (1.0 - transcript.lost_senders
                         .astype(np.float32))
    if not (a > 0).any():
        a[np.flatnonzero(np.asarray(u) > 0)[0]] = 1.0
    return a


# ---------------------------------------------------------------------------
# the transport interface + registry
# ---------------------------------------------------------------------------

TRANSPORTS: Dict[str, Type["Transport"]] = {}


def register_transport(cls: Type["Transport"]) -> Type["Transport"]:
    TRANSPORTS[cls.name] = cls
    return cls


class Transport:
    """A MessagePlan executor.

    One :meth:`run` call executes one FL iteration's plan and returns
    its :class:`Transcript`; ``clock`` accumulates seconds across
    iterations (simulated for the sim backend, wall-clock for real
    ones) and ``iterations`` counts runs — both feed the training
    history and benchmarks regardless of backend.
    """

    name: str = "?"
    #: a real transport serializes actual update tensors into its
    #: frames; the federation only encodes payloads when this is set
    wants_payloads: bool = False
    #: the plan shape this backend runs fastest on: ``"list"``
    #: (MessagePlan / ArrayMessagePlan, the default) or ``"super"``
    #: (the symbolic :class:`~repro.core.transport.SuperMessagePlan`
    #: recipe — no materialized messages). The federation negotiates
    #: via this attribute; every backend still accepts list plans.
    plan_format: str = "list"

    clock: float = 0.0
    iterations: int = 0

    @property
    def n_peers(self) -> int:
        raise NotImplementedError

    @property
    def lossless(self) -> bool:
        """True when no message of any run can be dropped — the
        fast-path predicate callers use to skip mask plumbing."""
        raise NotImplementedError

    def run(self, plan: MessagePlan,
            compute_s: Optional[np.ndarray] = None,
            payloads: Optional[Any] = None) -> Transcript:
        """Execute one iteration's plan; ``compute_s`` (per real peer)
        seeds peer readiness where the backend models it, ``payloads``
        carries per-peer serialized update bytes for backends that move
        real data (``wants_payloads``)."""
        raise NotImplementedError

    def resize(self, new_n: int) -> None:
        """Elastic membership: survivors keep their identity (and, for
        modeled backends, their links); the cumulative clock carries
        over."""
        raise NotImplementedError

    @classmethod
    def from_config(cls, n_peers: int, *, profile: Optional[str] = None,
                    seed: int = 0,
                    link_params: Optional[Dict[str, Any]] = None,
                    **kwargs: Any) -> "Transport":
        """Uniform constructor surface for :func:`build_transport`:
        every backend interprets the federation's link knobs its own
        way (the simulator builds a LinkModel; the socket backend has
        real loopback links and keeps only the loss rate for
        injection)."""
        raise NotImplementedError

    @staticmethod
    def _split_kd_bytes(tr: Transcript, plan: MessagePlan) -> None:
        """Fill ``kd_bytes`` from the plan's MKD prefix rounds — shared
        epilogue so both backends split distillation traffic the same
        way."""
        kd = getattr(plan, "kd_rounds", 0)
        if kd:
            tr.kd_bytes = float(sum(tr.bytes_by_round[:kd]))


def available_transports() -> List[str]:
    """Sorted names of every registered transport backend.

    Imports the bundled implementations first so the registry is
    populated (the same lazy import :func:`build_transport` does) —
    CLI validation and error messages use this list, so a newly
    registered backend shows up everywhere without edits.
    """
    # importing the implementations registers them; lazy to avoid the
    # transport_base <-> network import cycle
    from repro.runtime import (network, socket_transport,  # noqa: F401
                               super_network, vector_network)
    return sorted(TRANSPORTS)


def build_transport(name: str, n_peers: int, *,
                    profile: Optional[str] = None, seed: int = 0,
                    link_params: Optional[Dict[str, Any]] = None,
                    **kwargs: Any) -> Transport:
    """Build a registered transport backend by name.

    ``"sim"`` — the discrete-event simulator over modeled links;
    ``"vector_sim"`` — the same link model timed with batched numpy
    segment ops (the large-N engine, byte-exact and time-equal vs
    ``"sim"``); ``"super_sim"`` — the superpeer hybrid engine (closed
    forms for intra-cluster rounds, the vector engine for the rest;
    byte-exact always, time-equal on per-peer link profiles);
    ``"socket"`` — real asyncio tasks over loopback TCP (or, with an
    ``address_book=``/``rank=``, one rank of a multi-process world on
    fixed host:port endpoints).
    """
    names = available_transports()
    if name not in TRANSPORTS:
        raise ValueError(f"unknown transport {name!r}; "
                         f"registered: {names}")
    return TRANSPORTS[name].from_config(
        n_peers, profile=profile, seed=seed, link_params=link_params,
        **kwargs)
