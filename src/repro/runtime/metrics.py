"""Training metrics: JSONL stream + rolling throughput summaries.

Append-only JSONL (one record per log call) so concurrent tails,
crashes, and elastic restarts never corrupt history — the restart
appends with a new ``run_id`` and the reader reconciles by step.

Alongside measured wall time (``wall_s``/``step_ms``), the logger
tracks *simulated* network wall-clock: pass ``sim_s`` (one iteration's
``Transcript.iteration_s`` from ``runtime/network.py``) and each record
carries the per-step value plus the cumulative ``sim_total_s`` — the
time axis the wall-clock scaling benchmarks report.
"""
from __future__ import annotations

import json
import os
import time
import uuid
from collections import deque
from typing import Any, Dict, Optional


class MetricsLogger:
    def __init__(self, path: Optional[str] = None, window: int = 20):
        self.path = path
        self.run_id = uuid.uuid4().hex[:8]
        self._t0 = time.time()
        self._durations = deque(maxlen=window)
        self._last: Optional[float] = None
        self.sim_total_s = 0.0
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def log(self, step: int, tokens: Optional[int] = None,
            sim_s: Optional[float] = None,
            **metrics: Any) -> Dict[str, Any]:
        now = time.time()
        if self._last is not None:
            self._durations.append(now - self._last)
        self._last = now
        rec: Dict[str, Any] = {
            "run_id": self.run_id, "step": int(step),
            "wall_s": round(now - self._t0, 3),
        }
        if sim_s is not None:
            self.sim_total_s += float(sim_s)
            rec["sim_s"] = round(float(sim_s), 6)
            rec["sim_total_s"] = round(self.sim_total_s, 6)
        if self._durations:
            avg = sum(self._durations) / len(self._durations)
            rec["step_ms"] = round(avg * 1e3, 1)
            if tokens:
                rec["tokens_per_s"] = round(tokens / max(avg, 1e-9), 1)
        for k, v in metrics.items():
            rec[k] = float(v) if hasattr(v, "item") or \
                isinstance(v, (int, float)) else v
        if self.path:
            with open(self.path, "a") as f:
                f.write(json.dumps(rec) + "\n")
        return rec


def read_metrics(path: str):
    """Reconciled history: the newest record per step wins (restarts)."""
    by_step: Dict[int, Dict[str, Any]] = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rec = json.loads(line)
                by_step[rec["step"]] = rec
    return [by_step[s] for s in sorted(by_step)]
