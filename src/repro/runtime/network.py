"""Discrete-event P2P network layer: measured messages, wall-clock time.

The wireless-FL literature (PAPERS.md: Zhou et al. "Towards Scalable
Wireless Federated Learning"; Le et al. "Exploring the Practicality of
Federated Learning") is clear that link heterogeneity and per-round
timing — not byte counts alone — decide real-world scalability. This
module gives the stack that time axis:

* :class:`LinkModel` registry — per-peer link parameters (uplink /
  downlink bandwidth, propagation latency, per-message loss
  probability). Built-ins: ``uniform`` (homogeneous wired links, the
  lossless default whose transcript is byte-identical to the analytic
  oracles), ``wireless`` (lognormal bandwidth/latency heterogeneity —
  the slow-uplink tail that makes per-round *seconds* diverge from
  per-round *bytes*), ``regions`` (contiguous peer blocks on shared
  per-region profiles: fiber / cable / wireless tiers).

* :class:`NetworkSim` — an event-driven simulator over a
  :class:`~repro.core.transport.MessagePlan`. Each message becomes a
  timed event: it leaves when its sender is ready (previous round done)
  and its uplink drains (transmissions serialize over the sender's
  uplink — the wireless contention model that makes AR-FL's N-1 sends
  per peer cost O(N) *seconds*, not just O(N^2) bytes), arrives after
  transfer + propagation, and may be lost. Arrival events drain through
  a single time-ordered queue; per-peer ready times advance to the last
  arrival, so group barriers, ring hops, and hierarchy waits all emerge
  from message structure alone.

* :class:`~repro.runtime.transport_base.Transcript` — what actually
  happened: per-link and per-round bytes, per-round completion times,
  per-peer finish times, dropped messages, and the senders whose
  traffic was lost (the federation demotes them to receiver-only for
  the iteration — paper §3.1 churn semantics). The transcript, not the
  closed-form formulas in ``core/topology.py``, feeds the
  ``CommLedger``; the formulas stay as cross-checked oracles
  (``tests/test_network.py``).

:class:`NetworkSim` is the ``"sim"`` backend of the pluggable
:class:`~repro.runtime.transport_base.Transport` interface — the same
MessagePlans run unchanged over real loopback TCP
(``runtime/socket_transport.py``), and the two transcripts are
byte-identical in the no-loss case (DESIGN.md §10).

Node ids ``>= n_peers`` (the FedAvg server, the hierarchical
rendezvous) are infrastructure: unbounded bandwidth, zero latency,
lossless — client links stay the bottleneck.
"""
from __future__ import annotations

import heapq
from typing import Any, Dict, List, Optional, Tuple, Type

import numpy as np

from repro.core.transport import Message, MessagePlan
from repro.runtime.transport_base import (LinkAccounting, Transcript,
                                          Transport,
                                          demote_lost_senders,
                                          register_transport)

__all__ = ["LINK_MODELS", "LinkModel", "MBPS", "NetworkSim", "Transcript",
           "UniformLinks", "LognormalWirelessLinks", "RegionLinks",
           "build_link_model", "demote_lost_senders",
           "register_link_model"]

MBPS = 125_000.0          # 1 Mbit/s in bytes/s


# ---------------------------------------------------------------------------
# link models
# ---------------------------------------------------------------------------

LINK_MODELS: Dict[str, Type["LinkModel"]] = {}


def register_link_model(cls: Type["LinkModel"]) -> Type["LinkModel"]:
    LINK_MODELS[cls.name] = cls
    return cls


def build_link_model(name: str, n_peers: int, seed: int = 0,
                     **params: Any) -> "LinkModel":
    if name not in LINK_MODELS:
        raise ValueError(f"unknown link profile {name!r}; "
                         f"registered: {sorted(LINK_MODELS)}")
    return LINK_MODELS[name](n_peers, seed=seed, **params)


class LinkModel:
    """Per-peer link parameters, drawn once at construction.

    Arrays (length ``n_peers``): ``up`` / ``down`` in bytes/s, ``lat``
    one-way propagation seconds, ``loss`` per-message loss probability.
    ``resize`` keeps survivors' links bit-identical and draws fresh
    links for joiners (elastic membership).
    """

    name: str = "?"

    def __init__(self, n_peers: int, seed: int = 0):
        self.n_peers = n_peers
        self.seed = seed
        self.up, self.down, self.lat, self.loss = self._draw(n_peers)

    def _draw(self, n: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                     np.ndarray]:
        raise NotImplementedError

    def peer_attrs(self) -> Dict[str, np.ndarray]:
        """Ground-truth per-peer link parameters (copies).

        The public accessor benchmarks and tests score against instead
        of reaching into private fields; profiles with structure beyond
        the four base arrays (e.g. :class:`RegionLinks`) extend the
        dict — ``"region"`` is the ground-truth cluster label the
        placement benchmark grades recovered clusters with.
        """
        return {"up": self.up.copy(), "down": self.down.copy(),
                "lat": self.lat.copy(), "loss": self.loss.copy()}

    @property
    def has_pair_terms(self) -> bool:
        """True when some (src, dst) pairs carry extra cost beyond the
        endpoints' own parameters (see :meth:`pair_terms`). The
        closed-form engines cannot model this and must refuse."""
        return False

    def pair_terms(self, src: np.ndarray | int,
                   dst: np.ndarray | int) -> Tuple[np.ndarray,
                                                   np.ndarray]:
        """Pairwise ``(bandwidth_cap_bps, extra_latency_s)`` for real
        src/dst indices — ``(inf, 0.0)`` where the pair adds nothing.
        Base models have no pair structure."""
        src = np.asarray(src)
        return (np.full(src.shape, np.inf),
                np.zeros(src.shape))

    def resize(self, new_n: int) -> None:
        old = (self.up, self.down, self.lat, self.loss)
        keep = min(new_n, self.n_peers)
        self.up, self.down, self.lat, self.loss = self._draw(new_n)
        for new_arr, old_arr in zip(
                (self.up, self.down, self.lat, self.loss), old):
            new_arr[:keep] = old_arr[:keep]
        self.n_peers = new_n


@register_link_model
class UniformLinks(LinkModel):
    """Homogeneous wired links — the lossless default.

    With loss 0 the transcript's *bytes* are exactly the analytic
    oracle's at full participation; time is still modeled, so even the
    ideal profile yields per-round wall-clock.
    """

    name = "uniform"

    def __init__(self, n_peers: int, seed: int = 0,
                 bandwidth_bps: float = 1000 * MBPS,
                 latency_s: float = 0.001, loss: float = 0.0):
        self.bandwidth_bps = bandwidth_bps
        self.latency_s = latency_s
        self.loss_rate = loss
        super().__init__(n_peers, seed)

    def _draw(self, n):
        return (np.full(n, self.bandwidth_bps),
                np.full(n, self.bandwidth_bps),
                np.full(n, self.latency_s),
                np.full(n, self.loss_rate))


@register_link_model
class LognormalWirelessLinks(LinkModel):
    """Lognormal-heterogeneous wireless edge links.

    Medians default to a mid-band cellular uplink (20 Mbit/s up,
    100 Mbit/s down, 25 ms one-way); ``sigma`` controls the
    heterogeneity tail — at the default 0.6 the p95/median uplink ratio
    is ~2.7x, the slow tail that turns byte savings into wall-clock
    savings. Per-message loss is i.i.d. at ``loss``.
    """

    name = "wireless"

    def __init__(self, n_peers: int, seed: int = 0,
                 uplink_bps: float = 20 * MBPS,
                 downlink_bps: float = 100 * MBPS,
                 latency_s: float = 0.025, sigma: float = 0.6,
                 latency_sigma: float = 0.4, loss: float = 0.0):
        self.uplink_bps = uplink_bps
        self.downlink_bps = downlink_bps
        self.latency_s = latency_s
        self.sigma = sigma
        self.latency_sigma = latency_sigma
        self.loss_rate = loss
        super().__init__(n_peers, seed)

    def _draw(self, n):
        rng = np.random.default_rng(self.seed * 64901 + 17)
        up = self.uplink_bps * np.exp(rng.normal(0, self.sigma, n))
        down = self.downlink_bps * np.exp(rng.normal(0, self.sigma, n))
        lat = self.latency_s * np.exp(rng.normal(0, self.latency_sigma, n))
        return up, down, lat, np.full(n, self.loss_rate)


@register_link_model
class RegionLinks(LinkModel):
    """Per-region profiles: contiguous peer blocks share a tier.

    ``profiles`` is a sequence of ``(uplink_bps, downlink_bps,
    latency_s, loss)`` tuples assigned round-robin to ``n_regions``
    contiguous blocks (the same region layout as
    ``lifecycle.CorrelatedOutageChurn``); per-peer jitter stays small so
    within-region links are near-identical — the structured
    heterogeneity a lognormal draw cannot express.

    Cross-region messages additionally traverse the WAN:
    ``inter_bw_bps`` caps their transfer (and the sender's uplink drain
    for that message — a flow throttled by the WAN frees the local
    uplink no faster than the WAN accepts bytes) and
    ``inter_latency_s`` adds one-way propagation. Intra-region traffic
    pays neither, which is exactly the asymmetry topology-aware
    placement (``core/placement.py``) exploits. Set
    ``inter_bw_bps=None, inter_latency_s=0.0`` for the flat pre-WAN
    behavior.

    ``shuffle=True`` scatters the region assignment over peer indices
    (seeded) instead of contiguous blocks — peers joined in arbitrary
    order, so raw-index grid coordinates interleave regions and every
    aggregation round crosses the WAN. This is the misaligned world
    placement policies exist for; the default stays the contiguous
    (aligned) layout, bit-identical to the historical draws.
    """

    name = "regions"

    DEFAULT_PROFILES = (
        (500 * MBPS, 500 * MBPS, 0.002, 0.0),     # fiber
        (50 * MBPS, 200 * MBPS, 0.015, 0.0),      # cable
        (10 * MBPS, 50 * MBPS, 0.040, 0.01),      # congested wireless
    )

    def __init__(self, n_peers: int, seed: int = 0, n_regions: int = 4,
                 profiles: Optional[Tuple[Tuple[float, float, float, float],
                                          ...]] = None,
                 jitter: float = 0.05, loss: Optional[float] = None,
                 inter_bw_bps: Optional[float] = 5 * MBPS,
                 inter_latency_s: float = 0.03,
                 shuffle: bool = False):
        self.n_regions = max(1, min(n_regions, n_peers))
        self.profiles = tuple(profiles or self.DEFAULT_PROFILES)
        self.jitter = jitter
        self.loss_override = loss      # None -> per-tier profile loss
        self.inter_bw_bps = inter_bw_bps
        self.inter_latency_s = inter_latency_s
        self.shuffle = shuffle
        super().__init__(n_peers, seed)

    def region_of(self, n: Optional[int] = None) -> np.ndarray:
        n = self.n_peers if n is None else n
        block = -(-n // self.n_regions)
        region = np.arange(n) // block
        if self.shuffle:
            region = region[np.random.default_rng(
                self.seed * 31337 + 11).permutation(n)]
        return region

    def peer_attrs(self) -> Dict[str, np.ndarray]:
        attrs = super().peer_attrs()
        attrs["region"] = self.region_of()
        return attrs

    @property
    def has_pair_terms(self) -> bool:
        return self.inter_bw_bps is not None or self.inter_latency_s > 0

    def pair_terms(self, src, dst):
        r = self.region_of()
        cross = r[np.asarray(src)] != r[np.asarray(dst)]
        cap = np.where(
            cross,
            np.inf if self.inter_bw_bps is None else self.inter_bw_bps,
            np.inf)
        return cap, np.where(cross, self.inter_latency_s, 0.0)

    def _draw(self, n):
        rng = np.random.default_rng(self.seed * 88007 + 5)
        region = self.region_of(n)
        prof = np.array([self.profiles[r % len(self.profiles)]
                         for r in region])
        jit = np.exp(rng.normal(0, self.jitter, (n, 3)))
        loss = (np.full(n, self.loss_override)
                if self.loss_override is not None else prof[:, 3].copy())
        return (prof[:, 0] * jit[:, 0], prof[:, 1] * jit[:, 1],
                prof[:, 2] * jit[:, 2], loss)


# ---------------------------------------------------------------------------
# the simulator
# ---------------------------------------------------------------------------

@register_transport
class NetworkSim(Transport):
    """Event-driven message timing over a :class:`LinkModel` — the
    ``"sim"`` transport backend.

    One :meth:`run` call simulates one FL iteration's
    :class:`MessagePlan` and returns its :class:`Transcript`;
    ``clock`` accumulates simulated seconds across iterations (the
    wall-clock axis benchmarks and the training history report).

    Timing model, per message ``src -> dst`` in round ``r``:

    * *send start* — when ``src`` is ready (all its round ``r-1``
      arrivals in, uplink drained) and its uplink frees up: a peer's
      transmissions serialize over its single uplink.
    * *transfer* — ``nbytes / min(up[src], down[dst])``; the slower
      endpoint is the bottleneck.
    * *arrival* — send end + ``lat[src] + lat[dst]``.
    * *loss* — Bernoulli per message at the combined endpoint rate;
      lost messages consumed airtime (bytes are billed) but never
      arrive, and their sender is flagged in ``lost_senders``.

    Loopback messages (``src == dst``) and infrastructure nodes
    (``id >= n_peers``) take zero time; infrastructure is lossless.
    """

    name = "sim"

    def __init__(self, n_peers: int, profile: str = "uniform",
                 seed: int = 0,
                 link_params: Optional[Dict[str, Any]] = None,
                 links: Optional[LinkModel] = None):
        self.links = links if links is not None else build_link_model(
            profile, n_peers, seed=seed, **(link_params or {}))
        self.seed = seed
        self.clock = 0.0           # cumulative simulated seconds
        self.iterations = 0

    @classmethod
    def from_config(cls, n_peers, *, profile=None, seed=0,
                    link_params=None, **kwargs):
        return cls(n_peers, profile=profile or "uniform", seed=seed,
                   link_params=link_params, **kwargs)

    @property
    def n_peers(self) -> int:
        return self.links.n_peers

    @property
    def lossless(self) -> bool:
        return not self.links.loss.any()

    def resize(self, new_n: int) -> None:
        """Elastic membership: survivors keep their links, joiners draw
        fresh ones; the cumulative clock carries over."""
        self.links.resize(new_n)

    # ------------------------------------------------------------------
    def run(self, plan: MessagePlan,
            compute_s: Optional[np.ndarray] = None,
            payloads: Optional[Any] = None) -> Transcript:
        """Simulate one iteration; ``compute_s`` (per real peer) seeds
        each peer's ready time with its local-update duration so slow
        *compute* and slow *links* compose into one finish time.
        ``payloads`` is accepted for Transport-interface compatibility
        and ignored — no real byte crosses the simulator."""
        links = self.links
        n_real = links.n_peers
        n_nodes = max(plan.n_nodes, n_real)
        rng = np.random.default_rng(
            (self.seed + 1) * 48611 + self.iterations)

        ready = np.zeros(n_nodes)
        if compute_s is not None:
            ready[:min(n_real, len(compute_s))] = \
                compute_s[:n_real]
        tr = Transcript(technique=plan.technique,
                        lost_senders=np.zeros(n_real, bool))
        acct = LinkAccounting(n_nodes, n_real)

        def up(i):
            return links.up[i] if i < n_real else np.inf

        def down(i):
            return links.down[i] if i < n_real else np.inf

        def lat(i):
            return links.lat[i] if i < n_real else 0.0

        def loss_p(s, d):
            ls = links.loss[s] if s < n_real else 0.0
            ld = links.loss[d] if d < n_real else 0.0
            return 1.0 - (1.0 - ls) * (1.0 - ld)

        pairwise = getattr(links, "has_pair_terms", False)

        def pair(s, d):
            if pairwise and s < n_real and d < n_real:
                cap, xlat = links.pair_terms(s, d)
                return float(cap), float(xlat)
            return np.inf, 0.0

        for messages in plan.rounds:
            events: List[Tuple[float, int, Message, bool]] = []
            busy = ready.copy()            # per-node uplink drain time
            rbytes = 0.0
            for seq, msg in enumerate(messages):
                rbytes += msg.nbytes
                tr.total_bytes += msg.nbytes
                tr.n_messages += 1
                if msg.src == msg.dst:
                    acct.add(msg.src, msg.dst, msg.nbytes, 0.0)
                    continue               # loopback: billed, instant
                cap, xlat = pair(msg.src, msg.dst)
                bw = min(min(up(msg.src), down(msg.dst)), cap)
                tx = msg.nbytes / bw if np.isfinite(bw) else 0.0
                # the sender's uplink is occupied at its *own* drain
                # rate (infrastructure never serializes) — but a flow
                # capped by a pairwise WAN bottleneck drains no faster
                # than the WAN accepts bytes; the transfer itself runs
                # at the slowest of endpoint and pair terms
                occ_bw = min(up(msg.src), cap)
                occupy = (msg.nbytes / occ_bw
                          if np.isfinite(occ_bw) else 0.0)
                start = busy[msg.src]
                busy[msg.src] = start + occupy
                arrival = start + tx + lat(msg.src) + lat(msg.dst) + xlat
                acct.add(msg.src, msg.dst, msg.nbytes, arrival - start)
                lost = bool(rng.random() < loss_p(msg.src, msg.dst))
                heapq.heappush(events, (arrival, seq, msg, lost))
            # drain arrivals in time order
            new_ready = np.maximum(ready, busy)
            while events:
                t, _, msg, lost = heapq.heappop(events)
                if lost:
                    tr.dropped.append(msg)
                    if msg.src < n_real:
                        tr.lost_senders[msg.src] = True
                else:
                    new_ready[msg.dst] = max(new_ready[msg.dst], t)
            ready = new_ready
            tr.bytes_by_round.append(rbytes)
            tr.round_s.append(float(ready.max()))

        tr.peer_finish_s = ready[:n_real].copy()
        tr.iteration_s = float(ready.max()) if n_nodes else 0.0
        acct.finalize(tr)
        self._split_kd_bytes(tr, plan)
        self.clock += tr.iteration_s
        self.iterations += 1
        return tr
