"""Divisibility-aware sharding rules for the production mesh.

``make_shard_plan`` maps a (mesh, n_peers) pair to a :class:`ShardPlan`;
``state_shardings`` walks any peer-stacked pytree and assigns each leaf:

  dim 0              -> the peer axes (MAR replicas; "pod" on the
                        multi-pod mesh, "data" on the single-pod mesh)
  largest other dim  -> TP axis ("model"), if divisible
  next largest dim   -> FSDP axes (remaining DP axes inside a peer), if
                        divisible

Greedy-with-fallback: any dim that fails divisibility is replicated on
that axis instead — no config ever fails to shard, it just shards less
(logged via ``plan.report``). This one rule set covers all 10 assigned
architectures: MoE expert stacks [L, E, d, f] get E->model + d->fsdp
(384 % 16 == 0), dense stacks [L, d, ff] get ff->model + d->fsdp, vocab
embeddings [V, d] get V->model, SSM conv/gate vectors stay replicated.

Batch arrays shard their leading (global-batch or peer) dim over *all*
DP axes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    mesh: Mesh
    peer_axes: Tuple[str, ...]     # mesh axes enumerating MAR peers
    fsdp_axes: Tuple[str, ...]     # within-peer param-shard axes
    tp_axes: Tuple[str, ...]       # tensor-parallel axes
    n_peers: int

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        return self.peer_axes + self.fsdp_axes

    def axis_size(self, axes: Sequence[str]) -> int:
        return int(np.prod([self.mesh.shape[a] for a in axes])) if axes else 1


def make_shard_plan(mesh: Mesh, peer_axes: Optional[Sequence[str]] = None
                    ) -> ShardPlan:
    """Default plans for the two production meshes (DESIGN.md §5):

    * (data=16, model=16)           -> peers over "data" (16 peers, MAR
                                       grid 4x4), TP over "model", no FSDP
    * (pod=2, data=16, model=16)    -> peers over "pod" (2 peers), FSDP
                                       over "data", TP over "model" —
                                       cross-pod traffic only in the MAR
                                       round over the pod axis
    """
    names = mesh.axis_names
    if peer_axes is None:
        peer_axes = ("pod",) if "pod" in names else ("data",)
    peer_axes = tuple(peer_axes)
    tp_axes = ("model",) if ("model" in names
                             and "model" not in peer_axes) else ()
    fsdp_axes = tuple(a for a in names
                      if a not in peer_axes and a not in tp_axes)
    n_peers = int(np.prod([mesh.shape[a] for a in peer_axes]))
    return ShardPlan(mesh, peer_axes, fsdp_axes, tp_axes, n_peers)


# ---------------------------------------------------------------------------
# leaf rules — name-aware Megatron-style TP with divisibility fallbacks
# ---------------------------------------------------------------------------

# column-parallel (shard the OUTPUT dim, -1): activations stay sharded,
# no collective until the paired row-parallel matmul
_COL_PARALLEL = {"wg", "wu", "up_proj", "w_in"}
# row-parallel (shard the INPUT dim, -2): consumes col-parallel output,
# emits one all-reduce
_ROW_PARALLEL = {"wd", "out_proj"}
# attention projections: shard only on whole-head boundaries
_ATTN_COL = {"wq"}          # out dim = num_heads * head_dim
_ATTN_KV = {"wk", "wv"}     # out dim = num_kv_heads * head_dim
_ATTN_ROW = {"wo"}          # in  dim = num_heads * head_dim
_NEVER_TP = {"router", "a_log", "dt_bias", "d_skip", "bias", "conv_w",
             "r_rec", "norm", "norm1", "norm2", "final_norm",
             "frontend_norm"}


def _assign(spec, i, axes):
    spec[i] = axes if len(axes) > 1 else axes[0]


def _leaf_spec(name: str, shape: Tuple[int, ...], plan: ShardPlan,
               peer_stacked: bool, head_dim: int = 0,
               num_heads: int = 0, num_kv_heads: int = 0) -> P:
    nd = len(shape)
    spec: List[Any] = [None] * nd
    start = 0
    if peer_stacked and nd >= 1 and shape[0] == plan.n_peers \
            and plan.n_peers > 1:
        _assign(spec, 0, plan.peer_axes)
        start = 1

    tp = plan.axis_size(plan.tp_axes)
    tp_dim: Optional[int] = None

    def head_ok(heads: int) -> bool:
        return heads > 0 and heads % tp == 0

    if tp > 1 and nd - start >= 1 and name not in _NEVER_TP:
        cand: Optional[int] = None
        if name in _COL_PARALLEL or name in _ATTN_COL or name in _ATTN_KV:
            # column-parallel; for attention, whole-head alignment is
            # preferred but plain divisibility still shards (GSPMD
            # reshards the head reshape — costed in the roofline)
            cand = nd - 1
        elif (name in _ROW_PARALLEL or name in _ATTN_ROW) \
                and nd - start >= 2:
            cand = nd - 2
        elif name == "tok" and nd - start >= 2:
            cand = nd - 2                   # vocab-parallel embedding
        elif name == "unembed":
            cand = nd - 1                   # vocab-parallel logits
        else:  # fallback: largest dim, preferring later (output) dims
            cand = max(range(start, nd), key=lambda i: (shape[i], i)) \
                if nd > start else None
        # MoE expert stacks [*, E, d, ff]: prefer expert-parallel on E
        if name in ("wg", "wu", "wd") and nd - start >= 3 \
                and shape[nd - 3] % tp == 0:
            cand = nd - 3
        if cand is not None and cand >= start \
                and shape[cand] % tp == 0 and shape[cand] >= tp:
            _assign(spec, cand, plan.tp_axes)
            tp_dim = cand

    fsdp = plan.axis_size(plan.fsdp_axes)
    if fsdp > 1:
        order = sorted((i for i in range(start, nd) if i != tp_dim),
                       key=lambda i: -shape[i])
        for i in order:
            if shape[i] % fsdp == 0 and shape[i] >= fsdp:
                _assign(spec, i, plan.fsdp_axes)
                break
    return P(*spec)


def state_shardings(tree: PyTree, plan: ShardPlan,
                    peer_stacked: bool = True, head_dim: int = 0,
                    num_heads: int = 0, num_kv_heads: int = 0) -> PyTree:
    """NamedShardings for a (possibly peer-stacked) state pytree. Leaf
    names (last dict key on the path) select Megatron-style TP rules."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, x in flat:
        name = ""
        for p in reversed(path):
            k = getattr(p, "key", None)
            if isinstance(k, str):
                name = k
                break
        out.append(NamedSharding(plan.mesh, _leaf_spec(
            name, tuple(x.shape), plan, peer_stacked, head_dim,
            num_heads, num_kv_heads)))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_shardings(tree: PyTree, plan: ShardPlan,
                    peer_leading: bool = True) -> PyTree:
    """Token/batch arrays: leading dim(s) over DP axes.

    Peer-led train batches [P, B, n_micro, mb, ...]: dim0 -> peer axes,
    mb dim -> fsdp axes. Flat serve batches [b, ...]: dim0 -> all DP
    axes (fallback: fewer axes when b isn't divisible).
    """
    def leaf(x):
        shape = tuple(x.shape)
        spec: List[Any] = [None] * len(shape)
        if peer_leading and shape[0] == plan.n_peers and plan.n_peers > 1:
            spec[0] = plan.peer_axes if len(plan.peer_axes) > 1 \
                else plan.peer_axes[0]
            if plan.fsdp_axes:
                size = plan.axis_size(plan.fsdp_axes)
                # shard the microbatch dim (index -2 for [..., mb, seq])
                for i in range(len(shape) - 2, 0, -1):
                    if shape[i] % size == 0 and shape[i] >= size:
                        spec[i] = plan.fsdp_axes if len(plan.fsdp_axes) > 1 \
                            else plan.fsdp_axes[0]
                        break
        else:
            # flat batch: greedily shard dim0 over as many DP axes as divide
            axes = []
            for a in plan.dp_axes:
                if shape[0] % int(np.prod(
                        [plan.mesh.shape[x] for x in axes + [a]])) == 0:
                    axes.append(a)
            if axes:
                spec[0] = tuple(axes) if len(axes) > 1 else axes[0]
        return NamedSharding(plan.mesh, P(*spec))
    return jax.tree.map(leaf, tree)


def cache_shardings(cache: PyTree, plan: ShardPlan, batch_size: int
                    ) -> PyTree:
    """Decode-cache rules: the batch dim shards over DP axes; the largest
    remaining dim (the 32k seq axis of KV caches, the head/state dims of
    SSM caches) shards over TP — seq-over-model is the split-K /
    flash-decode layout, whose softmax reductions are tiny collectives.
    """
    def leaf(x):
        shape = tuple(x.shape)
        spec: List[Any] = [None] * len(shape)
        # locate the batch dim (first exact size match)
        bdim = None
        for i, s in enumerate(shape):
            if s == batch_size:
                bdim = i
                break
        if bdim is not None:
            axes = []
            for a in plan.dp_axes:
                if batch_size % int(np.prod(
                        [plan.mesh.shape[x] for x in axes + [a]])) == 0:
                    axes.append(a)
            if axes:
                spec[bdim] = tuple(axes) if len(axes) > 1 else axes[0]
        if plan.tp_axes:
            size = plan.axis_size(plan.tp_axes)
            order = sorted((i for i in range(len(shape)) if i != bdim),
                           key=lambda i: -shape[i])
            for i in order:
                if shape[i] % size == 0 and shape[i] >= size:
                    spec[i] = plan.tp_axes if len(plan.tp_axes) > 1 \
                        else plan.tp_axes[0]
                    break
        return NamedSharding(plan.mesh, P(*spec))
    return jax.tree.map(leaf, cache)


def report(tree: PyTree, plan: ShardPlan, peer_stacked: bool = True,
           **head_kw) -> Dict[str, str]:
    """Human-readable leaf -> spec table (DESIGN/EXPERIMENTS appendix)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        name = key.split("/")[-1]
        out[key] = f"{tuple(leaf.shape)} -> " \
                   f"{_leaf_spec(name, tuple(leaf.shape), plan, peer_stacked, **head_kw)}"
    return out
