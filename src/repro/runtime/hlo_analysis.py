"""Scan-aware cost analysis over optimized HLO text.

``compiled.cost_analysis()`` counts every computation ONCE — a
``lax.scan`` over 95 layers reports 1/95th of the real FLOPs (verified
in tests). Since the whole framework scans over depth (HLO-size sanity),
we re-derive FLOPs / bytes / collective bytes from ``compiled.as_text()``
with **while-loop trip-count multiplication**:

* parse the module into computations and instructions;
* ``while`` cost = trip x (body + condition), trip extracted from the
  condition's comparison constant (scan emits ``iter < L``);
* ``fusion`` FLOPs recurse into the fused computation, but bytes count
  only the fusion's operands/outputs (internal values never hit HBM —
  HloCostAnalysis' own convention);
* ``dot`` FLOPs = 2 x prod(result) x prod(contracting dims), read off
  the printed shapes; elementwise ops count 1 FLOP/element;
* collective ops (all-gather / all-reduce / reduce-scatter / all-to-all
  / collective-permute) accumulate operand bytes, multiplied by the
  enclosing loops' trip counts.

Everything is per-device: the module is the SPMD-partitioned program.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "ragged-all-to-all", "collective-permute")

_TYPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\](?:\{[^}]*\})?")

# instruction prefix: `  [ROOT] %name = ` (type + opcode parsed procedurally
# because tuple types contain nested parens and /*index=N*/ comments)
_INSTR_PREFIX_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")

# computation headers sit at column 0 and end with `{`; instructions are
# indented. Params may contain nested tuple types -> balanced extraction.
_COMP_NAME_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _type_bytes(type_str: str) -> int:
    return sum(_shape_elems(dims) * _DTYPE_BYTES[dt]
               for dt, dims in _TYPE_RE.findall(type_str))


def _first_shape(type_str: str) -> Tuple[str, List[int]]:
    m = _TYPE_RE.search(type_str)
    if not m:
        return "f32", []
    dt, dims = m.group(1), m.group(2)
    return dt, [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    param_types: Dict[str, str]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0            # compute-carrying HBM traffic
    layout_bytes: float = 0.0     # pure copy/convert/transpose traffic —
    #                               CPU-backend bf16->f32 artifacts that a
    #                               TPU build fuses away; reported separately
    collective_bytes: float = 0.0
    collective_by_op: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})
    collective_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES})

    def add(self, other: "Cost", times: float = 1.0):
        self.flops += times * other.flops
        self.bytes += times * other.bytes
        self.layout_bytes += times * other.layout_bytes
        self.collective_bytes += times * other.collective_bytes
        for k in _COLLECTIVES:
            self.collective_by_op[k] += times * other.collective_by_op[k]
            self.collective_counts[k] += times * other.collective_counts[k]


def _split_top_level(s: str) -> List[str]:
    """Split on commas outside any (), {}, [] nesting."""
    out, cur, depth = [], [], 0
    for ch in s:
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out


def _balanced(s: str, start: int) -> str:
    """Contents of the paren group opening at s[start] == '('."""
    depth = 0
    for i in range(start, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return s[start + 1:i]
    return s[start + 1:]


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if line[0] != " " and line.rstrip().endswith("{"):
            mc = _COMP_NAME_RE.match(line)
            if mc:
                params = {}
                body = _balanced(line, line.index("("))
                for p in _split_top_level(body):
                    if ":" in p:
                        pname, ptype = p.split(":", 1)
                        params[pname.strip().lstrip("%")] = ptype.strip()
                cur = Computation(mc.group(1), [], params)
                comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        ins = _parse_instr(line)
        if ins:
            cur.instrs.append(ins)
    return comps


def _parse_instr(line: str) -> Optional[Instr]:
    m = _INSTR_PREFIX_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    i = m.end()
    if i >= len(line):
        return None
    if line[i] == "(":                       # tuple result type
        inner = _balanced(line, i)
        type_str = "(" + inner + ")"
        i += len(inner) + 2
    else:
        m2 = re.match(r"\S+", line[i:])
        if not m2:
            return None
        type_str = m2.group(0)
        i += m2.end()
    m3 = _OPCODE_RE.match(line[i:])
    if not m3:
        return None
    opcode = m3.group(1)
    operand_start = i + m3.end() - 1
    operands = _operand_names(line, operand_start)
    return Instr(name, type_str, opcode, operands, line)


def _operand_names(line: str, start: int) -> List[str]:
    # depth counts (), [] and {} alike: shape strings like
    # f32[256,256]{1,0} carry commas that must not split operands
    depth, i, toks, cur = 0, start, [], []
    while i < len(line):
        ch = line[i]
        if ch == "(":
            depth += 1
            if depth > 1:
                cur.append(ch)
        elif ch == ")":
            depth -= 1
            if depth == 0:
                toks.append("".join(cur))
                break
            cur.append(ch)
        elif ch in "[{":
            depth += 1
            cur.append(ch)
        elif ch in "]}":
            depth -= 1
            cur.append(ch)
        elif ch == "," and depth == 1:
            toks.append("".join(cur))
            cur = []
        else:
            if depth >= 1:
                cur.append(ch)
        i += 1
    out = []
    for tok in toks:
        tok = tok.strip()
        m = re.search(r"%([\w.\-]+)\s*$", tok)
        if m:
            out.append(m.group(1))
        elif tok and "[" not in tok:
            out.append(tok.lstrip("%"))
    return out


_ATTR_COMP_RE = {
    "body": re.compile(r"body=%?([\w.\-]+)"),
    "condition": re.compile(r"condition=%?([\w.\-]+)"),
    "calls": re.compile(r"calls=%?([\w.\-]+)"),
    "branches": re.compile(r"branch_computations=\{([^}]*)\}"),
    "to_apply": re.compile(r"to_apply=%?([\w.\-]+)"),
}

_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "iota"}


class ModuleAnalysis:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self._cost_cache: Dict[str, Cost] = {}
        self.entry = self._find_entry(text)
        self.unknown_trip_whiles = 0

    def _find_entry(self, text: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
        if m:
            return m.group(1)
        # fallback: the computation named like main
        for name in self.comps:
            if "main" in name:
                return name
        return next(iter(self.comps))

    # ------------------------------------------------------------------
    def cost(self) -> Cost:
        return self.comp_cost(self.entry)

    def comp_cost(self, comp_name: str) -> Cost:
        if comp_name in self._cost_cache:
            return self._cost_cache[comp_name]
        comp = self.comps.get(comp_name)
        total = Cost()
        self._cost_cache[comp_name] = total  # guards recursion
        if comp is None:
            return total
        types = dict(comp.param_types)
        for ins in comp.instrs:
            types[ins.name] = ins.type_str
        for ins in comp.instrs:
            total.add(self._instr_cost(ins, types, comp))
        return total

    # ------------------------------------------------------------------
    def _instr_cost(self, ins: Instr, types: Dict[str, str],
                    comp: Computation) -> Cost:
        c = Cost()
        op = ins.opcode
        out_bytes = _type_bytes(ins.type_str)
        opnd_bytes = sum(_type_bytes(types.get(o, "")) for o in ins.operands)

        if op == "while":
            body = _ATTR_COMP_RE["body"].search(ins.line)
            cond = _ATTR_COMP_RE["condition"].search(ins.line)
            # prefer XLA's own analysis: backend_config known_trip_count
            mt = re.search(r'known_trip_count[":{\s]+n[":\s]+"?(\d+)',
                           ins.line)
            if mt:
                trip = int(mt.group(1))
            elif cond:
                trip = self._trip_count(cond.group(1))
            else:
                trip = 1
            if body:
                c.add(self.comp_cost(body.group(1)), trip)
            if cond:
                c.add(self.comp_cost(cond.group(1)), trip)
            # loop carries are buffer-aliased in place — no traffic for
            # the while op itself; body slice/DUS reads are counted above
            return c
        if op == "conditional":
            mb = _ATTR_COMP_RE["branches"].search(ins.line)
            if mb:
                branch_costs = [self.comp_cost(b.strip().lstrip("%"))
                                for b in mb.group(1).split(",")]
                worst = max(branch_costs, key=lambda x: x.flops,
                            default=Cost())
                c.add(worst)
            c.bytes += out_bytes + opnd_bytes
            return c
        if op in ("call", "fusion", "async-start"):
            mcalls = _ATTR_COMP_RE["calls"].search(ins.line)
            if mcalls:
                inner = self.comp_cost(mcalls.group(1))
                # fused internals never touch HBM: take flops+collectives
                c.flops += inner.flops
                c.collective_bytes += inner.collective_bytes
                for k in _COLLECTIVES:
                    c.collective_by_op[k] += inner.collective_by_op[k]
                    c.collective_counts[k] += inner.collective_counts[k]
                fb = self._fusion_bytes(mcalls.group(1), ins, out_bytes,
                                        types)
                if self._layout_only(mcalls.group(1)):
                    c.layout_bytes += fb
                else:
                    c.bytes += fb
            else:
                c.bytes += out_bytes + opnd_bytes
            return c

        base = op
        for suffix in ("-start", "-done"):
            if base.endswith(suffix):
                base = base[: -len(suffix)]
        if base in _COLLECTIVES:
            if not op.endswith("-done"):
                c.collective_bytes += opnd_bytes
                c.collective_by_op[base] += opnd_bytes
                c.collective_counts[base] += 1
                c.bytes += out_bytes + opnd_bytes
            return c

        if op in _SKIP_BYTES_OPS:
            return c
        # slice-like ops read/write only the moved window, not the buffer
        if op in ("slice", "dynamic-slice"):
            c.bytes += 2.0 * out_bytes
            return c
        if op == "dynamic-update-slice":
            upd = _type_bytes(types.get(ins.operands[1], "")) \
                if len(ins.operands) > 1 else out_bytes
            c.bytes += 2.0 * upd
            return c
        if op == "gather":
            idx = _type_bytes(types.get(ins.operands[1], "")) \
                if len(ins.operands) > 1 else 0
            c.bytes += 2.0 * out_bytes + idx
            return c
        if op == "scatter":
            upd = _type_bytes(types.get(ins.operands[-1], "")) \
                if ins.operands else out_bytes
            c.bytes += 2.0 * upd
            return c
        if op in ("broadcast", "reshape", "copy", "transpose", "convert",
                  "reverse"):
            c.layout_bytes += 2.0 * out_bytes
            return c
        if op in ("concatenate", "pad"):
            c.bytes += 2.0 * out_bytes
            return c
        c.bytes += out_bytes + opnd_bytes

        if op == "dot":
            c.flops += self._dot_flops(ins, types)
        elif op == "convolution":
            c.flops += self._conv_flops(ins, types)
        elif op in ("exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                    "logistic", "sine", "cosine", "erf"):
            _, dims = _first_shape(ins.type_str)
            c.flops += 8.0 * _prod(dims)       # transcendental weight
        elif op in ("reduce", "reduce-window"):
            c.flops += float(opnd_bytes) / 4.0  # ~1 op per input element
        else:
            _, dims = _first_shape(ins.type_str)
            c.flops += float(_prod(dims))
        return c

    # ops whose fusion is pure re-typing/re-layout of VALUES ALREADY READ
    # elsewhere. Deliberately excludes slice/dynamic-slice (per-layer
    # weight reads from stacked buffers are real HBM traffic) and
    # dynamic-update-slice (activation/grad saves are real writes).
    _LAYOUT_OPS = frozenset({
        "copy", "convert", "bitcast", "transpose", "reshape", "broadcast",
        "parameter", "constant", "tuple", "get-tuple-element"})

    def _layout_only(self, comp_name: str) -> bool:
        """True when the fused computation only moves/re-types data —
        CPU-backend bf16<->f32 staging a TPU build would fuse away."""
        comp = self.comps.get(comp_name)
        if comp is None:
            return False
        return all(i.opcode in self._LAYOUT_OPS for i in comp.instrs)

    # ------------------------------------------------------------------
    def _fusion_bytes(self, comp_name: str, ins: Instr, out_bytes: float,
                      types: Dict[str, str]) -> float:
        """HBM bytes of a fusion: output written + parameters read.

        Refinements over naive operand+output counting:
        * a parameter only consumed via slice/dynamic-slice/gather reads
          just the sliced window (scanned weight stacks);
        * a parameter flowing into dynamic-update-slice operand 0 is an
          in-place aliased accumulator: the full buffer is neither read
          nor rewritten — only the update window is written (gradient
          accumulation into stacked [L, ...] buffers).
        """
        comp = self.comps.get(comp_name)
        if comp is None:
            return out_bytes + float(sum(_type_bytes(types.get(o, ""))
                                         for o in ins.operands))
        inner_types = dict(comp.param_types)
        for inner in comp.instrs:
            inner_types[inner.name] = inner.type_str

        aliased: Dict[str, float] = {}      # param -> update bytes
        for inner in comp.instrs:
            if inner.opcode == "dynamic-update-slice" and inner.operands:
                dst = inner.operands[0]
                if dst in comp.param_types:
                    upd = (_type_bytes(inner_types.get(inner.operands[1], ""))
                           if len(inner.operands) > 1 else 0)
                    aliased[dst] = aliased.get(dst, 0.0) + float(upd)

        reads: Dict[str, float] = {}
        for inner in comp.instrs:
            for o in inner.operands:
                if o not in comp.param_types or o in aliased:
                    continue
                full = float(_type_bytes(comp.param_types[o]))
                if inner.opcode in ("slice", "dynamic-slice", "gather"):
                    contrib = float(_type_bytes(inner.type_str))
                else:
                    contrib = full
                reads[o] = max(reads.get(o, 0.0), contrib)

        total_out = out_bytes
        for p, upd in aliased.items():
            total_out -= float(_type_bytes(comp.param_types[p]))
            total_out += upd
        return max(total_out, 0.0) + float(sum(reads.values()))

    # ------------------------------------------------------------------
    def _dot_flops(self, ins: Instr, types: Dict[str, str]) -> float:
        _, out_dims = _first_shape(ins.type_str)
        mlhs = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
        if not mlhs or not ins.operands:
            return 2.0 * _prod(out_dims)
        _, lhs_dims = _first_shape(types.get(ins.operands[0], ""))
        k = 1
        for d in mlhs.group(1).split(","):
            if d and int(d) < len(lhs_dims):
                k *= lhs_dims[int(d)]
        return 2.0 * _prod(out_dims) * k

    def _conv_flops(self, ins: Instr, types: Dict[str, str]) -> float:
        _, out_dims = _first_shape(ins.type_str)
        if len(ins.operands) < 2:
            return 2.0 * _prod(out_dims)
        _, ker = _first_shape(types.get(ins.operands[1], ""))
        # kernel = spatial... x in_features x out_features (dim order
        # varies; product/out_features is the per-output work)
        work = _prod(ker) / max(out_dims[-1] if out_dims else 1, 1)
        return 2.0 * _prod(out_dims) * max(work, 1.0)

    def _trip_count(self, cond_name: str) -> int:
        comp = self.comps.get(cond_name)
        if comp is None:
            self.unknown_trip_whiles += 1
            return 1
        consts = []
        for ins in comp.instrs:
            m = re.search(r"constant\(([0-9]+)\)", ins.line)
            if m:
                consts.append(int(m.group(1)))
        if not consts:
            self.unknown_trip_whiles += 1
            return 1
        return max(consts)


def _prod(dims: List[int]) -> float:
    n = 1.0
    for d in dims:
        n *= d
    return n


def analyze_text(text: str) -> Dict[str, float]:
    """Scan-aware per-device cost summary of an optimized HLO module."""
    mod = ModuleAnalysis(text)
    c = mod.cost()
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "layout_bytes": c.layout_bytes,
        "collective_bytes": c.collective_bytes,
        "collective_by_op": dict(c.collective_by_op),
        "collective_counts": dict(c.collective_counts),
        "unknown_trip_whiles": mod.unknown_trip_whiles,
    }
