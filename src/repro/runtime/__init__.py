from repro.runtime.lifecycle import (CHURN_MODELS, ChurnModel, ChurnTick,
                                     LifecycleTick, MembershipEvent,
                                     PeerLifecycle, build_churn_model,
                                     build_lifecycle, load_trace,
                                     save_trace)
from repro.runtime.sharding import (ShardPlan, make_shard_plan,
                                    state_shardings, batch_shardings)
