from repro.runtime.sharding import (ShardPlan, make_shard_plan,
                                    state_shardings, batch_shardings)
