"""Superpeer hybrid engine: closed-form intra-cluster tiers + the
vector engine for everything else — the ``"super_sim"`` backend.

The vectorized engine (``vector_network.py``) still materializes every
message: one MAR iteration at N=2^20 is ~21M (src, dst, nbytes) tuples
before a single timing op runs. This engine never builds them. It
consumes the symbolic :class:`~repro.core.transport.SuperMessagePlan`
recipe and splits each technique's round structure at a grid level:

* **intra-cluster rounds** — the trailing grid coordinates, which
  under the clustered placement policy (``core/placement.py``) stay
  inside one contiguous, link-homogeneous cluster — are timed by the
  closed-form group recurrences of ``vector_network.py``
  (``_closed_allpairs_round`` and friends): O(groups) vector ops per
  round instead of O(messages), bitwise equal to the materialized
  engines on any *per-peer* link profile (the closed forms reproduce
  the rectangle-cumsum arithmetic term by term; neutral pairwise
  cap/xlat fills and ``min(x, inf)`` / ``+ 0.0`` are exact no-ops);
* **inter-cluster rounds** — leading coordinates whose groups span
  clusters, plus any round that needs non-neutral pairwise WAN terms —
  are materialized per round as arrays and pushed through the shared
  ``_timed_round`` step, keeping regions-profile pair terms exact;
* **loss** is all-or-nothing: per-message drops consume a seeded RNG
  stream in materialized-message order, so a lossy link model routes
  the whole plan through an internal ``VectorNetworkSim`` with synced
  seed/iteration counters — transcripts (drops included) stay
  identical to running ``"vector_sim"`` directly.

Exactness contract (DESIGN.md §15): **bytes are exact always**; times
are bit-equal to ``vector_sim`` on uniform / wireless (any per-peer
profile) and on regions wherever clustered placement makes trailing
axes region-pure; the opt-in ``approx_level`` trades exactness for a
*bounded* error — cluster-mean link rates with relative error ≤ the
links' max relative deviation from their cluster means (every atomic
time term lands within (1 ± δ) of its exact value, and the engine's
only combinators, ``+`` of nonnegative terms and ``max``, preserve
that interval).

Per-link accounting reuses :class:`LinkAccounting` peer mode; past
``link_budget`` estimated messages (default 4M) the deferred per-link
top-k buffers are disabled (``track_links=False``) — per-node tx/rx
totals stay exact, only the heavy-link dict comes back empty.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.transport import (ArrayMessagePlan, MessagePlan,
                                  SuperMessagePlan, _active_ids,
                                  _group_rows, _leaf_groups,
                                  _mar_round_arrays, _valid_slots,
                                  mkd_round_arrays)
from repro.runtime.network import LinkModel, build_link_model
from repro.runtime.transport_base import (LINK_DETAIL_MAX_PEERS,
                                          LinkAccounting, Transcript,
                                          Transport, register_transport)
from repro.runtime.vector_network import (VectorNetworkSim,
                                          _closed_allpairs_round,
                                          _closed_fan_in_round,
                                          _closed_fan_out_round,
                                          _closed_leaf_bcast_round,
                                          _closed_leaf_gather_round,
                                          _closed_single_round,
                                          _extended_links, _row_counts,
                                          _timed_round)

__all__ = ["SuperNetworkSim", "approx_link_arrays"]


def approx_link_arrays(links: LinkModel, plan, level: int
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                  float]:
    """Cluster-mean link arrays for the reduced intra-cluster tier.

    Clusters are the contiguous slot blocks below grid ``level`` (block
    size = prod(dims[level:])). Per cluster, the per-peer *rates* —
    seconds-per-byte ``1/up`` and ``1/down``, and ``lat`` — are
    replaced by their cluster means. Returns ``(up_hat, down_hat,
    lat_hat, delta)`` where ``delta`` is the max relative deviation of
    any peer's rate from its cluster mean: every closed-form time
    computed from the hat arrays is within ``(1 ± delta)`` of the exact
    value (each atomic term is, and ``+`` / ``max`` preserve the
    interval). ``delta == 0`` exactly when clusters are link-
    homogeneous.
    """
    n = links.n_peers
    level = max(0, min(int(level), plan.depth))
    block = int(np.prod(plan.dims[level:], dtype=np.int64))
    cluster = plan.slot_of(np.arange(n)) // block
    up_hat = links.up.copy()
    down_hat = links.down.copy()
    lat_hat = links.lat.copy()
    delta = 0.0

    def _rel(vals: np.ndarray, mean: float) -> float:
        if mean == 0.0:
            return 0.0 if not vals.any() else np.inf
        return float(np.abs(vals - mean).max() / mean)

    for c in np.unique(cluster):
        ids = np.flatnonzero(cluster == c)
        iu = 1.0 / links.up[ids]
        idn = 1.0 / links.down[ids]
        la = links.lat[ids]
        mu, md, ml = float(iu.mean()), float(idn.mean()), float(la.mean())
        up_hat[ids] = 1.0 / mu
        down_hat[ids] = 1.0 / md
        lat_hat[ids] = ml
        delta = max(delta, _rel(iu, mu), _rel(idn, md), _rel(la, ml))
    return up_hat, down_hat, lat_hat, delta


class _GridInfo:
    """Per-grid derived state, cached across iterations (the grid
    object is immutable; regroup swaps it, naturally invalidating)."""

    def __init__(self, plan, links: LinkModel,
                 approx_level: Optional[int]):
        self.plan = plan
        self.rows: Dict[int, np.ndarray] = {}
        self._cols: Dict[Tuple[int, float], "_PairData"] = {}
        self._slot: Dict[float, "_SlotData"] = {}
        n_real = links.n_peers
        # axis purity: an axis is closed-form-eligible iff no group of
        # that round spans regions (pairwise terms stay neutral inside
        # a region). Profiles without pair terms are pure everywhere;
        # pairwise profiles without region labels are pure nowhere.
        if not getattr(links, "has_pair_terms", False):
            self.pure = np.ones(plan.depth, bool)
        elif getattr(links, "region_of", None) is None:
            self.pure = np.zeros(plan.depth, bool)
        else:
            reg = links.region_of()
            self.pure = np.empty(plan.depth, bool)
            for axis in range(plan.depth):
                rows = self.axis_rows(axis)
                real = rows < n_real
                r = np.where(real, reg[np.where(real, rows, 0)], -1)
                first = r[np.arange(rows.shape[0]),
                          np.argmax(real, axis=1)]
                self.pure[axis] = bool(
                    ((r == first[:, None]) | ~real).all())
        self.approx: Optional[Tuple[np.ndarray, np.ndarray,
                                    np.ndarray]] = None
        self.delta = 0.0
        if approx_level is not None:
            uh, dh, lh, self.delta = approx_link_arrays(
                links, plan, approx_level)
            self.approx = (uh, dh, lh)

    def axis_rows(self, axis: int) -> np.ndarray:
        rows = self.rows.get(axis)
        if rows is None:
            rows = self.rows[axis] = _group_rows(self.plan, axis)
        return rows

    def pair_data(self, axis: int, b: float, up: np.ndarray,
                  down: np.ndarray, lat: np.ndarray) -> "_PairData":
        """For a dims[axis]==2 round: every iteration-invariant array
        the pair round needs, cached per (axis, model bytes). Link
        values (and the derived transfer/occupancy times ``b/rate``)
        are frozen at first use; ``resize`` — the only sanctioned link
        mutation — drops the whole cache."""
        pd = self._cols.get((axis, b))
        if pd is None:
            pd = self._cols[(axis, b)] = _PairData(
                self.axis_rows(axis), b, up, down, lat)
        return pd

    def slot_data(self, b: float, up: np.ndarray, down: np.ndarray,
                  lat: np.ndarray) -> "_SlotData":
        sd = self._slot.get(b)
        if sd is None:
            sd = self._slot[b] = _SlotData(self.plan, b, up, down, lat)
        return sd


class _SlotData:
    """Slot-ordered constants for the all-closed, full-participation
    MAR run on an all-binary grid — the large-N hot loop.

    In slot order the two members of every axis-``a`` group sit in the
    contiguous lanes of ``slot_ready.reshape(pre, 2, post)``, so each
    round is pure elementwise math on views: no index gathers at all.
    Entity↔slot conversion happens once per run, and per-node seconds
    totals accumulate in slot order (bitwise safe — each node adds its
    per-round values in the same order, just at a different address).
    Per-pair arithmetic is term-for-term :meth:`SuperNetworkSim._pair_round`,
    with the within-pair send order flipped where the placement orders
    a group's entity ids against its slot coordinates — a symmetric
    exchange, so every transcript field is unchanged."""

    __slots__ = ("cap", "ent", "sl", "identity", "axes",
                 "t0", "t1", "t2")

    def __init__(self, plan, b: float, up: np.ndarray,
                 down: np.ndarray, lat: np.ndarray):
        cap = plan.capacity
        self.cap = cap
        self.identity = plan.placement is None
        if self.identity:
            self.ent = self.sl = np.arange(cap)
        else:
            self.ent, self.sl = plan._entity_at, plan._slot_of
        up_s = up[self.ent]
        down_s = down[self.ent]
        lat_s = lat[self.ent]
        self.axes = []
        for a in range(plan.depth):
            pre = int(np.prod(plan.dims[:a], dtype=np.int64))
            post = cap // (pre * 2)
            u = up_s.reshape(pre, 2, post)
            d = down_s.reshape(pre, 2, post)
            lv = lat_s.reshape(pre, 2, post)
            self.axes.append(
                (pre, post,
                 b / np.minimum(u[:, 0], d[:, 1]),      # tx 0 -> 1
                 b / np.minimum(u[:, 1], d[:, 0]),      # tx 1 -> 0
                 b / np.ascontiguousarray(u[:, 0]),     # occ lane 0
                 b / np.ascontiguousarray(u[:, 1]),     # occ lane 1
                 np.ascontiguousarray(lv[:, 0]),
                 np.ascontiguousarray(lv[:, 1])))
        m = cap // 2
        self.t0 = np.empty(m)
        self.t1 = np.empty(m)
        self.t2 = np.empty(m)


class _PairData:
    """Frozen per-(axis, bytes) arrays for the dims==2 MAR fast path.

    The links and payload size never change within a grid's lifetime,
    so the per-message transfer time ``tx01 = b / min(up0, down1)``,
    the sender occupancy ``occ = b / up``, the latency gathers, and
    the round's (senders, receivers) id layout are all constants; the
    hot loop is left with two gathers of ``ready`` plus adds/maxima.
    Scratch buffers are preallocated and reused across rounds (their
    contents never outlive one round)."""

    __slots__ = ("s0", "s1", "cs", "cd", "l0", "l1", "tx01", "tx10",
                 "occ0", "occ1", "t0", "t1", "t2", "secs")

    def __init__(self, rows: np.ndarray, b: float, up: np.ndarray,
                 down: np.ndarray, lat: np.ndarray):
        s0 = rows[:, 0].copy()
        s1 = rows[:, 1].copy()
        self.s0, self.s1 = s0, s1
        self.cs = np.concatenate([s0, s1])
        self.cd = np.concatenate([s1, s0])
        self.l0 = lat[s0]
        self.l1 = lat[s1]
        self.tx01 = b / np.minimum(up[s0], down[s1])
        self.tx10 = b / np.minimum(up[s1], down[s0])
        self.occ0 = b / up[s0]
        self.occ1 = b / up[s1]
        m = s0.size
        self.t0 = np.empty(m)
        self.t1 = np.empty(m)
        self.t2 = np.empty(m)
        self.secs = np.empty(2 * m)


@register_transport
class SuperNetworkSim(Transport):
    """Hybrid closed-form / vectorized plan executor — the
    ``"super_sim"`` transport backend.

    Accepts :class:`SuperMessagePlan` (the symbolic hot path) or any
    list/array plan (delegated verbatim to an internal
    :class:`VectorNetworkSim` over the same links with synced
    seed/iteration counters, so probe plans and mixed callers see
    ``vector_sim``-identical transcripts). ``split_level`` forces
    grid axes below it onto the materialized path (``None`` = closed
    forms wherever exact); ``approx_level`` opts into the bounded-error
    cluster-mean tier.
    """

    name = "super_sim"
    plan_format = "super"

    def __init__(self, n_peers: int, profile: str = "uniform",
                 seed: int = 0,
                 link_params: Optional[Dict[str, Any]] = None,
                 links: Optional[LinkModel] = None,
                 split_level: Optional[int] = None,
                 approx_level: Optional[int] = None,
                 link_budget: int = 500_000):
        self.links = links if links is not None else build_link_model(
            profile, n_peers, seed=seed, **(link_params or {}))
        self.seed = seed
        self.clock = 0.0
        self.iterations = 0
        self.split_level = split_level
        self.approx_level = approx_level
        self.link_budget = link_budget
        self._vec: Optional[VectorNetworkSim] = None
        self._info: Optional[_GridInfo] = None

    @classmethod
    def from_config(cls, n_peers, *, profile=None, seed=0,
                    link_params=None, **kwargs):
        return cls(n_peers, profile=profile or "uniform", seed=seed,
                   link_params=link_params, **kwargs)

    @property
    def n_peers(self) -> int:
        return self.links.n_peers

    @property
    def lossless(self) -> bool:
        return not self.links.loss.any()

    def resize(self, new_n: int) -> None:
        self.links.resize(new_n)
        self._info = None

    # ------------------------------------------------------------------
    def _delegate(self, plan: Any,
                  compute_s: Optional[np.ndarray]) -> Transcript:
        if self._vec is None:
            self._vec = VectorNetworkSim(self.links.n_peers,
                                         links=self.links)
        vec = self._vec
        vec.seed = self.seed
        vec.iterations = self.iterations
        tr = vec.run(plan, compute_s=compute_s)
        self.clock += tr.iteration_s
        self.iterations += 1
        return tr

    def _grid_info(self, plan) -> _GridInfo:
        if self._info is None or self._info.plan is not plan:
            self._info = _GridInfo(plan, self.links, self.approx_level)
        return self._info

    def run(self, plan: Any,
            compute_s: Optional[np.ndarray] = None,
            payloads: Optional[Any] = None) -> Transcript:
        """Execute one iteration's plan; symbolic recipes run hybrid,
        everything else (and every lossy profile) delegates."""
        if not isinstance(plan, SuperMessagePlan):
            return self._delegate(plan, compute_s)
        if (self.links.loss.any() or plan.mode != "naive"
                or plan.technique == "ar"):
            # per-message loss draws need the materialized RNG stream;
            # butterfly MAR and all-to-all have no structured rounds
            return self._delegate(plan.to_array_plan(), compute_s)
        return self._run_hybrid(plan, compute_s)

    # ------------------------------------------------------------------
    @staticmethod
    def _pair_round(pd: "_PairData", ready: np.ndarray,
                    valid: np.ndarray, full: bool, b: float,
                    acct: LinkAccounting
                    ) -> Tuple[np.ndarray, int]:
        """Specialized dims[axis]==2 MAR round: one symmetric exchange
        per group, all lanes at once — the N=2^20 hot loop. Same
        arithmetic as :func:`_closed_allpairs_round` (send start =
        ready, arrival = ((start + tx) + lat_s) + lat_d, drain =
        start + occ, node ready = max(drain, arrival)), message order
        [position-0 senders, position-1 senders]. Transfer/occupancy
        times come precomputed in ``pd``; the full-participation case
        runs allocation-free on ``pd``'s scratch buffers and updates
        ``ready`` in place (both lane gathers are copies)."""
        r0 = ready[pd.s0]
        r1 = ready[pd.s1]
        a01 = np.add(r0, pd.tx01, out=pd.t0)
        np.add(a01, pd.l0, out=a01)
        np.add(a01, pd.l1, out=a01)
        a10 = np.add(r1, pd.tx10, out=pd.t1)
        np.add(a10, pd.l1, out=a10)
        np.add(a10, pd.l0, out=a10)
        m = pd.s0.size
        secs = pd.secs
        np.subtract(a01, r0, out=secs[:m])
        np.subtract(a10, r1, out=secs[m:])
        # new0 = max(r0 + occ0, arr10) lands in a10's buffer (and new1
        # in a01's) once the arrivals have fed the seconds above
        np.maximum(np.add(r1, pd.occ1, out=pd.t2), a01, out=a01)
        np.maximum(np.add(r0, pd.occ0, out=pd.t2), a10, out=a10)
        if full:
            ready[pd.s0] = a10
            ready[pd.s1] = a01
            acct.add_uniform_round(pd.cs, pd.cd, b, secs)
            return ready, 2 * m
        both = valid[pd.s0] & valid[pd.s1]
        if not both.any():
            return ready, 0
        ready[pd.s0[both]] = a10[both]
        ready[pd.s1[both]] = a01[both]
        ss = np.concatenate([pd.s0[both], pd.s1[both]])
        dd = np.concatenate([pd.s1[both], pd.s0[both]])
        acct.add_batch(ss, dd, np.full(ss.size, b),
                       np.concatenate([secs[:m][both], secs[m:][both]]),
                       unique=True)
        return ready, int(ss.size)

    # ------------------------------------------------------------------
    @staticmethod
    def _mar_slot_run(sd: "_SlotData", ready: np.ndarray, rounds: int,
                      b: float, acct: LinkAccounting,
                      tr: Transcript) -> np.ndarray:
        """All rounds of an all-closed, full-participation MAR
        iteration on an all-binary grid, in slot order (see
        :class:`_SlotData`). Per-node seconds totals accumulate in the
        slot-ordered ``stx``/``srx`` and flush once — valid only when
        nothing else contributes to the accounting totals this run
        (callers gate on no-KD, peer mode, no per-link tracking)."""
        cap = sd.cap
        slot_ready = ready if sd.identity else ready[sd.ent]
        stx = np.zeros(cap)
        srx = np.zeros(cap)
        rb = b * cap
        n_axes = len(sd.axes)
        for g in range(rounds):
            pre, post, tx01, tx10, occ0, occ1, l0, l1 = \
                sd.axes[g % n_axes]
            r = slot_ready.reshape(pre, 2, post)
            r0, r1 = r[:, 0], r[:, 1]
            t0 = sd.t0.reshape(pre, post)
            t1 = sd.t1.reshape(pre, post)
            t2 = sd.t2.reshape(pre, post)
            a01 = np.add(r0, tx01, out=t0)
            np.add(a01, l0, out=a01)
            np.add(a01, l1, out=a01)
            a10 = np.add(r1, tx10, out=t1)
            np.add(a10, l1, out=a10)
            np.add(a10, l0, out=a10)
            sx = stx.reshape(pre, 2, post)
            rx = srx.reshape(pre, 2, post)
            sec = np.subtract(a01, r0, out=t2)
            sx[:, 0] += sec
            rx[:, 1] += sec
            sec = np.subtract(a10, r1, out=t2)
            sx[:, 1] += sec
            rx[:, 0] += sec
            # node ready = max(own drain, peer's arrival); lane 0 is
            # untouched while lane 1 is written, so r0 stays the
            # round's start values
            np.maximum(np.add(r1, occ1, out=t2), a01, out=t2)
            r[:, 1] = t2
            np.maximum(np.add(r0, occ0, out=t2), a10, out=t2)
            r[:, 0] = t2
            acct.tx += b
            acct.rx += b
            tr.n_messages += cap
            tr.total_bytes += rb
            tr.bytes_by_round.append(rb)
            tr.round_s.append(float(slot_ready.max()))
        acct.tx_s += stx if sd.identity else stx[sd.sl]
        acct.rx_s += srx if sd.identity else srx[sd.sl]
        return slot_ready if sd.identity else slot_ready[sd.sl]

    # ------------------------------------------------------------------
    def _run_hybrid(self, plan: SuperMessagePlan,
                    compute_s: Optional[np.ndarray]) -> Transcript:
        links = self.links
        n_real = links.n_peers
        n_nodes = max(plan.n_nodes, n_real)
        up, down, lat, _ = _extended_links(links, n_nodes)
        ready = np.zeros(n_nodes)
        if compute_s is not None:
            ready[:min(n_real, len(compute_s))] = compute_s[:n_real]
        tr = Transcript(technique=plan.technique,
                        lost_senders=np.zeros(n_real, bool))
        # small fleets (the exact-dict / parity tier) always track
        # per-link detail like the vector engine; past that, the
        # deferred top-k buffers only run under the message budget
        acct = LinkAccounting(
            n_nodes, n_real,
            track_links=(n_real <= 2 * LINK_DETAIL_MAX_PEERS
                         or plan.n_messages_estimate()
                         <= self.link_budget))
        info = self._grid_info(plan.plan)
        grid = plan.plan
        active = _active_ids(plan.mask, n_real)
        b = float(plan.model_bytes)
        split = (0 if self.split_level is None
                 else max(0, min(self.split_level, grid.depth)))
        # closed rounds use the (possibly cluster-mean) hat arrays;
        # materialized rounds always use the exact ones
        if info.approx is not None:
            c_up, c_down, c_lat = [
                np.concatenate([h, a[n_real:]])
                for h, a in zip(info.approx, (up, down, lat))]
        else:
            c_up, c_down, c_lat = up, down, lat

        def sink(nb):
            def _s(s, d, secs):
                acct.add_batch(s, d, np.full(s.size, nb), secs)
            return _s

        def finish_round(count: int, nb: float) -> None:
            tr.n_messages += count
            rbytes = nb * count
            tr.total_bytes += rbytes
            tr.bytes_by_round.append(rbytes)
            tr.round_s.append(float(ready.max()))

        def vector_round(s, d, nb_arr) -> None:
            """The materialized path: one round through the shared
            vector-engine step, pairwise terms included."""
            nonlocal ready
            tr.n_messages += s.size
            rbytes = float(nb_arr.sum())
            tr.total_bytes += rbytes
            nz = s != d
            sz, dz, bz = s[nz], d[nz], nb_arr[nz]
            if sz.size == 0:
                acct.add_batch(s, d, nb_arr)
                tr.bytes_by_round.append(rbytes)
                tr.round_s.append(float(ready.max()))
                return
            cap = np.full(sz.size, np.inf)
            xlat = np.zeros(sz.size)
            if getattr(links, "has_pair_terms", False):
                both = (sz < n_real) & (dz < n_real)
                pc, pl = links.pair_terms(sz[both], dz[both])
                cap[both] = pc
                xlat[both] = pl
            senders, drain, arr, start = _timed_round(
                ready, sz, dz, bz, up, down, lat, cap, xlat)
            new_ready = ready.copy()
            new_ready[senders] = np.maximum(ready[senders], drain)
            np.maximum.at(new_ready, dz, arr)
            secs = np.zeros(s.size)
            secs[nz] = arr - start
            acct.add_batch(s, d, nb_arr, secs)
            ready = new_ready
            tr.bytes_by_round.append(rbytes)
            tr.round_s.append(float(ready.max()))

        if plan.use_kd:
            # MKD prefix: teacher pulls + logit messages, materialized
            # (mixed byte sizes, interleaved order) at raw model bytes
            for s, d, nb_arr in mkd_round_arrays(
                    grid, plan.mask, plan.raw_model_bytes,
                    plan.kd_logit_bytes, num_rounds=plan.num_rounds):
                vector_round(s, d, nb_arr)

        tech = plan.technique
        if tech == "mar":
            valid = _valid_slots(grid, active)
            full = bool(valid.all())
            rounds = (grid.depth if plan.num_rounds is None
                      else plan.num_rounds)
            if (full and not plan.use_kd and split == 0
                    and grid.capacity == n_real
                    and set(grid.dims) == {2}
                    and bool(info.pure.all())
                    and not acct.exact and not acct.track_links):
                ready = self._mar_slot_run(
                    info.slot_data(b, c_up, c_down, c_lat), ready,
                    rounds, b, acct, tr)
                rounds = 0  # all done, gather-free
            for g in range(rounds):
                axis = g % grid.depth
                if axis >= split and info.pure[axis]:
                    if grid.dims[axis] == 2 and grid.capacity <= n_real:
                        ready, count = self._pair_round(
                            info.pair_data(axis, b, c_up, c_down,
                                           c_lat),
                            ready, valid, full, b, acct)
                        finish_round(count, b)
                        continue
                    rows = info.axis_rows(axis)
                    vrows = valid[rows]
                    kk = _row_counts(vrows)
                    count = int((kk * (kk - 1)).sum())
                    chunks: List[Tuple[np.ndarray, np.ndarray,
                                       np.ndarray]] = []
                    ready = _closed_allpairs_round(
                        ready, rows, vrows, b, c_up, c_down, c_lat,
                        sink=lambda s, d, secs: chunks.append(
                            (s, d, secs)), kk=kk)
                    if chunks:
                        cs = np.concatenate([c[0] for c in chunks])
                        cd = np.concatenate([c[1] for c in chunks])
                        csec = np.concatenate([c[2] for c in chunks])
                        acct.add_batch(cs, cd, np.full(cs.size, b),
                                       csec)
                    finish_round(count, b)
                else:
                    rows = info.axis_rows(axis)
                    s, d, nb_arr = _mar_round_arrays(rows, valid[rows],
                                                     b)
                    vector_round(s, d, nb_arr)
        elif tech == "gossip":
            n = grid.n_peers
            rounds = plan.num_rounds
            if rounds is None:
                rounds = max(1, int(math.ceil(math.log2(max(n, 2)))))
            nb_arr = np.full(active.size, b)
            for r in range(rounds):
                d_all = (active + (1 << r)) % n
                if (1 << r) % n == 0 or active.size == 0:
                    # all loopbacks (or nobody active): billed, instant
                    acct.add_batch(active, d_all, nb_arr)
                    finish_round(active.size, b)
                elif getattr(links, "has_pair_terms", False):
                    vector_round(active, d_all, nb_arr)
                else:
                    ready = _closed_single_round(
                        ready, active, d_all, b, c_up, c_down, c_lat,
                        sink=sink(b))
                    finish_round(active.size, b)
        elif tech == "fedavg":
            server = grid.n_peers
            if active.size:
                ready = _closed_fan_in_round(ready, active, server, b,
                                             c_up, c_down, c_lat,
                                             sink=sink(b))
            finish_round(active.size, b)
            if active.size:
                ready = _closed_fan_out_round(ready, server, active, b,
                                              c_up, c_down, c_lat,
                                              sink=sink(b))
            finish_round(active.size, b)
        elif tech == "rdfl":
            k = active.size
            if k >= 2:
                d_all = np.roll(active, -1)
                pairwise = getattr(links, "has_pair_terms", False)
                nb_arr = np.full(k, b)
                for _ in range(k - 1):
                    if pairwise:
                        vector_round(active, d_all, nb_arr)
                    else:
                        ready = _closed_single_round(
                            ready, active, d_all, b, c_up, c_down,
                            c_lat, sink=sink(b))
                        finish_round(k, b)
        elif tech == "hierarchical":
            rows, vrows, leaders = _leaf_groups(grid, active)
            nonempty = vrows.any(axis=1)
            glead = leaders[nonempty].astype(np.int64)
            rv = grid.n_peers
            n_members = int(np.count_nonzero(vrows))
            leaf_pure = bool(info.pure[grid.depth - 1])
            leaf_closed = leaf_pure and grid.depth - 1 >= split
            # up: members -> leaders (leader's own copy loops back)
            if leaf_closed:
                ready = _closed_leaf_gather_round(
                    ready, rows, vrows, leaders, b, c_up, c_down,
                    c_lat, sink=sink(b))
                acct.add_batch(glead, glead, np.full(glead.size, b))
                finish_round(n_members, b)
            else:
                members = rows[vrows]
                mlead = np.broadcast_to(leaders[:, None],
                                        rows.shape)[vrows]
                vector_round(members, mlead,
                             np.full(members.size, b))
            # mid: leaders <-> rendezvous (infrastructure: pairwise
            # terms never apply, closed is exact on every profile)
            if glead.size:
                ready = _closed_fan_in_round(ready, glead, rv, b, c_up,
                                             c_down, c_lat,
                                             sink=sink(b))
            finish_round(glead.size, b)
            if glead.size:
                ready = _closed_fan_out_round(ready, rv, glead, b,
                                              c_up, c_down, c_lat,
                                              sink=sink(b))
            finish_round(glead.size, b)
            # down: leaders -> members
            if leaf_closed:
                ready = _closed_leaf_bcast_round(
                    ready, rows, vrows, leaders, b, c_up, c_down,
                    c_lat, sink=sink(b))
                acct.add_batch(glead, glead, np.full(glead.size, b))
                finish_round(n_members, b)
            else:
                members = rows[vrows]
                mlead = np.broadcast_to(leaders[:, None],
                                        rows.shape)[vrows]
                vector_round(mlead, members,
                             np.full(members.size, b))
        else:  # pragma: no cover - build_super_plan validates
            return self._delegate(plan.to_array_plan(), compute_s)

        tr.peer_finish_s = ready[:n_real].copy()
        tr.iteration_s = float(ready.max()) if n_nodes else 0.0
        acct.finalize(tr)
        self._split_kd_bytes(tr, plan)
        self.clock += tr.iteration_s
        self.iterations += 1
        return tr
