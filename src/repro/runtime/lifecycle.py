"""Churn-aware peer lifecycle runtime: event-driven membership.

MAR-FL's resilience claim (paper §3.1/Fig. 3) was reproduced as
per-iteration i.i.d. Bernoulli masks; real deployments exhibit
*structured* availability — session churn with dwell times, correlated
regional outages, deadline-bound wireless stragglers, and permanent
capacity changes. This module makes membership a first-class runtime
concern:

* :class:`ChurnModel` — a registry of availability processes, each
  producing one :class:`ChurnTick` (participation mask U_t, aggregation
  mask A_t, optional simulated durations, membership events) per FL
  iteration. Built-ins:

  - ``bernoulli`` — i.i.d. per-iteration masks; the degenerate case,
    bit-identical to the old ``Federation.sample_masks``.
  - ``sessions`` — per-peer two-state Markov chains (online/offline)
    with configurable mean dwell times: availability is correlated in
    time (a peer that is up tends to stay up for ``mean_up``
    iterations), matching session-structured wireless traces.
  - ``correlated`` — region-level outages: peers are partitioned into
    regions; a region fails together with geometric outage durations,
    on top of background i.i.d. dropout (rack/cell failures).
  - ``wireless`` — deadline stragglers: per-peer compute rates (a slow
    tail) produce per-iteration durations; peers over the
    :class:`~repro.runtime.fault.StragglerPolicy` deadline run their
    local update (U_t) but miss aggregation (A_t) — the paper's
    dropout semantics.
  - ``link`` — deadline stragglers whose durations come from *modeled
    link time*: per-peer uplink/latency are drawn from a
    ``runtime/network.py`` link profile and each iteration costs
    compute + simulated MAR send time, so a slow uplink — not an
    abstract compute rate — is what misses the deadline.
  - ``trace`` — replayable event files (JSONL): record any run's
    membership events with :func:`save_trace`, replay them exactly.

* :class:`PeerLifecycle` — binds a model to the fault machinery
  (:class:`~repro.runtime.fault.HealthTracker` heartbeats + sweeps,
  :class:`~repro.runtime.fault.StragglerPolicy` deadlines) and to a
  permanent-resize schedule. ``tick(t)`` returns the masks the training
  loop consumes plus ``resize_to`` when the fleet permanently grows or
  shrinks — the signal ``Federation.resize`` acts on (elastic
  regrouping via ``elastic_replan``, no checkpoint/restart).

Events are host-side numpy/python — the jitted iteration function only
ever sees the two float32 masks, so every scenario shares one trace.
"""
from __future__ import annotations

import dataclasses
import json
from typing import (Any, Callable, Dict, Iterable, List, Optional,
                    Sequence, Tuple, Type)

import numpy as np

from repro.runtime.fault import HealthTracker, StragglerPolicy

# event kinds
DOWN = "down"          # transient: peer unavailable this iteration
UP = "up"              # transient: peer came back
STRAGGLE = "straggle"  # ran the local update but missed the deadline
DEAD = "dead"          # health timeout (no heartbeat)
JOIN = "join"          # permanent: fleet grew
LEAVE = "leave"        # permanent: fleet shrank

EVENT_KINDS = (DOWN, UP, STRAGGLE, DEAD, JOIN, LEAVE)


@dataclasses.dataclass(frozen=True)
class MembershipEvent:
    """One membership change, attributed to an FL iteration."""

    iteration: int
    kind: str
    peers: Tuple[int, ...]

    def to_json(self) -> Dict[str, Any]:
        return {"t": int(self.iteration), "kind": self.kind,
                "peers": [int(p) for p in self.peers]}

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "MembershipEvent":
        return MembershipEvent(int(d["t"]), str(d["kind"]),
                               tuple(int(p) for p in d["peers"]))


@dataclasses.dataclass
class ChurnTick:
    """One iteration's membership view.

    ``u`` — participation mask U_t (peers that run the local update);
    ``a`` — aggregation mask A_t (peers whose update joins the group
    means); ``durations`` — simulated per-peer local-update durations
    (seconds), when the model has a latency notion; ``events`` — what
    changed versus the previous iteration.
    """

    u: np.ndarray
    a: np.ndarray
    durations: Optional[np.ndarray] = None
    events: List[MembershipEvent] = dataclasses.field(default_factory=list)


# ---------------------------------------------------------------------------
# churn models
# ---------------------------------------------------------------------------

CHURN_MODELS: Dict[str, Type["ChurnModel"]] = {}


def register_churn(cls: Type["ChurnModel"]) -> Type["ChurnModel"]:
    CHURN_MODELS[cls.name] = cls
    return cls


def build_churn_model(name: str, n_peers: int, seed: int = 0,
                      **params: Any) -> "ChurnModel":
    if name not in CHURN_MODELS:
        raise ValueError(f"unknown churn model {name!r}; "
                         f"registered: {sorted(CHURN_MODELS)}")
    return CHURN_MODELS[name](n_peers, seed=seed, **params)


class ChurnModel:
    """An availability process over ``n_peers``; ``tick(t)`` must be
    called with consecutive iterations (models carry session state)."""

    name: str = "?"

    def __init__(self, n_peers: int, seed: int = 0):
        self.n_peers = n_peers
        self.seed = seed

    def tick(self, t: int) -> ChurnTick:
        raise NotImplementedError

    def resize(self, new_n: int) -> None:
        """Permanent capacity change: models with per-peer state resize
        it here (survivors keep their state; new peers start online)."""
        self.n_peers = new_n

    # -- helpers --------------------------------------------------------
    @staticmethod
    def _ensure_someone(mask: np.ndarray, rng: np.random.Generator
                        ) -> np.ndarray:
        if not mask.any():
            mask[int(rng.integers(len(mask)))] = True
        return mask

    @staticmethod
    def _delta_events(t: int, prev: np.ndarray, cur: np.ndarray
                      ) -> List[MembershipEvent]:
        events = []
        went_down = np.flatnonzero(prev & ~cur)
        came_up = np.flatnonzero(~prev & cur)
        if went_down.size:
            events.append(MembershipEvent(t, DOWN, tuple(went_down)))
        if came_up.size:
            events.append(MembershipEvent(t, UP, tuple(came_up)))
        return events


@register_churn
class BernoulliChurn(ChurnModel):
    """i.i.d. per-iteration masks — the degenerate case.

    Reproduces the retired ``Federation.sample_masks`` bit-for-bit: the
    per-iteration rng is seeded ``seed * 100003 + t`` and consumed in
    the same order, so pre-lifecycle runs replay exactly.
    """

    name = "bernoulli"

    def __init__(self, n_peers: int, seed: int = 0,
                 participation_rate: float = 1.0,
                 dropout_rate: float = 0.0):
        super().__init__(n_peers, seed)
        self.participation_rate = participation_rate
        self.dropout_rate = dropout_rate
        self._prev = np.ones(n_peers, bool)

    def tick(self, t: int) -> ChurnTick:
        rng = np.random.default_rng(self.seed * 100003 + t)
        n = self.n_peers
        u = rng.random(n) < self.participation_rate
        u = self._ensure_someone(u, rng)
        drop = rng.random(n) < self.dropout_rate
        a = u & ~drop
        if not a.any():
            a[np.flatnonzero(u)[0]] = True
        # events are deltas (like every other model), so a recorded
        # bernoulli run replays through TraceChurn's toggle semantics
        events = self._delta_events(t, self._prev, u)
        self._prev = u.copy()
        dropped = np.flatnonzero(u & ~a)
        if dropped.size:
            events.append(MembershipEvent(t, STRAGGLE, tuple(dropped)))
        return ChurnTick(u.astype(np.float32), a.astype(np.float32),
                         events=events)

    def resize(self, new_n: int) -> None:
        old = self._prev
        self._prev = np.ones(new_n, bool)
        self._prev[:min(new_n, len(old))] = old[:new_n]
        self.n_peers = new_n


@register_churn
class MarkovSessionChurn(ChurnModel):
    """Per-peer on/off Markov sessions with mean dwell times.

    A peer online at t stays online with probability ``1 - 1/mean_up``;
    an offline peer returns with probability ``1/mean_down`` (geometric
    dwell times, the discrete-time M/M/1-style session model used for
    wireless FL availability). Long-run availability is
    ``mean_up / (mean_up + mean_down)``, but unlike Bernoulli the
    masks are correlated across iterations — whole sessions drop out.
    """

    name = "sessions"

    def __init__(self, n_peers: int, seed: int = 0, mean_up: float = 8.0,
                 mean_down: float = 3.0, start_online: float = 1.0):
        super().__init__(n_peers, seed)
        if mean_up < 1.0 or mean_down < 1.0:
            raise ValueError("dwell times are in iterations; need >= 1")
        self.mean_up = mean_up
        self.mean_down = mean_down
        self._rng = np.random.default_rng(seed * 9176 + 11)
        self.online = self._rng.random(n_peers) < start_online

    def tick(self, t: int) -> ChurnTick:
        prev = self.online.copy()
        leave = self._rng.random(self.n_peers) < 1.0 / self.mean_up
        come = self._rng.random(self.n_peers) < 1.0 / self.mean_down
        self.online = np.where(prev, ~leave, come)
        self.online = self._ensure_someone(self.online, self._rng)
        u = self.online.astype(np.float32)
        return ChurnTick(u, u.copy(),
                         events=self._delta_events(t, prev, self.online))

    def resize(self, new_n: int) -> None:
        old = self.online
        self.online = np.ones(new_n, bool)
        self.online[:min(new_n, len(old))] = old[:new_n]
        self.n_peers = new_n


@register_churn
class CorrelatedOutageChurn(ChurnModel):
    """Region-level correlated outages + background i.i.d. dropout.

    Peers are split into ``n_regions`` contiguous blocks (think racks,
    cells, or MAR leaf groups). Each iteration a healthy region fails
    with probability ``outage_rate``; an outage lasts a geometric number
    of iterations with mean ``mean_outage``. All peers of a failed
    region go down *together* — the failure mode i.i.d. masks cannot
    express, and the one that stresses MAR's group structure most (a
    whole group missing leaves its group mean to the fallback path).
    """

    name = "correlated"

    def __init__(self, n_peers: int, seed: int = 0, n_regions: int = 4,
                 outage_rate: float = 0.05, mean_outage: float = 3.0,
                 base_dropout: float = 0.05):
        super().__init__(n_peers, seed)
        self.n_regions = max(1, min(n_regions, n_peers))
        self.outage_rate = outage_rate
        self.mean_outage = max(1.0, mean_outage)
        self.base_dropout = base_dropout
        self._rng = np.random.default_rng(seed * 5147 + 29)
        self._remaining = np.zeros(self.n_regions, np.int64)
        self._prev = np.ones(n_peers, bool)

    def region_of(self, peers: Optional[np.ndarray] = None) -> np.ndarray:
        peers = np.arange(self.n_peers) if peers is None else peers
        block = -(-self.n_peers // self.n_regions)
        return peers // block

    def tick(self, t: int) -> ChurnTick:
        rng = self._rng
        self._remaining = np.maximum(self._remaining - 1, 0)
        fresh = (self._remaining == 0) & \
            (rng.random(self.n_regions) < self.outage_rate)
        if fresh.any():
            self._remaining[fresh] = 1 + rng.geometric(
                1.0 / self.mean_outage, int(fresh.sum()))
        region_ok = self._remaining == 0
        up = region_ok[self.region_of()]
        u = up & ~(rng.random(self.n_peers) < self.base_dropout)
        u = self._ensure_someone(u, rng)
        events = self._delta_events(t, self._prev, u)
        self._prev = u.copy()
        m = u.astype(np.float32)
        return ChurnTick(m, m.copy(), events=events)

    def resize(self, new_n: int) -> None:
        self.n_peers = new_n
        new_regions = max(1, min(self.n_regions, new_n))
        if new_regions != self.n_regions:
            rem = np.zeros(new_regions, np.int64)
            rem[:min(new_regions, len(self._remaining))] = \
                self._remaining[:new_regions]
            self._remaining = rem
            self.n_regions = new_regions
        self._prev = np.ones(new_n, bool)


@register_churn
class WirelessStragglerChurn(ChurnModel):
    """Deadline-based wireless stragglers (paper's dropout semantics).

    Every peer draws a base compute rate at init — a ``slow_frac`` tail
    runs ``slow_factor`` x slower (heterogeneous edge hardware). Each
    iteration the peer's local-update duration is its base time under
    lognormal jitter; the :class:`StragglerPolicy` deadline (median +
    k * MAD) decides who misses aggregation. Stragglers stay in U_t
    (their update happened, state advances) but leave A_t — exactly the
    paper's "update done, aggregation missed" dropout.
    """

    name = "wireless"

    def __init__(self, n_peers: int, seed: int = 0, mean_s: float = 1.0,
                 slow_frac: float = 0.2, slow_factor: float = 4.0,
                 jitter: float = 0.15, policy: Optional[StragglerPolicy]
                 = None):
        super().__init__(n_peers, seed)
        self.mean_s = mean_s
        self.slow_frac = slow_frac
        self.slow_factor = slow_factor
        self.jitter = jitter
        self.policy = policy or StragglerPolicy(k_std=3.0,
                                                min_deadline_s=0.0)
        self._rng = np.random.default_rng(seed * 7877 + 3)
        self._base = self._draw_base(n_peers)

    def _draw_base(self, n: int) -> np.ndarray:
        base = np.full(n, self.mean_s)
        slow = self._rng.random(n) < self.slow_frac
        base[slow] *= self.slow_factor
        return base

    def tick(self, t: int) -> ChurnTick:
        dur = self._base * np.exp(
            self._rng.normal(0.0, self.jitter, self.n_peers))
        a = self.policy.mask(dur)
        u = np.ones(self.n_peers, np.float32)
        events = []
        stragglers = np.flatnonzero(a == 0.0)
        if stragglers.size:
            events.append(MembershipEvent(t, STRAGGLE, tuple(stragglers)))
        return ChurnTick(u, a.astype(np.float32), durations=dur,
                         events=events)

    def resize(self, new_n: int) -> None:
        old = self._base
        self._base = self._draw_base(new_n)
        self._base[:min(new_n, len(old))] = old[:new_n]
        self.n_peers = new_n


@register_churn
class LinkStragglerChurn(ChurnModel):
    """Deadline stragglers driven by *modeled link time* (DESIGN.md §9).

    Where :class:`WirelessStragglerChurn` draws abstract compute rates,
    this model binds the straggler semantics to the discrete-event
    network layer: each peer's per-iteration duration is its local
    compute time plus the simulated cost of its MAR sends — ``rounds``
    rounds of ``(group_size - 1)`` model transfers serialized over the
    peer's own modeled uplink, plus propagation latency — drawn from a
    ``runtime/network.py`` link profile. A peer misses its group
    deadline *because its simulated uplink is slow*, the paper §3.1
    "update done, aggregation missed" dropout, now with a physical
    cause. Share ``profile``/``link_params``/``seed`` with the
    federation's ``NetworkSim`` to keep the straggler process and the
    transcript on the same links.
    """

    name = "link"

    def __init__(self, n_peers: int, seed: int = 0,
                 profile: str = "wireless", model_bytes: float = 4e6,
                 group_size: int = 4, rounds: int = 3,
                 compute_s: float = 0.5, jitter: float = 0.2,
                 link_params: Optional[Dict[str, Any]] = None,
                 policy: Optional[StragglerPolicy] = None):
        from repro.runtime.network import build_link_model
        super().__init__(n_peers, seed)
        self.links = build_link_model(profile, n_peers, seed=seed,
                                      **(link_params or {}))
        self.model_bytes = model_bytes
        self.group_size = group_size
        self.rounds = rounds
        self.compute_s = compute_s
        self.jitter = jitter
        # lognormal link tails are one-sided: median + 2*MAD keeps the
        # bulk while cutting the slow-uplink tail every iteration
        self.policy = policy or StragglerPolicy(k_std=2.0,
                                                min_deadline_s=0.0)
        self._rng = np.random.default_rng(seed * 12553 + 19)

    def comm_s(self) -> np.ndarray:
        """Deterministic per-peer aggregation cost on the modeled links:
        uplink serialization of the round sends + per-round latency."""
        sends = max(self.group_size - 1, 0) * self.model_bytes
        return self.rounds * (sends / self.links.up
                              + 2.0 * self.links.lat)

    def tick(self, t: int) -> ChurnTick:
        compute = self.compute_s * np.exp(
            self._rng.normal(0.0, self.jitter, self.n_peers))
        dur = compute + self.comm_s()
        a = self.policy.mask(dur)
        u = np.ones(self.n_peers, np.float32)
        events = []
        stragglers = np.flatnonzero(a == 0.0)
        if stragglers.size:
            events.append(MembershipEvent(t, STRAGGLE, tuple(stragglers)))
        return ChurnTick(u, a.astype(np.float32), durations=dur,
                         events=events)

    def resize(self, new_n: int) -> None:
        self.links.resize(new_n)   # survivors keep their links
        self.n_peers = new_n


@register_churn
class TraceChurn(ChurnModel):
    """Replay a recorded membership-event stream (JSONL).

    The trace is the event *delta* representation written by
    :func:`save_trace`: ``down``/``up`` toggle availability,
    ``straggle`` removes peers from A_t for one iteration, and
    ``join``/``leave`` change the peer count permanently (the lifecycle
    turns those into elastic resizes). Iterations past the last traced
    event hold the final availability.
    """

    name = "trace"

    def __init__(self, n_peers: int, seed: int = 0,
                 path: Optional[str] = None,
                 events: Optional[Iterable[MembershipEvent]] = None):
        super().__init__(n_peers, seed)
        if (path is None) == (events is None):
            raise ValueError("TraceChurn needs exactly one of path/events")
        evs = load_trace(path) if path is not None else list(events)
        self._by_t: Dict[int, List[MembershipEvent]] = {}
        for e in evs:
            self._by_t.setdefault(e.iteration, []).append(e)
        self.available = np.ones(n_peers, bool)

    def pending_resize(self, t: int,
                       n_peers: Optional[int] = None) -> Optional[int]:
        """Net peer count after iteration ``t``'s join/leave events, or
        None when membership is unchanged (lifecycle polls this first).
        ``n_peers`` overrides the live count for pure look-ahead scans
        (:meth:`PeerLifecycle.planned_resizes`)."""
        n0 = self.n_peers if n_peers is None else n_peers
        n = n0
        for e in self._by_t.get(t, ()):
            if e.kind == JOIN:
                n += len(e.peers)
            elif e.kind == LEAVE:
                n -= len(e.peers)
        return n if n != n0 else None

    def tick(self, t: int) -> ChurnTick:
        events = list(self._by_t.get(t, ()))
        straggle = np.zeros(self.n_peers, bool)
        for e in events:
            for p in e.peers:
                if p >= self.n_peers:
                    continue
                if e.kind == DOWN:
                    self.available[p] = False
                elif e.kind == UP:
                    self.available[p] = True
                elif e.kind in (STRAGGLE, DEAD):
                    straggle[p] = True
        u = self.available.copy()
        if not u.any():
            u[0] = True
        a = u & ~straggle
        if not a.any():
            a[np.flatnonzero(u)[0]] = True
        return ChurnTick(u.astype(np.float32), a.astype(np.float32),
                         events=events)

    def resize(self, new_n: int) -> None:
        old = self.available
        self.available = np.ones(new_n, bool)
        self.available[:min(new_n, len(old))] = old[:new_n]
        self.n_peers = new_n


def save_trace(path: str, events: Sequence[MembershipEvent]) -> None:
    """Write a replayable JSONL membership trace."""
    with open(path, "w") as f:
        for e in sorted(events, key=lambda e: e.iteration):
            f.write(json.dumps(e.to_json()) + "\n")


def load_trace(path: str) -> List[MembershipEvent]:
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(MembershipEvent.from_json(json.loads(line)))
    return out


# ---------------------------------------------------------------------------
# the lifecycle runtime
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LifecycleTick:
    """What the training loop consumes each iteration."""

    u: np.ndarray                      # participation mask U_t [n] f32
    a: np.ndarray                      # aggregation mask A_t [n] f32
    resize_to: Optional[int] = None    # permanent capacity change
    events: List[MembershipEvent] = dataclasses.field(default_factory=list)


class PeerLifecycle:
    """Event-driven membership runtime for one federation.

    Composes a :class:`ChurnModel` with the fault machinery and a
    permanent-resize schedule:

    * model ticks produce base U_t/A_t and transient events;
    * simulated (or reported) durations feed :class:`HealthTracker`
      heartbeats; ``sweep()`` runs every iteration, so a peer that
      stops heartbeating for ``timeout`` iterations is marked DEAD and
      masked until it heartbeats again;
    * ``schedule`` entries ``(iteration, n_peers)`` — plus JOIN/LEAVE
      events from trace models — surface as ``resize_to``, which the
      training loop answers with ``Federation.resize`` (elastic
      regrouping, no restart).

    The lifecycle clock is the FL iteration counter: heartbeat
    timestamps and timeouts are measured in iterations for simulated
    models. Callers with real wall-clock durations (``launch/train.py``)
    report them via :meth:`observe_durations`.
    """

    def __init__(self, model: ChurnModel,
                 health: Optional[HealthTracker] = None,
                 straggler: Optional[StragglerPolicy] = None,
                 schedule: Sequence[Tuple[int, int]] = ()):
        self.model = model
        self.health = health
        self.straggler = straggler
        self.schedule = dict(schedule)
        self.event_log: List[MembershipEvent] = []
        self._prev_u = np.ones(model.n_peers, bool)
        if self.health is not None:
            for p in self.health.peers.values():
                p.last_heartbeat = 0.0   # iteration clock starts at 0

    @property
    def n_peers(self) -> int:
        return self.model.n_peers

    # ------------------------------------------------------------------
    def tick(self, t: int) -> LifecycleTick:
        # 1) permanent membership first, so masks are sized for the new
        #    fleet: scheduled resizes, then trace-driven join/leave
        resize_to = self.schedule.get(t)
        if resize_to is None and hasattr(self.model, "pending_resize"):
            resize_to = self.model.pending_resize(t)
        if resize_to is not None and resize_to != self.model.n_peers:
            old_n = self.model.n_peers
            kind = JOIN if resize_to > old_n else LEAVE
            lo, hi = sorted((old_n, resize_to))
            self.event_log.append(
                MembershipEvent(t, kind, tuple(range(lo, hi))))
            self.resize(resize_to, now=float(t))
        else:
            resize_to = None

        # 2) the availability process
        ct = self.model.tick(t)
        u, a = ct.u.copy(), ct.a.copy()
        events = list(ct.events)

        # 3) health. Masks use the PRE-heartbeat alive state, so an
        #    externally mark_failed peer is excluded this iteration and
        #    rejoins via its next heartbeat (with the group mean — the
        #    paper's recovery path); heartbeats for peers the model ran
        #    this iteration come after, then the sweep that catches
        #    silent peers (timeout measured in iterations).
        if self.health is not None:
            alive = self.health.alive_mask()
            for p in np.flatnonzero(u > 0):
                dur = (float(ct.durations[p])
                       if ct.durations is not None else None)
                self.health.heartbeat(int(p), dur, now=float(t))
            dead = self.health.sweep(now=float(t))
            if dead:
                events.append(MembershipEvent(t, DEAD, tuple(dead)))
            u, a = u * alive, a * alive

        # 4) deadline policy on reported durations (when the model did
        #    not already apply one)
        if (self.straggler is not None and ct.durations is not None
                and not isinstance(self.model, (WirelessStragglerChurn,
                                                LinkStragglerChurn))):
            sm = self.straggler.mask(ct.durations)
            cut = np.flatnonzero((a > 0) & (sm == 0))
            if cut.size:
                events.append(MembershipEvent(t, STRAGGLE, tuple(cut)))
            a = a * sm

        # never let the fleet go fully silent (Alg. 1 needs >= 1 peer)
        if not (u > 0).any():
            u[0] = 1.0
        if not (a > 0).any():
            a[np.flatnonzero(u > 0)[0]] = 1.0

        # the event_log records deltas of the FINAL masks (health and
        # deadline effects folded in), so save_trace(event_log) replays
        # this exact run through TraceChurn; ``tick.events`` keeps the
        # richer per-consumer view (DEAD, model-level transitions)
        self.event_log.extend(
            ChurnModel._delta_events(t, self._prev_u, u > 0))
        self._prev_u = u > 0
        stragglers = np.flatnonzero((u > 0) & (a == 0))
        if stragglers.size:
            self.event_log.append(
                MembershipEvent(t, STRAGGLE, tuple(stragglers)))
        return LifecycleTick(u.astype(np.float32), a.astype(np.float32),
                             resize_to=resize_to, events=events)

    # ------------------------------------------------------------------
    def planned_resizes(self, start: int, stop: int
                        ) -> List[Tuple[int, int]]:
        """Permanent join/leave the schedule and the trace will request
        in iterations ``[start, stop)`` — ``[(iteration, new_n), ...]``
        in order.

        Pure look-ahead (no model state is consumed): callers that
        cannot honor mid-run resizes — the device backend in
        ``launch/train.py`` needs an exact grid — validate the whole
        run up front and fail fast at launch instead of discovering the
        constraint when the tick fires mid-run.
        """
        out: List[Tuple[int, int]] = []
        n = self.model.n_peers
        for t in range(start, stop):
            target = self.schedule.get(t)
            if target is None and hasattr(self.model, "pending_resize"):
                target = self.model.pending_resize(t, n_peers=n)
            if target is not None and target != n:
                out.append((t, int(target)))
                n = int(target)
        return out

    # ------------------------------------------------------------------
    def observe_durations(self, t: int, durations: np.ndarray,
                          mask: Optional[np.ndarray] = None) -> None:
        """Report measured per-peer durations (wall-clock callers)."""
        if self.health is None:
            return
        for p in range(min(len(durations), self.model.n_peers)):
            if mask is None or mask[p] > 0:
                self.health.heartbeat(p, float(durations[p]),
                                      now=float(t))

    def resize(self, new_n: int, now: Optional[float] = None) -> None:
        """Propagate a permanent capacity change to model + trackers.

        ``now`` is the lifecycle-clock time joining peers count as
        first seen (their heartbeat baseline) — without it a late
        joiner would look timeout-stale at its very first sweep.
        """
        from collections import deque

        from repro.runtime.fault import PeerHealth
        self.model.resize(new_n)
        old_prev = self._prev_u
        self._prev_u = np.ones(new_n, bool)
        self._prev_u[:min(new_n, len(old_prev))] = old_prev[:new_n]
        if self.health is not None:
            old = self.health.peers
            history = (next(iter(old.values())).durations.maxlen
                       if old else 16)
            if now is None and old:
                now = max(p.last_heartbeat for p in old.values())
            self.health.peers = {
                i: old[i] if i in old else
                PeerHealth(now or 0.0, deque(maxlen=history))
                for i in range(new_n)
            }


# ---------------------------------------------------------------------------
# config-driven assembly
# ---------------------------------------------------------------------------

def build_lifecycle(churn: Optional[str], n_peers: int, *, seed: int = 0,
                    participation_rate: float = 1.0,
                    dropout_rate: float = 0.0,
                    churn_params: Optional[Dict[str, Any]] = None,
                    schedule: Sequence[Tuple[int, int]] = (),
                    health: Optional[HealthTracker] = None,
                    straggler: Optional[StragglerPolicy] = None
                    ) -> PeerLifecycle:
    """One factory for every caller (Federation, train.py, benchmarks).

    ``churn=None`` builds the Bernoulli degenerate case from the legacy
    participation/dropout knobs — existing configs replay bit-exact.
    """
    params = dict(churn_params or {})
    name = churn or "bernoulli"
    if name == "bernoulli":
        params.setdefault("participation_rate", participation_rate)
        params.setdefault("dropout_rate", dropout_rate)
    if name in ("wireless", "link") and straggler is not None:
        # the caller's deadline policy governs the simulated stragglers
        params.setdefault("policy", straggler)
    model = build_churn_model(name, n_peers, seed=seed, **params)
    return PeerLifecycle(model, health=health, straggler=straggler,
                         schedule=schedule)
