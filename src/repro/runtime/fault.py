"""Fault tolerance and straggler mitigation for 1000+ node fleets.

MAR-FL's core property — a dropped peer only corrupts its own group,
and incomplete group means still converge (paper §3.2) — is the
fault-tolerance mechanism. This module supplies the fleet-side glue:

* :class:`HealthTracker` — per-peer heartbeats; marks peers dead after
  ``timeout_s`` and yields per-iteration participation masks (the same
  masks ``mar_aggregate_*`` consumes, so a dead peer is excluded from
  its group's mean instead of stalling the step — dropout semantics).
* :class:`StragglerPolicy` — deadline-based: a peer whose local update
  exceeds mean + k*std of recent durations gets masked for the current
  aggregation round only (it rejoins next iteration with the group
  average, since every MAR round *broadcasts* the mean back).
* :func:`elastic_replan` — on permanent capacity change, re-factorize
  the MAR grid for the new peer count and remap checkpointed state
  (``Checkpointer.restore_elastic``) — restart-free for sim peers,
  restart-with-checkpoint for mesh peers.

On a real multi-pod deployment the heartbeat source is the cluster
manager; here it is fed by the simulation loop and by tests that
script failure sequences.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.moshpit import GridPlan, plan_grid


@dataclasses.dataclass
class PeerHealth:
    last_heartbeat: float
    durations: Deque[float]
    alive: bool = True


class HealthTracker:
    def __init__(self, n_peers: int, timeout_s: float = 30.0,
                 history: int = 16):
        self.timeout_s = timeout_s
        self.peers: Dict[int, PeerHealth] = {
            i: PeerHealth(time.monotonic(), deque(maxlen=history))
            for i in range(n_peers)
        }

    def heartbeat(self, peer: int, duration_s: Optional[float] = None,
                  now: Optional[float] = None):
        now = time.monotonic() if now is None else now
        p = self.peers[peer]
        p.last_heartbeat = now
        p.alive = True
        if duration_s is not None:
            p.durations.append(duration_s)

    def mark_failed(self, peer: int):
        self.peers[peer].alive = False

    def sweep(self, now: Optional[float] = None) -> List[int]:
        """Mark timed-out peers dead; returns newly-dead peer ids."""
        now = time.monotonic() if now is None else now
        dead = []
        for i, p in self.peers.items():
            if p.alive and now - p.last_heartbeat > self.timeout_s:
                p.alive = False
                dead.append(i)
        return dead

    def alive_mask(self) -> np.ndarray:
        return np.array([float(p.alive) for p in self.peers.values()],
                        np.float32)


class StragglerPolicy:
    """Deadline = median + k * scaled-MAD of recent local-update times.

    Robust statistics matter here: a straggler's own duration must not
    inflate the deadline that is supposed to catch it (mean/std would be
    dragged by the outlier). ``mask(durations)`` returns the aggregation
    mask for this iteration: stragglers are excluded from MAR (their
    group averages without them — the paper's dropout path) instead of
    blocking the barrier.
    """

    def __init__(self, k_std: float = 3.0, min_deadline_s: float = 1.0):
        self.k_std = k_std
        self.min_deadline_s = min_deadline_s

    def deadline(self, durations: np.ndarray) -> float:
        if durations.size == 0:
            return self.min_deadline_s
        med = float(np.median(durations))
        mad = float(np.median(np.abs(durations - med))) * 1.4826
        spread = max(mad, 0.05 * max(med, 1e-9))   # floor for zero-MAD
        return max(self.min_deadline_s, med + self.k_std * spread)

    def mask(self, durations: np.ndarray) -> np.ndarray:
        dl = self.deadline(durations)
        return (durations <= dl).astype(np.float32)


def elastic_replan(old_plan: GridPlan, new_n_peers: int) -> GridPlan:
    """Re-factorize the MAR grid after a permanent capacity change.

    Keeps the old group size when it still factors the new count
    (minimal schedule churn), otherwise replans from scratch.
    """
    m = old_plan.dims[0]
    if all(d == m for d in old_plan.dims):
        d = 0
        n = new_n_peers
        while n % m == 0:
            n //= m
            d += 1
        if n == 1 and d >= 1:
            return GridPlan(new_n_peers, (m,) * d)
    return plan_grid(new_n_peers)


def failure_impact(plan: GridPlan, failed: List[int]) -> Dict[str, float]:
    """How much of the fleet a failure set touches, per MAR round —
    quantifies the paper's 'dropouts only affect a single group'."""
    out = {}
    for g in range(plan.depth):
        groups = plan.groups_for_round(g)
        touched = sum(1 for grp in groups
                      if any(p in set(grp.tolist()) for p in failed))
        out[f"round_{g}_groups_touched"] = touched / max(len(groups), 1)
    return out
