"""Flash-style causal GQA attention in pure JAX with a custom VJP.

Why this exists: differentiating naive chunked attention makes XLA save
the softmax probabilities ([seq, seq] f32 per layer per microbatch) for
the backward pass — the dry-run roofline showed this dominating HBM
traffic at seq 4096+. The flash pattern saves only (o, logsumexp) and
*recomputes* probabilities blockwise in the backward — paying ~2.5x
attention FLOPs to kill O(s^2) memory traffic (EXPERIMENTS.md §Perf,
iteration "naive->flash").

This module is also the semantics reference for the Pallas TPU kernel
(``repro.kernels.flash_attention``): same blocking, same online-softmax
recurrences, validated against ``kernels/ref.py``.

Shapes: q [b, s, h, d]; k, v [b, skv, kvh, d]; GQA via h = g * kvh.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

NEG_INF = -1e30


def _chunks(s: int, target: int) -> int:
    c = min(target, s)
    while s % c != 0:
        c -= 1
    return c


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q: Array, k: Array, v: Array, causal: bool = True,
                    q_chunk: int = 1024, kv_chunk: int = 2048) -> Array:
    out, _ = _flash_fwd_impl(q, k, v, causal, q_chunk, kv_chunk)
    return out


def _flash_fwd_impl(q, k, v, causal, q_chunk, kv_chunk):
    b, s, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qc = _chunks(s, q_chunk)
    kc = _chunks(skv, kv_chunk)
    nq, nk = s // qc, skv // kc
    scale = 1.0 / np.sqrt(d)

    # [b, kvh, g, s, d] view for grouped heads
    qg = q.reshape(b, s, kvh, g, d).transpose(0, 2, 3, 1, 4)
    kg = k.transpose(0, 2, 1, 3)                     # [b, kvh, skv, d]
    vg = v.transpose(0, 2, 1, 3)

    def q_block(iq):
        q_i = jax.lax.dynamic_slice_in_dim(qg, iq * qc, qc, axis=3)
        q_pos = iq * qc + jnp.arange(qc)

        def kv_step(carry, ik):
            o, m, l = carry
            k_j = jax.lax.dynamic_slice_in_dim(kg, ik * kc, kc, axis=2)
            v_j = jax.lax.dynamic_slice_in_dim(vg, ik * kc, kc, axis=2)
            sc = jnp.einsum("bkgqd,bksd->bkgqs", q_i.astype(jnp.float32),
                            k_j.astype(jnp.float32)) * scale
            if causal:
                kv_pos = ik * kc + jnp.arange(kc)
                mask = q_pos[:, None] >= kv_pos[None, :]
                sc = jnp.where(mask, sc, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            o_new = o * alpha[..., None] + jnp.einsum(
                "bkgqs,bksd->bkgqd", p, v_j.astype(jnp.float32))
            return (o_new, m_new, l_new), None

        o0 = jnp.zeros((b, kvh, g, qc, d), jnp.float32)
        m0 = jnp.full((b, kvh, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, qc), jnp.float32)
        (o, m, l), _ = jax.lax.scan(kv_step, (o0, m0, l0), jnp.arange(nk))
        o = o / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return o.astype(q.dtype), lse

    outs, lses = jax.lax.map(q_block, jnp.arange(nq))
    # outs: [nq, b, kvh, g, qc, d] -> [b, s, h, d]
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, kvh, g, s, d)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, d)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(b, kvh, g, s)
    return out, lse


def _flash_fwd(q, k, v, causal, q_chunk, kv_chunk):
    out, lse = _flash_fwd_impl(q, k, v, causal, q_chunk, kv_chunk)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, q_chunk, kv_chunk, res, dout):
    q, k, v, out, lse = res
    b, s, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qc = _chunks(s, q_chunk)
    kc = _chunks(skv, kv_chunk)
    nq, nk = s // qc, skv // kc
    scale = 1.0 / np.sqrt(d)

    qg = q.reshape(b, s, kvh, g, d).transpose(0, 2, 3, 1, 4)
    og = out.reshape(b, s, kvh, g, d).transpose(0, 2, 3, 1, 4)
    dog = dout.reshape(b, s, kvh, g, d).transpose(0, 2, 3, 1, 4)
    kg = k.transpose(0, 2, 1, 3)
    vg = v.transpose(0, 2, 1, 3)
    # delta = rowsum(dO * O)  [b, kvh, g, s]
    delta = jnp.sum(dog.astype(jnp.float32) * og.astype(jnp.float32), -1)

    def kv_block(ik):
        k_j = jax.lax.dynamic_slice_in_dim(kg, ik * kc, kc, axis=2)
        v_j = jax.lax.dynamic_slice_in_dim(vg, ik * kc, kc, axis=2)
        kv_pos = ik * kc + jnp.arange(kc)

        def q_step(carry, iq):
            dk, dv = carry
            q_i = jax.lax.dynamic_slice_in_dim(qg, iq * qc, qc, axis=3)
            do_i = jax.lax.dynamic_slice_in_dim(dog, iq * qc, qc, axis=3)
            lse_i = jax.lax.dynamic_slice_in_dim(lse, iq * qc, qc, axis=3)
            dl_i = jax.lax.dynamic_slice_in_dim(delta, iq * qc, qc, axis=3)
            sc = jnp.einsum("bkgqd,bksd->bkgqs", q_i.astype(jnp.float32),
                            k_j.astype(jnp.float32)) * scale
            if causal:
                q_pos = iq * qc + jnp.arange(qc)
                mask = q_pos[:, None] >= kv_pos[None, :]
                sc = jnp.where(mask, sc, NEG_INF)
            p = jnp.exp(sc - lse_i[..., None])               # true probs
            dp = jnp.einsum("bkgqd,bksd->bkgqs",
                            do_i.astype(jnp.float32),
                            v_j.astype(jnp.float32))
            ds = p * (dp - dl_i[..., None]) * scale
            dk = dk + jnp.einsum("bkgqs,bkgqd->bksd", ds,
                                 q_i.astype(jnp.float32))
            dv = dv + jnp.einsum("bkgqs,bkgqd->bksd", p,
                                 do_i.astype(jnp.float32))
            dq_i = jnp.einsum("bkgqs,bksd->bkgqd", ds,
                              k_j.astype(jnp.float32))
            return (dk, dv), dq_i

        dk0 = jnp.zeros((b, kvh, kc, d), jnp.float32)
        dv0 = jnp.zeros((b, kvh, kc, d), jnp.float32)
        (dk, dv), dq_parts = jax.lax.scan(q_step, (dk0, dv0),
                                          jnp.arange(nq))
        return dk, dv, dq_parts            # dq_parts: [nq, b, kvh, g, qc, d]

    dks, dvs, dqs = jax.lax.map(kv_block, jnp.arange(nk))
    # dks: [nk, b, kvh, kc, d] -> [b, nk, kc, kvh, d] -> [b, skv, kvh, d]
    dk = dks.transpose(1, 0, 3, 2, 4).reshape(b, skv, kvh, d)
    dv = dvs.transpose(1, 0, 3, 2, 4).reshape(b, skv, kvh, d)
    # dqs: [nk, nq, b, kvh, g, qc, d] — sum over kv blocks
    dq = jnp.sum(dqs, axis=0)              # [nq, b, kvh, g, qc, d]
    dq = dq.transpose(1, 2, 3, 0, 4, 5).reshape(b, kvh, g, s, d)
    dq = dq.transpose(0, 3, 1, 2, 4).reshape(b, s, h, d)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_flash_fwd, _flash_bwd)
