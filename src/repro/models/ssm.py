"""Recurrent blocks: Mamba2 (SSD), mLSTM and sLSTM (xLSTM) — pure JAX.

Mamba2 and mLSTM share one *chunked gated linear recurrence* primitive
(`chunked_linear_scan`): per-step state update

    H_t = exp(a_t) * H_{t-1} + k_t^T (outer) v_t,     y_t = q_t . H_t

with per-(head, step) scalar log-decay ``a_t <= 0``. Mamba2 maps
(q,k,v,a) = (C, B, dt*x, A*dt); mLSTM maps (q,k,v,a) = (q, k, i*v,
logsigmoid(f)) with the normalizer tracked via an appended ones-column.
The chunked form (intra-chunk parallel, inter-chunk scan) is the reference
for the ``repro.kernels.ssd_scan`` Pallas kernel.

Faithfulness notes (DESIGN.md §8): mLSTM's exponential input gate is
implemented with the max-stabilizer folded into sigmoid gating for scan
stability (standard practice in xLSTM reimplementations); sLSTM keeps the
exact exponential-gating stabilizer (m_t) since it runs a sequential scan.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

Array = jax.Array


# ---------------------------------------------------------------------------
# Chunked gated linear recurrence (shared by Mamba2 / mLSTM)
# ---------------------------------------------------------------------------

def chunked_linear_scan(q: Array, k: Array, v: Array, log_a: Array,
                        h0: Array, chunk: int = 256) -> Tuple[Array, Array]:
    """q,k: [b, nh, S, dk]; v: [b, nh, S, dv]; log_a: [b, nh, S] (<= 0).

    Returns (y [b, nh, S, dv], h_final [b, nh, dk, dv]).
    """
    b, nh, s, dk = q.shape
    dv = v.shape[-1]
    if s % chunk != 0:
        chunk = s  # smoke shapes
    nchunks = s // chunk

    qc = q.reshape(b, nh, nchunks, chunk, dk)
    kc = k.reshape(b, nh, nchunks, chunk, dk)
    vc = v.reshape(b, nh, nchunks, chunk, dv)
    ac = log_a.reshape(b, nh, nchunks, chunk).astype(jnp.float32)

    def chunk_fn(h, inputs):
        qi, ki, vi, ai = inputs  # [b, nh, chunk, *]
        cum = jnp.cumsum(ai, axis=-1)                     # A_i = sum_{j<=i} a_j
        total = cum[..., -1]                              # [b, nh]
        # intra-chunk: S_ij = (q_i.k_j) exp(A_i - A_j), j <= i
        qk = jnp.einsum("bhid,bhjd->bhij", qi.astype(jnp.float32),
                        ki.astype(jnp.float32))
        decay = cum[..., :, None] - cum[..., None, :]     # A_i - A_j
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        gate = jnp.where(causal, jnp.exp(jnp.minimum(decay, 0.0)), 0.0)
        y_intra = jnp.einsum("bhij,bhjv->bhiv", qk * gate,
                             vi.astype(jnp.float32))
        # inter-chunk: y_i += exp(A_i) q_i . H0
        y_inter = jnp.einsum("bhid,bhdv->bhiv", qi.astype(jnp.float32),
                             h) * jnp.exp(cum)[..., None]
        # state update: H' = exp(A_total) H0 + sum_j exp(A_total - A_j) k_j v_j
        w = jnp.exp(total[..., None] - cum)               # [b, nh, chunk]
        h_new = h * jnp.exp(total)[..., None, None] + jnp.einsum(
            "bhjd,bhjv->bhdv", ki.astype(jnp.float32) * w[..., None],
            vi.astype(jnp.float32))
        return h_new, (y_intra + y_inter).astype(v.dtype)

    xs = (jnp.moveaxis(qc, 2, 0), jnp.moveaxis(kc, 2, 0),
          jnp.moveaxis(vc, 2, 0), jnp.moveaxis(ac, 2, 0))
    h_final, ys = jax.lax.scan(chunk_fn, h0.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 2).reshape(b, nh, s, dv)
    return y, h_final


def linear_scan_step(q: Array, k: Array, v: Array, log_a: Array,
                     h: Array) -> Tuple[Array, Array]:
    """Single decode step. q,k: [b, nh, dk]; v: [b, nh, dv]; log_a: [b, nh]."""
    h_new = h * jnp.exp(log_a.astype(jnp.float32))[..., None, None] + \
        jnp.einsum("bhd,bhv->bhdv", k.astype(jnp.float32), v.astype(jnp.float32))
    y = jnp.einsum("bhd,bhdv->bhv", q.astype(jnp.float32), h_new)
    return y.astype(v.dtype), h_new


# ---------------------------------------------------------------------------
# Depthwise causal conv (width-w, shift-add form)
# ---------------------------------------------------------------------------

def causal_conv(x: Array, w: Array, state: Array = None):
    """x: [b, S, c]; w: [width, c] depthwise taps. Returns y same shape.

    If ``state`` [b, width-1, c] is given, runs in streaming mode (decode):
    x is [b, 1, c] and the updated state is returned as well.
    """
    width = w.shape[0]
    if state is not None:
        buf = jnp.concatenate([state, x], axis=1)      # [b, width, c]
        y = jnp.einsum("bwc,wc->bc", buf, w)[:, None, :]
        return jax.nn.silu(y), buf[:, 1:, :]
    acc = x * w[-1]
    for i in range(1, width):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i, :]
        acc = acc + shifted * w[width - 1 - i]
    return jax.nn.silu(acc)


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

def mamba_dims(cfg: ModelConfig):
    inner = cfg.ssm_expand * cfg.d_model
    headdim = 64
    nheads = inner // headdim
    return inner, headdim, nheads


def mamba_init(key, cfg: ModelConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d, n = cfg.d_model, cfg.ssm_state
    inner, headdim, nheads = mamba_dims(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    zxbcdt = 2 * inner + 2 * n + nheads
    return {
        "in_proj": dense_init(k1, d, zxbcdt, dt),
        "conv_w": (jax.random.normal(k2, (cfg.ssm_conv_width, inner + 2 * n),
                                     jnp.float32) * 0.1).astype(dt),
        "a_log": jnp.log(jnp.linspace(1.0, float(nheads), nheads,
                                      dtype=jnp.float32)),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "d_skip": jnp.ones((nheads,), jnp.float32),
        "out_proj": dense_init(k4, inner, d, dt),
    }


def mamba_split(params, x: Array, cfg: ModelConfig):
    d, n = cfg.d_model, cfg.ssm_state
    inner, headdim, nheads = mamba_dims(cfg)
    zxbcdt = x @ params["in_proj"]
    z, xs, bc, dt_raw = jnp.split(
        zxbcdt, [inner, 2 * inner, 2 * inner + 2 * n], axis=-1)
    return z, xs, bc, dt_raw


def mamba_block(params, x: Array, cfg: ModelConfig,
                h0: Array = None) -> Array:
    """x: [b, S, d] -> (y [b, S, d], h_final, conv_state).

    ``conv_state`` [b, width-1, inner+2n] is the raw conv-input tail
    (zero-padded when S < width-1) — exactly the streaming buffer
    ``causal_conv`` expects, so prefill hands off to
    ``mamba_decode_step`` without replaying the prompt.
    """
    b, s, d = x.shape
    n = cfg.ssm_state
    inner, headdim, nheads = mamba_dims(cfg)
    z, xs, bc, dt_raw = mamba_split(params, x, cfg)
    conv_in = jnp.concatenate([xs, bc], axis=-1)
    cw = cfg.ssm_conv_width
    conv_state = jnp.pad(conv_in, ((0, 0), (cw - 1, 0), (0, 0)))[:, -(cw - 1):]
    conv_out = causal_conv(conv_in, params["conv_w"])
    xs, bmat, cmat = jnp.split(conv_out, [inner, inner + n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])                       # [nheads], < 0
    log_decay = (dt * a).transpose(0, 2, 1)             # [b, nheads, S]

    xh = xs.reshape(b, s, nheads, headdim).transpose(0, 2, 1, 3)
    # B/C shared across heads (ngroups=1)
    kk = jnp.broadcast_to(bmat[:, None], (b, nheads, s, n))
    qq = jnp.broadcast_to(cmat[:, None], (b, nheads, s, n))
    vv = xh * dt.transpose(0, 2, 1)[..., None].astype(xh.dtype)

    if h0 is None:
        h0 = jnp.zeros((b, nheads, n, headdim), jnp.float32)
    if cfg.attn_impl == "pallas":
        from repro.kernels import ops as kops
        y, h_final = kops.ssd_scan(qq, kk, vv, log_decay, h0)
    else:
        y, h_final = chunked_linear_scan(qq, kk, vv, log_decay, h0)
    y = y + xh * params["d_skip"][None, :, None, None].astype(xh.dtype)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, inner)
    y = y * jax.nn.silu(z)
    return y @ params["out_proj"], h_final, conv_state


def mamba_decode_step(params, x: Array, cfg: ModelConfig, conv_state: Array,
                      ssm_state: Array):
    """x: [b, 1, d]. conv_state: [b, w-1, inner+2n]; ssm_state [b,nh,n,hd]."""
    b = x.shape[0]
    n = cfg.ssm_state
    inner, headdim, nheads = mamba_dims(cfg)
    z, xs, bc, dt_raw = mamba_split(params, x, cfg)
    conv_in = jnp.concatenate([xs, bc], axis=-1)
    conv_out, conv_state = causal_conv(conv_in, params["conv_w"], conv_state)
    xs, bmat, cmat = jnp.split(conv_out, [inner, inner + n], axis=-1)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])
    log_decay = dt * a                                   # [b, nheads]
    xh = xs.reshape(b, nheads, headdim)
    kk = jnp.broadcast_to(bmat[:, None, 0] if bmat.ndim == 3 else bmat[:, None],
                          (b, nheads, n))
    qq = jnp.broadcast_to(cmat[:, None, 0] if cmat.ndim == 3 else cmat[:, None],
                          (b, nheads, n))
    vv = xh * dt[..., None].astype(xh.dtype)
    y, ssm_state = linear_scan_step(qq, kk, vv, log_decay, ssm_state)
    y = y + xh * params["d_skip"][None, :, None].astype(xh.dtype)
    y = y.reshape(b, 1, inner) * jax.nn.silu(z)
    return y @ params["out_proj"], conv_state, ssm_state


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM matrix memory)
# ---------------------------------------------------------------------------

def mlstm_dims(cfg: ModelConfig):
    inner = cfg.ssm_expand * cfg.d_model
    nh = cfg.num_heads
    return inner, inner // nh, nh


def mlstm_init(key, cfg: ModelConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    inner, hd, nh = mlstm_dims(cfg)
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 7)
    return {
        "up_proj": dense_init(k1, d, 2 * inner, dt),
        "wq": dense_init(k2, inner, inner, dt),
        "wk": dense_init(k3, inner, inner, dt),
        "wv": dense_init(k4, inner, inner, dt),
        "wi": dense_init(k5, inner, nh, jnp.float32),
        "wf": dense_init(k6, inner, nh, jnp.float32),
        "out_proj": dense_init(k7, inner, d, dt),
    }


def _mlstm_qkvif(params, x: Array, cfg: ModelConfig):
    b, s, _ = x.shape
    inner, hd, nh = mlstm_dims(cfg)
    up = x @ params["up_proj"]
    xi, z = jnp.split(up, 2, axis=-1)
    q = (xi @ params["wq"]).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
    k = (xi @ params["wk"]).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
    v = (xi @ params["wv"]).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
    igate = jax.nn.sigmoid(xi.astype(jnp.float32) @ params["wi"])  # [b,s,nh]
    fgate = jax.nn.log_sigmoid(xi.astype(jnp.float32) @ params["wf"])
    q = q / np.sqrt(hd)
    return q, k, v, igate.transpose(0, 2, 1), fgate.transpose(0, 2, 1), z


def _mlstm_normalize(y_aug: Array) -> Array:
    num, den = y_aug[..., :-1], y_aug[..., -1:]
    return num / jnp.maximum(jnp.abs(den), 1.0)


def mlstm_block(params, x: Array, cfg: ModelConfig, h0: Array = None):
    b, s, d = x.shape
    inner, hd, nh = mlstm_dims(cfg)
    q, k, v, i, f, z = _mlstm_qkvif(params, x, cfg)
    # normalizer trick: append ones column to v, scaled by input gate
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    v_aug = v_aug * i[..., None].astype(v.dtype)
    if h0 is None:
        h0 = jnp.zeros((b, nh, hd, hd + 1), jnp.float32)
    if cfg.attn_impl == "pallas":
        from repro.kernels import ops as kops
        y_aug, h_final = kops.ssd_scan(q, k, v_aug, f, h0)
    else:
        y_aug, h_final = chunked_linear_scan(q, k, v_aug, f, h0)
    y = _mlstm_normalize(y_aug.astype(jnp.float32)).astype(x.dtype)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, inner)
    y = y * jax.nn.silu(z)
    return y @ params["out_proj"], h_final


def mlstm_decode_step(params, x: Array, cfg: ModelConfig, state: Array):
    b = x.shape[0]
    inner, hd, nh = mlstm_dims(cfg)
    q, k, v, i, f, z = _mlstm_qkvif(params, x, cfg)
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    v_aug = (v_aug * i[..., None].astype(v.dtype))[:, :, 0]
    y_aug, state = linear_scan_step(q[:, :, 0], k[:, :, 0], v_aug,
                                    f[:, :, 0], state)
    y = _mlstm_normalize(y_aug.astype(jnp.float32)).astype(x.dtype)
    y = y.reshape(b, 1, inner) * jax.nn.silu(z)
    return y @ params["out_proj"], state


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM scalar memory, exact exponential gating + stabilizer)
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    nh = cfg.num_heads
    hd = d // nh
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_in": dense_init(k1, d, 4 * d, dt),           # z, i, f, o pre-acts
        # block-diagonal recurrent weights: per head [nh, hd, 4*hd]
        "r_rec": (jax.random.normal(k2, (nh, hd, 4 * hd), jnp.float32)
                  / np.sqrt(hd)).astype(jnp.float32),
        "bias": jnp.zeros((4 * d,), jnp.float32),
        "out_proj": dense_init(k3, d, d, dt),
    }


def slstm_cell(params, xt: Array, carry, cfg: ModelConfig):
    """One timestep. xt: [b, 4d] pre-activations from input projection."""
    h, c, n, m = carry                                   # [b, d] each (fp32)
    nh = cfg.num_heads
    d = h.shape[-1]
    hd = d // nh
    hh = h.reshape(-1, nh, hd)
    rec = jnp.einsum("bnd,ndk->bnk", hh, params["r_rec"]).reshape(-1, 4 * d)
    pre = xt.astype(jnp.float32) + rec + params["bias"]
    zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
    zt = jnp.tanh(zt)
    ot = jax.nn.sigmoid(ot)
    log_f = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(log_f + m, it)                   # stabilizer
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c_new = f_p * c + i_p * zt
    n_new = f_p * n + i_p
    h_new = ot * c_new / jnp.maximum(n_new, 1e-6)
    return (h_new, c_new, n_new, m_new)


def slstm_block(params, x: Array, cfg: ModelConfig, carry=None):
    """x: [b, S, d] -> [b, S, d]; sequential scan over time."""
    b, s, d = x.shape
    xin = x @ params["w_in"]                             # [b, S, 4d]
    if carry is None:
        zeros = jnp.zeros((b, d), jnp.float32)
        carry = (zeros, zeros, zeros, jnp.full((b, d), -1e30, jnp.float32))

    def step(carry, xt):
        new = slstm_cell(params, xt, carry, cfg)
        return new, new[0]

    carry, hs = jax.lax.scan(step, carry, jnp.moveaxis(xin, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)           # [b, S, d]
    return y @ params["out_proj"], carry


def slstm_decode_step(params, x: Array, cfg: ModelConfig, carry):
    xin = (x @ params["w_in"])[:, 0]
    carry = slstm_cell(params, xin, carry, cfg)
    y = carry[0][:, None, :].astype(x.dtype)
    return y @ params["out_proj"], carry
