"""Core transformer layers: norms, RoPE, GQA attention, SwiGLU — pure JAX.

All layers are functional: ``init_*`` returns a params pytree (bf16 by
default), ``apply`` fns are jit/scan/shard-friendly. Layer params for a
depth-L stack are stacked along a leading axis by the caller
(``transformer.py``) so the decoder is a single ``lax.scan``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

Array = jax.Array


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, in_dim: int, out_dim: int, dtype) -> Array:
    scale = 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> Array:
    return jnp.ones((d,), dtype)


def rmsnorm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * scale.astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> Array:
    """Inverse frequencies [head_dim//2], fp32."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq]."""
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, theta)
    # angles [..., seq, 1, head_dim//2]
    ang = positions[..., None, None].astype(jnp.float32) * inv_freq
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, causal, chunked-q blockwise softmax)
# ---------------------------------------------------------------------------

def attention_init(key, cfg: ModelConfig) -> dict:
    dt = _dtype(cfg)
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d, h * hd, dt),
        "wk": dense_init(kk, d, kvh * hd, dt),
        "wv": dense_init(kv, d, kvh * hd, dt),
        "wo": dense_init(ko, h * hd, d, dt),
    }


def _qkv(params, x: Array, cfg: ModelConfig, positions: Array):
    b, s, _ = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(b, s, h, hd)
    k = (x @ params["wk"]).reshape(b, s, kvh, hd)
    v = (x @ params["wv"]).reshape(b, s, kvh, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa_chunk(q: Array, k: Array, v: Array, mask: Optional[Array],
                scale: float) -> Array:
    """One q-chunk of GQA attention. q:[b,qc,h,hd] k/v:[b,skv,kvh,hd]."""
    b, qc, h, hd = q.shape
    kvh = k.shape[2]
    grp = h // kvh
    qg = q.reshape(b, qc, kvh, grp, hd)
    # scores [b, kvh, grp, qc, skv] in fp32
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(b, qc, h, hd)


def causal_attention(q: Array, k: Array, v: Array, cfg: ModelConfig,
                     q_offset: int = 0) -> Array:
    """Chunked causal attention: scan over q chunks keeps peak memory at
    one [b, qc, seq] score block (flash-style memory footprint; the Pallas
    kernel in ``repro.kernels.flash_attention`` is the TPU version)."""
    b, s, h, hd = q.shape
    scale = 1.0 / np.sqrt(hd)
    qc = min(cfg.attn_q_chunk, s)
    if s % qc != 0:  # fall back to single chunk for ragged smoke shapes
        qc = s
    n_chunks = s // qc
    kv_pos = jnp.arange(k.shape[1])

    def chunk_fn(carry, idx):
        q_chunk = jax.lax.dynamic_slice_in_dim(q, idx * qc, qc, axis=1)
        q_pos = q_offset + idx * qc + jnp.arange(qc)
        mask = kv_pos[None, None, :] <= q_pos[None, :, None]  # [1, qc, skv]
        mask = jnp.broadcast_to(mask, (b, qc, k.shape[1]))
        out = _sdpa_chunk(q_chunk, k, v, mask, scale)
        return carry, out

    _, outs = jax.lax.scan(chunk_fn, None, jnp.arange(n_chunks))
    # outs: [n_chunks, b, qc, h, hd] -> [b, s, h, hd]
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, h, hd)


def attention_impl(q: Array, k: Array, v: Array, cfg: ModelConfig) -> Array:
    """Dispatch on cfg.attn_impl: flash (custom-vjp, default) | xla
    (naive chunked; baseline in EXPERIMENTS §Perf) | pallas (TPU)."""
    if cfg.attn_impl == "pallas":
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=True)
    if cfg.attn_impl == "flash":
        from repro.models.attention_flash import flash_attention
        return flash_attention(q, k, v, True, cfg.attn_q_chunk,
                               cfg.attn_kv_chunk)
    return causal_attention(q, k, v, cfg)


def attention_block(params, x: Array, cfg: ModelConfig, positions: Array) -> Array:
    q, k, v = _qkv(params, x, cfg, positions)
    out = attention_impl(q, k, v, cfg)
    b, s = x.shape[:2]
    return out.reshape(b, s, -1) @ params["wo"]


def attention_decode(params, x: Array, cfg: ModelConfig, k_cache: Array,
                     v_cache: Array, pos: Array,
                     window: int = 0) -> Tuple[Array, Array, Array]:
    """Single-token decode. x:[b,1,d]; caches [b, S_max, kvh, hd]; pos [b].

    Returns (out [b,1,d], new_k_cache, new_v_cache). With ``window`` > 0 the
    cache is a ring buffer of that length (used by zamba2's shared block).
    """
    b = x.shape[0]
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(b, 1, h, hd)
    k = (x @ params["wk"]).reshape(b, 1, kvh, hd)
    v = (x @ params["wv"]).reshape(b, 1, kvh, hd)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)

    s_max = k_cache.shape[1]
    slot = pos % window if window else pos
    k_cache = jax.vmap(lambda c, kk, i: jax.lax.dynamic_update_slice_in_dim(
        c, kk, i, axis=0))(k_cache, k, slot)
    v_cache = jax.vmap(lambda c, vv, i: jax.lax.dynamic_update_slice_in_dim(
        c, vv, i, axis=0))(v_cache, v, slot)

    kv_pos = jnp.arange(s_max)
    if window:
        valid = kv_pos[None, :] < jnp.minimum(pos + 1, window)[:, None]
    else:
        valid = kv_pos[None, :] <= pos[:, None]
    mask = valid[:, None, :]  # [b, 1, s_max]
    out = _sdpa_chunk(q, k_cache, v_cache, mask, 1.0 / np.sqrt(hd))
    return out.reshape(b, 1, -1) @ params["wo"], k_cache, v_cache


def attention_decode_paged(params, x: Array, cfg: ModelConfig,
                           k_pages: Array, v_pages: Array,
                           block_tables: Array, pos: Array
                           ) -> Tuple[Array, Array, Array]:
    """Single-token decode against a paged KV pool (serving tier).

    x:[b,1,d]; pages [num_blocks, bs, kvh, hd] (this layer's slice of the
    pool); block_tables [b, nblk] maps each session's logical block k to
    a physical page; pos [b] = tokens already cached. The new K/V row is
    scattered into page ``block_tables[i, pos // bs]`` slot ``pos % bs``;
    attention runs through ``kernels.ops.paged_decode_attention`` (TPU
    split-K kernel / CPU gather+dense). Inactive batch rows should point
    their whole table at the scratch page 0 with pos 0.

    Returns (out [b,1,d], k_pages, v_pages).
    """
    b = x.shape[0]
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    bs = k_pages.shape[1]
    q = (x @ params["wq"]).reshape(b, 1, h, hd)
    k = (x @ params["wk"]).reshape(b, 1, kvh, hd)
    v = (x @ params["wv"]).reshape(b, 1, kvh, hd)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)

    blk = jnp.take_along_axis(block_tables, (pos // bs)[:, None], axis=1)[:, 0]
    slot = pos % bs
    # duplicate (blk, slot) targets only occur on the scratch page 0
    # (inactive rows) — the undefined winner there is never read.
    k_pages = k_pages.at[blk, slot].set(k[:, 0])
    v_pages = v_pages.at[blk, slot].set(v[:, 0])

    from repro.kernels import ops as kops
    out = kops.paged_decode_attention(q[:, 0], k_pages, v_pages,
                                      block_tables, pos + 1)
    return out.reshape(b, 1, -1) @ params["wo"], k_pages, v_pages


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    dt = _dtype(cfg)
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "wg": dense_init(kg, d, ff, dt),
        "wu": dense_init(ku, d, ff, dt),
        "wd": dense_init(kd, ff, d, dt),
    }


def mlp_block(params, x: Array) -> Array:
    return (jax.nn.silu(x @ params["wg"]) * (x @ params["wu"])) @ params["wd"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embedding_init(key, cfg: ModelConfig) -> dict:
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    p = {"tok": (jax.random.normal(k1, (cfg.vocab_size, cfg.d_model), jnp.float32)
                 * 0.02).astype(dt)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(k2, cfg.d_model, cfg.vocab_size, dt)
    return p


def embed(params, tokens: Array) -> Array:
    return params["tok"][tokens]


def unembed(params, x: Array, cfg: ModelConfig) -> Array:
    if cfg.tie_embeddings:
        w = params["tok"].T
    else:
        w = params["unembed"]
    return (x @ w).astype(jnp.float32)
