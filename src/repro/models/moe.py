"""Mixture-of-Experts FFN: top-k routing with capacity-based dispatch.

Two implementations:

* ``moe_block`` — production path. Sort-by-expert + scatter into a fixed
  ``[E, C, d]`` buffer (GShard-style token dropping at capacity), grouped
  einsum ``ecd,edf->ecf`` (shards cleanly: E over the EP/model axis, C over
  data), gather back with combine weights. FLOPs == active-expert compute
  x capacity factor.
* ``moe_block_dense_oracle`` — all-experts-per-token reference used by unit
  tests to validate routing/combine math (never for big shapes).

Shared experts (DeepSeek-V3 / Kimi lineage) are plain SwiGLU applied to all
tokens, added to the routed output.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, mlp_block, mlp_init

Array = jax.Array


def moe_init(key, cfg: ModelConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    p = {
        "router": dense_init(kr, d, e, jnp.float32),
        "wg": (jax.random.normal(kg, (e, d, ff), jnp.float32) * scale).astype(dt),
        "wu": (jax.random.normal(ku, (e, d, ff), jnp.float32) * scale).astype(dt),
        "wd": (jax.random.normal(kd, (e, ff, d), jnp.float32) * scale).astype(dt),
    }
    if cfg.num_shared_experts:
        p["shared"] = mlp_init(ks, cfg, d_ff=cfg.num_shared_experts * cfg.d_ff)
    return p


def router_probs(params, x: Array, cfg: ModelConfig) -> Tuple[Array, Array]:
    """Top-k gates (renormalized) and expert ids. x: [T, d]."""
    logits = (x.astype(jnp.float32) @ params["router"])  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.experts_per_token)  # [T, k]
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    return gates, idx


def load_balance_loss(params, x: Array, cfg: ModelConfig) -> Array:
    """Switch-style aux loss: E * sum(fraction_tokens * fraction_prob)."""
    logits = x.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    counts = jnp.sum(jax.nn.one_hot(idx, cfg.num_experts, dtype=jnp.float32),
                     axis=(0, 1))
    f = counts / jnp.maximum(jnp.sum(counts), 1.0)
    p = jnp.mean(probs, axis=0)
    return cfg.num_experts * jnp.sum(f * p)


def moe_block(params, x: Array, cfg: ModelConfig) -> Array:
    """x: [B, S, d] -> [B, S, d]."""
    b, s, d = x.shape
    t = b * s
    k = cfg.experts_per_token
    e = cfg.num_experts
    cap = int(t * k / e * cfg.moe_capacity_factor)
    cap = max(8, -(-cap // 8) * 8)  # round up to 8, floor 8

    xf = x.reshape(t, d)
    gates, idx = router_probs(params, xf, cfg)  # [T, k]

    # Flatten (token, slot) assignments and sort by expert id.
    e_flat = idx.reshape(t * k)
    g_flat = gates.reshape(t * k)
    t_flat = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(e_flat)  # stable
    e_sort, g_sort, t_sort = e_flat[order], g_flat[order], t_flat[order]

    # Position of each assignment within its expert's contiguous run.
    counts = jnp.bincount(e_flat, length=e)              # [E]
    starts = jnp.cumsum(counts) - counts                 # [E]
    slot = jnp.arange(t * k) - starts[e_sort]            # [T*k]
    keep = slot < cap
    slot_c = jnp.where(keep, slot, 0)

    # Scatter tokens into [E, C, d] buffers (dropped tokens zeroed).
    vals = xf[t_sort] * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((e, cap, d), x.dtype).at[e_sort, slot_c].add(
        vals, mode="drop")

    # Grouped SwiGLU over experts.
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["wg"])) * \
        jnp.einsum("ecd,edf->ecf", buf, params["wu"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["wd"])  # [E, C, d]

    # Gather back with combine weights; dropped assignments contribute 0.
    gathered = out_buf[e_sort, slot_c] * (g_sort * keep)[:, None].astype(x.dtype)
    yf = jnp.zeros((t, d), x.dtype).at[t_sort].add(gathered, mode="drop")

    if "shared" in params:
        yf = yf + mlp_block(params["shared"], xf)
    return yf.reshape(b, s, d)


def moe_block_dense_oracle(params, x: Array, cfg: ModelConfig) -> Array:
    """All-experts oracle (tiny shapes only): exact, no capacity drops."""
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    gates, idx = router_probs(params, xf, cfg)
    # y_e = FFN_e(x) for every expert: [T, E, d]
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xf, params["wg"])) * \
        jnp.einsum("td,edf->tef", xf, params["wu"])
    y_all = jnp.einsum("tef,efd->ted", h, params["wd"])
    combine = jnp.zeros((xf.shape[0], cfg.num_experts), jnp.float32)
    combine = combine.at[jnp.arange(xf.shape[0])[:, None], idx].add(gates)
    yf = jnp.einsum("te,ted->td", combine.astype(x.dtype), y_all)
    if "shared" in params:
        yf = yf + mlp_block(params["shared"], xf)
    return yf.reshape(b, s, d)
