"""Decoder stack: scan-over-layers forward, prefill and decode paths.

Layer params are stacked along a leading axis so the whole depth is a
single ``jax.lax.scan`` (HLO size independent of depth; remat per block).
Heterogeneous families use *periodic groups*:

* dense/vlm/audio : one run of L attention blocks
* moe             : one run of L (attention + MoE-FFN) blocks
* ssm (xlstm)     : G groups of (p-1 mLSTM + 1 sLSTM), p = slstm_every
* hybrid (zamba2) : G groups of (p-1 Mamba2 + 1 SHARED attention block),
                    p = attn_every; the attention block's weights are a
                    single copy reused by every group (Zamba2's trick)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S

Array = jax.Array
PyTree = Any


def _maybe_remat(fn, cfg: ModelConfig):
    return jax.checkpoint(fn) if cfg.remat == "block" else fn

PREFIX_LEN = {"vision_patches": 256, "audio_frames": 64}


def _stack(key, n: int, init_fn):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def group_layout(cfg: ModelConfig) -> Tuple[int, int]:
    """(num_groups, layers_per_group). Uniform families: (1, L)."""
    if cfg.family == "ssm" and cfg.slstm_every:
        p = cfg.slstm_every
        assert cfg.num_layers % p == 0, "num_layers must divide slstm_every"
        return cfg.num_layers // p, p
    if cfg.family == "hybrid" and cfg.attn_every:
        p = cfg.attn_every
        assert cfg.num_layers % p == 0, "num_layers must divide attn_every"
        return cfg.num_layers // p, p
    return 1, cfg.num_layers


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: Array) -> Dict[str, PyTree]:
    ke, kb, ks = jax.random.split(key, 3)
    params: Dict[str, PyTree] = {"embedding": L.embedding_init(ke, cfg)}
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    g, p = group_layout(cfg)

    if cfg.family in ("dense", "vlm", "audio"):
        params["blocks"] = _stack(kb, cfg.num_layers, lambda k: {
            "norm1": L.rmsnorm_init(d, dt),
            "attn": L.attention_init(jax.random.fold_in(k, 0), cfg),
            "norm2": L.rmsnorm_init(d, dt),
            "mlp": L.mlp_init(jax.random.fold_in(k, 1), cfg),
        })
    elif cfg.family == "moe":
        params["blocks"] = _stack(kb, cfg.num_layers, lambda k: {
            "norm1": L.rmsnorm_init(d, dt),
            "attn": L.attention_init(jax.random.fold_in(k, 0), cfg),
            "norm2": L.rmsnorm_init(d, dt),
            "moe": M.moe_init(jax.random.fold_in(k, 1), cfg),
        })
    elif cfg.family == "ssm":
        def group_init(k):
            return {
                "mlstm": _stack(jax.random.fold_in(k, 0), p - 1, lambda kk: {
                    "norm": L.rmsnorm_init(d, dt),
                    "cell": S.mlstm_init(kk, cfg),
                }),
                "slstm": {
                    "norm": L.rmsnorm_init(d, dt),
                    "cell": S.slstm_init(jax.random.fold_in(k, 1), cfg),
                },
            }
        params["blocks"] = _stack(kb, g, group_init)
    elif cfg.family == "hybrid":
        def group_init(k):
            return _stack(k, p - 1, lambda kk: {
                "norm": L.rmsnorm_init(d, dt),
                "cell": S.mamba_init(kk, cfg),
            })
        params["blocks"] = _stack(kb, g, group_init)
        params["shared_attn"] = {
            "norm1": L.rmsnorm_init(d, dt),
            "attn": L.attention_init(jax.random.fold_in(ks, 0), cfg),
            "norm2": L.rmsnorm_init(d, dt),
            "mlp": L.mlp_init(jax.random.fold_in(ks, 1), cfg),
        }
    else:
        raise ValueError(cfg.family)

    if cfg.frontend != "none":
        params["frontend_norm"] = L.rmsnorm_init(d, dt)
    params["final_norm"] = L.rmsnorm_init(d, dt)
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _attn_mlp_block(bp, h, cfg, positions, use_moe: bool,
                    want_kv: bool = False):
    hn = L.rmsnorm(h, bp["norm1"], cfg.norm_eps)
    q, k, v = L._qkv(bp["attn"], hn, cfg, positions)
    att = L.attention_impl(q, k, v, cfg)
    b, s = h.shape[:2]
    h = h + att.reshape(b, s, -1) @ bp["attn"]["wo"]
    hin = L.rmsnorm(h, bp["norm2"], cfg.norm_eps)
    if use_moe:
        out = M.moe_block(bp["moe"], hin, cfg)
        aux = M.load_balance_loss(bp["moe"], hin.reshape(-1, cfg.d_model), cfg)
    else:
        out = L.mlp_block(bp["mlp"], hin)
        aux = jnp.zeros((), jnp.float32)
    kv = (k, v) if want_kv else ()
    return h + out, aux, kv


def forward(params: PyTree, tokens: Array, cfg: ModelConfig,
            prefix_embeds: Optional[Array] = None,
            collect_cache: bool = False):
    """tokens: [b, s_text]. Returns (logits [b, s_text, V], aux_loss, cache).

    ``prefix_embeds`` [b, P, d] (modality stub) is prepended; logits are
    produced for token positions only.
    """
    b, s_text = tokens.shape
    h = L.embed(params["embedding"], tokens)
    if prefix_embeds is not None:
        pre = L.rmsnorm(prefix_embeds.astype(h.dtype), params["frontend_norm"],
                        cfg.norm_eps)
        h = jnp.concatenate([pre, h], axis=1)
    s = h.shape[1]
    positions = jnp.arange(s)
    g, p = group_layout(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    cache = None

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        use_moe = cfg.family == "moe"

        def block(h, bp):
            h, aux, kv = _attn_mlp_block(bp, h, cfg, positions, use_moe,
                                         want_kv=collect_cache)
            return h, (aux, kv)

        h, (auxs, kvs) = jax.lax.scan(_maybe_remat(block, cfg), h,
                                      params["blocks"])
        aux_total = jnp.sum(auxs)
        if collect_cache:
            # kvs: ([L, b, s, kvh, hd], [L, b, s, kvh, hd]) — one pass
            cache = {"k": kvs[0], "v": kvs[1],
                     "pos": jnp.full((b,), s, jnp.int32)}

    elif cfg.family == "ssm":
        def group(h, gp):
            def mblock(h, lp):
                y, hf = S.mlstm_block(
                    lp["cell"], L.rmsnorm(h, lp["norm"], cfg.norm_eps), cfg)
                return h + y, hf
            h, mstates = jax.lax.scan(_maybe_remat(mblock, cfg), h, gp["mlstm"])
            sp = gp["slstm"]
            y, scarry = S.slstm_block(sp["cell"],
                                      L.rmsnorm(h, sp["norm"], cfg.norm_eps),
                                      cfg)
            return h + y, (mstates, scarry)
        h, (mstates, scarries) = jax.lax.scan(group, h, params["blocks"])
        if collect_cache:
            cache = {"mlstm": mstates, "slstm": scarries,
                     "pos": jnp.full((b,), s, jnp.int32)}

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]
        w = min(cfg.shared_attn_window, s)

        def group(h, gp):
            def mblock(h, lp):
                y, hf, ctail = S.mamba_block(
                    lp["cell"], L.rmsnorm(h, lp["norm"], cfg.norm_eps), cfg)
                return h + y, (hf, ctail)
            h, (sstates, convs) = jax.lax.scan(_maybe_remat(mblock, cfg), h,
                                               gp)
            h, _, kv = _attn_mlp_block(shared, h, cfg, positions, False,
                                       want_kv=collect_cache)
            if collect_cache:
                # keep only the last `w` positions (sliding-window cache)
                kv = (kv[0][:, -w:], kv[1][:, -w:])
            return h, (sstates, convs, kv)
        h, (sstates, convs, kvs) = jax.lax.scan(group, h, params["blocks"])
        if collect_cache:
            cache = {"ssm": sstates, "conv": convs,
                     "attn_k": kvs[0], "attn_v": kvs[1],
                     "pos": jnp.full((b,), s, jnp.int32)}

    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    if prefix_embeds is not None:
        h = h[:, -s_text:]
    logits = L.unembed(params["embedding"], h, cfg)
    return logits, aux_total, cache


def lm_loss(params: PyTree, batch: Dict[str, Array], cfg: ModelConfig,
            aux_coef: float = 0.01) -> Array:
    """Next-token cross entropy (+ MoE aux)."""
    logits, aux, _ = forward(params, batch["tokens"], cfg,
                             prefix_embeds=batch.get("prefix_embeds"))
    targets = batch["labels"]
    # one-hot contraction instead of take_along_axis: with vocab-sharded
    # logits this reduces to a tiny psum instead of a logits all-gather
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    picked = jnp.einsum("...v,...v->...", logits, onehot)
    nll = lse - picked
    mask = batch.get("loss_mask")
    if mask is None:
        loss = jnp.mean(nll)
    else:
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + aux_coef * aux


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> PyTree:
    """Zeroed decode cache pytree (family-dependent; see DESIGN.md §4)."""
    dt = jnp.dtype(cfg.dtype)
    g, p = group_layout(cfg)
    kvh, hd = cfg.num_kv_heads, cfg.head_dim
    if cfg.family in ("dense", "vlm", "audio", "moe"):
        lshape = (cfg.num_layers, batch, max_len, kvh, hd)
        return {"k": jnp.zeros(lshape, dt), "v": jnp.zeros(lshape, dt),
                "pos": jnp.zeros((batch,), jnp.int32)}
    if cfg.family == "ssm":
        inner, mhd, nh = S.mlstm_dims(cfg)
        d = cfg.d_model
        return {
            "mlstm": jnp.zeros((g, p - 1, batch, nh, mhd, mhd + 1), jnp.float32),
            "slstm": tuple(jnp.zeros((g, batch, d), jnp.float32)
                           for _ in range(4)),
            "pos": jnp.zeros((batch,), jnp.int32),
        }
    if cfg.family == "hybrid":
        inner, mhd, nh = S.mamba_dims(cfg)
        n = cfg.ssm_state
        w = min(cfg.shared_attn_window, max_len)
        return {
            "conv": jnp.zeros((g, p - 1, batch, cfg.ssm_conv_width - 1,
                               inner + 2 * n), dt),
            "ssm": jnp.zeros((g, p - 1, batch, nh, n, mhd), jnp.float32),
            "attn_k": jnp.zeros((g, batch, w, kvh, hd), dt),
            "attn_v": jnp.zeros((g, batch, w, kvh, hd), dt),
            "pos": jnp.zeros((batch,), jnp.int32),
        }
    raise ValueError(cfg.family)


def decode_step(params: PyTree, cache: PyTree, token: Array,
                cfg: ModelConfig) -> Tuple[Array, PyTree]:
    """One decode step. token: [b] int32. Returns (logits [b, V], cache)."""
    b = token.shape[0]
    pos = cache["pos"]
    h = L.embed(params["embedding"], token[:, None])      # [b, 1, d]
    g, p = group_layout(cfg)

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        def block(h, xs):
            bp, kc, vc = xs
            hn = L.rmsnorm(h, bp["norm1"], cfg.norm_eps)
            att, kc, vc = L.attention_decode(bp["attn"], hn, cfg, kc, vc, pos)
            h = h + att
            hn = L.rmsnorm(h, bp["norm2"], cfg.norm_eps)
            if cfg.family == "moe":
                h = h + M.moe_block(bp["moe"], hn, cfg)
            else:
                h = h + L.mlp_block(bp["mlp"], hn)
            return h, (kc, vc)

        h, (ks, vs) = jax.lax.scan(block, h,
                                   (params["blocks"], cache["k"], cache["v"]))
        cache = {"k": ks, "v": vs, "pos": pos + 1}

    elif cfg.family == "ssm":
        def group(h, xs):
            gp, mstate, sstate = xs

            def mblock(h, xs2):
                lp, st = xs2
                y, st = S.mlstm_decode_step(
                    lp["cell"], L.rmsnorm(h, lp["norm"], cfg.norm_eps), cfg, st)
                return h + y, st
            h, mstate = jax.lax.scan(mblock, h, (gp["mlstm"], mstate))
            sp = gp["slstm"]
            y, sstate = S.slstm_decode_step(
                sp["cell"], L.rmsnorm(h, sp["norm"], cfg.norm_eps), cfg, sstate)
            return h + y, (mstate, sstate)

        h, (ms, ss) = jax.lax.scan(group, h,
                                   (params["blocks"], cache["mlstm"],
                                    cache["slstm"]))
        cache = {"mlstm": ms, "slstm": ss, "pos": pos + 1}

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]
        w = cache["attn_k"].shape[2]

        def group(h, xs):
            gp, conv_st, ssm_st, kc, vc = xs

            def mblock(h, xs2):
                lp, cst, sst = xs2
                y, cst, sst = S.mamba_decode_step(
                    lp["cell"], L.rmsnorm(h, lp["norm"], cfg.norm_eps),
                    cfg, cst, sst)
                return h + y, (cst, sst)
            h, (conv_st, ssm_st) = jax.lax.scan(mblock, h,
                                                (gp, conv_st, ssm_st))
            hn = L.rmsnorm(h, shared["norm1"], cfg.norm_eps)
            att, kc, vc = L.attention_decode(shared["attn"], hn, cfg, kc, vc,
                                             pos, window=w)
            h = h + att
            h = h + L.mlp_block(shared["mlp"],
                                L.rmsnorm(h, shared["norm2"], cfg.norm_eps))
            return h, (conv_st, ssm_st, kc, vc)

        h, (cs, ss, ks, vs) = jax.lax.scan(
            group, h, (params["blocks"], cache["conv"], cache["ssm"],
                       cache["attn_k"], cache["attn_v"]))
        cache = {"conv": cs, "ssm": ss, "attn_k": ks, "attn_v": vs,
                 "pos": pos + 1}

    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embedding"], h, cfg)[:, 0]
    return logits, cache


# ---------------------------------------------------------------------------
# Prefill -> decode cache handoff
# ---------------------------------------------------------------------------

def prefill_cache_to_decode(cache: PyTree, cfg: ModelConfig, max_len: int,
                            seq_len: int,
                            lengths: Optional[Array] = None) -> PyTree:
    """Convert a ``forward(collect_cache=True)`` cache into the decode
    layout of ``init_cache(cfg, b, max_len)`` — no prompt replay.

    * dense/vlm/audio/moe: pad the KV seq axis out to ``max_len``.
    * ssm: states are O(1) and already decode-shaped — pass through.
    * hybrid: conv/ssm states pass through; the sliding-window KV kept by
      forward (last ``w_f = min(window, s)`` positions, in position
      order) is padded to the decode window ``w_d = min(window,
      max_len)`` and rotated so index ``j`` lands at ring slot
      ``pos % w_d`` expected by ``attention_decode(window=w_d)``.

    ``lengths`` [b] overrides ``pos`` for batches prefilled on
    right-padded prompts (decode then overwrites the first pad slot and
    masks the rest). Only meaningful for KV-cache families — recurrent
    states absorb pad tokens, so ssm/hybrid must prefill at exact
    length.

    Hybrid continuation is bit-exact vs token-by-token replay only while
    ``seq_len <= window``: forward runs the shared block full-causal,
    decode windows it (a pre-existing semantic gap — see
    tests/test_serve.py). The handoff itself is exact either way: the
    converted cache reproduces forward's states and KV placement.
    """
    pos = cache["pos"] if lengths is None else lengths.astype(jnp.int32)

    if cfg.family in ("dense", "vlm", "audio", "moe"):
        s = cache["k"].shape[2]
        assert s <= max_len, (s, max_len)
        pad = ((0, 0), (0, 0), (0, max_len - s), (0, 0), (0, 0))
        return {"k": jnp.pad(cache["k"], pad), "v": jnp.pad(cache["v"], pad),
                "pos": pos}

    if cfg.family == "ssm":
        return {"mlstm": cache["mlstm"], "slstm": cache["slstm"],
                "pos": pos}

    if cfg.family == "hybrid":
        k, v = cache["attn_k"], cache["attn_v"]     # [g, b, w_f, kvh, hd]
        w_f = k.shape[2]
        s = seq_len                   # static prompt length (jit-safe)
        w_d = min(cfg.shared_attn_window, max_len)
        assert w_f <= w_d, (w_f, w_d)
        if w_f < w_d:
            pad = ((0, 0), (0, 0), (0, w_d - w_f), (0, 0), (0, 0))
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        # index j holds position s - w_f + j -> ring slot (s - w_f + j) % w_d
        shift = (s - w_f) % w_d
        if shift:
            k = jnp.roll(k, shift, axis=2)
            v = jnp.roll(v, shift, axis=2)
        return {"conv": cache["conv"], "ssm": cache["ssm"],
                "attn_k": k, "attn_v": v, "pos": pos}

    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# Paged decode (serving tier)
# ---------------------------------------------------------------------------

PAGED_FAMILIES = ("dense", "vlm", "audio", "moe")


def init_paged_cache(cfg: ModelConfig, num_blocks: int,
                     block_size: int) -> PyTree:
    """Zeroed paged KV pool shared by all sessions: ``[L, num_blocks,
    block_size, kvh, hd]`` per tensor. Block 0 is the engine's scratch
    page (inactive batch rows write there). KV-cache families only —
    ssm/hybrid state is O(1)/O(window) and needs no paging."""
    if cfg.family not in PAGED_FAMILIES:
        raise ValueError(
            f"paged KV serving needs a KV-cache family, got {cfg.family}")
    dt = jnp.dtype(cfg.dtype)
    shape = (cfg.num_layers, num_blocks, block_size, cfg.num_kv_heads,
             cfg.head_dim)
    return {"k_pages": jnp.zeros(shape, dt), "v_pages": jnp.zeros(shape, dt)}


def paged_decode_step(params: PyTree, pages: PyTree, block_tables: Array,
                      pos: Array, token: Array, cfg: ModelConfig
                      ) -> Tuple[Array, PyTree]:
    """One decode step over the paged pool. token [b] int32; block_tables
    [b, nblk]; pos [b] = tokens already in cache (the new token writes at
    slot ``pos`` of its session's pages). Returns (logits [b, V], pages).
    """
    if cfg.family not in PAGED_FAMILIES:
        raise ValueError(cfg.family)
    h = L.embed(params["embedding"], token[:, None])      # [b, 1, d]

    def block(h, xs):
        bp, kp, vp = xs
        hn = L.rmsnorm(h, bp["norm1"], cfg.norm_eps)
        att, kp, vp = L.attention_decode_paged(bp["attn"], hn, cfg, kp, vp,
                                               block_tables, pos)
        h = h + att
        hn = L.rmsnorm(h, bp["norm2"], cfg.norm_eps)
        if cfg.family == "moe":
            h = h + M.moe_block(bp["moe"], hn, cfg)
        else:
            h = h + L.mlp_block(bp["mlp"], hn)
        return h, (kp, vp)

    h, (kps, vps) = jax.lax.scan(
        block, h, (params["blocks"], pages["k_pages"], pages["v_pages"]))
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embedding"], h, cfg)[:, 0]
    return logits, {"k_pages": kps, "v_pages": vps}
