from repro.models.model import Model, input_specs, batch_specs
