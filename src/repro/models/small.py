"""Small peer models for the paper-scale FL experiments.

* ``cnn_classifier``  — two-block conv net + MLP head (MNIST-analogue,
  paper §3.1 "CNN-based architecture").
* ``mlp_classifier``  — classification head on frozen features
  (20NG-on-DistilBERT analogue: the trainable part of the paper's text
  model is exactly a head over frozen CLS features).

Both are functional (init/apply) and vmap cleanly over a leading peer
axis — the sim-backend federation stacks N copies of these params.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
PyTree = Any


def _dense(key, n_in, n_out):
    w = jax.random.normal(key, (n_in, n_out), jnp.float32) / np.sqrt(n_in)
    return {"w": w, "b": jnp.zeros((n_out,), jnp.float32)}


# ---------------------------------------------------------------------------
# MLP head (text task)
# ---------------------------------------------------------------------------

def mlp_init(key, feature_dim: int, num_classes: int,
             hidden: int = 128) -> PyTree:
    k1, k2 = jax.random.split(key)
    return {"fc1": _dense(k1, feature_dim, hidden),
            "fc2": _dense(k2, hidden, num_classes)}


def mlp_apply(params: PyTree, x: Array) -> Array:
    h = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return h @ params["fc2"]["w"] + params["fc2"]["b"]


# ---------------------------------------------------------------------------
# Small CNN (vision task); input x: [B, 784] reshaped to 28x28x1
# ---------------------------------------------------------------------------

def cnn_init(key, feature_dim: int = 784, num_classes: int = 10) -> PyTree:
    side = int(np.sqrt(feature_dim))
    assert side * side == feature_dim, "vision features must be square"
    k1, k2, k3, k4 = jax.random.split(key, 4)
    c1, c2 = 8, 16
    flat = (side // 4) * (side // 4) * c2
    return {
        "conv1": jax.random.normal(k1, (3, 3, 1, c1), jnp.float32) * 0.1,
        "conv2": jax.random.normal(k2, (3, 3, c1, c2), jnp.float32) * 0.1,
        "fc1": _dense(k3, flat, 64),
        "fc2": _dense(k4, 64, num_classes),
    }


def _conv(x, w):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _pool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def cnn_apply(params: PyTree, x: Array) -> Array:
    side = int(np.sqrt(x.shape[-1]))
    img = x.reshape(-1, side, side, 1)
    h = _pool(jax.nn.relu(_conv(img, params["conv1"])))
    h = _pool(jax.nn.relu(_conv(h, params["conv2"])))
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["fc1"]["w"] + params["fc1"]["b"])
    return h @ params["fc2"]["w"] + params["fc2"]["b"]


def build_peer_model(task: str, feature_dim: int, num_classes: int):
    """Returns (init_fn(key) -> params, apply_fn(params, x) -> logits)."""
    if task == "vision":
        return (lambda key: cnn_init(key, feature_dim, num_classes),
                cnn_apply)
    return (lambda key: mlp_init(key, feature_dim, num_classes),
            mlp_apply)
