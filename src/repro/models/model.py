"""Model facade: one object per architecture config.

``Model`` bundles init / loss / decode for any of the 10 assigned
architectures; ``input_specs`` produces ShapeDtypeStruct stand-ins for
every input of the lowered step (the dry-run's no-allocation path).

Modality frontends (pixtral / musicgen) are stubs per the brief: the
batch carries precomputed patch/frame embeddings ``prefix_embeds``
[b, P, d_model] feeding the transformer backbone.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as T
from repro.models.transformer import PREFIX_LEN

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    def init(self, key: Array) -> PyTree:
        return T.init_params(self.cfg, key)

    def init_shape(self) -> PyTree:
        """Param ShapeDtypeStructs without allocating (dry-run path)."""
        return jax.eval_shape(lambda: T.init_params(
            self.cfg, jax.random.PRNGKey(0)))

    def loss(self, params: PyTree, batch: Dict[str, Array]) -> Array:
        return T.lm_loss(params, batch, self.cfg)

    def forward(self, params: PyTree, tokens: Array, **kw):
        return T.forward(params, tokens, self.cfg, **kw)

    def init_cache(self, batch: int, max_len: int) -> PyTree:
        return T.init_cache(self.cfg, batch, max_len)

    def cache_shape(self, batch: int, max_len: int) -> PyTree:
        return jax.eval_shape(
            lambda: T.init_cache(self.cfg, batch, max_len))

    def decode_step(self, params: PyTree, cache: PyTree, token: Array
                    ) -> Tuple[Array, PyTree]:
        return T.decode_step(params, cache, token, self.cfg)

    def prefill_cache_to_decode(self, cache: PyTree, max_len: int,
                                seq_len: int,
                                lengths: Optional[Array] = None) -> PyTree:
        return T.prefill_cache_to_decode(cache, self.cfg, max_len, seq_len,
                                         lengths)

    def init_paged_cache(self, num_blocks: int, block_size: int) -> PyTree:
        return T.init_paged_cache(self.cfg, num_blocks, block_size)

    def paged_decode_step(self, params: PyTree, pages: PyTree,
                          block_tables: Array, pos: Array, token: Array
                          ) -> Tuple[Array, PyTree]:
        return T.paged_decode_step(params, pages, block_tables, pos, token,
                                   self.cfg)

    @property
    def has_frontend(self) -> bool:
        return self.cfg.frontend != "none"


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; dry-run never allocates)
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                n_peers: int = 1, local_steps: int = 1,
                n_micro: int = 1) -> Dict[str, jax.ShapeDtypeStruct]:
    """Train/prefill batch stand-ins.

    Train batches carry the FL structure [n_peers, local_steps, n_micro,
    micro_batch, seq]: B local Momentum-SGD steps per peer (Alg. 1), each
    accumulating over n_micro microbatches.
    """
    f32, i32 = jnp.float32, jnp.int32
    gb, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        assert gb % (n_peers * local_steps * n_micro) == 0, \
            (gb, n_peers, local_steps, n_micro)
        mb = gb // (n_peers * local_steps * n_micro)
        lead = (n_peers, local_steps, n_micro, mb)
    else:  # prefill: flat per-request batch
        lead = (gb,)
    s_text = s
    specs = {}
    if cfg.frontend != "none":
        p = PREFIX_LEN[cfg.frontend]
        s_text = s - p
        specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            lead + (p, cfg.d_model), f32)
    specs["tokens"] = jax.ShapeDtypeStruct(lead + (s_text,), i32)
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct(lead + (s_text,), i32)
    return specs


def input_specs(cfg: ModelConfig, shape: ShapeConfig, n_peers: int = 1,
                local_steps: int = 1, n_micro: int = 1) -> Dict[str, Any]:
    """All inputs of the lowered step for one (arch x shape) cell.

    * train   -> {"batch": ...} for ``fl_train_step`` (state passed
                 separately as eval_shape'd pytree)
    * prefill -> {"batch": ...} for ``prefill_step``
    * decode / long_decode -> {"token": [b], "cache": ...} for
      ``serve_step``; the cache covers ``seq_len`` history (window/state
      caches for hybrid/ssm are O(window)/O(1) — the long_500k point).
    """
    model = Model(cfg)
    if shape.kind == "train":
        return {"batch": batch_specs(cfg, shape, n_peers, local_steps,
                                     n_micro)}
    if shape.kind == "prefill":
        return {"batch": batch_specs(cfg, shape)}
    # decode shapes
    b = shape.global_batch
    cache = model.cache_shape(b, shape.seq_len)
    # decode starts from a full history: pos = seq_len (static shape only)
    return {
        "token": jax.ShapeDtypeStruct((b,), jnp.int32),
        "cache": cache,
    }
