"""AdamW — used by the LM pretraining driver (``launch/train.py``).

Functional, pytree-structured (no optax dependency in the offline
container). State is (mu, nu, count); params may be bf16 with fp32
moments.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    mu: PyTree
    nu: PyTree
    count: jax.Array


def adamw_init(params: PyTree) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params),
                      count=jnp.zeros((), jnp.int32))


def adamw_step(params: PyTree, state: AdamWState, grads: PyTree, lr: float,
               b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
               weight_decay: float = 0.1) -> Tuple[PyTree, AdamWState]:
    count = state.count + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    new_mu = jax.tree.map(
        lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
        state.mu, grads)
    new_nu = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state.nu, grads)

    def upd(p, m, v):
        step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        step = step + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_p = jax.tree.map(upd, params, new_mu, new_nu)
    return new_p, AdamWState(new_mu, new_nu, count)
