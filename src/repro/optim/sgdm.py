"""Damped momentum SGD (Reddi et al., 2020) — the paper's local optimizer.

Update (the "damped" form used by FedOpt's ClientOpt and by MAR-FL):

    m_t = mu * m_{t-1} + (1 - mu) * g_t
    theta_t = theta_{t-1} - eta * m_t

Momentum vectors are first-class federation state: MAR averages (theta, m)
jointly (Alg. 1 line 10), so ``m`` lives in the same pytree structure as
the params.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def momentum_sgd_init(params: PyTree, dtype=jnp.float32) -> PyTree:
    """Zero momentum (fp32 default; bf16 supported for the 1T-scale
    memory hillclimb — EXPERIMENTS.md §Perf B-ladder)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, dtype), params)


def momentum_sgd_step(params: PyTree, momentum: PyTree, grads: PyTree,
                      lr: float, mu: float = 0.9) -> Tuple[PyTree, PyTree]:
    """Update in fp32, store momentum back in its own dtype."""
    new_m = jax.tree.map(
        lambda m, g: (mu * m.astype(jnp.float32)
                      + (1.0 - mu) * g.astype(jnp.float32)).astype(m.dtype),
        momentum, grads)
    new_p = jax.tree.map(
        lambda p, m: (p.astype(jnp.float32)
                      - lr * m.astype(jnp.float32)).astype(p.dtype),
        params, new_m)
    return new_p, new_m
