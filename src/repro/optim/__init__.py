from repro.optim.sgdm import momentum_sgd_init, momentum_sgd_step
from repro.optim.adamw import adamw_init, adamw_step
