"""Pallas TPU masked group mean — the MAR aggregation hot spot.

MAR round g averages each group of M peer states (paper Alg. 1 line 10);
on a host/accelerator that owns several peer replicas this is a masked
mean over the group axis, memory-bound over the full model state. The
kernel fuses mask multiply, group-sum, count, divide and the empty-group
fallback into one VMEM pass over [M, D] tiles — one read of x, one
write of y, instead of the 4 materialized intermediates of the jnp path
(mask-mul, sum, count-div, where).

Grid (G, n_tiles); block [1, M, bd]. The mask [G, M] rides in SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _group_mean_kernel(mask_ref, x_ref, o_ref, *, m: int):
    x = x_ref[0].astype(jnp.float32)                 # [M, bd]
    mask = mask_ref[0]                                # [M] f32 in SMEM
    mk = jnp.asarray([mask[i] for i in range(m)], jnp.float32)[:, None]
    num = jnp.sum(x * mk, axis=0, keepdims=True)     # [1, bd]
    den = jnp.sum(mk)
    mean = num / jnp.maximum(den, 1.0)
    out = jnp.where(den > 0, jnp.broadcast_to(mean, x.shape), x)
    o_ref[0] = out.astype(o_ref.dtype)


def group_mean_fwd(x: jax.Array, mask: jax.Array, block_d: int = 2048,
                   interpret: bool = False) -> jax.Array:
    """x [G, M, D]; mask [G, M] -> [G, M, D] (each slot gets its group's
    masked mean; fully-dropped groups keep their own values)."""
    g, m, d = x.shape
    bd = min(block_d, d)
    while d % bd:
        bd //= 2
    nt = d // bd

    kernel = functools.partial(_group_mean_kernel, m=m)
    out = pl.pallas_call(
        kernel,
        grid=(g, nt),
        in_specs=[
            pl.BlockSpec((1, m), lambda i, j: (i, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, m, bd), lambda i, j: (i, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, m, bd), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((g, m, d), x.dtype),
        interpret=interpret,
    )(mask.astype(jnp.float32), x)
    return out
