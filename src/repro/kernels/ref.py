"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests).

Each function is the semantic ground truth at f32 precision with no
blocking — the kernels must match these for every swept (shape, dtype).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def flash_attention_ref(q: Array, k: Array, v: Array,
                        causal: bool = True) -> Array:
    """q [b,s,h,d]; k,v [b,skv,kvh,d] -> [b,s,h,d] (GQA, causal)."""
    b, s, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, d)
    sc = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                    k.astype(jnp.float32)) / np.sqrt(d)
    if causal:
        mask = jnp.arange(s)[:, None] >= jnp.arange(skv)[None, :]
        sc = jnp.where(mask[None, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, s, h, d).astype(q.dtype)


def decode_attention_ref(q: Array, k_cache: Array, v_cache: Array,
                         lengths: Array) -> Array:
    """q [b,h,d]; caches [b,S,kvh,d]; lengths [b] -> [b,h,d].

    Attends to positions < lengths[b] (the filled prefix of the cache).
    """
    b, h, d = q.shape
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, d)
    sc = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                    k_cache.astype(jnp.float32)) / np.sqrt(d)
    valid = jnp.arange(s)[None, :] < lengths[:, None]
    sc = jnp.where(valid[:, None, None, :], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, h, d).astype(q.dtype)


def paged_decode_attention_ref(q: Array, k_pages: Array, v_pages: Array,
                               block_tables: Array, lengths: Array) -> Array:
    """q [b,h,d]; pages [nb,bs,kvh,d]; block_tables [b,nblk]; lengths [b].

    Gathers each session's pages into a dense [b, nblk*bs, kvh, d] cache
    (block-table order == position order) and defers to the dense decode
    oracle — the semantic ground truth for the paged kernel.
    """
    b = q.shape[0]
    bs, kvh, d = k_pages.shape[1], k_pages.shape[2], k_pages.shape[3]
    s = block_tables.shape[1] * bs
    k = k_pages[block_tables].reshape(b, s, kvh, d)
    v = v_pages[block_tables].reshape(b, s, kvh, d)
    return decode_attention_ref(q, k, v, lengths)


def ssd_scan_ref(q: Array, k: Array, v: Array, log_a: Array,
                 h0: Array) -> Tuple[Array, Array]:
    """Gated linear recurrence (Mamba2 SSD / mLSTM shared primitive).

    q,k [b,nh,S,dk]; v [b,nh,S,dv]; log_a [b,nh,S] (<=0);
    h0 [b,nh,dk,dv].  Sequential-scan ground truth:
        H_t = exp(a_t) H_{t-1} + k_t^T v_t;   y_t = q_t . H_t
    """
    def step(h, xs):
        qt, kt, vt, at = xs
        h = h * jnp.exp(at.astype(jnp.float32))[..., None, None] + \
            jnp.einsum("bhd,bhv->bhdv", kt.astype(jnp.float32),
                       vt.astype(jnp.float32))
        y = jnp.einsum("bhd,bhdv->bhv", qt.astype(jnp.float32), h)
        return h, y

    xs = (jnp.moveaxis(q, 2, 0), jnp.moveaxis(k, 2, 0),
          jnp.moveaxis(v, 2, 0), jnp.moveaxis(log_a, 2, 0))
    h_final, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 2).astype(v.dtype), h_final


def group_mean_ref(x: Array, mask: Array) -> Array:
    """Masked group mean (MAR aggregation hot spot).

    x [G, M, D]; mask [G, M] -> [G, M, D]: every slot receives its
    group's masked mean; empty groups keep their own values.
    """
    m = mask[..., None].astype(jnp.float32)
    num = jnp.sum(x.astype(jnp.float32) * m, axis=1, keepdims=True)
    den = jnp.sum(m, axis=1, keepdims=True)
    mean = num / jnp.maximum(den, 1.0)
    out = jnp.where(den > 0, mean, x.astype(jnp.float32))
    return jnp.broadcast_to(out, x.shape).astype(x.dtype)
