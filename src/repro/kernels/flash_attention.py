"""Pallas TPU flash attention (causal GQA, forward).

Canonical 3-D grid (batch*kv_head, q_block, kv_block) with VMEM scratch
accumulators — the kv axis is the innermost ("arbitrary") dimension so
the online-softmax state (acc, m, l) lives in scratch across kv steps.

TPU adaptation notes (DESIGN.md §2): VMEM working set per grid cell =
q block [g*bq, d] + k/v blocks [bk, d] + acc [g*bq, d] f32 + score tile
[g*bq, bk] f32 — ~6.5 MB at the defaults (bq=bk=512, d=128, g=4), well
under v5e's ~128 MB VMEM, with every matmul dim a multiple of 128 (MXU
aligned). Causal skipping: kv blocks entirely above the diagonal do no
work (``pl.when``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, causal: bool, scale: float, nk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * bq
    k_start = ik * bk

    def _step():
        q = q_ref[0].astype(jnp.float32)            # [g*bq, d]
        k = k_ref[0].astype(jnp.float32)            # [bk, d]
        v = v_ref[0].astype(jnp.float32)            # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [g*bq, bk]
        if causal:
            rows = q.shape[0]
            q_pos = q_start + (jax.lax.broadcasted_iota(
                jnp.int32, (rows, bk), 0) % bq)     # row layout [g, bq]
            k_pos = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (rows, bk), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        pl.when(k_start <= q_start + bq - 1)(_step)
    else:
        _step()

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, block_q: int = 512,
                        block_k: int = 512,
                        interpret: bool = False) -> jax.Array:
    """q [b,s,h,d]; k,v [b,skv,kvh,d] -> [b,s,h,d]."""
    b, s, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    bq = min(block_q, s)
    while s % bq:
        bq //= 2
    bk = min(block_k, skv)
    while skv % bk:
        bk //= 2
    nq, nk = s // bq, skv // bk
    scale = 1.0 / np.sqrt(d)

    # [b*kvh, nq*g*bq, d]: q block j holds rows [g, bq] flattened
    qr = q.reshape(b, s, kvh, g, d).transpose(0, 2, 3, 1, 4) \
        .reshape(b * kvh, g, s, d)
    qr = qr.transpose(0, 2, 1, 3).reshape(b * kvh, nq, bq, g, d) \
        .transpose(0, 1, 3, 2, 4).reshape(b * kvh, nq * g * bq, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv, d)

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, causal=causal,
                               scale=scale, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(b * kvh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, g * bq, d), lambda i, j, kk: (i, j, 0)),
            pl.BlockSpec((1, bk, d), lambda i, j, kk: (i, kk, 0)),
            pl.BlockSpec((1, bk, d), lambda i, j, kk: (i, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, g * bq, d), lambda i, j, kk: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh, nq * g * bq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * bq, d), jnp.float32),
            pltpu.VMEM((g * bq,), jnp.float32),
            pltpu.VMEM((g * bq,), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)

    out = out.reshape(b * kvh, nq, g, bq, d).transpose(0, 2, 1, 3, 4) \
        .reshape(b, kvh, g, s, d).transpose(0, 3, 1, 2, 4) \
        .reshape(b, s, h, d)
    return out
