"""Pallas TPU chunked SSD scan (Mamba2 / mLSTM shared recurrence).

Computes the gated linear recurrence

    H_t = exp(a_t) H_{t-1} + k_t^T v_t;     y_t = q_t . H_t

in chunk-parallel form: grid (batch*head, n_chunks) with the chunk axis
innermost and the running state H [dk, dv] carried in f32 VMEM scratch.
Per chunk (all in VMEM, MXU matmuls):

    cum_i   = cumsum(a)                         # [c]
    intra   = (q k^T * exp(cum_i - cum_j) * causal) v        (3 matmuls)
    inter   = (q . H) * exp(cum_i)
    H'      = exp(cum_c) H + (k * exp(cum_c - cum_j))^T v

which matches ``repro.models.ssm.chunked_linear_scan`` (the jnp
reference used for training) and ``ref.ssd_scan_ref`` (the sequential
oracle). This is the long_500k hot spot for zamba2/xlstm decode-train.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(q_ref, k_ref, v_ref, a_ref, h0_ref, y_ref, hout_ref,
                h_ref, *, chunk: int, nchunks: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    q = q_ref[0].astype(jnp.float32)                 # [c, dk]
    k = k_ref[0].astype(jnp.float32)                 # [c, dk]
    v = v_ref[0].astype(jnp.float32)                 # [c, dv]
    a = a_ref[0].astype(jnp.float32)                 # [c]
    h = h_ref[...]                                   # [dk, dv]

    cum = jnp.cumsum(a)                              # [c]
    total = cum[-1]
    qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [c, c]
    decay = cum[:, None] - cum[None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    gate = jnp.where(rows >= cols, jnp.exp(jnp.minimum(decay, 0.0)), 0.0)
    y_intra = jax.lax.dot_general(qk * gate, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_inter = jax.lax.dot_general(q, h, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32) \
        * jnp.exp(cum)[:, None]
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    w = jnp.exp(total - cum)[:, None]                # [c, 1]
    h_new = h * jnp.exp(total) + jax.lax.dot_general(
        k * w, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    h_ref[...] = h_new

    @pl.when(ic == nchunks - 1)
    def _finish():
        hout_ref[0] = h_new


def ssd_scan_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                 log_a: jax.Array, h0: jax.Array, chunk: int = 256,
                 interpret: bool = False):
    """q,k [b,nh,S,dk]; v [b,nh,S,dv]; log_a [b,nh,S]; h0 [b,nh,dk,dv].

    Returns (y [b,nh,S,dv], h_final [b,nh,dk,dv] f32).
    """
    b, nh, s, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, s)
    while s % c:
        c //= 2
    nchunks = s // c

    qr = q.reshape(b * nh, s, dk)
    kr = k.reshape(b * nh, s, dk)
    vr = v.reshape(b * nh, s, dv)
    ar = log_a.reshape(b * nh, s)
    hr = h0.reshape(b * nh, dk, dv)

    kernel = functools.partial(_ssd_kernel, chunk=c, nchunks=nchunks)
    y, h_final = pl.pallas_call(
        kernel,
        grid=(b * nh, nchunks),
        in_specs=[
            pl.BlockSpec((1, c, dk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, c, dk), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, c, dv), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, c), lambda i, j: (i, j)),
            pl.BlockSpec((1, dk, dv), lambda i, j: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, dv), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, dk, dv), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * nh, s, dv), v.dtype),
            jax.ShapeDtypeStruct((b * nh, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(qr, kr, vr, ar, hr)
    return (y.reshape(b, nh, s, dv), h_final.reshape(b, nh, dk, dv))
