"""Pallas TPU decode attention: one query token vs a long KV cache.

Split-K layout: grid (batch*kv_head, kv_split) — each grid cell reduces
one contiguous cache segment into partial (acc, m, l) carried in VMEM
scratch across the split axis (innermost, "arbitrary"), exactly the
flash recurrence with a single q row per (b, kv-head, group).

The hot spot of decode_32k is pure HBM bandwidth (read the whole cache
per token); the kernel streams [bk, d] cache tiles through VMEM and
keeps everything else resident. Out-of-range positions (beyond the
filled length) are masked with the same lane-position iota used for
causality in the prefill kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, bk: int, scale: float,
                   nk: int):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[0]
    k_start = ik * bk

    @pl.when(k_start < length)
    def _step():
        q = q_ref[0].astype(jnp.float32)             # [g, d]
        k = k_ref[0].astype(jnp.float32)             # [bk, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # [g, bk]
        pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_fwd(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, lengths: jax.Array,
                         block_k: int = 512,
                         interpret: bool = False) -> jax.Array:
    """q [b,h,d]; caches [b,S,kvh,d]; lengths [b] -> [b,h,d]."""
    b, h, d = q.shape
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    # Keep the full block size and pad the cache view up to a block
    # multiple instead of shrinking bk to a divisor of s (the old
    # ``while s % bk: bk //= 2`` silently degraded to bk=1-ish tiles for
    # non-power-of-two caches). Padded positions sit at pos >= s >=
    # length, so the existing length mask (and the k_start < length
    # block skip) already excludes them.
    bk = min(block_k, s)
    nk = -(-s // bk)
    s_pad = nk * bk
    scale = 1.0 / np.sqrt(d)

    qr = q.reshape(b, kvh, g, d).reshape(b * kvh, g, d)
    kr = k_cache.transpose(0, 2, 1, 3).reshape(b * kvh, s, d)
    vr = v_cache.transpose(0, 2, 1, 3).reshape(b * kvh, s, d)
    if s_pad != s:
        pad = ((0, 0), (0, s_pad - s), (0, 0))
        kr, vr = jnp.pad(kr, pad), jnp.pad(vr, pad)
    lens = jnp.repeat(lengths.astype(jnp.int32), kvh)      # [b*kvh]

    kernel = functools.partial(_decode_kernel, bk=bk, scale=scale, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(b * kvh, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda i, kk: (i,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, g, d), lambda i, kk: (i, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda i, kk: (i, kk, 0)),
            pl.BlockSpec((1, bk, d), lambda i, kk: (i, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda i, kk: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qr, kr, vr)
    return out.reshape(b, kvh, g, d).reshape(b, h, d)
