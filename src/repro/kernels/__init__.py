"""Pallas TPU kernels (interpret-mode validated on CPU; see ops.py)."""
from repro.kernels import ops, ref
