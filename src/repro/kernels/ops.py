"""Public jit'd wrappers for the Pallas kernels.

On this CPU container the kernels execute through the Pallas
interpreter (``interpret=True`` — the kernel body runs in Python,
semantics-exact); on TPU set ``REPRO_PALLAS_INTERPRET=0`` (or rely on
the default platform check) for compiled Mosaic kernels.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import decode_attention_fwd
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.group_mean import group_mean_fwd
from repro.kernels.paged_attention import (gather_dense_decode,
                                           paged_decode_attention_fwd)
from repro.kernels.ssd_scan import ssd_scan_fwd

Array = jax.Array


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _check(cond, msg):
    if not cond:
        raise ValueError(msg)


@functools.partial(jax.jit, static_argnames=("causal",))
def flash_attention(q: Array, k: Array, v: Array,
                    causal: bool = True) -> Array:
    """q [b,s,h,d]; k,v [b,skv,kvh,d] -> [b,s,h,d]."""
    _check(q.ndim == 4 and k.ndim == 4 and v.ndim == 4, "rank-4 inputs")
    _check(k.shape == v.shape, "k/v shape mismatch")
    _check(q.shape[3] == k.shape[3], "head_dim mismatch")
    _check(q.shape[2] % k.shape[2] == 0, "GQA heads must divide")
    return flash_attention_fwd(q, k, v, causal, interpret=_interpret())


@jax.jit
def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     lengths: Array) -> Array:
    """q [b,h,d]; caches [b,S,kvh,d]; lengths [b] -> [b,h,d]."""
    _check(q.ndim == 3 and k_cache.ndim == 4, "bad ranks")
    _check(q.shape[2] == k_cache.shape[3], "head_dim mismatch")
    return decode_attention_fwd(q, k_cache, v_cache, lengths,
                                interpret=_interpret())


@jax.jit
def paged_decode_attention(q: Array, k_pages: Array, v_pages: Array,
                           block_tables: Array, lengths: Array) -> Array:
    """q [b,h,d]; pages [nb,bs,kvh,d]; block_tables [b,nblk]; lengths [b]
    -> [b,h,d].

    TPU: split-K kernel gathering pages via the scalar-prefetched block
    table. CPU/interpret: gather+dense fallback (running the kernel
    through the Python interpreter per page would be the slow path;
    the gathered einsum is semantics-exact).
    """
    _check(q.ndim == 3 and k_pages.ndim == 4, "bad ranks")
    _check(q.shape[2] == k_pages.shape[3], "head_dim mismatch")
    _check(k_pages.shape == v_pages.shape, "k/v pages mismatch")
    _check(block_tables.ndim == 2 and block_tables.shape[0] == q.shape[0],
           "block_tables must be [b, nblk]")
    if _interpret():
        return gather_dense_decode(q, k_pages, v_pages, block_tables,
                                   lengths)
    return paged_decode_attention_fwd(q, k_pages, v_pages, block_tables,
                                      lengths, interpret=False)


@jax.jit
def ssd_scan(q: Array, k: Array, v: Array, log_a: Array, h0: Array):
    """Chunked gated linear recurrence; see ssd_scan.py."""
    _check(q.shape == k.shape, "q/k shape mismatch")
    _check(q.shape[:3] == v.shape[:3], "v batch/seq mismatch")
    return ssd_scan_fwd(q, k, v, log_a, h0, interpret=_interpret())


@jax.jit
def group_mean(x: Array, mask: Array) -> Array:
    """Masked MAR group mean; x [G, M, D], mask [G, M]."""
    _check(x.ndim == 3 and mask.shape == x.shape[:2], "bad shapes")
    return group_mean_fwd(x, mask, interpret=_interpret())
