"""Pallas TPU paged decode attention: one query token vs a block-paged cache.

The serving tier stores KV in fixed-size *blocks* (``[num_blocks,
block_size, kvh, d]``) owned by a host-side allocator; each session
holds an ordered *block table* row mapping its logical positions to
physical blocks (``serve/paged_cache.py``). This kernel is the paged
variant of ``decode_attention.py``: the same split-K flash recurrence
over grid ``(batch*kv_head, blocks_per_session)``, but the K/V tile for
grid cell ``(i, kk)`` is *gathered through the block table* — the table
(and the per-session filled lengths) ride in as scalar-prefetch
operands so the BlockSpec index map can pick the physical page before
the tile DMA is issued. Out-of-range positions (beyond ``lengths[b]``,
including the garbage tail of a partially-filled last block and any
scratch-page padding rows of the table) are masked by the same
lane-position iota as the dense kernel.

On CPU/interpret the production path does not run the kernel at all:
``gather_dense_decode`` materializes the session's pages into a dense
cache view and applies the exact einsum/softmax used by the dense
decode path (``interpret=True`` on the kernel itself is kept for
parity tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, bs: int, scale: float,
                         nblk: int, kvh: int):
    i, kk = pl.program_id(0), pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[i // kvh]
    k_start = kk * bs

    @pl.when(k_start < length)
    def _step():
        q = q_ref[0].astype(jnp.float32)             # [g, d]
        k = k_ref[0, :, 0, :].astype(jnp.float32)    # [bs, d]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # [g, bs]
        pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kk == nblk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_decode_attention_fwd(q: jax.Array, k_pages: jax.Array,
                               v_pages: jax.Array, block_tables: jax.Array,
                               lengths: jax.Array,
                               interpret: bool = False) -> jax.Array:
    """q [b,h,d]; pages [nb,bs,kvh,d]; block_tables [b,nblk]; lengths [b]
    -> [b,h,d]."""
    b, h, d = q.shape
    bs, kvh = k_pages.shape[1], k_pages.shape[2]
    nblk = block_tables.shape[1]
    g = h // kvh
    scale = 1.0 / np.sqrt(d)

    qr = q.reshape(b, kvh, g, d).reshape(b * kvh, g, d)
    kernel = functools.partial(_paged_decode_kernel, bs=bs, scale=scale,
                               nblk=nblk, kvh=kvh)
    page_spec = pl.BlockSpec(
        (1, bs, 1, d),
        lambda i, kk, bt, ln: (bt[i // kvh, kk], 0, i % kvh, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,       # block_tables, lengths
        grid=(b * kvh, nblk),
        in_specs=[
            pl.BlockSpec((1, g, d), lambda i, kk, bt, ln: (i, 0, 0)),
            page_spec,
            page_spec,
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda i, kk, bt, ln: (i, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * kvh, g, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      qr, k_pages, v_pages)
    return out.reshape(b, kvh, g, d).reshape(b, h, d)


def gather_dense_decode(q: jax.Array, k_pages: jax.Array,
                        v_pages: jax.Array, block_tables: jax.Array,
                        lengths: jax.Array) -> jax.Array:
    """CPU/interpret fallback: gather the session's pages into a dense
    [b, nblk*bs, kvh, d] view and run the dense decode einsum.

    Mirrors ``layers._sdpa_chunk`` op-for-op (fp32 scores/softmax, probs
    cast back to the value dtype) so the paged serve path stays
    numerically aligned with the dense-cache path on identical shapes.
    """
    b, h, d = q.shape
    bs, kvh = k_pages.shape[1], k_pages.shape[2]
    nblk = block_tables.shape[1]
    s = nblk * bs
    g = h // kvh
    scale = 1.0 / np.sqrt(d)

    k = k_pages[block_tables].reshape(b, s, kvh, d)
    v = v_pages[block_tables].reshape(b, s, kvh, d)
    qg = q.reshape(b, 1, kvh, g, d)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    valid = jnp.arange(s)[None, :] < lengths[:, None]          # [b, s]
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(b, 1, h, d)[:, 0]
