"""Fault-tolerant checkpointing for federation / training state.

Design goals (1000+ node deployments):

* **Atomic**: write to ``<dir>/.tmp-<step>`` then ``os.rename`` — a
  crashed writer never corrupts the latest checkpoint.
* **Self-describing**: a JSON manifest stores the pytree structure,
  shapes/dtypes and user metadata (FL iteration, MAR grid dims, clipping
  bound, RNG); arrays go to one ``.npz``. Restore works without the
  original code object.
* **Keep-last-k** retention with never-delete-latest.
* **Elastic**: :meth:`restore_elastic` re-shards the stacked peer axis
  when the peer count changed between runs (crash of a pod, scale-up):
  shrinking selects the first N' peers (they already hold near-global
  averages — MAR's mixing makes any subset representative); growing
  replicates cyclically. The MAR grid is re-planned by the caller via
  ``moshpit.plan_grid``.
* **Async**: ``save(..., blocking=False)`` offloads serialization to a
  daemon thread (double-buffered; at most one outstanding write, callers
  never block on I/O longer than one pending save).

On a real multi-host deployment each host writes only its addressable
shards; here the process is single-host so we write the full tree —
the layout (manifest + array blobs) is the multi-host-ready one.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

PyTree = Any

_SEP = "/"

# numpy's npz format can't describe ml_dtypes (bf16 etc.); store them as
# same-width unsigned views and restore via the manifest dtype string
_VIEW_DTYPES = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                "float8_e5m2": np.uint8}


def _to_savable(a: np.ndarray) -> np.ndarray:
    name = a.dtype.name
    if name in _VIEW_DTYPES:
        return a.view(_VIEW_DTYPES[name])
    return a


def _from_savable(a: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_DTYPES:
        return a.view(getattr(ml_dtypes, dtype_name))
    return a


def _flatten_with_paths(tree: PyTree) -> List[Tuple[str, np.ndarray]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out.append((key, np.asarray(leaf)))
    return out


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    if hasattr(entry, "name"):
        return str(entry.name)
    return str(entry)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: Optional[threading.Thread] = None
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def save(self, step: int, tree: PyTree,
             metadata: Optional[Dict[str, Any]] = None,
             blocking: bool = True) -> str:
        """Snapshot ``tree`` (host copy happens synchronously; disk write
        may be async)."""
        arrays = _flatten_with_paths(tree)          # device->host sync copy
        treedef = jax.tree.structure(tree)
        manifest = {
            "step": int(step),
            "treedef": str(treedef),
            "keys": [k for k, _ in arrays],
            "shapes": {k: list(a.shape) for k, a in arrays},
            "dtypes": {k: str(a.dtype) for k, a in arrays},
            "metadata": metadata or {},
        }

        def write():
            tmp = os.path.join(self.dir, f".tmp-{step}")
            final = os.path.join(self.dir, f"step_{step:010d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{k: _to_savable(a) for k, a in arrays})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f, indent=1)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            self.wait()   # a pending async save may share .tmp-<step>
            write()
        else:
            self.wait()                              # one outstanding write
            with self._lock:
                self._pending = threading.Thread(target=write, daemon=True)
                self._pending.start()
        return os.path.join(self.dir, f"step_{step:010d}")

    def wait(self):
        with self._lock:
            t, self._pending = self._pending, None
        if t is not None:
            t.join()

    # ------------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def poll(self, since: Optional[int] = None) -> Optional[int]:
        """Newest step strictly newer than ``since`` (None if nothing new).

        The serving tier's hot-swap watcher: call between decode steps
        with the step of the weights currently loaded."""
        latest = self.latest_step()
        if latest is None or (since is not None and latest <= since):
            return None
        return latest

    def restore(self, step: Optional[int] = None,
                like: Optional[PyTree] = None
                ) -> Tuple[PyTree, Dict[str, Any]]:
        """Returns (tree, metadata). With ``like`` given, leaves adopt its
        structure/dtypes; otherwise a nested-dict tree keyed by path.

        When the checkpoint was saved at a different peer count than
        ``like`` carries (the manifest records ``n_peers``), peer-
        stacked leaves are remapped through the membership contract's
        :func:`~repro.core.replan.resize_peer_axis` — survivors'
        slices bit-exact, joiners from the group mean — instead of
        failing the shape mismatch at unflatten time.
        """
        self.wait()
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        blobs = np.load(os.path.join(path, "arrays.npz"))

        def load(key):
            return _from_savable(blobs[key], manifest["dtypes"][key])

        if like is not None:
            from repro.core.replan import resize_peer_axis
            old_n = manifest["metadata"].get("n_peers")
            flat, _ = jax.tree_util.tree_flatten_with_path(like)
            leaves, remapped = [], 0
            for p, leaf in flat:
                key = _SEP.join(_path_str(e) for e in p)
                arr = load(key)
                if (old_n is not None and arr.ndim >= 1
                        and hasattr(leaf, "ndim") and leaf.ndim >= 1
                        and arr.shape[0] == old_n
                        and leaf.shape[0] != old_n
                        and arr.shape[1:] == leaf.shape[1:]):
                    arr = resize_peer_axis(jnp.asarray(arr), old_n,
                                           leaf.shape[0])
                    remapped += 1
                leaves.append(jnp.asarray(arr, leaf.dtype))
            if remapped:
                print(f"[checkpoint] step {step}: remapped {remapped} "
                      f"peer-stacked leaves from {old_n} saved peers "
                      f"to the requested axis (survivors exact, "
                      f"joiners group-mean)")
            tree = jax.tree.unflatten(jax.tree.structure(like), leaves)
        else:
            tree = {}
            for key in manifest["keys"]:
                node = tree
                parts = key.split(_SEP)
                for p in parts[:-1]:
                    node = node.setdefault(p, {})
                node[parts[-1]] = jnp.asarray(load(key))
        return tree, manifest["metadata"]

    # ------------------------------------------------------------------
    def restore_elastic(self, n_peers: int, step: Optional[int] = None,
                        like: Optional[PyTree] = None
                        ) -> Tuple[PyTree, Dict[str, Any]]:
        """Restore a peer-stacked tree onto a *different* peer count."""
        tree, meta = self.restore(step, like=None)
        old_n = meta.get("n_peers")

        def remap(x):
            x = np.asarray(x)
            if old_n is None or x.ndim == 0 or x.shape[0] != old_n \
                    or old_n == n_peers:
                return jnp.asarray(x)
            if n_peers < old_n:
                return jnp.asarray(x[:n_peers])
            reps = -(-n_peers // old_n)
            return jnp.asarray(
                np.concatenate([x] * reps, axis=0)[:n_peers])

        tree = jax.tree.map(remap, tree)
        if like is not None:
            # match leaves by *path*, not flatten order: the checkpoint
            # may carry extra branches ``like`` lacks (or vice versa —
            # e.g. a wire stage enabled/disabled between runs); a path
            # missing from the checkpoint keeps the template's value
            flat, _ = jax.tree_util.tree_flatten_with_path(like)
            leaves = []
            for p, leaf in flat:
                node = tree
                try:
                    for e in p:
                        node = node[_path_str(e)]
                    leaves.append(jnp.asarray(node, leaf.dtype))
                except (KeyError, TypeError):
                    leaves.append(leaf)
            tree = jax.tree.unflatten(jax.tree.structure(like), leaves)
        meta = dict(meta, n_peers=n_peers)
        return tree, meta

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)
