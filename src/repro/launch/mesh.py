"""Production mesh construction (functions only — importing this module
never touches jax device state).

Meshes (DESIGN.md §5):
  single-pod: (data=16, model=16)            — 256 chips
  multi-pod : (pod=2, data=16, model=16)     — 512 chips

MAR peer mapping:
  single-pod: peers = "data" axis -> 16 peers on a 4x4 MAR grid
  multi-pod : peers = ("pod", "data") -> 32 peers on a (2,4,4) grid with
              the pod axis as the *outermost* MAR round, so DCN-crossing
              traffic happens in exactly one of the three rounds.
  big-model fallback (``peer_axes=("pod",)``): 2 peers, FSDP over "data"
  — used when per-peer state exceeds 16 TP chips' HBM (kimi-k2 1T).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.moshpit import GridPlan, mesh_grid_plan
from repro.runtime.sharding import ShardPlan, make_shard_plan


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) != n:
        if len(devices) < n:
            raise RuntimeError(
                f"need {n} devices, have {len(devices)} — the dry-run "
                f"entry point must set XLA_FLAGS device count first")
        devices = devices[:n]
    return jax.sharding.Mesh(
        np.asarray(devices).reshape(shape), axes)


def make_test_mesh(shape: Tuple[int, ...] = (2, 2),
                   axes: Tuple[str, ...] = ("data", "model")):
    """Reduced mesh for CPU tests (requires forced host device count)."""
    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(jax.devices())}")
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)


def production_plans(mesh, peer_axes: Optional[Sequence[str]] = None
                     ) -> Tuple[ShardPlan, GridPlan]:
    """(ShardPlan, MAR GridPlan) for a production mesh."""
    names = mesh.axis_names
    if peer_axes is None:
        peer_axes = ("pod", "data") if "pod" in names else ("data",)
    splan = make_shard_plan(mesh, peer_axes)
    sizes = [mesh.shape[a] for a in splan.peer_axes]
    grid = mesh_grid_plan(sizes)
    return splan, grid
