import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb runner: the three chosen (arch x shape) pairs, each
with an iteration ladder of hypotheses (EXPERIMENTS.md §Perf).

  A granite-8b  x train_4k (single-pod)  — worst dominant memory term +
     big TP collectives on a dense 8B: naive->flash attention, one-shot
     MAR, microbatch ladder.
  B kimi-k2-1t  x train_4k (multi-pod)   — worst HBM fit (1T MoE):
     TP-only peers -> pod-peers+FSDP, fp32 -> bf16 momentum.
  C xlstm-350m  x train_4k (single-pod)  — most paper-representative:
     small-model cross-silo federation; TP=16 -> 256 pure-DP peers
     (MAR grid 4^4), one-shot fusion.

Each entry prints the three roofline terms and appends to a JSON log.

  PYTHONPATH=src python -m repro.launch.hillclimb --pair A --out a.json
"""
import argparse
import json
import sys
import time
import traceback

from repro.launch.dryrun import dryrun_cell

LADDERS = {
    "A": [
        ("A0 paper-faithful baseline: naive chunked attention "
         "(materialized probs), fp32 momentum",
         dict(arch_id="granite-8b", shape_id="train_4k", multi_pod=False,
              overrides={"attn_impl": "xla"})),
        ("A1 flash attention (custom-vjp, recompute-in-backward): "
         "hypothesis — kills O(s^2) prob traffic, memory term down >25%",
         dict(arch_id="granite-8b", shape_id="train_4k", multi_pod=False)),
        ("A2 + one-shot MAR (fuse 2 grid rounds into 1 global AR): "
         "hypothesis — MAR collective bytes down ~2x(M-1)/M -> (N-1)/N, "
         "small because TP dominates collectives",
         dict(arch_id="granite-8b", shape_id="train_4k", multi_pod=False,
              one_shot=True)),
        ("A3 + fewer microbatches (n_micro 8->4, mb 2->4): hypothesis — "
         "fewer per-micro layout passes; live activations still <HBM",
         dict(arch_id="granite-8b", shape_id="train_4k", multi_pod=False,
              one_shot=True, n_micro=4)),
        ("A4 + bf16 momentum: hypothesis — optimizer/MAR traffic and "
         "state memory down ~1.7x on the (theta,m) pair",
         dict(arch_id="granite-8b", shape_id="train_4k", multi_pod=False,
              one_shot=True, n_micro=4, momentum_dtype="bfloat16")),
        ("A5 A3 + remat off: hypothesis — drop recompute, compute -20%; "
         "expect memory blow-up (kept for the record, reverted)",
         dict(arch_id="granite-8b", shape_id="train_4k", multi_pod=False,
              one_shot=True, n_micro=4, overrides={"remat": "none"})),
    ],
    "B": [
        ("B0 baseline: peers=(pod,data) -> 32 peers, TP-only sharding "
         "inside a peer: hypothesis — 1T params cannot fit 16 chips/peer",
         dict(arch_id="kimi-k2-1t-a32b", shape_id="train_4k",
              multi_pod=True)),
        ("B1 peers=(pod,) -> 2 pod-peers with FSDP over data(16) + "
         "TP(16): hypothesis — state/chip drops 16x; fp32 momentum "
         "still ~40GB/chip",
         dict(arch_id="kimi-k2-1t-a32b", shape_id="train_4k",
              multi_pod=True, peer_axes=("pod",))),
        ("B2 + bf16 momentum: hypothesis — state/chip ~16GB, inside "
         "v5e HBM with high n_micro",
         dict(arch_id="kimi-k2-1t-a32b", shape_id="train_4k",
              multi_pod=True, peer_axes=("pod",),
              momentum_dtype="bfloat16")),
        ("B3 + n_micro=32: hypothesis — activation temp floor down, "
         "fit margin restored; terms per-step unchanged to first order",
         dict(arch_id="kimi-k2-1t-a32b", shape_id="train_4k",
              multi_pod=True, peer_axes=("pod",),
              momentum_dtype="bfloat16", n_micro=32)),
    ],
    "C": [
        ("C0 baseline: 16 peers x TP16 for a 350M model: hypothesis — "
         "TP collectives drown compute (sub-3% MFU)",
         dict(arch_id="xlstm-350m", shape_id="train_4k",
              multi_pod=False)),
        ("C1 peers=(data,model) -> 256 pure-DP peers, MAR grid 4^4, "
         "no TP: hypothesis — only MAR collectives remain; collective "
         "term down >5x (the paper's regime: small model, many peers)",
         dict(arch_id="xlstm-350m", shape_id="train_4k", multi_pod=False,
              peer_axes=("data", "model"))),
        ("C2 + one-shot MAR (4 rounds -> 1 global AR): hypothesis — "
         "MAR bytes 4*(3/4) -> (255/256), ~3x fewer collective bytes",
         dict(arch_id="xlstm-350m", shape_id="train_4k", multi_pod=False,
              peer_axes=("data", "model"), one_shot=True)),
        ("C3 C1 + bf16 momentum: hypothesis — MAR operand bytes down "
         "~1.7x vs C1 (theta bf16 + m bf16 instead of f32)",
         dict(arch_id="xlstm-350m", shape_id="train_4k", multi_pod=False,
              peer_axes=("data", "model"), momentum_dtype="bfloat16")),
        ("C4 C1 + bf16 comm_dtype (delta compression on the wire): "
         "hypothesis — the group-mean reduce upcasts to f32 BEFORE the "
         "collective, so momentum dtype alone cannot shrink wire bytes; "
         "casting the reduce operand itself halves MAR collective bytes",
         dict(arch_id="xlstm-350m", shape_id="train_4k", multi_pod=False,
              peer_axes=("data", "model"), momentum_dtype="bfloat16",
              comm_dtype="bfloat16")),
    ],
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pair", choices=list(LADDERS) + ["all"],
                    default="all")
    ap.add_argument("--out", default="hillclimb.json")
    args = ap.parse_args(argv)

    pairs = list(LADDERS) if args.pair == "all" else [args.pair]
    records = []
    for pair in pairs:
        for label, kw in LADDERS[pair]:
            print(f"\n=== {label}")
            t0 = time.time()
            try:
                rec = dryrun_cell(verbose=True, **kw)
            except Exception as e:
                traceback.print_exc()
                rec = {"status": "FAILED",
                       "error": f"{type(e).__name__}: {e}"}
            rec["label"] = label
            rec["pair"] = pair
            records.append(rec)
            with open(args.out, "w") as f:
                json.dump(records, f, indent=1)
    print(f"\nwrote {len(records)} records -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
