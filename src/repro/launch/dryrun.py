import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: for each
cell we build the production mesh, shard the (state, batch) specs, and
``jax.jit(step).lower(...).compile()``. Success means the sharding
rules, the MAR collective schedule, and the memory layout are mutually
consistent; ``memory_analysis()`` / ``cost_analysis()`` feed the
roofline table (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod --out results.json
  python -m repro.launch.dryrun --all --mesh both --out results.json

The XLA_FLAGS line above MUST run before any jax import (device count
locks on first init) — keep this module free of global jax state.
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, shape_applicable
from repro.configs.registry import ARCH_IDS, get_config, get_shape
from repro.core.fl_device import (fl_state_shape, make_fl_train_step,
                                  make_prefill_step, make_serve_step)
from repro.launch.mesh import make_production_mesh, production_plans
from repro.models.model import Model, batch_specs, input_specs
from repro.runtime import roofline
from repro.runtime.sharding import (batch_shardings, cache_shardings,
                                    state_shardings)
from jax.sharding import NamedSharding, PartitionSpec as P


def dryrun_cell(arch_id: str, shape_id: str, multi_pod: bool,
                peer_axes: Optional[tuple] = None, one_shot: bool = False,
                local_steps: int = 1, n_micro: Optional[int] = None,
                momentum_dtype: str = "float32",
                comm_dtype: Optional[str] = None,
                overrides: Optional[Dict[str, Any]] = None,
                verbose: bool = True) -> Dict[str, Any]:
    """Lower + compile one cell; returns the roofline record.

    ``overrides`` patches ModelConfig fields (e.g. attn_impl="xla") for
    §Perf before/after comparisons.
    """
    import dataclasses
    cfg = get_config(arch_id)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = get_shape(shape_id)
    if not shape_applicable(cfg, shape):
        return {"arch": arch_id, "shape": shape_id, "status": "skipped",
                "reason": "long_500k needs sub-quadratic attention "
                          "(DESIGN.md §4)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    splan, grid = production_plans(mesh, peer_axes)
    model = Model(cfg)
    mesh_name = "multi-pod-2x16x16" if multi_pod else "single-pod-16x16"
    chips = mesh.devices.size
    t0 = time.time()
    head_kw = dict(head_dim=cfg.head_dim, num_heads=cfg.num_heads,
                   num_kv_heads=cfg.num_kv_heads)

    if shape.kind == "train":
        n_micro = n_micro or default_n_micro(cfg, shape, splan)
        state_shape = fl_state_shape(model, splan.n_peers, momentum_dtype)
        batch = batch_specs(cfg, shape, splan.n_peers, local_steps, n_micro)
        step = make_fl_train_step(model, grid, one_shot=one_shot,
                                  comm_dtype=comm_dtype)
        in_sh = (state_shardings(state_shape, splan, **head_kw),
                 batch_shardings(batch, splan))
        out_sh = (state_shardings(state_shape, splan, **head_kw),
                  jax.tree.map(lambda _: NamedSharding(mesh, P()),
                               {"loss": 0.0}))
        with mesh:
            lowered = jax.jit(step, in_shardings=in_sh,
                              out_shardings=out_sh).lower(state_shape, batch)
            compiled = lowered.compile()
    elif shape.kind == "prefill":
        params = model.init_shape()
        batch = batch_specs(cfg, shape)
        step = make_prefill_step(model)
        serve_plan = _serve_plan(splan)
        # shard the cache the step actually emits (hybrid prefill caches
        # omit the conv state — see transformer.forward collect_cache)
        _, out_cache_shape = jax.eval_shape(step, params, batch)
        cache_sh = cache_shardings(out_cache_shape, serve_plan,
                                   shape.global_batch)
        in_sh = (state_shardings(params, serve_plan, peer_stacked=False,
                                 **head_kw),
                 batch_shardings(batch, serve_plan, peer_leading=False))
        out_sh = (NamedSharding(mesh, P()), cache_sh)
        with mesh:
            lowered = jax.jit(step, in_shardings=in_sh,
                              out_shardings=out_sh).lower(params, batch)
            compiled = lowered.compile()
    else:  # decode / long_decode
        params = model.init_shape()
        specs = input_specs(cfg, shape)
        step = make_serve_step(model)
        serve_plan = _serve_plan(splan)
        cache_sh = cache_shardings(specs["cache"], serve_plan,
                                   shape.global_batch)
        tok_sh = batch_shardings({"t": specs["token"]}, serve_plan,
                                 peer_leading=False)["t"]
        in_sh = (state_shardings(params, serve_plan, peer_stacked=False,
                                 **head_kw),
                 cache_sh, tok_sh)
        out_sh = (tok_sh, cache_sh)
        with mesh:
            lowered = jax.jit(step, in_shardings=in_sh,
                              out_shardings=out_sh).lower(
                params, specs["cache"], specs["token"])
            compiled = lowered.compile()

    report = roofline.analyze(
        compiled, arch=arch_id, shape=shape_id, mesh=mesh_name, chips=chips,
        model_flops=roofline.model_flops_estimate(cfg, shape, shape.kind))
    rec = report.to_dict()
    rec.update(status="ok", compile_s=round(time.time() - t0, 1),
               n_peers=splan.n_peers, grid_dims=list(grid.dims),
               local_steps=local_steps, one_shot=one_shot,
               overrides=overrides or {},
               peer_axes=list(splan.peer_axes))
    if verbose:
        ma = rec["memory_per_chip"]
        print(f"[{arch_id} x {shape_id} x {mesh_name}] OK "
              f"({rec['compile_s']}s)\n"
              f"  per-chip: {ma.get('total_bytes', 0)/2**30:.2f} GiB "
              f"({ma.get('hbm_fraction', 0)*100:.0f}% of v5e HBM) | "
              f"flops/chip {rec['hlo_flops_per_chip']:.3e} | "
              f"coll/chip {rec['collective_bytes_per_chip']/2**20:.1f} MiB\n"
              f"  terms (s): compute {rec['compute_s']:.4f} "
              f"memory {rec['memory_s']:.4f} "
              f"collective {rec['collective_s']:.4f} "
              f"-> {rec['dominant']}-bound | MFU {rec['mfu']*100:.1f}%")
    return rec


def _serve_plan(splan):
    """Serving has no peers: all DP axes become FSDP."""
    from repro.runtime.sharding import make_shard_plan
    return make_shard_plan(splan.mesh, peer_axes=())


def default_n_micro(cfg, shape, splan) -> int:
    """Pick microbatch count so per-chip live activations stay ~<2 GiB
    under remat (stored boundary = mb*seq*d_model bf16 per layer)."""
    per_peer = shape.global_batch // splan.n_peers
    fsdp = splan.axis_size(splan.fsdp_axes)
    budget = 2 * 2 ** 30
    layers = cfg.num_layers
    for n_micro in (1, 2, 4, 8, 16, 32):
        mb = per_peer // n_micro
        if mb < max(fsdp, 1):
            break
        live = layers * mb * shape.seq_len * cfg.d_model * 2 // max(fsdp, 1)
        if live <= budget:
            return n_micro
    return max(per_peer // max(fsdp, 1), 1)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, choices=ARCH_IDS)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", default=None, choices=["single", "multi",
                                                     "both"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--one-shot", action="store_true",
                    help="fuse MAR rounds into one all-reduce (perf variant)")
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--peer-axes", default=None,
                    help="comma list, e.g. 'pod' for 2 big peers")
    ap.add_argument("--momentum-dtype", default="float32")
    ap.add_argument("--out", default=None, help="write JSON records here")
    args = ap.parse_args(argv)

    meshes = []
    if args.mesh == "both":
        meshes = [False, True]
    elif args.mesh:
        meshes = [args.mesh == "multi"]
    else:
        meshes = [args.multi_pod]
    peer_axes = tuple(args.peer_axes.split(",")) if args.peer_axes else None

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape_id in SHAPES:
                cells.append((arch, shape_id))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required (or --all)")
        cells = [(args.arch, args.shape)]

    records, failures = [], 0
    for multi_pod in meshes:
        for arch, shape_id in cells:
            try:
                rec = dryrun_cell(arch, shape_id, multi_pod,
                                  peer_axes=peer_axes,
                                  one_shot=args.one_shot,
                                  local_steps=args.local_steps,
                                  n_micro=args.n_micro,
                                  momentum_dtype=args.momentum_dtype)
            except Exception as e:  # a failing cell is a bug in the system
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape_id,
                       "mesh": "multi" if multi_pod else "single",
                       "status": "FAILED", "error": f"{type(e).__name__}: {e}"}
                failures += 1
            records.append(rec)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {len(records)} records -> {args.out}")
    ok = sum(1 for r in records if r.get("status") == "ok")
    sk = sum(1 for r in records if r.get("status") == "skipped")
    print(f"dry-run: {ok} ok, {sk} skipped, {failures} FAILED")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
