"""Multi-host bootstrap for real pod deployments.

The dry-run proves the mesh compiles; this module is the glue an actual
multi-pod launch uses: per-host `jax.distributed.initialize`, env-based
topology discovery (GKE/TPU-VM/SLURM conventions), and the guard rails
for elastic restarts.

Supported environments (first match wins):
  * explicit flags / env: REPRO_COORDINATOR, REPRO_NUM_PROCESSES,
    REPRO_PROCESS_ID
  * SLURM: SLURM_STEP_NODELIST / SLURM_NTASKS / SLURM_PROCID
  * TPU pod runtime: jax.distributed.initialize() auto-detect (no args)

Usage on every host:

    from repro.launch.cluster import initialize_cluster
    info = initialize_cluster()          # safe no-op on single host
    mesh = make_production_mesh(multi_pod=info.num_processes > 1)

`scripts/run_pod.sh` shows the scheduler-side invocation.
"""
from __future__ import annotations

import dataclasses
import os
import re
import socket
from typing import Optional

import jax


@dataclasses.dataclass(frozen=True)
class ClusterInfo:
    coordinator: Optional[str]
    num_processes: int
    process_id: int
    initialized: bool

    @property
    def is_coordinator(self) -> bool:
        return self.process_id == 0


def _first_host(nodelist: str) -> str:
    """SLURM nodelist -> first hostname ('node[003-008]' -> 'node003')."""
    m = re.match(r"([^\[,]+)(?:\[(\d+)[-,\d]*\])?", nodelist)
    if not m:
        return nodelist.split(",")[0]
    base, first = m.group(1), m.group(2)
    return f"{base}{first}" if first else base


def detect_topology() -> ClusterInfo:
    env = os.environ
    if "REPRO_NUM_PROCESSES" in env:
        return ClusterInfo(
            coordinator=env.get("REPRO_COORDINATOR",
                                f"{socket.gethostname()}:8476"),
            num_processes=int(env["REPRO_NUM_PROCESSES"]),
            process_id=int(env.get("REPRO_PROCESS_ID", "0")),
            initialized=False)
    if "SLURM_NTASKS" in env and int(env["SLURM_NTASKS"]) > 1:
        host = _first_host(env.get("SLURM_STEP_NODELIST",
                                   env.get("SLURM_NODELIST", "")))
        return ClusterInfo(
            coordinator=f"{host}:8476",
            num_processes=int(env["SLURM_NTASKS"]),
            process_id=int(env.get("SLURM_PROCID", "0")),
            initialized=False)
    return ClusterInfo(coordinator=None, num_processes=1, process_id=0,
                       initialized=False)


def initialize_cluster(timeout_s: int = 300) -> ClusterInfo:
    """Idempotent multi-host init; single-host is a no-op."""
    info = detect_topology()
    if info.num_processes <= 1:
        return dataclasses.replace(info, initialized=False)
    jax.distributed.initialize(
        coordinator_address=info.coordinator,
        num_processes=info.num_processes,
        process_id=info.process_id,
        initialization_timeout=timeout_s)
    return dataclasses.replace(info, initialized=True)


def assert_mesh_feasible(num_hosts: int, chips_per_host: int,
                         mesh_shape) -> None:
    """Fail fast before compile when the scheduler allocation can't
    realize the requested mesh."""
    import numpy as np
    need = int(np.prod(mesh_shape))
    have = num_hosts * chips_per_host
    if have < need:
        raise RuntimeError(
            f"mesh {tuple(mesh_shape)} needs {need} chips; allocation has "
            f"{num_hosts} hosts x {chips_per_host} = {have}")
