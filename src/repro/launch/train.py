"""MAR-FL training driver for the assigned LM architectures.

Runs real steps on the available devices (CPU here, reduced configs) or
lowers the production config under the dry-run entry point. Integrates
the full stack: config registry, synthetic LM pipeline, device-backend
MAR-FL step, checkpoint/restart, and the churn-aware peer lifecycle
(``runtime/lifecycle.py``): per-step participation masks come from a
``--churn`` scenario, measured step durations feed the
``HealthTracker`` heartbeats, and the per-iteration ``sweep()`` masks
peers that stop heartbeating. ``--transport`` picks the
MessagePlan executor (``runtime/transport_base.py``): ``sim`` unrolls
aggregation traffic into per-round messages and times them over
``--link-profile`` modeled links; ``socket`` runs every peer as an
asyncio task on loopback TCP and really transmits int8-serialized
update tensors. Either way the ledger and per-step communication
seconds come from the measured transcript, and lost sends
(``--link-loss`` — modeled drops on sim, injected failures on socket)
demote their peer to receiver-only for that step.

Permanent membership changes are handled *in place* (DESIGN.md §16):
scheduled resizes (``--resize-at``) and trace join/leave events route
through the unified :class:`~repro.core.replan.MembershipChange`
contract — survivors' state maps bit-exact, joiners bootstrap from the
group mean, the train step re-jits for the new grid, and the run keeps
going with no relaunch. The whole planned schedule is validated at
launch (every target peer count must have an exact grid).

Multi-host: ``--peer-hosts book.json --rank R`` runs this process as
one rank of a socket-transport world — the JSON address book fixes
``host:port`` per plan node and which rank owns it; start one process
per rank with the same book (see README "Multi-host quickstart").

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \
      --smoke --steps 20 --peers 4 --ckpt-dir /tmp/ck
  PYTHONPATH=src python -m repro.launch.train --arch xlstm-350m --smoke \
      --steps 10 --resume --ckpt-dir /tmp/ck
  PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \
      --smoke --steps 10 --peers 4 --churn sessions
  PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \
      --smoke --steps 3 --peers 4 --transport socket
  PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \
      --smoke --steps 8 --peers 16 --resize-at 4:9
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.core import topology
from repro.core.aggregation import CommLedger, build_pipeline
from repro.core.fl_device import (apply_membership, init_fl_state,
                                  make_fl_train_step)
from repro.core.moshpit import plan_grid
from repro.core.replan import (plan_membership_change,
                               validate_membership_schedule)
from repro.data.synthetic import lm_token_stream
from repro.models.model import Model
from repro.runtime.fault import HealthTracker, StragglerPolicy
from repro.runtime.lifecycle import CHURN_MODELS, build_lifecycle
from repro.runtime.metrics import MetricsLogger


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--peers", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--batch", type=int, default=2, help="per peer")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--one-shot", action="store_true")
    ap.add_argument("--compress", choices=["int8_ef"], default=None,
                    help="int8 error-feedback delta compression on the "
                         "aggregation wire")
    ap.add_argument("--comm-dtype", default=None,
                    help="wire dtype of the cross-peer reduce "
                         "(e.g. bfloat16)")
    ap.add_argument("--participation", type=float, default=1.0,
                    help="per-step peer participation rate (churn mask)")
    ap.add_argument("--churn", choices=sorted(CHURN_MODELS),
                    default=None,
                    help="peer-lifecycle scenario; default is i.i.d. "
                         "Bernoulli driven by --participation/--dropout")
    ap.add_argument("--dropout", type=float, default=0.0,
                    help="per-step aggregation-dropout rate (bernoulli)")
    ap.add_argument("--churn-trace", default=None,
                    help="membership trace file for --churn trace")
    ap.add_argument("--adaptive-m", default=None,
                    help="adaptive group sizing (core/adaptive.py): a "
                         "GroupSizeController name (static | "
                         "tail_aware | schedule) consuming each step's "
                         "transport transcript; proposals regroup the "
                         "MAR grid in place (exact factorizations "
                         "only — the device backend needs capacity == "
                         "N). Requires a transport (--transport / "
                         "--link-profile) for the transcript signal")
    ap.add_argument("--placement", default=None,
                    help="topology-aware grid placement "
                         "(core/placement.py): a PlacementPolicy name "
                         "(identity | random | clustered). 'clustered' "
                         "learns network regions from link evidence "
                         "(probe rounds through the live transport) "
                         "and regroups the grid so each region fills "
                         "contiguous coordinates — cross-region "
                         "traffic collapses into the high axes. "
                         "Composes with --adaptive-m. Requires a "
                         "transport (--transport / --link-profile)")
    ap.add_argument("--link-shuffle", action="store_true",
                    help="scatter the regions profile's region "
                         "assignment over peer indices (peers joined "
                         "in arbitrary order) — the misaligned layout "
                         "--placement clustered exists to fix")
    ap.add_argument("--health-timeout", type=float, default=30.0,
                    help="iterations without a heartbeat before a peer "
                         "is marked dead")
    ap.add_argument("--resize-at", default=None, metavar="STEP:N[,..]",
                    help="scheduled permanent resizes, e.g. '4:9' or "
                         "'3:6,7:8' — at each STEP the fleet becomes N "
                         "peers in place (survivors bit-exact, joiners "
                         "bootstrap from the group mean, the train "
                         "step re-jits for the new grid). Every N "
                         "needs an exact grid; the whole schedule is "
                         "validated at launch")
    ap.add_argument("--transport", default=None,
                    help="MessagePlan executor backend "
                         "(runtime/transport_base.py): 'sim' models "
                         "messages over --link-profile links; "
                         "'vector_sim' is the batched segment-op "
                         "engine with identical transcripts (use for "
                         "large --peers); 'super_sim' adds closed-"
                         "form intra-cluster tiers on top — identical "
                         "transcripts on uniform/wireless, O(rounds) "
                         "cost, for very large --peers; 'socket' runs "
                         "every peer as "
                         "an asyncio task on loopback TCP and really "
                         "transmits int8-serialized update tensors. "
                         "Default: 'sim' when --link-profile is "
                         "given, else no transport (analytic "
                         "accounting)")
    ap.add_argument("--link-profile", default=None,
                    choices=["uniform", "wireless", "regions"],
                    help="discrete-event link model for the sim "
                         "transport: aggregation traffic is unrolled "
                         "into messages, timed over per-peer modeled "
                         "links, and the ledger + per-step simulated "
                         "wall-clock come from the transcript "
                         "(runtime/network.py)")
    ap.add_argument("--link-loss", type=float, default=0.0,
                    help="per-message loss probability on the modeled "
                         "links (or injected send failures on the "
                         "socket transport); a peer whose send is "
                         "lost mid-round is demoted to receiver-only "
                         "for that step")
    ap.add_argument("--peer-hosts", default=None, metavar="FILE",
                    help="JSON address book for the socket transport "
                         "(multi-host mode): fixed host:port per plan "
                         "node plus the owning rank — this process "
                         "runs only its --rank's nodes; start one "
                         "process per rank with the same book")
    ap.add_argument("--rank", type=int, default=0,
                    help="this process's rank in the --peer-hosts "
                         "world (default 0)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--metrics", default=None,
                    help="JSONL metrics path")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.transport is not None:
        from repro.runtime.transport_base import available_transports
        names = available_transports()
        if args.transport not in names:
            ap.error(f"--transport must be one of {names}, "
                     f"got {args.transport!r}")
    resize_schedule = []
    if args.resize_at:
        try:
            for part in args.resize_at.split(","):
                step_s, n_s = part.split(":")
                resize_schedule.append((int(step_s), int(n_s)))
        except ValueError:
            ap.error(f"--resize-at must be STEP:N[,STEP:N...], "
                     f"got {args.resize_at!r}")
    if args.peer_hosts and args.transport != "socket":
        ap.error("--peer-hosts is the socket transport's address "
                 "book; pass --transport socket")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    n_peers = args.peers
    grid = plan_grid(n_peers)
    print(f"[train] arch={cfg.name} peers={n_peers} "
          f"grid={grid.dims} params={cfg.param_count():,}")

    pipeline = build_pipeline("mar", grid, backend="device",
                              one_shot=args.one_shot,
                              comm_dtype=args.comm_dtype,
                              compress=args.compress)
    if pipeline.stage_names:
        print(f"[train] wire stages: {', '.join(pipeline.stage_names)}")
    step_fn = jax.jit(make_fl_train_step(
        model, grid, lr=args.lr, pipeline=pipeline))

    state = init_fl_state(model, n_peers, jax.random.PRNGKey(args.seed),
                          pipeline=pipeline)
    ledger = CommLedger()
    peer_model_bytes = (topology.pytree_bytes(state["params"])
                        + topology.pytree_bytes(state["momentum"])
                        ) // n_peers
    start = 0
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and args.resume and ckpt.latest_step() is not None:
        state, meta = ckpt.restore_elastic(n_peers, like=state)
        start = meta.get("step", 0)
        print(f"[train] resumed from step {start} "
              f"(was {meta.get('n_peers')} peers)")

    stream = lm_token_stream(cfg.vocab_size, n_peers * args.local_steps
                             * args.batch, args.seq, seed=args.seed)
    # lifecycle: scenario masks + health heartbeats/sweeps + deadlines.
    # The lifecycle clock is the step counter, so --health-timeout is
    # "steps without a heartbeat".
    churn_params = {}
    if args.churn == "trace":
        if not args.churn_trace:
            ap.error("--churn trace requires --churn-trace FILE")
        churn_params["path"] = args.churn_trace
    lifecycle = build_lifecycle(
        args.churn, n_peers, seed=args.seed,
        participation_rate=args.participation,
        dropout_rate=args.dropout, churn_params=churn_params,
        schedule=resize_schedule,
        health=HealthTracker(n_peers, timeout_s=args.health_timeout),
        straggler=StragglerPolicy())
    metrics_log = MetricsLogger(args.metrics)
    network = None
    transport = args.transport or ("sim" if args.link_profile else None)
    if transport is not None:
        from repro.runtime.transport_base import (build_transport,
                                                  demote_lost_senders)
        link_params = {}
        if args.link_loss:
            link_params["loss"] = args.link_loss
        if args.link_shuffle:
            link_params["shuffle"] = True
        transport_kwargs = {}
        if args.peer_hosts:
            from repro.runtime.socket_transport import AddressBook
            book = AddressBook.from_json(args.peer_hosts)
            print(f"[train] address book: {book.n_nodes} nodes over "
                  f"{book.world_size} ranks; this is rank {args.rank} "
                  f"(owns nodes {list(book.owned(args.rank))})")
            transport_kwargs["address_book"] = book
            transport_kwargs["rank"] = args.rank
        network = build_transport(
            transport, n_peers, profile=args.link_profile,
            seed=args.seed, link_params=link_params or None,
            **transport_kwargs)
    # the mask-free fast path needs a genuinely lossless transport too:
    # the regions profile carries per-tier loss even without --link-loss
    always_full = args.churn is None and args.participation >= 1.0 \
        and args.dropout <= 0.0 \
        and (network is None or network.lossless)

    # launch-path validation: every planned permanent resize (schedule
    # entries + trace join/leave) is honored mid-run through the
    # MembershipChange contract, but the device backend needs an exact
    # grid at every hop — chain-validate the whole step range NOW so an
    # unreachable peer count fails at launch, not mid-run
    planned = lifecycle.planned_resizes(start, start + args.steps)
    if planned:
        validate_membership_schedule(grid, planned, exact_only=True)
        print("[train] elastic schedule: " + ", ".join(
            f"step {ts}: -> {n} peers" for ts, n in planned))

    controller = None
    if args.adaptive_m is not None:
        from repro.core.adaptive import CONTROLLERS, build_controller
        if args.adaptive_m not in CONTROLLERS:
            ap.error(f"--adaptive-m must be one of "
                     f"{sorted(CONTROLLERS)}, got {args.adaptive_m!r}")
        if network is None:
            ap.error("--adaptive-m needs a transcript signal: pass "
                     "--link-profile (sim) or --transport socket")
        controller = build_controller(args.adaptive_m, grid,
                                      exact_only=True)

    placement_policy = None
    if args.placement is not None:
        from repro.core.placement import PLACEMENTS, build_placement
        if args.placement not in PLACEMENTS:
            ap.error(f"--placement must be one of "
                     f"{sorted(PLACEMENTS)}, got {args.placement!r}")
        if network is None:
            ap.error("--placement needs a transport for link evidence "
                     "and probe rounds: pass --link-profile (sim) or "
                     "--transport")

        def run_probe(mplan):
            tr = network.run(mplan)
            ledger.record("placement_probe", tr.total_bytes)
            ledger.record_time(tr.iteration_s)
            return tr

        placement_policy = build_placement(args.placement, grid,
                                           seed=args.seed)
        placement_policy.bind_prober(run_probe)

    for t in range(start, start + args.steps):
        tick = lifecycle.tick(t)
        if tick.resize_to is not None and tick.resize_to != n_peers:
            # permanent join/leave, in place: one MembershipChange from
            # the unified contract — survivors bit-exact, joiners
            # group-mean-bootstrapped, train step re-jitted for the new
            # exact grid (validated at launch). No relaunch.
            change = plan_membership_change(
                grid, tick.resize_to, iteration=t, exact_only=True)
            state, pipeline = apply_membership(state, change, pipeline)
            grid, n_peers = change.new_plan, change.new_n
            print(f"[train] elastic resize at step {t}: "
                  f"{change.old_n} -> {change.new_n} peers, "
                  f"grid={grid.dims} "
                  f"(+{change.n_joiners} joiners)")
            step_fn = jax.jit(make_fl_train_step(
                model, grid, lr=args.lr, pipeline=pipeline))
            stream = lm_token_stream(
                cfg.vocab_size,
                n_peers * args.local_steps * args.batch, args.seq,
                seed=args.seed + t)
            if network is not None:
                network.resize(n_peers)
            if controller is not None:
                controller.rebind(grid)
            if placement_policy is not None:
                placement_policy.rebind(grid)
        raw = next(stream)
        batch = {
            k: v.reshape(n_peers, args.local_steps, 1, args.batch,
                         args.seq)
            for k, v in raw.items()
        }
        u, a = tick.u, tick.a
        # modeled network: time this step's messages first so lost
        # sends demote their peer before the aggregation runs
        transcript = None
        if network is not None:
            n_act = int(a.sum())
            mplan = pipeline.message_plan(np.asarray(a),
                                          peer_model_bytes, n_act)
            payloads = None
            if network.wants_payloads:
                from repro.runtime.socket_transport import \
                    encode_state_payloads
                payloads = encode_state_payloads(state["params"])
            transcript = network.run(mplan, payloads=payloads)
            a = demote_lost_senders(a, u, transcript)
        t0 = time.time()
        if always_full:
            state, metrics = step_fn(state, batch)
        else:
            # U_t gates the local-update carry, A_t the aggregation —
            # a straggler keeps its update but misses its group mean
            state, metrics = step_fn(state, batch, jnp.asarray(u),
                                     jnp.asarray(a))
        dt = time.time() - t0
        if transcript is not None:
            pipeline.record_transcript(ledger, transcript, n_act,
                                       peer_model_bytes)
            # heartbeat with compute + each peer's simulated comm
            # finish: slow modeled uplinks surface as stragglers via
            # the lifecycle's deadline policy next iteration
            lifecycle.observe_durations(
                t, dt + transcript.peer_finish_s, mask=u)
            if controller is not None:
                proposal = controller.observe(t, transcript, grid)
                if proposal is not None and \
                        tuple(proposal.dims) != tuple(grid.dims):
                    # same-N regroup on the device backend: exact grid
                    # swap — pipeline re-binds, state is untouched (the
                    # peer axis is unchanged), only the step jit
                    # retraces
                    print(f"[train] adaptive-M regroup at step {t+1}: "
                          f"{grid.dims} -> {proposal.dims}")
                    grid = proposal
                    pipeline = pipeline.with_plan(grid)
                    step_fn = jax.jit(make_fl_train_step(
                        model, grid, lr=args.lr, pipeline=pipeline))
                    if placement_policy is not None:
                        # dims changed: re-emit the permutation for the
                        # new grid on the next observe
                        placement_policy.rebind(grid)
            if placement_policy is not None:
                target = placement_policy.observe(t, transcript, grid)
                if target is not None and target != grid:
                    moved = int(np.sum(
                        grid.slot_of(np.arange(grid.n_peers))
                        != target.slot_of(np.arange(grid.n_peers))))
                    print(f"[train] placement regroup at step {t+1}: "
                          f"{moved}/{grid.n_peers} peers moved")
                    grid = target
                    pipeline = pipeline.with_plan(grid)
                    step_fn = jax.jit(make_fl_train_step(
                        model, grid, lr=args.lr, pipeline=pipeline))
        else:
            pipeline.record_iteration(ledger, int(a.sum()),
                                      peer_model_bytes)
            # heartbeat every peer that ran this step with its measured
            # duration; silent peers age toward the sweep timeout
            lifecycle.observe_durations(t, np.full(n_peers, dt),
                                        mask=u)
        metrics_log.log(t + 1, tokens=n_peers * args.local_steps
                        * args.batch * args.seq,
                        sim_s=(transcript.iteration_s
                               if transcript is not None else None),
                        loss=float(metrics["loss"]))
        if (t + 1) % 5 == 0 or t == start:
            sim = (f" sim={transcript.iteration_s*1e3:.0f}ms"
                   if transcript is not None else "")
            print(f"  step {t+1:4d} loss={float(metrics['loss']):.4f} "
                  f"({dt*1e3:.0f} ms){sim} "
                  f"active={int(a.sum())}/{n_peers}")
        if ckpt and (t + 1) % args.ckpt_every == 0:
            ckpt.save(t + 1, state,
                      metadata={"step": t + 1, "n_peers": n_peers,
                                "grid_dims": list(grid.dims),
                                "arch": cfg.name},
                      blocking=False)
    if ckpt:
        ckpt.save(start + args.steps, state,
                  metadata={"step": start + args.steps,
                            "n_peers": n_peers,
                            "grid_dims": list(grid.dims),
                            "arch": cfg.name})
        ckpt.wait()
        print(f"[train] checkpointed at {start + args.steps}")
    per_source = " ".join(f"{k}={v/1e6:.1f}MB"
                          for k, v in ledger.by_source.items())
    sim = ""
    if network is not None:
        kind = ("wall-clock" if network.name == "socket"
                else f"simulated ({args.link_profile or 'uniform'})")
        sim = f" comm_s={ledger.total_seconds:.2f} [{kind}]"
    print(f"[train] comm total={ledger.total_bytes/1e6:.1f}MB "
          f"{per_source}{sim}")
    if lifecycle.event_log:
        by_kind: dict = {}
        for e in lifecycle.event_log:
            by_kind[e.kind] = by_kind.get(e.kind, 0) + len(e.peers)
        print("[train] membership events: " + " ".join(
            f"{k}={v}" for k, v in sorted(by_kind.items())))
    if network is not None and hasattr(network, "close"):
        network.close()   # book-mode sockets + background loop thread
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
