"""Decode-serving driver over the continuous-batching engine.

Thin CLI around :mod:`repro.serve`: enqueue N synthetic sessions
(mixed prompt lengths with ``--vary-prompts``), drain them through the
paged-KV :class:`~repro.serve.engine.DecodeServer`, print throughput
and latency percentiles. ``--sequential`` runs the one-session-at-a-time
baseline instead (also the only path for recurrent families, whose
state cannot be paged). ``--ckpt-dir`` serves weights from a training
checkpoint directory and hot-swaps newer checkpoints mid-run;
``--swap-demo`` performs an identity hot-swap mid-drain to demonstrate
zero-drop swapping.

  PYTHONPATH=src python -m repro.launch.serve --smoke --sessions 8 \
      --prompt-len 24 --gen 16 --max-batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.models.model import Model
from repro.serve import (DecodeServer, ServeConfig, run_sequential,
                         serving_params_from_checkpoint)

PAGED = ("dense", "vlm", "audio", "moe")


def _summarize(tag, sessions, elapsed):
    toks = sum(len(s.generated) for s in sessions)
    times = [t for s in sessions for t in s.token_times[1:]]
    p50 = np.percentile(times, 50) * 1e3 if times else 0.0
    p99 = np.percentile(times, 99) * 1e3 if times else 0.0
    print(f"[serve] {tag}: {len(sessions)} sessions, {toks} tokens in "
          f"{elapsed:.2f}s ({toks / max(elapsed, 1e-9):.1f} tok/s), "
          f"per-token p50 {p50:.1f}ms p99 {p99:.1f}ms")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="starcoder2-3b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--sessions", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--vary-prompts", action="store_true",
                    help="mixed prompt lengths in [1, prompt_len]")
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="KV pool size (default: a full batch's worst case)")
    ap.add_argument("--sequential", action="store_true",
                    help="one-session-at-a-time dense baseline")
    ap.add_argument("--ckpt-dir", default=None,
                    help="serve (and hot-swap) weights from this "
                         "checkpoint directory")
    ap.add_argument("--swap-demo", action="store_true",
                    help="identity hot-swap mid-drain (zero-drop demo)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    rng = np.random.default_rng(args.seed)
    params = model.init(jax.random.PRNGKey(args.seed))

    ckpt = None
    if args.ckpt_dir:
        from repro.checkpoint.checkpointer import Checkpointer
        ckpt = Checkpointer(args.ckpt_dir)
        if ckpt.latest_step() is not None:
            state, meta = ckpt.restore()
            params = serving_params_from_checkpoint(state, params)
            print(f"[serve] restored step {ckpt.latest_step()} "
                  f"from {args.ckpt_dir} (meta: {meta})")

    paged = cfg.family in PAGED and cfg.frontend == "none" \
        and not args.sequential
    if not paged and (args.vary_prompts and cfg.family not in PAGED):
        print("[serve] recurrent family: fixed-length prompts only")
        args.vary_prompts = False
    plens = (rng.integers(1, args.prompt_len + 1, args.sessions)
             if args.vary_prompts
             else np.full(args.sessions, args.prompt_len))
    prompts = [rng.integers(0, cfg.vocab_size, n).tolist() for n in plens]

    if not paged:
        print(f"[serve] sequential baseline ({cfg.family})")
        t0 = time.perf_counter()
        done = run_sequential(model, params, prompts, max_new=args.gen,
                              pad_len=args.prompt_len)
        _summarize("sequential", done, time.perf_counter() - t0)
        print("[serve] sample:", done[0].generated[:16])
        return 0

    need = -(-(args.prompt_len + args.gen) // args.block_size)
    num_blocks = args.num_blocks or 1 + need * args.max_batch
    scfg = ServeConfig(max_batch=args.max_batch, block_size=args.block_size,
                       num_blocks=num_blocks, pad_len=args.prompt_len,
                       max_new=args.gen)
    srv = DecodeServer(model, params, scfg)
    if ckpt is not None:
        srv.attach_checkpointer(ckpt, params)
    for p in prompts:
        srv.enqueue(p)
    print(f"[serve] engine: {args.sessions} sessions, pool "
          f"{num_blocks}x{args.block_size} KV slots, batch {args.max_batch}")
    t0 = time.perf_counter()
    if args.swap_demo:
        for _ in range(3):
            srv.step()
        srv.swap_params(srv.params, tag="demo-identity")
    srv.run()
    elapsed = time.perf_counter() - t0
    srv.assert_quiescent()
    _summarize("continuous", srv.finished, elapsed)
    st = srv.stats()
    print(f"[serve] {st['prefills']} prefills, {st['decode_steps']} decode "
          f"steps, {st['swaps']} hot-swaps")
    if srv.swap_log:
        print("[serve] swap log:", srv.swap_log)
    print("[serve] sample:", srv.finished[0].generated[:16])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
