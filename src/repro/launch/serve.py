"""Batched decode serving driver (prefill -> decode with KV/state cache).

Serves a (smoke or full) architecture: prefill the prompt batch in one
forward pass, then greedy-decode tokens step by step. On CPU this runs
reduced configs end-to-end; the production shapes are exercised by the
dry-run (decode_32k / long_500k cells).

  PYTHONPATH=src python -m repro.launch.serve --arch zamba2-2.7b --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.core.fl_device import make_prefill_step, make_serve_step
from repro.models.model import Model


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = Model(cfg)
    rng = np.random.default_rng(args.seed)
    params = model.init(jax.random.PRNGKey(args.seed))
    max_len = args.prompt_len + args.gen

    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size,
                     size=(args.batch, args.prompt_len)), jnp.int32)
    batch = {"tokens": prompts}
    if cfg.frontend != "none":
        from repro.models.transformer import PREFIX_LEN
        p = PREFIX_LEN[cfg.frontend]
        batch["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(args.batch, p, cfg.d_model)), jnp.float32)

    # Prefill: logits for the last prompt position (cache is rebuilt in
    # decode form below — the production handoff pads prefill KV into the
    # ring/linear cache; on smoke scale we simply replay the prompt).
    prefill = jax.jit(make_prefill_step(model))
    t0 = time.time()
    last_logits, _ = prefill(params, batch)
    print(f"[serve] prefill {args.batch}x{args.prompt_len} "
          f"in {time.time()-t0:.2f}s")

    serve = jax.jit(make_serve_step(model))
    cache = model.init_cache(args.batch, max_len)
    # replay prompt tokens through decode steps to fill the cache
    tok = prompts[:, 0]
    for i in range(args.prompt_len):
        nxt, cache = serve(params, cache, prompts[:, i])
    generated = [nxt]
    t0 = time.time()
    for _ in range(args.gen - 1):
        nxt, cache = serve(params, cache, generated[-1])
        generated.append(nxt)
    dt = time.time() - t0
    out = jnp.stack(generated, axis=1)
    print(f"[serve] generated {args.gen} tokens/seq x{args.batch} in "
          f"{dt:.2f}s ({args.gen*args.batch/max(dt,1e-9):.1f} tok/s)")
    print("[serve] sample:", np.asarray(out[0])[:16].tolist())
    agree = float(jnp.mean((jnp.argmax(last_logits, -1) == generated[0])
                           .astype(jnp.float32)))
    print(f"[serve] prefill/decode first-token agreement: {agree:.2f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
