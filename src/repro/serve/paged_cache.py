"""Paged KV cache: host-side block allocator + device scatter helpers.

The serving pool is one tensor pair per model (``transformer.
init_paged_cache``): ``[L, num_blocks, block_size, kvh, hd]``. Sessions
own disjoint sets of physical blocks; a per-session *block table* row
lists them in logical-position order, so position ``p`` lives at page
``table[p // block_size]`` slot ``p % block_size``. Block 0 is the
scratch page: inactive batch rows (and table columns beyond a session's
allocation) point there, so padded decode steps always have a legal
write target — scratch contents are garbage by design and masked out of
every attention read by the per-session lengths.

The allocator is deliberately host-side Python (like vLLM's): block
churn is tiny (a handful of ints per admit/evict) next to the device
work per decode step, and keeping it out of jit means admission control
can be arbitrary policy code.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

Array = jax.Array

SCRATCH_BLOCK = 0


class BlockAllocator:
    """Fixed pool of KV blocks; block 0 is never handed out (scratch)."""

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is scratch)")
        self.num_blocks = num_blocks
        # pop() from the end -> blocks hand out in ascending order
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._owned: set = set()

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> List[int]:
        """Take ``n`` blocks; raises if the pool can't cover them (the
        engine checks ``can_alloc`` at admission, so a raise here means a
        scheduler bug, not load)."""
        if n > len(self._free):
            raise RuntimeError(
                f"allocator exhausted: want {n}, have {len(self._free)}")
        out = [self._free.pop() for _ in range(n)]
        self._owned.update(out)
        return out

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            if b not in self._owned:
                raise RuntimeError(f"double free of block {b}")
            self._owned.discard(b)
            self._free.append(b)


def session_table(blocks: List[int], width: int) -> List[int]:
    """A session's block-table row, padded to the engine's fixed table
    width with the scratch page."""
    if len(blocks) > width:
        raise ValueError(f"{len(blocks)} blocks > table width {width}")
    return list(blocks) + [SCRATCH_BLOCK] * (width - len(blocks))


def write_prefill_to_pages(pages: Dict[str, Array], k: Array, v: Array,
                           block_tables: Array) -> Dict[str, Array]:
    """Scatter a prefill KV cache (``forward(collect_cache=True)``:
    k/v ``[L, b, s, kvh, hd]``) into the paged pool through the block
    tables — position ``p`` of row ``i`` lands at page
    ``block_tables[i, p // bs]`` slot ``p % bs``.

    ``s`` may overhang the last block; the overhang (and any pad tokens
    inside ``s``) writes garbage into blocks the session already owns —
    or the scratch page where the table runs out — and is masked by
    lengths on every read. Rows of different sessions never share a
    non-scratch page, so scatter collisions only hit scratch.
    """
    k_pages = pages["k_pages"]
    bs = k_pages.shape[2]
    L, b, s = k.shape[0], k.shape[1], k.shape[2]
    nblk = -(-s // bs)
    if block_tables.shape[1] < nblk:
        raise ValueError(
            f"table width {block_tables.shape[1]} < {nblk} blocks for s={s}")
    s_pad = nblk * bs
    if s_pad != s:
        pad = ((0, 0), (0, 0), (0, s_pad - s), (0, 0), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    kb = k.reshape(L, b, nblk, bs, *k.shape[3:])
    vb = v.reshape(L, b, nblk, bs, *v.shape[3:])
    bt = block_tables[:, :nblk]
    return {"k_pages": k_pages.at[:, bt].set(kb),
            "v_pages": pages["v_pages"].at[:, bt].set(vb)}


def gather_session_cache(pages: Dict[str, Array], table: List[int],
                         bs: Optional[int] = None) -> Dict[str, Array]:
    """Debug/test helper: materialize one session's dense KV view
    ``[L, 1, nblk*bs, kvh, hd]`` from its block-table row."""
    bt = jnp.asarray(table, jnp.int32)
    k = pages["k_pages"][:, bt]            # [L, nblk, bs, kvh, hd]
    v = pages["v_pages"][:, bt]
    L, nblk, bsz = k.shape[0], k.shape[1], k.shape[2]
    return {"k": k.reshape(L, 1, nblk * bsz, *k.shape[3:]),
            "v": v.reshape(L, 1, nblk * bsz, *v.shape[3:])}
