"""Serving tier: paged KV cache + continuous-batching decode engine.

See DESIGN.md §14. Entry points: :class:`~repro.serve.engine.DecodeServer`
(continuous batching), :func:`~repro.serve.engine.run_sequential`
(baseline), :func:`~repro.serve.engine.serving_params_from_checkpoint`
(FL checkpoint -> serving weights for hot-swap).
"""
from repro.serve.engine import (DecodeServer, ServeConfig, Session,
                                run_sequential,
                                serving_params_from_checkpoint)
from repro.serve.paged_cache import (SCRATCH_BLOCK, BlockAllocator,
                                     gather_session_cache, session_table,
                                     write_prefill_to_pages)

__all__ = [
    "DecodeServer", "ServeConfig", "Session", "run_sequential",
    "serving_params_from_checkpoint", "BlockAllocator", "SCRATCH_BLOCK",
    "session_table", "write_prefill_to_pages", "gather_session_cache",
]
