"""Continuous-batching decode service over the paged KV pool.

The serving story for an FL deployment (ROADMAP "heavy-traffic
serving"): one model replica decodes many interactive sessions at once,
while training keeps publishing fresh checkpoints that must go live
*without dropping in-flight sessions*.

Three pieces:

* :class:`DecodeServer` — the continuous-batching engine. Admission is
  FIFO with head-of-line blocking (a session is admitted the moment a
  batch row AND its full worst-case block budget are both available —
  conservative reservation means a running session can never hit pool
  exhaustion mid-flight). Prefill runs the whole prompt in one forward
  pass and scatters KV straight into the session's pages
  (``write_prefill_to_pages``) — no token-by-token prompt replay; the
  prompt is right-padded to a fixed ``pad_len`` so admission reuses a
  single jit trace. Decode assembles every running session — whatever
  their lengths — into one fixed-width batched step against the shared
  pool; finished sessions are evicted between steps and their blocks
  reclaimed, so a long generation never convoys short ones.
* Sequential baseline (:func:`run_sequential`) — the pre-engine serve
  loop (one session at a time, dense cache), kept as the benchmark
  yardstick for ``benchmarks/serving.py``.
* Checkpoint hot-swap — params enter the jitted step as a plain
  argument, so swapping weights between steps is free (no retrace, no
  cache rebuild: RoPE/KV are weight-independent). ``swap_params``
  records the engine step and in-flight sessions;
  ``attach_checkpointer`` polls a training run's checkpoint directory
  and swaps automatically. ``serving_params_from_checkpoint`` folds a
  peer-stacked FL checkpoint into serving weights (the peer mean —
  post-aggregation peers agree, so the mean is a no-op then, and the
  consensus estimate mid-round).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.serve.paged_cache import (SCRATCH_BLOCK, BlockAllocator,
                                     session_table, write_prefill_to_pages)

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# Config / session bookkeeping
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Static engine geometry (one jit trace per config)."""
    max_batch: int = 8          # decode rows assembled per step
    block_size: int = 16        # KV positions per page
    num_blocks: int = 257       # pool size incl. the scratch page 0
    pad_len: int = 64           # prompts are right-padded to this length
    max_new: int = 32           # per-session generation cap (upper bound)
    eos_id: Optional[int] = None

    @property
    def table_width(self) -> int:
        """Block-table columns: worst-case session footprint, plus the
        prefill's padded overhang (pad KV beyond a session's own blocks
        lands on the scratch page)."""
        need = -(-(self.pad_len + self.max_new) // self.block_size)
        pref = -(-self.pad_len // self.block_size)
        return max(need, pref)


@dataclasses.dataclass
class Session:
    sid: int
    prompt: np.ndarray                       # [plen] int32
    max_new: int
    blocks: List[int] = dataclasses.field(default_factory=list)
    row: int = -1
    generated: List[int] = dataclasses.field(default_factory=list)
    state: str = "queued"                    # queued -> running -> done
    token_times: List[float] = dataclasses.field(default_factory=list)
    t_enqueue: float = 0.0
    t_done: float = 0.0

    @property
    def plen(self) -> int:
        return int(self.prompt.shape[0])

    def blocks_needed(self, block_size: int) -> int:
        return -(-(self.plen + self.max_new) // block_size)


# ---------------------------------------------------------------------------
# Checkpoint -> serving weights
# ---------------------------------------------------------------------------

def serving_params_from_checkpoint(state: PyTree, template: PyTree) -> PyTree:
    """Fold a restored checkpoint into serving params shaped/dtyped like
    ``template`` (``model.init(...)`` / ``model.init_shape()``).

    Accepts either raw params or a full FL state dict (``{"params":
    ..., "momentum": ...}``); leaves carrying a peer axis (ndim ==
    template ndim + 1) are averaged over it.
    """
    from repro.checkpoint.checkpointer import _path_str
    if isinstance(state, dict) and "params" in state:
        state = state["params"]
    flat, _ = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        node = state
        for e in path:
            node = node[_path_str(e)] if isinstance(node, dict) \
                else node[int(_path_str(e))]
        arr = jnp.asarray(node)
        if arr.ndim == leaf.ndim + 1:
            arr = jnp.mean(arr.astype(jnp.float32), axis=0)
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree.unflatten(jax.tree.structure(template), leaves)


# ---------------------------------------------------------------------------
# Continuous-batching engine
# ---------------------------------------------------------------------------

class DecodeServer:
    """Greedy continuous-batching decode over a paged KV pool.

    Drive with :meth:`enqueue` + :meth:`run` (or :meth:`step` for
    external control loops). Finished sessions accumulate in
    ``self.finished``; no session is ever dropped — a prompt that can
    never fit (``plen > pad_len`` or a footprint larger than the whole
    pool) is rejected at enqueue instead of deadlocking the queue.
    """

    def __init__(self, model: Model, params: PyTree, cfg: ServeConfig):
        if model.cfg.family not in ("dense", "vlm", "audio", "moe"):
            raise ValueError(
                f"paged serving supports KV-cache families, "
                f"got {model.cfg.family}")
        if model.has_frontend:
            raise ValueError("paged serving takes token prompts only")
        self.model = model
        self.params = params
        self.cfg = cfg
        self.pages = model.init_paged_cache(cfg.num_blocks, cfg.block_size)
        self.alloc = BlockAllocator(cfg.num_blocks)
        self.queue: List[Session] = []
        self.running: List[Session] = []
        self.finished: List[Session] = []
        self.engine_step = 0
        self.prefill_count = 0
        self.decode_steps = 0
        self.swap_log: List[Dict[str, Any]] = []
        self._watch = None                      # (checkpointer, every, step)

        mb, tw = cfg.max_batch, cfg.table_width
        self._free_rows = list(range(mb - 1, -1, -1))
        self._tok = np.zeros((mb,), np.int32)
        self._pos = np.zeros((mb,), np.int32)
        self._bt = np.full((mb, tw), SCRATCH_BLOCK, np.int32)

        self._prefill = jax.jit(self._prefill_impl)
        self._decode = jax.jit(self._decode_impl)

    # -- jitted bodies ---------------------------------------------------
    def _prefill_impl(self, params, pages, tokens, length, block_table):
        """tokens [1, pad_len] (right-padded); length [1]; block_table
        [1, tw]. One forward pass writes the whole prompt's KV into the
        session's pages and emits the first generated token."""
        logits, _, cache = self.model.forward(params, tokens,
                                              collect_cache=True)
        pages = write_prefill_to_pages(pages, cache["k"], cache["v"],
                                       block_table)
        last = jnp.take_along_axis(
            logits, (length - 1)[:, None, None], axis=1)[:, 0]
        return jnp.argmax(last, axis=-1).astype(jnp.int32), pages

    def _decode_impl(self, params, pages, bt, pos, tok):
        logits, pages = self.model.paged_decode_step(params, pages, bt,
                                                     pos, tok)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), pages

    # -- session lifecycle ----------------------------------------------
    def enqueue(self, prompt: Sequence[int], max_new: Optional[int] = None,
                sid: Optional[int] = None) -> Session:
        prompt = np.asarray(prompt, np.int32)
        max_new = self.cfg.max_new if max_new is None else max_new
        if prompt.shape[0] > self.cfg.pad_len:
            raise ValueError(
                f"prompt len {prompt.shape[0]} > pad_len {self.cfg.pad_len}")
        if not (1 <= max_new <= self.cfg.max_new):
            raise ValueError(f"max_new {max_new} outside [1, "
                             f"{self.cfg.max_new}]")
        sess = Session(
            sid=len(self.queue) + len(self.running) + len(self.finished)
            if sid is None else sid,
            prompt=prompt, max_new=max_new, t_enqueue=time.perf_counter())
        need = sess.blocks_needed(self.cfg.block_size)
        if need > self.alloc.num_blocks - 1:
            raise ValueError(f"session needs {need} blocks; pool has "
                             f"{self.alloc.num_blocks - 1}")
        self.queue.append(sess)
        return sess

    def _admit(self) -> None:
        """FIFO admission with head-of-line blocking: stop at the first
        session that doesn't fit — later arrivals must not overtake it
        (fairness over packing)."""
        while self.queue and self._free_rows:
            sess = self.queue[0]
            need = sess.blocks_needed(self.cfg.block_size)
            if not self.alloc.can_alloc(need):
                return
            self.queue.pop(0)
            t0 = time.perf_counter()
            sess.blocks = self.alloc.alloc(need)
            sess.row = self._free_rows.pop()
            table = session_table(sess.blocks, self.cfg.table_width)
            toks = np.zeros((1, self.cfg.pad_len), np.int32)
            toks[0, :sess.plen] = sess.prompt
            first, self.pages = self._prefill(
                self.params, self.pages, jnp.asarray(toks),
                jnp.asarray([sess.plen], jnp.int32),
                jnp.asarray([table], jnp.int32))
            first = int(np.asarray(first)[0])
            sess.generated.append(first)
            sess.token_times.append(time.perf_counter() - t0)
            sess.state = "running"
            self.prefill_count += 1
            self._bt[sess.row] = table
            self._tok[sess.row] = first
            self._pos[sess.row] = sess.plen
            self.running.append(sess)
            if self._is_finished(sess, first):
                self._evict(sess)

    def _is_finished(self, sess: Session, tok: int) -> bool:
        return (len(sess.generated) >= sess.max_new
                or (self.cfg.eos_id is not None and tok == self.cfg.eos_id))

    def _evict(self, sess: Session) -> None:
        self.alloc.free(sess.blocks)
        sess.blocks = []
        self._free_rows.append(sess.row)
        self._bt[sess.row] = SCRATCH_BLOCK
        self._tok[sess.row] = 0
        self._pos[sess.row] = 0
        sess.row = -1
        sess.state = "done"
        sess.t_done = time.perf_counter()
        self.running.remove(sess)
        self.finished.append(sess)

    # -- checkpoint hot-swap ---------------------------------------------
    def swap_params(self, params: PyTree, tag: str = "manual") -> None:
        """Install new weights; takes effect on the next decode step.
        In-flight sessions keep their KV pages and positions — the cache
        holds context tokens, not weight state, so generation simply
        continues under the new model."""
        self.params = params
        self.swap_log.append({
            "engine_step": self.engine_step, "tag": tag,
            "in_flight": [s.sid for s in self.running]})

    def attach_checkpointer(self, ckpt, template: PyTree,
                            every: int = 8) -> None:
        """Poll ``ckpt`` (a ``Checkpointer``) every ``every`` engine
        steps; any newer step is restored, peer-folded and swapped in."""
        self._watch = {"ckpt": ckpt, "template": template, "every": every,
                       "seen": ckpt.latest_step()}

    def _maybe_swap(self) -> None:
        w = self._watch
        if w is None or self.engine_step % w["every"]:
            return
        step = w["ckpt"].poll(w["seen"])
        if step is None:
            return
        state, _ = w["ckpt"].restore(step)
        self.swap_params(
            serving_params_from_checkpoint(state, w["template"]),
            tag=f"ckpt:{step}")
        w["seen"] = step

    # -- engine loop -----------------------------------------------------
    def step(self) -> bool:
        """Admit, run one batched decode step, evict finished sessions.
        Returns False once the engine is fully drained."""
        self._maybe_swap()
        self._admit()
        if not self.running:
            return bool(self.queue)
        t0 = time.perf_counter()
        ntok, self.pages = self._decode(
            self.params, self.pages, jnp.asarray(self._bt),
            jnp.asarray(self._pos), jnp.asarray(self._tok))
        ntok = np.asarray(ntok)
        dt = time.perf_counter() - t0
        self.decode_steps += 1
        for sess in list(self.running):
            tok = int(ntok[sess.row])
            sess.generated.append(tok)
            sess.token_times.append(dt)
            self._tok[sess.row] = tok
            self._pos[sess.row] += 1
            if self._is_finished(sess, tok):
                self._evict(sess)
        self.engine_step += 1
        return True

    def run(self, max_steps: Optional[int] = None) -> List[Session]:
        steps = 0
        while self.step():
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        return self.finished

    def assert_quiescent(self) -> None:
        """Invariant check after a drain: every block reclaimed, every
        row free, nothing in flight."""
        assert not self.queue and not self.running, \
            (len(self.queue), len(self.running))
        free = self.alloc.free_blocks
        assert free == self.alloc.num_blocks - 1, \
            f"block leak: {self.alloc.num_blocks - 1 - free} unreclaimed"
        assert len(self._free_rows) == self.cfg.max_batch

    def stats(self) -> Dict[str, float]:
        times = [t for s in self.finished for t in s.token_times[1:]]
        ttft = [s.token_times[0] for s in self.finished]
        toks = sum(len(s.generated) for s in self.finished)
        return {
            "sessions": len(self.finished),
            "tokens": toks,
            "decode_steps": self.decode_steps,
            "prefills": self.prefill_count,
            "p50_tok_s": float(np.percentile(times, 50)) if times else 0.0,
            "p99_tok_s": float(np.percentile(times, 99)) if times else 0.0,
            "p50_ttft_s": float(np.percentile(ttft, 50)) if ttft else 0.0,
            "swaps": len(self.swap_log),
        }


# ---------------------------------------------------------------------------
# Sequential baseline (pre-engine serve loop)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _sequential_fns(model: Model, pad_len: int, max_new: int):
    """Jitted (prefill, decode) pair for the sequential baseline, cached
    per (model, shape) so repeated baseline runs never re-trace (a
    fresh-jit baseline would bill tracing to the timed region and
    flatter the engine in benchmarks/serving.py)."""
    max_len = pad_len + max_new

    def prefill(params, tokens, length):
        logits, _, cache = model.forward(params, tokens, collect_cache=True)
        cache = model.prefill_cache_to_decode(cache, max_len, pad_len,
                                              lengths=length)
        last = jnp.take_along_axis(
            logits, (length - 1)[:, None, None], axis=1)[:, 0]
        return jnp.argmax(last, axis=-1).astype(jnp.int32), cache

    def decode(params, cache, tok):
        logits, cache = model.decode_step(params, cache, tok)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return jax.jit(prefill), jax.jit(decode)


def run_sequential(model: Model, params: PyTree,
                   prompts: Sequence[Sequence[int]], max_new: int,
                   pad_len: int) -> List[Session]:
    """One session at a time against a dense cache — the old
    ``launch/serve.py`` loop, minus its prompt replay (it now uses the
    decode-ready prefill handoff). The benchmark baseline: identical
    greedy tokens to the engine, none of the batching."""
    if model.has_frontend:
        raise ValueError("run_sequential takes token prompts only")
    recurrent = model.cfg.family in ("ssm", "hybrid")
    prefill, decode = _sequential_fns(model, pad_len, max_new)
    out = []
    for sid, prompt in enumerate(prompts):
        prompt = np.asarray(prompt, np.int32)
        if prompt.shape[0] > pad_len:
            raise ValueError(f"prompt len {prompt.shape[0]} > {pad_len}")
        if recurrent and prompt.shape[0] != pad_len:
            # recurrent state absorbs pad tokens — exact length only
            raise ValueError(
                f"{model.cfg.family} prompts must be exactly pad_len="
                f"{pad_len} (got {prompt.shape[0]})")
        sess = Session(sid=sid, prompt=prompt, max_new=max_new,
                       t_enqueue=time.perf_counter())
        toks = np.zeros((1, pad_len), np.int32)
        toks[0, :sess.plen] = prompt
        t0 = time.perf_counter()
        tok, cache = prefill(params, jnp.asarray(toks),
                             jnp.asarray([sess.plen], jnp.int32))
        tok_host = int(np.asarray(tok)[0])
        sess.generated.append(tok_host)
        sess.token_times.append(time.perf_counter() - t0)
        while len(sess.generated) < max_new:
            t0 = time.perf_counter()
            tok, cache = decode(params, cache, tok)
            tok_host = int(np.asarray(tok)[0])
            sess.generated.append(tok_host)
            sess.token_times.append(time.perf_counter() - t0)
        sess.state = "done"
        sess.t_done = time.perf_counter()
        out.append(sess)
    return out
