"""Synthetic data: federated classification tasks + LM token streams.

The paper uses MNIST (vision) and 20 Newsgroups (text, frozen-encoder
features). This container is offline, so we generate statistically
analogous synthetic tasks:

* ``classification_task("vision")`` — 10-class Gaussian-mixture images
  (flattened 28x28-like), stand-in for MNIST's CNN task.
* ``classification_task("text")``  — 20-class anisotropic Gaussian
  feature clusters in d=768 (stand-in for frozen-DistilBERT CLS
  features on 20NG — the paper's model IS a linear/MLP head on frozen
  features, so a feature-space task is the faithful analogue).

Both are learnable-but-not-trivial (cluster overlap controlled by
``margin``) so FL convergence curves behave qualitatively like the
paper's. LM token streams feed the big-architecture training drivers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    name: str
    num_classes: int
    feature_dim: int
    num_train: int
    num_test: int


TASKS = {
    # MNIST analogue: 10 classes, 784 features
    "vision": TaskSpec("vision", 10, 784, 8_192, 2_048),
    # 20NG-on-frozen-DistilBERT analogue: 20 classes, 768-dim features
    "text": TaskSpec("text", 20, 768, 4_096, 1_024),
}


def _smooth_templates(rng, num_classes: int, side: int,
                      coarse: int = 7) -> np.ndarray:
    """Low-frequency class template "images" (bilinear-upsampled coarse
    grids) so conv layers have spatial structure to exploit."""
    grids = rng.normal(size=(num_classes, coarse, coarse))
    xs = np.linspace(0, coarse - 1, side)
    x0 = np.clip(np.floor(xs).astype(int), 0, coarse - 2)
    w = xs - x0                                        # [side]
    # separable bilinear upsample: rows then columns
    up_r = (grids[:, x0, :] * (1 - w)[None, :, None]
            + grids[:, x0 + 1, :] * w[None, :, None])  # [C, side, coarse]
    up = (up_r[:, :, x0] * (1 - w)[None, None, :]
          + up_r[:, :, x0 + 1] * w[None, None, :])     # [C, side, side]
    flat = up.reshape(num_classes, side * side)
    return flat / np.linalg.norm(flat, axis=1, keepdims=True)


def classification_task(name: str, seed: int = 0, margin: float = 5.0
                        ) -> Tuple[TaskSpec, Dict[str, np.ndarray],
                                   Dict[str, np.ndarray]]:
    """Returns (spec, train, test) with numpy arrays x [N, D], y [N]."""
    spec = TASKS[name]
    rng = np.random.default_rng(seed)
    if name == "vision":
        side = int(np.sqrt(spec.feature_dim))
        means = margin * _smooth_templates(rng, spec.num_classes, side)
        scales = np.ones(spec.feature_dim)
    else:
        # class means on a scaled random simplex; anisotropic noise
        means = rng.normal(size=(spec.num_classes, spec.feature_dim))
        means = margin * means / np.linalg.norm(means, axis=1, keepdims=True)
        scales = 0.5 + rng.random(spec.feature_dim)

    def sample(n):
        y = rng.integers(0, spec.num_classes, size=n)
        x = means[y] + rng.normal(size=(n, spec.feature_dim)) * scales
        return {"x": x.astype(np.float32), "y": y.astype(np.int32)}

    return spec, sample(spec.num_train), sample(spec.num_test)


def lm_token_stream(vocab_size: int, batch: int, seq_len: int,
                    seed: int = 0) -> Iterator[Dict[str, Array]]:
    """Infinite synthetic LM batches with Zipfian unigram statistics and a
    short-range bigram structure (so loss decreases measurably)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
    shift = rng.integers(1, vocab_size)
    while True:
        base = rng.choice(vocab_size, size=(batch, seq_len + 1), p=unigram)
        # 50% of positions continue a deterministic bigram chain
        cont = rng.random((batch, seq_len)) < 0.5
        for t in range(1, seq_len + 1):
            nxt = (base[:, t - 1] + shift) % vocab_size
            base[:, t] = np.where(cont[:, t - 1], nxt, base[:, t])
        yield {
            "tokens": jnp.asarray(base[:, :-1], jnp.int32),
            "labels": jnp.asarray(base[:, 1:], jnp.int32),
        }


def lm_batch(vocab_size: int, batch: int, seq_len: int, seed: int = 0
             ) -> Dict[str, Array]:
    return next(lm_token_stream(vocab_size, batch, seq_len, seed))
