from repro.data.synthetic import (classification_task, lm_token_stream,
                                  TaskSpec)
from repro.data.partition import dirichlet_partition, partition_stats
