"""Dirichlet non-i.i.d. federated partitioning (paper's LDA, alpha=1.0).

For each class c, draw p_c ~ Dir(alpha * 1_N) over the N peers and
multinomially assign that class's examples — the standard label-skew
construction the paper calls "Latent Dirichlet Allocation (alpha=1.0)".
alpha -> inf recovers i.i.d.; small alpha concentrates classes on few
peers.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np


def dirichlet_partition(labels: np.ndarray, n_peers: int, alpha: float = 1.0,
                        seed: int = 0, min_per_peer: int = 2
                        ) -> List[np.ndarray]:
    """Returns per-peer index arrays covering all examples exactly once."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    shards: List[List[int]] = [[] for _ in range(n_peers)]
    for c in classes:
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        p = rng.dirichlet(np.full(n_peers, alpha))
        # proportional contiguous split (largest-remainder rounding)
        cuts = np.floor(np.cumsum(p) * len(idx)).astype(int)
        prev = 0
        for peer, cut in enumerate(cuts):
            shards[peer].extend(idx[prev:cut].tolist())
            prev = cut
        shards[-1].extend(idx[prev:].tolist())
    # guarantee every peer has a floor of examples (steal from richest)
    sizes = [len(s) for s in shards]
    for peer in range(n_peers):
        while len(shards[peer]) < min_per_peer:
            donor = int(np.argmax([len(s) for s in shards]))
            shards[peer].append(shards[donor].pop())
    return [np.asarray(sorted(s), np.int64) for s in shards]


def iid_partition(n_examples: int, n_peers: int, seed: int = 0
                  ) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_examples)
    return [np.sort(p) for p in np.array_split(perm, n_peers)]


def partition_stats(shards: List[np.ndarray], labels: np.ndarray
                    ) -> Dict[str, float]:
    """Heterogeneity diagnostics: size spread + mean label-dist TV from
    the global distribution."""
    n_classes = int(labels.max()) + 1
    global_p = np.bincount(labels, minlength=n_classes) / len(labels)
    tvs, sizes = [], []
    for s in shards:
        sizes.append(len(s))
        local = np.bincount(labels[s], minlength=n_classes) / max(len(s), 1)
        tvs.append(0.5 * np.abs(local - global_p).sum())
    return {
        "mean_tv": float(np.mean(tvs)),
        "max_tv": float(np.max(tvs)),
        "min_size": int(np.min(sizes)),
        "max_size": int(np.max(sizes)),
    }
