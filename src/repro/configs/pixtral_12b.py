"""pixtral-12b [vlm] — pixtral-ViT frontend (STUB) + mistral-nemo backbone
[hf:mistralai/Pixtral-12B-2409].

The modality frontend is a stub per the brief: ``input_specs()`` feeds
precomputed patch embeddings alongside token embeddings; the backbone is
the mistral-nemo decoder (head_dim=128 with d_model=5120, as published).
"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    frontend="vision_patches",
)


def smoke_config() -> ModelConfig:
    return reduced(CONFIG)
