"""moonshot-v1-16b-a3b [moe] — kimi/moonlight 64e top-6
[hf:moonshotai/Moonlight-16B-A3B].

48L, d_model=2048, 16H MHA (kv=16), per-expert d_ff=1408, vocab=163840,
64 experts top-6, 2 shared experts (Moonlight lineage).
"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    num_experts=64,
    experts_per_token=6,
    num_shared_experts=2,
)


def smoke_config() -> ModelConfig:
    return reduced(CONFIG, num_kv_heads=4)
