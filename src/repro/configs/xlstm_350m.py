"""xlstm-350m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517].

24L, d_model=1024, 4 heads, vocab=50304, d_ff=0 (xLSTM blocks carry their
own up/down projections). Block pattern: 1 sLSTM per 8 layers, rest mLSTM
(matrix-memory linear recurrence). O(1) decode state -> long_500k applies.
"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    ssm_expand=2,
    slstm_every=8,
)


def smoke_config() -> ModelConfig:
    return reduced(CONFIG, num_layers=4, slstm_every=2, head_dim=32)
