from repro.configs.base import (  # noqa: F401
    ModelConfig,
    ShapeConfig,
    SHAPES,
    reduced,
    shape_applicable,
)
from repro.configs.registry import (  # noqa: F401
    ARCH_IDS,
    all_cells,
    get_config,
    get_shape,
    get_smoke_config,
)
