"""Architecture registry: ``get_config(arch_id)`` / ``get_smoke_config``."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, shape_applicable

# arch id -> module name
_ARCHS = {
    "granite-8b": "granite_8b",
    "glm4-9b": "glm4_9b",
    "deepseek-67b": "deepseek_67b",
    "starcoder2-3b": "starcoder2_3b",
    "pixtral-12b": "pixtral_12b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "xlstm-350m": "xlstm_350m",
    "zamba2-2.7b": "zamba2_2_7b",
    "musicgen-medium": "musicgen_medium",
}

ARCH_IDS: List[str] = list(_ARCHS)


def _module(arch_id: str):
    if arch_id not in _ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_ARCHS[arch_id]}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).smoke_config()


def get_shape(shape_id: str) -> ShapeConfig:
    return SHAPES[shape_id]


def all_cells(include_skipped: bool = False):
    """All (arch, shape) cells; skipped=long_500k on quadratic archs."""
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id)
        for shape_id, shape in SHAPES.items():
            ok = shape_applicable(cfg, shape)
            if ok or include_skipped:
                yield arch_id, shape_id, ok


def describe() -> Dict[str, dict]:
    out = {}
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id)
        out[arch_id] = dict(
            family=cfg.family,
            layers=cfg.num_layers,
            d_model=cfg.d_model,
            params=cfg.param_count(),
            active_params=cfg.active_param_count(),
        )
    return out
