"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8
[arXiv:2501.kimi2 paper table].

Per the assignment table: 61L, d_model=7168, 64H (GQA kv=8), per-expert
d_ff=2048, vocab=163840, 384 experts top-8. One shared expert (Kimi-K2 /
DeepSeek-V3 lineage). head_dim=128 (MXU-aligned; q-dim 8192 != d_model is
standard for this lineage).
"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    vocab_size=163840,
    num_experts=384,
    experts_per_token=8,
    num_shared_experts=1,
)


def smoke_config() -> ModelConfig:
    return reduced(CONFIG)
