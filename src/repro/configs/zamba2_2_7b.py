"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; hf].

54L, d_model=2560, shared attn block (32H MHA, d_ff=10240) applied every 6
layers with SHARED weights (Zamba2's parameter-sharing trick); remaining
layers are Mamba2 (ssd_state=64). Hybrid family -> long_500k applies; the
shared attention block uses a sliding-window KV cache for long decode.
"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    attn_every=6,
    shared_attn_window=4096,
)


def smoke_config() -> ModelConfig:
    return reduced(CONFIG, num_heads=4, num_kv_heads=4, head_dim=32)
