"""musicgen-medium [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf].

48L, d_model=1536, 24H MHA, d_ff=6144, vocab=2048 (EnCodec codebook). The
EnCodec frontend is a STUB per the brief: ``input_specs()`` provides
precomputed frame embeddings; the codebook-interleave pattern is collapsed
to a single token stream (backbone-only scope, see DESIGN.md §4).
"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    frontend="audio_frames",
)


def smoke_config() -> ModelConfig:
    return reduced(CONFIG)
