"""Config system: model architecture + run shapes.

Every assigned architecture gets one module in ``repro.configs`` exporting
``CONFIG`` (the exact published config) and ``smoke_config()`` (a reduced
same-family config for CPU smoke tests). ``registry.py`` exposes them by id.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (family-generic superset)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_capacity_factor: float = 1.25

    # --- SSM / recurrent ---
    ssm_state: int = 0          # mamba2 state dim per head
    ssm_conv_width: int = 4     # depthwise causal conv width
    ssm_expand: int = 2         # inner expansion factor
    slstm_every: int = 0        # xlstm: 1 sLSTM block per this many layers

    # --- hybrid (zamba2-style) ---
    attn_every: int = 0         # shared attention block period (0 = none)
    shared_attn_window: int = 4096  # sliding window for the shared attn cache

    # --- modality frontend stubs ---
    frontend: str = "none"      # none | vision_patches | audio_frames

    # --- common ---
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # attention implementation: "flash" (custom-vjp blockwise, default)
    # | "xla" (naive chunked; §Perf baseline) | "pallas" (TPU kernel)
    attn_impl: str = "flash"
    # activation rematerialization: "block" (checkpoint each layer,
    # default) | "none" (save everything: more memory, ~25% fewer FLOPs)
    remat: str = "block"
    # attention q/kv chunking for memory-bounded prefill
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 2048

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, self.name

    # ------------------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_recurrent(self) -> bool:
        """True when decode state is O(1) in sequence length (no KV cache)."""
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM and hybrid families only."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, L = self.d_model, self.num_layers
        hd = self.head_dim
        n_embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total = n_embed
        for kind in self.block_pattern():
            if kind == "attn" or kind == "shared_attn":
                qkv = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd)
                o = (self.num_heads * hd) * d
                total += qkv + o + d  # + norm
                if kind == "attn":
                    total += self._ffn_params() + d
            elif kind == "moe":
                qkv = d * (self.num_heads * hd) + 2 * d * (self.num_kv_heads * hd)
                o = (self.num_heads * hd) * d
                total += qkv + o + d
                total += self._moe_params() + d
            elif kind == "mamba":
                total += self._mamba_params() + d
            elif kind == "mlstm":
                total += self._mlstm_params() + d
            elif kind == "slstm":
                total += self._slstm_params() + d
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Active params per token (differs from total for MoE)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        dense = self.param_count()
        all_expert = self.num_layers * self.num_experts * 3 * d * self.d_ff
        active_expert = self.num_layers * (
            (self.experts_per_token + self.num_shared_experts) * 3 * d * self.d_ff
        )
        return dense - all_expert + active_expert

    def _ffn_params(self) -> int:
        return 3 * self.d_model * self.d_ff  # SwiGLU: gate, up, down

    def _moe_params(self) -> int:
        d = self.d_model
        e = self.num_experts + self.num_shared_experts
        return self.num_experts * d + e * 3 * d * self.d_ff

    def _mamba_params(self) -> int:
        d = self.d_model
        inner = self.ssm_expand * d
        nheads = max(1, inner // 64)
        # in_proj -> (z, x, B, C, dt), conv, A/D, out_proj
        return (
            d * (2 * inner + 2 * self.ssm_state + nheads)
            + self.ssm_conv_width * (inner + 2 * self.ssm_state)
            + 2 * nheads
            + inner * d
        )

    def _mlstm_params(self) -> int:
        d = self.d_model
        inner = self.ssm_expand * d
        # up_proj(2x for gate), qkv projections on inner, i/f gates, out_proj
        return d * 2 * inner + 3 * inner * inner + 2 * inner * 2 + inner * d

    def _slstm_params(self) -> int:
        d = self.d_model
        return 4 * 2 * d * d + 4 * d + d * d  # 4 gates x (Wx, Rh) + bias + out

    def block_pattern(self) -> Tuple[str, ...]:
        """Per-layer block kinds, length == num_layers."""
        kinds = []
        for i in range(self.num_layers):
            if self.family == "moe":
                kinds.append("moe")
            elif self.family == "ssm":
                if self.slstm_every and (i + 1) % self.slstm_every == 0:
                    kinds.append("slstm")
                else:
                    kinds.append("mlstm")
            elif self.family == "hybrid":
                if self.attn_every and (i + 1) % self.attn_every == 0:
                    kinds.append("shared_attn")
                else:
                    kinds.append("mamba")
            else:  # dense / vlm / audio -> plain attention blocks
                kinds.append("attn")
        return tuple(kinds)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell: (kind, seq_len, global_batch)."""

    name: str
    kind: str  # train | prefill | decode | long_decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind in ("decode", "long_decode")


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "long_decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k needs sub-quadratic attention (see DESIGN.md §4)."""
    if shape.kind == "long_decode":
        return cfg.supports_long_context
    return True


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Family-preserving reduced config for CPU smoke tests."""
    base = dict(
        num_layers=min(cfg.num_layers, 4 if cfg.family != "hybrid" else 6),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
    )
    if cfg.is_moe:
        base.update(num_experts=8, experts_per_token=2, d_ff=64,
                    num_shared_experts=min(cfg.num_shared_experts, 1))
    if cfg.family == "ssm":
        base.update(ssm_state=16, slstm_every=cfg.slstm_every and 2)
    if cfg.family == "hybrid":
        base.update(ssm_state=16, attn_every=3, shared_attn_window=64)
    base.update(overrides)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **base)
