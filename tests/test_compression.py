"""int8 error-feedback delta compression (beyond-paper MAR wire format)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.compression import (INT8_RATIO, compress_tree,
                                    dequantize_int8, quantize_int8)
from repro.core.federation import Federation, FederationConfig


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 256)), jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    # absmax scaling: per-element error <= scale/2 = absmax/254
    bound = jnp.max(jnp.abs(x), axis=1, keepdims=True) / 254.0 + 1e-9
    assert bool(jnp.all(err <= bound * 1.01))


def test_error_feedback_carries_residual():
    x = {"w": jnp.asarray([[0.3, -0.7, 1.2]], jnp.float32)}
    deq1, err1 = compress_tree(x, None)
    # feeding the same value again with the carried error reduces bias
    deq2, err2 = compress_tree(x, err1)
    total1 = deq1["w"]
    total2 = deq1["w"] + deq2["w"]
    assert float(jnp.max(jnp.abs(total2 / 2 - x["w"]))) <= \
        float(jnp.max(jnp.abs(total1 - x["w"]))) + 1e-9


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_quantize_idempotent_on_grid(seed):
    """Values on the int8 grid with full-range absmax survive exactly
    (the quantizer's scale is absmax/127, so pin absmax to 127*scale)."""
    rng = np.random.default_rng(seed)
    scale = abs(rng.normal()) + 0.1
    ints = rng.integers(-126, 127, size=(1, 32))
    ints[0, 0] = 127                        # pin the absmax to the grid
    x = jnp.asarray(ints.astype(np.float32) * scale)
    q, s = quantize_int8(x)
    np.testing.assert_allclose(dequantize_int8(q, s), x, rtol=1e-5,
                               atol=1e-5)


def test_compressed_federation_matches_uncompressed():
    """4x fewer bytes at (near-)equal accuracy — the headline claim."""
    res = {}
    for comp in (None, "int8_ef"):
        cfg = FederationConfig(n_peers=8, technique="mar", task="text",
                               local_batches=4, compress=comp, seed=3)
        fed = Federation(cfg)
        state = fed.init_state()
        for _ in range(20):
            state = fed.step(state)
        res[comp] = (fed.evaluate(state), fed.comm_bytes)
    acc_full, bytes_full = res[None]
    acc_q, bytes_q = res["int8_ef"]
    assert bytes_q == pytest.approx(bytes_full / INT8_RATIO)
    assert acc_q >= acc_full - 0.05


def test_compressed_peers_agree():
    cfg = FederationConfig(n_peers=8, technique="mar", task="text",
                           compress="int8_ef", seed=1)
    fed = Federation(cfg)
    state = fed.init_state()
    for _ in range(3):
        state = fed.step(state)
    x = jax.tree.leaves(state.params)[0]
    spread = float(jnp.max(jnp.abs(x - jnp.mean(x, 0, keepdims=True))))
    assert spread < 1e-5  # all peers re-anchor on the shared ref
