"""Topology-aware placement (ISSUE 7 acceptance).

The ``placement`` permutation on :class:`GridPlan` must be invisible
when identity (bit-exact transcripts on both engines), byte-preserving
for *any* permutation (``topology.mar_bytes`` stays the oracle), and
profitable when learned: on the shuffled ``regions`` profile the
``clustered`` policy must recover the ground-truth region partition
from probe evidence and strictly beat a random permutation in
simulated seconds. Also covers the evidence chain the policy runs on —
``Transcript.link_time_stats`` filled identically by both sim engines,
the ``bytes_by_link``+``peer_finish_s`` fallback derivation, and
``LinkModel.peer_attrs`` ground truth.
"""
import dataclasses
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import topology
from repro.core.aggregation import TECHNIQUES, make_aggregator
from repro.core.moshpit import GridPlan, plan_grid
from repro.core.placement import (ClusteredPlacement, PLACEMENTS,
                                  LinkQualityEstimator, build_placement,
                                  cluster_permutation, probe_plan)
from repro.core.transport import build_array_plan
from repro.runtime.network import NetworkSim, build_link_model
from repro.runtime.transport_base import (LINK_DETAIL_MAX_PEERS,
                                          LinkAccounting, Transcript)
from repro.runtime.vector_network import VectorNetworkSim

MB = 10_000
SHUF = {"shuffle": True}       # regions scattered over peer indices


def _same_transcripts(th: Transcript, tv: Transcript):
    assert tv.total_bytes == th.total_bytes
    assert tv.bytes_by_round == th.bytes_by_round
    assert tv.bytes_by_link == th.bytes_by_link
    assert tv.round_s == th.round_s
    assert np.array_equal(tv.peer_finish_s, th.peer_finish_s)
    assert tv.iteration_s == th.iteration_s
    assert tv.link_time_stats == th.link_time_stats


def _run(plan, n, mask=None, profile="regions", seed=0,
         link_params=None, engine=NetworkSim, tech="mar", mb=MB):
    agg = make_aggregator(tech, plan)
    if mask is None:
        mask = np.ones(n, np.float32)
    net = engine(n, profile=profile, seed=seed,
                 link_params=link_params)
    return net.run(agg.message_plan(mask, mb)), net


# ---------------------------------------------------------------------------
# GridPlan.placement mechanics
# ---------------------------------------------------------------------------

def test_identity_placement_normalizes_to_none():
    plan = plan_grid(27)
    placed = plan.with_placement(np.arange(27))
    assert placed.placement is None
    assert placed == plan
    assert GridPlan(27, (3, 3, 3),
                    tuple(range(27))).placement is None


def test_placement_validation():
    with pytest.raises(ValueError, match="permutation"):
        GridPlan(8, (2, 2, 2), (0, 1))              # wrong length
    with pytest.raises(ValueError, match="permutation"):
        GridPlan(8, (2, 2, 2), (0,) * 8)            # duplicates
    with pytest.raises(ValueError, match="cover"):
        plan_grid(8).with_placement(np.arange(5))   # bad shape
    with pytest.raises(ValueError, match="unknown placement"):
        build_placement("nope", plan_grid(8))


def test_short_form_fills_virtual_slots():
    """A length-n_peers perm over a padded grid parks virtual entities
    on the leftover slots, ascending."""
    plan = GridPlan(6, (2, 2, 2))                    # capacity 8
    placed = plan.with_placement(np.array([7, 0, 1, 2, 3, 4]))
    assert placed.placement == (7, 0, 1, 2, 3, 4, 5, 6)
    # round-trip: coords/index stay inverse bijections
    ent = np.arange(placed.capacity)
    assert np.array_equal(placed.index(placed.coords(ent)), ent)


def test_placement_routes_through_all_grid_queries():
    plan = plan_grid(27)
    rng = np.random.default_rng(3)
    perm = rng.permutation(27)
    placed = plan.with_placement(perm)
    ent = np.arange(27)
    assert np.array_equal(placed.slot_of(ent), perm)
    assert np.array_equal(placed.coords(ent), plan.coords(perm))
    for rnd in range(plan.depth):
        assert np.array_equal(placed.group_key(ent, rnd),
                              plan.group_key(perm, rnd))


def test_cluster_permutation_packs_largest_first():
    labels = np.array([0, 0, 1, 1, 1])
    # cluster 1 (size 3) takes slots 0..2; cluster 0 takes 3..4
    assert cluster_permutation(labels).tolist() == [3, 4, 0, 1, 2]
    # ties break on lowest member index; within-cluster order kept
    labels = np.array([1, 0, 1, 0])
    assert cluster_permutation(labels).tolist() == [0, 2, 1, 3]
    # stability: same labels -> same permutation
    assert np.array_equal(cluster_permutation(labels),
                          cluster_permutation(labels))


# ---------------------------------------------------------------------------
# identity bit-exactness + byte conservation (the safety half)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [8, 27, 64, 125])
@pytest.mark.parametrize("tech", sorted(TECHNIQUES))
def test_identity_bit_exact_every_technique(tech, n):
    """An explicitly identity-placed plan produces the byte-identical
    message schedule of the raw plan for every technique."""
    plan = plan_grid(n)
    placed = plan.with_placement(np.arange(plan.capacity))
    mask = np.ones(n, np.float32)
    a = make_aggregator(tech, plan).message_plan(mask, MB)
    b = make_aggregator(tech, placed).message_plan(mask, MB)
    assert [(m.src, m.dst, m.nbytes) for r in a.rounds for m in r] \
        == [(m.src, m.dst, m.nbytes) for r in b.rounds for m in r]


@pytest.mark.parametrize("n", [8, 27, 64, 125])
def test_any_permutation_preserves_bytes(n):
    """Placement moves traffic across links, never changes totals:
    measured bytes match ``topology.mar_bytes`` on the placed plan,
    and (full participation) the unplaced oracle too."""
    rng = np.random.default_rng(n)
    plan = plan_grid(n)
    for trial in range(3):
        placed = plan.with_placement(rng.permutation(plan.capacity))
        mask = np.ones(n, np.float32)
        if trial == 2:                     # one churned trial
            mask[rng.choice(n, size=n // 4, replace=False)] = 0.0
        tr, _ = _run(placed, n, mask=mask, link_params=SHUF)
        oracle = topology.mar_bytes(n, placed, MB, mask=mask)
        assert tr.total_bytes == pytest.approx(oracle)
        if mask.all():
            assert oracle == pytest.approx(
                topology.mar_bytes(n, plan, MB, mask=mask))


def test_engines_agree_under_placement_and_wan_terms():
    """Heap and vector transcripts stay equal for a placed plan on the
    pairwise-WAN regions profile — bytes, times and link seconds."""
    n = 64
    plan = plan_grid(n).with_placement(
        np.random.default_rng(9).permutation(64))
    agg = make_aggregator("mar", plan)
    mask = np.ones(n, np.float32)
    mplan = agg.message_plan(mask, MB)
    aplan = build_array_plan("mar", plan, mask, MB,
                             num_rounds=agg.num_rounds)
    th = NetworkSim(n, "regions", seed=2, link_params=SHUF).run(mplan)
    tv = VectorNetworkSim(n, "regions", seed=2,
                          link_params=SHUF).run(aplan)
    _same_transcripts(th, tv)
    assert th.link_time_stats                       # actually filled
    assert all(v >= 0.0 for v in th.link_time_stats.values())


# ---------------------------------------------------------------------------
# link-seconds evidence (satellites 1-2)
# ---------------------------------------------------------------------------

def test_peer_attrs_ground_truth():
    uni = build_link_model("uniform", 8)
    attrs = uni.peer_attrs()
    assert {"up", "down", "lat", "loss"} <= set(attrs)
    assert all(np.asarray(v).shape == (8,) for v in attrs.values())
    reg = build_link_model("regions", 16)
    assert "region" in reg.peer_attrs()
    # shuffle scatters region assignment but keeps the multiset
    shuf = build_link_model("regions", 16, shuffle=True)
    a = reg.peer_attrs()["region"]
    b = shuf.peer_attrs()["region"]
    assert not np.array_equal(a, b)
    assert np.array_equal(np.sort(a), np.sort(b))


def test_link_time_stats_exact_mode_values():
    """Per-link seconds = transfer + both latencies (no queue wait);
    loopbacks bill zero."""
    from repro.core.transport import Message, MessagePlan
    net = NetworkSim(4, "uniform", seed=0)
    up = net.links.peer_attrs()["up"]
    down = net.links.peer_attrs()["down"]
    lat = net.links.peer_attrs()["lat"]
    mplan = MessagePlan("probe", 4, 4,
                        ((Message(0, 1, 1e6), Message(2, 2, 1e6)),))
    tr = net.run(mplan)
    want = 1e6 / min(up[0], down[1]) + lat[0] + lat[1]
    assert tr.link_time_stats[(0, 1)] == pytest.approx(want)
    assert tr.link_time_stats[(2, 2)] == 0.0


def test_link_accounting_peer_mode_seconds():
    n = LINK_DETAIL_MAX_PEERS + 4
    rng = np.random.default_rng(1)
    src = rng.integers(0, n, 2000)
    dst = rng.integers(0, n, 2000)
    nb = rng.integers(1, 100, 2000).astype(float)
    secs = rng.random(2000)
    acct = LinkAccounting(n, n, top_k=8)
    acct.add_batch(src, dst, nb, secs)
    tr = Transcript(technique="mar")
    acct.finalize(tr)
    np.testing.assert_allclose(
        tr.tx_seconds_by_peer,
        np.bincount(src, weights=secs, minlength=n))
    np.testing.assert_allclose(
        tr.rx_seconds_by_peer,
        np.bincount(dst, weights=secs, minlength=n))
    # the seconds top-k rides the byte top-k's key set
    assert set(tr.link_time_stats) == set(tr.bytes_by_link)


def test_estimator_fallback_derives_from_bytes_and_finish():
    """Without link_time_stats the estimator apportions each sender's
    finish time over its outgoing links by byte share."""
    est = LinkQualityEstimator(3)
    tr = SimpleNamespace(
        link_time_stats={},
        bytes_by_link={(0, 1): 100.0, (0, 2): 300.0, (1, 1): 50.0},
        peer_finish_s=np.array([4.0, 1.0, 0.0]))
    est.update(tr)
    cost = est.cost_to(np.array([1, 2]))
    # sender 0: 4s over 400B -> 0.01 s/B on both outgoing links
    assert cost[0, 0] == pytest.approx(0.01)
    assert cost[0, 1] == pytest.approx(0.01)
    assert np.isnan(cost[1, 0])        # loopback carries no evidence
    assert est.n_links == 2


def test_estimator_prefers_measured_seconds():
    est = LinkQualityEstimator(2)
    tr = SimpleNamespace(
        link_time_stats={(0, 1): 2.0},
        bytes_by_link={(0, 1): 100.0},
        peer_finish_s=np.array([99.0, 0.0]))
    est.update(tr)
    assert est.cost_to(np.array([1]))[0, 0] == pytest.approx(0.02)


# ---------------------------------------------------------------------------
# the clustered policy (the payoff half)
# ---------------------------------------------------------------------------

def _probed_policy(n, seed=0, **kw):
    net = NetworkSim(n, "regions", seed=seed, link_params=SHUF)
    plan = plan_grid(n)
    policy = ClusteredPlacement(plan, seed=seed, **kw)
    calls = {"n": 0}

    def prober(mplan):
        calls["n"] += 1
        assert mplan.technique == "placement_probe"
        return net.run(mplan)

    policy.bind_prober(prober)
    return net, plan, policy, calls


def test_clustered_recovers_ground_truth_regions():
    net, plan, policy, calls = _probed_policy(64)
    target = policy.observe(0, None, plan)
    assert calls["n"] == 1                # sparse evidence -> probed
    assert target is not None and target.placement is not None
    truth = net.links.peer_attrs()["region"]
    # perfect purity: every learned cluster sits in one region
    for c in np.unique(policy.labels):
        assert np.unique(truth[policy.labels == c]).size == 1
    # and the permutation packs each region contiguously
    slot_region = np.empty(64, np.int64)
    slot_region[np.asarray(target.placement)[:64]] = truth
    changes = int(np.sum(np.diff(slot_region) != 0))
    assert changes == np.unique(truth).size - 1


def test_clustered_beats_random_in_seconds():
    """The acceptance inequality, small scale: on shuffled regions the
    learned placement is strictly faster than a random one and than
    raw indices (deterministic sim, so one iteration decides)."""
    net, plan, policy, _ = _probed_policy(64)
    target = policy.observe(0, None, plan)
    big = 2_000_000                        # bandwidth-bound transfers
    t_clustered, _ = _run(target, 64, link_params=SHUF, mb=big)
    t_identity, _ = _run(plan, 64, link_params=SHUF, mb=big)
    rand = plan.with_placement(
        np.random.default_rng(17).permutation(64))
    t_random, _ = _run(rand, 64, link_params=SHUF, mb=big)
    assert t_clustered.iteration_s < 0.8 * t_random.iteration_s
    assert t_clustered.iteration_s < 0.8 * t_identity.iteration_s
    assert t_clustered.total_bytes == t_random.total_bytes \
        == t_identity.total_bytes


def test_clustered_is_stable_and_rate_limited():
    net, plan, policy, calls = _probed_policy(27, interval=8)
    target = policy.observe(0, None, plan)
    assert target is not None and calls["n"] == 1
    # same evidence, inside the interval: no new probe, no proposal
    assert policy.observe(1, None, target) is None
    assert calls["n"] == 1


def test_rebind_reemits_without_reprobing():
    """After an adaptive-M dims change the cached labels re-emit the
    permutation for the new grid — no fresh probe round."""
    net, plan, policy, calls = _probed_policy(64)
    first = policy.observe(0, None, plan)
    assert first is not None
    new_dims = GridPlan(64, (4, 4, 4))
    policy.rebind(new_dims)
    again = policy.observe(1, None, new_dims)
    assert calls["n"] == 1
    assert again is not None and again.dims == (4, 4, 4)
    assert again.placement is not None
    # same labels -> same packing on the new grid
    assert again.placement == tuple(
        int(s) for s in cluster_permutation(policy.labels))


def test_rebind_resets_on_membership_change():
    net, plan, policy, calls = _probed_policy(64)
    policy.observe(0, None, plan)
    policy.rebind(plan_grid(27))
    assert policy.labels is None
    assert policy.estimator.n_peers == 27


def test_probe_plan_shape():
    lm = np.array([0, 5])
    mplan = probe_plan(12, lm, probe_bytes=1000.0)
    assert len(mplan.rounds) == 4          # broadcast+gather per lm
    assert all(len(r) == 11 for r in mplan.rounds)
    assert mplan.technique == "placement_probe"


def test_registry_contents():
    assert {"identity", "random", "clustered"} <= set(PLACEMENTS)
    pol = build_placement("identity", plan_grid(8))
    placed = plan_grid(8).with_placement(np.array([1, 0, 2, 3, 4,
                                                   5, 6, 7]))
    # identity clears a stray placement; random proposes exactly once
    assert pol.observe(0, None, placed) == plan_grid(8)
    rnd = build_placement("random", plan_grid(8), seed=3)
    prop = rnd.observe(0, None, plan_grid(8))
    assert prop is not None and prop.placement is not None
    assert rnd.observe(1, None, prop) is None
