"""MAR aggregation semantics: exactness, churn masks, backend parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import mar_allreduce as mar
from repro.core.moshpit import GridPlan, plan_grid


def _state(n, dim=7, seed=0):
    x = np.random.default_rng(seed).normal(size=(n, dim)).astype(np.float32)
    return {"x": jnp.asarray(x)}


def test_exact_global_average_125():
    """Paper §2.3: exact average after d rounds when N = M^d."""
    p = plan_grid(125)
    s = _state(125)
    out = mar.mar_aggregate_sim(s, p)
    gm = jnp.mean(s["x"], 0, keepdims=True)
    np.testing.assert_allclose(out["x"], jnp.broadcast_to(gm, (125, 7)),
                               atol=1e-5)


@pytest.mark.parametrize("n", [8, 16, 27, 64])
def test_exactness_various_grids(n):
    p = plan_grid(n)
    s = _state(n)
    out = mar.mar_aggregate_sim(s, p)
    gm = jnp.mean(s["x"], 0)
    assert float(jnp.max(jnp.abs(out["x"] - gm[None]))) < 1e-5


def test_fewer_rounds_is_approximate():
    """Fig. 11: fewer rounds -> approximate average that still contracts."""
    p = plan_grid(125)
    s = _state(125)
    gm = jnp.mean(s["x"], 0, keepdims=True)
    d0 = float(jnp.mean(jnp.sum((s["x"] - gm) ** 2, -1)))
    out1 = mar.mar_aggregate_sim(s, p, num_rounds=1)
    d1 = float(jnp.mean(jnp.sum((out1["x"] - gm) ** 2, -1)))
    out2 = mar.mar_aggregate_sim(s, p, num_rounds=2)
    d2 = float(jnp.mean(jnp.sum((out2["x"] - gm) ** 2, -1)))
    assert d1 < d0 * 0.5
    assert d2 < d1 * 0.5
    assert d2 > 1e-8  # genuinely approximate


def test_dropout_only_affects_own_group():
    """A dropped peer is excluded from its round-0 group's mean; other
    round-0 groups are untouched."""
    p = GridPlan(16, (4, 4))
    s = _state(16)
    mask = jnp.ones((16,)).at[0].set(0.0)
    out = mar.mar_round_sim(s, p, 0, mask)
    groups = p.groups_for_round(0)
    for g in groups:
        g = g.tolist()
        if 0 in g:
            others = [i for i in g if i != 0]
            expect = jnp.mean(s["x"][jnp.asarray(others)], 0)
        else:
            expect = jnp.mean(s["x"][jnp.asarray(g)], 0)
        for i in g:
            np.testing.assert_allclose(out["x"][i], expect, atol=1e-5)


def test_empty_group_keeps_state():
    p = GridPlan(4, (2, 2))
    s = _state(4)
    mask = jnp.asarray([0.0, 0.0, 1.0, 1.0])
    out = mar.mar_round_sim(s, p, 1, mask)  # round-1 groups: {0,1}, {2,3}
    np.testing.assert_allclose(out["x"][0], s["x"][0])
    np.testing.assert_allclose(out["x"][1], s["x"][1])


def test_virtual_slot_padding():
    """Non-power peer counts embed into a larger grid; result still
    averages over the real peers of each group."""
    p = plan_grid(10)  # capacity > 10
    s = _state(10)
    out = mar.mar_aggregate_sim(s, p)
    assert out["x"].shape == (10, 7)
    assert bool(jnp.all(jnp.isfinite(out["x"])))


def test_device_backend_parity():
    p = GridPlan(27, (3, 3, 3))
    s = _state(27)
    a = mar.mar_aggregate_sim(s, p)
    b = mar.mar_aggregate_device(s, p)
    np.testing.assert_allclose(a["x"], b["x"], atol=1e-5)


def test_one_shot_equals_rounds_full_participation():
    p = GridPlan(16, (4, 4))
    s = _state(16)
    a = mar.mar_aggregate_device(s, p)
    b = mar.mar_aggregate_device(s, p, one_shot=True)
    np.testing.assert_allclose(a["x"], b["x"], atol=1e-5)


def test_all_to_all_baseline():
    s = _state(9)
    out = mar.allreduce_all_to_all_sim(s)
    gm = jnp.mean(s["x"], 0)
    np.testing.assert_allclose(out["x"], jnp.broadcast_to(gm, (9, 7)),
                               atol=1e-6)


@given(st.integers(2, 4), st.integers(1, 3), st.integers(0, 100))
@settings(max_examples=25, deadline=None)
def test_masked_mean_bounded_property(m, d, seed):
    """Group means stay within [min, max] of inputs (convexity)."""
    n = m ** d
    x = np.random.default_rng(seed).normal(size=(n, 3)).astype(np.float32)
    mask = (np.random.default_rng(seed + 1).random(n) < 0.7).astype(
        np.float32)
    p = GridPlan(n, (m,) * d)
    out = mar.mar_aggregate_sim({"x": jnp.asarray(x)}, p,
                                jnp.asarray(mask))["x"]
    assert float(jnp.max(out)) <= x.max() + 1e-5
    assert float(jnp.min(out)) >= x.min() - 1e-5
