"""Secure aggregation of the DP clipping indicator (paper §A.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.moshpit import GridPlan, plan_grid
from repro.core.secagg import (masked_submissions, secure_group_sum,
                               secure_indicator_average)


def test_masks_cancel_in_group_sums():
    plan = GridPlan(16, (4, 4))
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.integers(0, 2, 16), jnp.float32)
    root = jax.random.PRNGKey(7)
    for rnd in range(2):
        sums, cnts = secure_group_sum(b, plan, rnd, root, t=3)
        for g in plan.groups_for_round(rnd):
            want = float(jnp.sum(b[jnp.asarray(g)]))
            for peer in g:
                assert float(sums[peer]) == pytest.approx(want, abs=1e-3)


def test_individual_submissions_are_masked():
    """A submission differs from the true value by O(mask range) —
    the aggregator learns nothing from a single peer's message."""
    plan = GridPlan(16, (4, 4))
    b = jnp.zeros((16,), jnp.float32)
    sub = masked_submissions(b, plan, 0, jax.random.PRNGKey(1), t=0)
    # every peer has 3 partners; at least most submissions move far
    # from the raw value 0
    assert float(jnp.mean(jnp.abs(sub) > 1.0)) > 0.8


def test_submissions_change_per_round_key():
    plan = GridPlan(8, (2, 2, 2))
    b = jnp.ones((8,), jnp.float32)
    s1 = masked_submissions(b, plan, 0, jax.random.PRNGKey(1), t=0)
    s2 = masked_submissions(b, plan, 0, jax.random.PRNGKey(1), t=1)
    assert float(jnp.max(jnp.abs(s1 - s2))) > 1.0


def test_full_depth_average_exact():
    plan = plan_grid(27)
    rng = np.random.default_rng(2)
    b = jnp.asarray(rng.integers(0, 2, 27), jnp.float32)
    avg = secure_indicator_average(b, plan, jax.random.PRNGKey(3), t=5)
    np.testing.assert_allclose(np.asarray(avg),
                               float(jnp.mean(b)) * np.ones(27), atol=1e-3)


def test_dropout_consistency():
    """A dead peer's pairwise masks never enter any submission, so sums
    stay exact over survivors."""
    plan = GridPlan(16, (4, 4))
    rng = np.random.default_rng(4)
    b = jnp.asarray(rng.integers(0, 2, 16), jnp.float32)
    alive = jnp.ones((16,)).at[5].set(0.0)
    sums, cnts = secure_group_sum(b, plan, 0, jax.random.PRNGKey(5), t=0,
                                  alive=alive)
    for g in plan.groups_for_round(0):
        g = g.tolist()
        live = [i for i in g if i != 5]
        want = float(jnp.sum(b[jnp.asarray(live)])) if 5 in g \
            else float(jnp.sum(b[jnp.asarray(g)]))
        for peer in g:
            assert float(sums[peer]) == pytest.approx(want, abs=1e-3)


def test_dp_with_secagg_end_to_end():
    from repro.core.federation import Federation, FederationConfig
    cfg = FederationConfig(n_peers=8, technique="mar", task="text",
                           use_dp=True, use_secagg=True,
                           noise_multiplier=0.3, seed=9)
    fed = Federation(cfg)
    state = fed.init_state()
    clip0 = float(state.dp["clip"])
    for _ in range(4):
        state = fed.step(state)
    assert bool(jnp.all(jnp.isfinite(jax.tree.leaves(state.params)[0])))
    assert float(state.dp["clip"]) != clip0


@given(st.integers(2, 4), st.integers(1, 3), st.integers(0, 500))
@settings(max_examples=15, deadline=None)
def test_secure_average_property(m, d, seed):
    n = m ** d
    plan = GridPlan(n, (m,) * d)
    rng = np.random.default_rng(seed)
    b = jnp.asarray(rng.random(n), jnp.float32)
    avg = secure_indicator_average(b, plan, jax.random.PRNGKey(seed), t=1)
    np.testing.assert_allclose(np.asarray(avg),
                               float(jnp.mean(b)) * np.ones(n), atol=2e-3)
