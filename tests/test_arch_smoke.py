"""Per-architecture smoke tests: reduced configs, one forward + one FL
train step on CPU, asserting shapes and finiteness (brief requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, shape_applicable
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.core.fl_device import (init_fl_state, make_fl_train_step,
                                  make_serve_step)
from repro.core.moshpit import plan_grid
from repro.models.model import Model
from repro.models.transformer import PREFIX_LEN

B, S = 2, 32


def _batch(cfg, rng, batch=B, seq=S):
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, seq)),
                       jnp.int32)
    out = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.frontend != "none":
        p = PREFIX_LEN[cfg.frontend]
        out["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(batch, p, cfg.d_model)), jnp.float32)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    rng = np.random.default_rng(0)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    logits, aux, _ = model.forward(params, batch["tokens"],
                                   prefix_embeds=batch.get("prefix_embeds"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_fl_train_step(arch):
    """One full MAR-FL iteration (2 peers, grid (2,)): loss finite,
    post-aggregation peers agree."""
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    rng = np.random.default_rng(1)
    n_peers = 2
    grid = plan_grid(n_peers)
    state = init_fl_state(model, n_peers, jax.random.PRNGKey(1))
    raw = _batch(cfg, rng, batch=n_peers * 2)
    batch = {k: v.reshape((n_peers, 1, 1, 2) + v.shape[1:])
             for k, v in raw.items()}
    step = jax.jit(make_fl_train_step(model, grid, lr=0.01))
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    p = jax.tree.leaves(state["params"])[0]
    assert bool(jnp.all(jnp.isfinite(p)))
    spread = float(jnp.max(jnp.abs(
        p.astype(jnp.float32) - jnp.mean(p.astype(jnp.float32), 0,
                                         keepdims=True))))
    assert spread < 1e-2, spread


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    cache = model.init_cache(B, max_len=16)
    serve = jax.jit(make_serve_step(model))
    tok = jnp.zeros((B,), jnp.int32)
    for _ in range(3):
        tok, cache = serve(params, cache, tok)
    assert tok.shape == (B,)
    assert int(cache["pos"][0]) == 3


@pytest.mark.parametrize("arch", ["granite-8b", "xlstm-350m",
                                  "zamba2-2.7b", "moonshot-v1-16b-a3b"])
def test_decode_matches_forward(arch):
    """Teacher-forcing parity: step-by-step decode logits == one-shot
    forward logits on the same token prefix."""
    cfg = get_smoke_config(arch)
    if cfg.attn_impl == "flash":
        cfg = __import__("dataclasses").replace(cfg, attn_impl="xla")
    model = Model(cfg)
    rng = np.random.default_rng(3)
    params = model.init(jax.random.PRNGKey(3))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)
    logits, _, _ = model.forward(params, toks)
    cache = model.init_cache(1, max_len=8)
    outs = []
    for i in range(8):
        lg, cache = model.decode_step(params, cache, toks[:, i])
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(logits, np.float32),
                               atol=8e-2, rtol=8e-2)


def test_param_counts_match_published_scale():
    """Full configs land near their names' parameter scale.

    Counts follow the ASSIGNED table dims with this framework's uniform
    SwiGLU FFN convention, which inflates archs whose published variant
    uses a 2-matrix MLP (starcoder2 +~40%, musicgen ~1.8B vs 1.5B) —
    and moonshot's assigned 48L exceeds Moonlight's published 27L
    (~29B total). Documented in DESIGN.md §8.
    """
    expect = {
        "granite-8b": (7e9, 9.5e9),
        "glm4-9b": (8e9, 10.5e9),
        "deepseek-67b": (60e9, 72e9),
        "starcoder2-3b": (2.5e9, 5e9),
        "pixtral-12b": (11e9, 14e9),
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "moonshot-v1-16b-a3b": (25e9, 32e9),
        "xlstm-350m": (0.2e9, 0.6e9),
        "zamba2-2.7b": (2.0e9, 3.3e9),
        "musicgen-medium": (1.2e9, 2.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:,}"


def test_moe_active_params():
    cfg = get_config("kimi-k2-1t-a32b")
    active = cfg.active_param_count()
    assert active < 0.1 * cfg.param_count()
    assert 2.5e10 < active < 4.5e10  # "A32B"


def test_shape_applicability():
    skipped = [(a, s) for a, s, ok in
               __import__("repro.configs.registry",
                          fromlist=["all_cells"]).all_cells(True) if not ok]
    assert len(skipped) == 8  # long_500k on the 8 quadratic archs
    assert all(s == "long_500k" for _, s in skipped)
