"""Superpeer hybrid engine parity + satellites (ISSUE 9 acceptance).

The ``super_sim`` backend must earn its O(rounds) cost model without
giving up the drop-in contract the vector engine established: on every
registered technique at every overlapping N the symbolic
``SuperMessagePlan`` run reproduces the vector engine's transcript
byte-for-byte, and — on per-peer (uniform / wireless) profiles —
*equal*, not merely close, round and per-peer finish times. Lossy
profiles delegate to an internal vector engine with a synced RNG
stream, so even seeded loss + demotion stays exact. The closed-form
group recurrences it leans on are pinned to the materialized engine up
to N=4096, and the opt-in cluster-mean approximation must honor the
error bound it reports.

Satellites covered here: the Federation plan-build memo (hits on
repeated (mask, parity) keys, invalidated by regroup/resize),
placement-aware virtual-slot packing (``cluster_permutation`` with
capacity/align), link-drift re-clustering with the probe path's
rate-limit contract, and placement carry-over across adaptive-M dims
proposals."""
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.adaptive import carry_placement
from repro.core.federation import Federation, FederationConfig
from repro.core.moshpit import GridPlan, plan_grid
from repro.core.placement import (ClusteredPlacement,
                                  LinkQualityEstimator,
                                  cluster_permutation)
from repro.core.transport import build_array_plan, build_super_plan
from repro.core.aggregation import TECHNIQUES, make_aggregator
from repro.runtime.network import build_link_model
from repro.runtime.super_network import (SuperNetworkSim,
                                         approx_link_arrays)
from repro.runtime.transport_base import TRANSPORTS, build_transport
from repro.runtime.vector_network import (VectorNetworkSim,
                                          group_broadcast_seconds,
                                          group_gather_seconds,
                                          mar_group_seconds)

from test_vector_network import MB, _assert_equal_transcripts

STRUCTURED = sorted(set(TECHNIQUES))


def _run_pair(tech, n, mask=None, profile="wireless", seed=0,
              link_params=None, compute_s=None, iters=1, **super_kw):
    """(vector, super) transcript pairs on identical links + plans."""
    plan = plan_grid(n)
    agg = make_aggregator(tech, plan)
    aplan = build_array_plan(tech, plan, mask, MB,
                             num_rounds=agg.num_rounds)
    splan = build_super_plan(tech, plan, mask, MB,
                             num_rounds=agg.num_rounds)
    vec = VectorNetworkSim(n, profile=profile, seed=seed,
                           link_params=link_params)
    sup = SuperNetworkSim(n, profile=profile, seed=seed,
                          link_params=link_params, **super_kw)
    return [(vec.run(aplan, compute_s=compute_s),
             sup.run(splan, compute_s=compute_s))
            for _ in range(iters)]


# ---------------------------------------------------------------------------
# tentpole: transcript parity with the vector engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tech", STRUCTURED)
@pytest.mark.parametrize("n", (64, 125))
@pytest.mark.parametrize("profile", ("uniform", "wireless"))
def test_super_parity_full_participation(tech, n, profile):
    for tv, ts in _run_pair(tech, n, profile=profile, iters=2):
        _assert_equal_transcripts(tv, ts)


@pytest.mark.parametrize("tech", ("mar", "gossip", "hierarchical"))
def test_super_parity_n1024(tech):
    for tv, ts in _run_pair(tech, 1024):
        _assert_equal_transcripts(tv, ts)


@pytest.mark.parametrize("tech", STRUCTURED)
def test_super_parity_under_churn(tech):
    rng = np.random.default_rng(5)
    mask = (rng.random(64) > 0.3).astype(np.float32)
    mask[:2] = 1.0
    for tv, ts in _run_pair(tech, 64, mask=mask):
        _assert_equal_transcripts(tv, ts)


def test_super_parity_compute_skew():
    skew = np.random.default_rng(9).uniform(0.0, 3.0, 64)
    for tv, ts in _run_pair("mar", 64, compute_s=skew):
        _assert_equal_transcripts(tv, ts)


def test_super_parity_seeded_loss_delegates():
    """Lossy profiles route the whole plan through the internal vector
    engine with a synced RNG stream — loss draws, drops and demotion
    land on identical messages across iterations."""
    lp = {"loss": 0.05}
    for tv, ts in _run_pair("mar", 64, link_params=lp, iters=3):
        _assert_equal_transcripts(tv, ts)


def test_super_parity_mkd_prefix():
    plan = plan_grid(27)
    agg = make_aggregator("mar", plan)
    aplan = build_array_plan("mar", plan, None, MB,
                             num_rounds=agg.num_rounds)
    from repro.core.transport import with_mkd_traffic_arrays
    aplan = with_mkd_traffic_arrays(aplan, plan, None, MB, 64.0,
                                    num_rounds=agg.num_rounds)
    splan = build_super_plan("mar", plan, None, MB,
                             num_rounds=agg.num_rounds, use_kd=True,
                             raw_model_bytes=MB, kd_logit_bytes=64.0)
    tv = VectorNetworkSim(27, profile="wireless", seed=1).run(aplan)
    ts = SuperNetworkSim(27, profile="wireless", seed=1).run(splan)
    _assert_equal_transcripts(tv, ts)
    assert ts.kd_bytes > 0


def test_slot_fast_path_parity():
    """Forcing the aggregated accounting mode (``link_budget=0``) at an
    all-binary grid takes the contiguous slot-order path — per-round
    times, finish vector and per-peer seconds must still equal the
    vector engine's, with and without a placement permutation."""
    n = 2048
    plan = plan_grid(n)
    perm = np.random.default_rng(3).permutation(n)
    for p in (plan, plan.with_placement(perm)):
        agg = make_aggregator("mar", p)
        aplan = build_array_plan("mar", p, None, MB,
                                 num_rounds=agg.num_rounds)
        splan = build_super_plan("mar", p, None, MB,
                                 num_rounds=agg.num_rounds)
        tv = VectorNetworkSim(n, profile="wireless", seed=2).run(aplan)
        ts = SuperNetworkSim(n, profile="wireless", seed=2,
                             link_budget=0).run(splan)
        assert ts.total_bytes == tv.total_bytes
        assert ts.round_s == tv.round_s
        assert np.array_equal(ts.peer_finish_s, tv.peer_finish_s)
        assert np.array_equal(np.asarray(ts.tx_seconds_by_peer),
                              np.asarray(tv.tx_seconds_by_peer))
        assert np.array_equal(np.asarray(ts.rx_seconds_by_peer),
                              np.asarray(tv.rx_seconds_by_peer))


def test_small_fleets_keep_link_detail():
    """The message budget only demotes *large* fleets to aggregated
    accounting — at parity-tier N the per-link dict stays populated
    even with a zero budget, so placement estimators keep their
    evidence stream."""
    n = 64
    plan = plan_grid(n)
    splan = build_super_plan("rdfl", plan, None, MB)
    ts = SuperNetworkSim(n, profile="wireless", seed=0,
                         link_budget=0).run(splan)
    aplan = build_array_plan("rdfl", plan, None, MB)
    tv = VectorNetworkSim(n, profile="wireless", seed=0).run(aplan)
    assert ts.bytes_by_link == tv.bytes_by_link
    assert len(ts.bytes_by_link) > 0


def test_super_sim_registered_and_negotiates_plan_format():
    assert "super_sim" in TRANSPORTS
    sim = build_transport("super_sim", 16, profile="uniform", seed=0)
    assert isinstance(sim, SuperNetworkSim)
    assert sim.plan_format == "super"
    assert VectorNetworkSim.plan_format == "array"


def test_super_accepts_foreign_plans():
    """Non-symbolic plans (list or array form) delegate — the backend
    is still a drop-in for callers that built the wrong plan type."""
    plan = plan_grid(27)
    agg = make_aggregator("mar", plan)
    mplan = agg.message_plan(None, MB)
    tv = VectorNetworkSim(27, profile="wireless", seed=4).run(
        build_array_plan("mar", plan, None, MB,
                         num_rounds=agg.num_rounds))
    ts = SuperNetworkSim(27, profile="wireless", seed=4).run(mplan)
    _assert_equal_transcripts(tv, ts)


# ---------------------------------------------------------------------------
# closed-form recurrences: pinned to the materialized engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", (64, 729, 4096))
def test_mar_closed_form_matches_materialized(n):
    plan = plan_grid(n)
    links = build_link_model("wireless", n, seed=7)
    it_s, finish = mar_group_seconds(links, plan, MB)
    aplan = build_array_plan("mar", plan, None, MB)
    tr = VectorNetworkSim(n, links=links).run(aplan)
    assert it_s == tr.iteration_s
    assert np.array_equal(finish, tr.peer_finish_s)


def test_group_gather_broadcast_roundtrip():
    """gather then broadcast over leaf groups: every member's finish
    time is at least the leader's gather finish (causality), and on a
    uniform profile all *receiving* members of a group finish together
    (the leader sends, it doesn't receive — its clock stays at the
    gather finish)."""
    n = 64
    plan = plan_grid(n)
    links = build_link_model("uniform", n, seed=0)
    _, after_gather = group_gather_seconds(links, plan, MB)
    # feed the gather finishes in as compute offsets for the broadcast
    it_s, after_bcast = group_broadcast_seconds(
        links, plan, MB, compute_s=after_gather)
    assert np.all(after_bcast >= after_gather - 1e-12)
    m = plan.dims[-1]
    groups = after_bcast.reshape(-1, m)
    assert np.allclose(groups[:, 1:], groups[:, 1:2])
    assert np.all(groups[:, 0] <= groups[:, 1] + 1e-12)
    assert it_s == float(after_bcast.max())


@pytest.mark.parametrize("fn", (mar_group_seconds,
                                group_gather_seconds,
                                group_broadcast_seconds))
def test_closed_forms_monotone_in_bytes(fn):
    plan = plan_grid(27)
    links = build_link_model("wireless", 27, seed=3)
    prev = -1.0
    for b in (1e3, 1e5, 1e7, 1e9):
        it_s, finish = fn(links, plan, b)
        assert it_s > prev
        assert np.all(finish >= 0.0)
        prev = it_s


def test_approx_honors_reported_error_bound():
    """Cluster-mean link approximation: every round time must land
    within (1 ± delta) of the exact engine's, delta being the bound
    ``approx_link_arrays`` itself reports."""
    n = 64
    plan = plan_grid(n)
    links = build_link_model("wireless", n, seed=11)
    level = plan.depth - 1                      # leaf-pair clusters
    *_, delta = approx_link_arrays(links, plan, level)
    assert 0.0 < delta < 1.0
    agg = make_aggregator("mar", plan)
    exact = VectorNetworkSim(n, links=links).run(
        build_array_plan("mar", plan, None, MB,
                         num_rounds=agg.num_rounds))
    approx = SuperNetworkSim(n, links=links, approx_level=level).run(
        build_super_plan("mar", plan, None, MB,
                         num_rounds=agg.num_rounds))
    assert approx.total_bytes == exact.total_bytes    # bytes stay exact
    for a, e in zip(approx.round_s, exact.round_s):
        assert e * (1 - delta) - 1e-12 <= a <= e * (1 + delta) + 1e-12


# ---------------------------------------------------------------------------
# satellite: Federation plan-build memo
# ---------------------------------------------------------------------------

def _fed(transport, **kw):
    cfg = FederationConfig(n_peers=8, technique="mar", task="text",
                           link_profile="wireless",
                           transport=transport, seed=3, **kw)
    return Federation(cfg)


def test_federation_super_transport_matches_heap_and_vector():
    outs = {}
    for backend in ("sim", "vector_sim", "super_sim"):
        fed = _fed(backend)
        state = fed.init_state()
        for _ in range(2):
            state = fed.step(state)
        outs[backend] = (fed.comm_bytes, fed.sim_seconds,
                         fed.last_transcript.n_messages)
    assert outs["super_sim"] == outs["sim"]
    assert outs["vector_sim"] == outs["sim"]


def test_plan_cache_hits_on_stable_membership():
    """Full participation repeats the (mask bytes, iteration parity)
    key every other step — by step 3 the planner must stop paying the
    build cost."""
    fed = _fed("super_sim")
    state = fed.init_state()
    for _ in range(4):
        state = fed.step(state)
    assert fed.plan_cache_misses <= 2      # one per iteration parity
    assert fed.plan_cache_hits >= 2


def test_plan_cache_invalidated_on_regroup_and_resize():
    fed = _fed("super_sim")
    state = fed.init_state()
    state = fed.step(state)
    assert len(fed._plan_cache) > 0
    state = fed.regroup(state, GridPlan(8, (4, 2)))
    assert len(fed._plan_cache) == 0
    state = fed.step(state)
    assert len(fed._plan_cache) > 0
    fed.resize(state, 12)
    assert len(fed._plan_cache) == 0


# ---------------------------------------------------------------------------
# satellite: placement-aware virtual-slot packing
# ---------------------------------------------------------------------------

def test_cluster_permutation_historical_default_bit_exact():
    """capacity=None is the pre-existing peer-only packing: largest
    cluster first, members in index order — pinned element by
    element."""
    labels = np.array([1, 1, 0, 0, 0, 2, 2, 0])
    perm = cluster_permutation(labels)
    # cluster 0 (4 members) -> slots 0..3, cluster 1 -> 4..5, 2 -> 6..7
    assert perm.tolist() == [4, 5, 0, 1, 2, 6, 7, 3]


def test_cluster_permutation_packs_virtuals_at_boundaries():
    """With capacity + align, each short cluster absorbs virtual
    entities up to its own sub-block boundary instead of pulling the
    next cluster across it."""
    labels = np.array([0, 0, 0, 1, 1])          # sizes 3 and 2
    perm = cluster_permutation(labels, capacity=8, align=4)
    assert perm.size == 8
    # cluster 0 -> slots 0..2, virtual 5 pads slot 3; cluster 1 ->
    # slots 4..5, virtuals 6, 7 pad the tail
    assert perm.tolist() == [0, 1, 2, 4, 5, 3, 6, 7]
    # every slot covered exactly once
    assert sorted(perm.tolist()) == list(range(8))


def test_cluster_permutation_capacity_validates():
    with pytest.raises(ValueError):
        cluster_permutation(np.zeros(8, np.int64), capacity=4)


def test_clustered_proposals_cover_full_capacity():
    """On a non-exact grid the policy's proposal assigns every virtual
    slot explicitly (placement length == capacity) and keeps each
    cluster contiguous among real peers."""
    n = 6
    plan = GridPlan(n, (2, 2, 2))               # capacity 8: 2 virtuals
    assert plan.capacity > n
    policy = ClusteredPlacement(plan, seed=0, min_coverage=0.0)
    policy.labels = np.array([0, 1, 0, 1, 0, 1])
    policy._last_cluster_t = 0
    target = policy.observe(1, None, plan)
    assert target is not None
    assert len(target.placement) == plan.capacity
    assert sorted(target.placement) == list(range(plan.capacity))


# ---------------------------------------------------------------------------
# satellite: link-drift re-clustering (rate-limited)
# ---------------------------------------------------------------------------

def _full_evidence_transcript(n, rate, nbytes=1e6):
    """Synthetic all-pairs transcript with per-link seconds-per-byte
    ``rate[s, d]`` — enough coverage that no probe round is needed."""
    stats, links = {}, {}
    for s in range(n):
        for d in range(n):
            if s != d:
                stats[(s, d)] = rate[s, d] * nbytes
                links[(s, d)] = nbytes
    return SimpleNamespace(link_time_stats=stats, bytes_by_link=links,
                           peer_finish_s=np.zeros(n))


def _two_tier_rates(n, scale=1.0):
    rate = np.full((n, n), 1e-4 * scale)
    rate[:n // 2, :n // 2] = 1e-6 * scale
    rate[n // 2:, n // 2:] = 1e-6 * scale
    return rate


def test_drift_statistic_and_mark():
    est = LinkQualityEstimator(4)
    est.update(_full_evidence_transcript(4, np.full((4, 4), 1e-5)))
    assert est.drift() == 0.0                   # no baseline yet
    est.mark()
    assert est.drift() == pytest.approx(0.0)
    est.update(_full_evidence_transcript(4, np.full((4, 4), 3e-5)))
    # accumulated rate doubles: (1 + 3) / 2 bytes-weighted
    assert est.drift() == pytest.approx(1.0)
    est.mark()
    assert est.drift() == pytest.approx(0.0)


def test_drift_triggers_early_recluster():
    n = 8
    plan = plan_grid(n)
    policy = ClusteredPlacement(plan, seed=0, interval=16,
                                drift_threshold=0.5,
                                drift_min_interval=2)
    policy.observe(0, _full_evidence_transcript(n, _two_tier_rates(n)),
                   plan)
    assert policy._last_cluster_t == 0
    # link quality shifts 10x: drift >> threshold, but inside the
    # rate-limit window nothing may fire (probe contract mirrored)
    drifted = _full_evidence_transcript(n, _two_tier_rates(n, 10.0))
    policy.observe(1, drifted, plan)
    assert policy._last_cluster_t == 0          # rate-limited
    policy.observe(2, drifted, plan)
    assert policy._last_cluster_t == 2          # early re-cluster
    # the re-cluster re-marked the baseline: same evidence again stays
    # quiet until the scheduled interval
    policy.observe(4, drifted, plan)
    assert policy._last_cluster_t == 2


def test_no_drift_no_early_recluster():
    n = 8
    plan = plan_grid(n)
    policy = ClusteredPlacement(plan, seed=0, interval=16,
                                drift_threshold=0.5,
                                drift_min_interval=2)
    tr = _full_evidence_transcript(n, _two_tier_rates(n))
    policy.observe(0, tr, plan)
    for t in (2, 5, 9):
        policy.observe(t, tr, plan)
        assert policy._last_cluster_t == 0      # steady links: cadence


# ---------------------------------------------------------------------------
# satellite: placement carry-over across dims proposals
# ---------------------------------------------------------------------------

def test_carry_placement_preserves_slot_order():
    old = plan_grid(8).with_placement(
        np.array([3, 1, 0, 2, 7, 5, 4, 6]))
    new = carry_placement(old, GridPlan(8, (4, 2)))
    assert new.placement is not None
    # peers keep their relative slot order across the dims change
    old_order = np.argsort(old.slot_of(np.arange(8)))
    new_order = np.argsort(new.slot_of(np.arange(8)))
    assert np.array_equal(old_order, new_order)


def test_carry_placement_identity_and_explicit_passthrough():
    old = plan_grid(8)                          # identity placement
    new = GridPlan(8, (4, 2))
    assert carry_placement(old, new) is new
    placed = GridPlan(8, (4, 2)).with_placement(
        np.random.default_rng(0).permutation(8))
    # a proposal that already carries a placement wins
    assert carry_placement(plan_grid(8).with_placement(
        np.array([1, 0, 2, 3, 4, 5, 6, 7])), placed) is placed
