"""Data partitioning, synthetic tasks, and the comm-cost models."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import topology
from repro.core.moshpit import plan_grid
from repro.data.partition import (dirichlet_partition, iid_partition,
                                  partition_stats)
from repro.data.synthetic import classification_task, lm_batch


# ---------------------------------------------------------------------------
# partitioning (the paper's LDA alpha=1.0 non-iid splits)
# ---------------------------------------------------------------------------

@given(st.integers(2, 40), st.floats(0.1, 10.0), st.integers(0, 50))
@settings(max_examples=25, deadline=None)
def test_dirichlet_partition_covers_exactly_once(n_peers, alpha, seed):
    labels = np.random.default_rng(seed).integers(0, 10, size=400)
    shards = dirichlet_partition(labels, n_peers, alpha, seed=seed)
    allidx = np.sort(np.concatenate(shards))
    assert np.array_equal(allidx, np.arange(400))
    assert all(len(s) >= 2 for s in shards)


def test_dirichlet_more_skewed_at_low_alpha():
    labels = np.random.default_rng(0).integers(0, 10, size=4000)
    tv_low = partition_stats(
        dirichlet_partition(labels, 20, alpha=0.1, seed=1), labels)["mean_tv"]
    tv_high = partition_stats(
        dirichlet_partition(labels, 20, alpha=100.0, seed=1),
        labels)["mean_tv"]
    assert tv_low > tv_high


def test_iid_partition():
    shards = iid_partition(100, 7)
    assert np.array_equal(np.sort(np.concatenate(shards)), np.arange(100))


def test_classification_tasks_learnable_stats():
    for name in ("vision", "text"):
        spec, train, test = classification_task(name)
        assert train["x"].shape == (spec.num_train, spec.feature_dim)
        assert set(np.unique(train["y"])) <= set(range(spec.num_classes))


def test_lm_batch_shapes():
    b = lm_batch(vocab_size=128, batch=4, seq_len=16)
    assert b["tokens"].shape == (4, 16)
    assert b["labels"].shape == (4, 16)
    assert int(b["tokens"].max()) < 128


# ---------------------------------------------------------------------------
# comm-cost models (Fig. 1 backbone)
# ---------------------------------------------------------------------------

def test_scaling_classes():
    """MAR grows ~N log N; AR grows ~N^2 (ratio test at two sizes)."""
    mb = 1_000
    for n1, n2 in [(64, 512)]:
        p1, p2 = plan_grid(n1), plan_grid(n2)
        mar1 = topology.iteration_bytes("mar", n1, mb, p1)
        mar2 = topology.iteration_bytes("mar", n2, mb, p2)
        ar1 = topology.iteration_bytes("ar", n1, mb)
        ar2 = topology.iteration_bytes("ar", n2, mb)
        assert ar2 / ar1 > 0.8 * (n2 / n1) ** 2
        assert mar2 / mar1 < 3.0 * (n2 / n1) * np.log2(n2) / np.log2(n1)


def test_fig11_approx_aggregation_33pct():
    """Group size 3 / 4 rounds at 125 peers cuts MAR bytes ~33% (Fig 11)."""
    mb = 1_000
    exact = topology.iteration_bytes(
        "mar", 125, mb, plan_grid(125, group_size=5))
    approx = topology.iteration_bytes(
        "mar", 125, mb, plan_grid(125, group_size=3), num_rounds=4)
    assert approx / exact == pytest.approx(2 / 3, rel=0.05)


def test_butterfly_mode_cheaper():
    p = plan_grid(125)
    naive = topology.iteration_bytes("mar", 125, 1000, p)
    btf = topology.iteration_bytes("mar", 125, 1000, p, mode="butterfly")
    assert btf < 0.5 * naive


def test_latency_rounds():
    p = plan_grid(125)
    assert topology.iteration_latency_rounds("mar", 125, p) == 3
    assert topology.iteration_latency_rounds("rdfl", 125) == 124
    assert topology.iteration_latency_rounds("ar", 125) == 1


def test_control_plane_negligible():
    n = 125
    ctrl = topology.control_plane_bytes(n)
    data = topology.iteration_bytes("mar", n, 100_000, plan_grid(n))
    assert ctrl < 0.01 * data


def test_complexity_table_shape():
    rows = topology.complexity_table(1000, peer_counts=(16, 64))
    techs = {"fedavg", "hierarchical", "mar", "gossip", "rdfl", "ar"}
    assert len(rows) == 2 * len(techs)
    assert {r["technique"] for r in rows} == techs
