"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _arr(shape, dtype, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale).astype(dtype)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,kvh,d", [
    (2, 128, 8, 2, 32),   # GQA 4:1
    (1, 256, 4, 4, 64),   # MHA
    (2, 64, 8, 1, 16),    # MQA
    (1, 96, 6, 2, 32),    # non-power seq
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, s, h, kvh, d, dtype, causal):
    q, k, v = (_arr((b, s, h, d), dtype),
               _arr((b, s, kvh, d), dtype), _arr((b, s, kvh, d), dtype))
    out = ops.flash_attention(q, k, v, causal=causal)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=_tol(dtype) * 4, rtol=_tol(dtype))


def test_flash_attention_cross_lengths():
    q = _arr((1, 64, 4, 32), jnp.float32)
    k = _arr((1, 128, 4, 32), jnp.float32)
    v = _arr((1, 128, 4, 32), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=False)
    expect = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out, expect, atol=1e-4)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,kvh,d", [
    (2, 256, 8, 2, 32), (1, 512, 4, 4, 64), (3, 128, 6, 1, 16),
])
def test_decode_attention_sweep(b, s, h, kvh, d, dtype):
    q = _arr((b, h, d), dtype)
    kc, vc = _arr((b, s, kvh, d), dtype), _arr((b, s, kvh, d), dtype)
    lens = jnp.asarray(RNG.integers(1, s + 1, size=(b,)), jnp.int32)
    out = ops.decode_attention(q, kc, vc, lens)
    expect = ref.decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=_tol(dtype) * 4, rtol=_tol(dtype))


def test_decode_attention_length_one():
    q = _arr((2, 4, 16), jnp.float32)
    kc, vc = _arr((2, 64, 2, 16), jnp.float32), _arr((2, 64, 2, 16),
                                                     jnp.float32)
    lens = jnp.asarray([1, 64], jnp.int32)
    out = ops.decode_attention(q, kc, vc, lens)
    expect = ref.decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(out, expect, atol=1e-4)


@pytest.mark.parametrize("s,block_k", [(98, 64), (1030, 512), (7, 8),
                                       (513, 512)])
def test_decode_attention_odd_lengths(s, block_k):
    """Regression (ISSUE 8 satellite): non-power-of-two caches used to
    shrink the K block via ``while s % bk: bk //= 2`` — degrading to
    tiny tiles. The fixed path pads the cache view to a block multiple
    and keeps full tiles; results must still match the oracle exactly,
    including a length right at the cache edge."""
    from repro.kernels.decode_attention import decode_attention_fwd
    b, h, kvh, d = 2, 4, 2, 16
    q = _arr((b, h, d), jnp.float32)
    kc, vc = _arr((b, s, kvh, d), jnp.float32), _arr((b, s, kvh, d),
                                                     jnp.float32)
    lens = jnp.asarray([s, max(1, s - 3)], jnp.int32)
    out = decode_attention_fwd(q, kc, vc, lens, block_k=block_k,
                               interpret=True)
    expect = ref.decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# paged decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,nblk,bs,h,kvh,d", [
    (2, 4, 16, 8, 2, 32), (1, 8, 8, 4, 4, 16), (3, 2, 32, 6, 1, 16),
])
def test_paged_decode_attention_sweep(b, nblk, bs, h, kvh, d, dtype):
    nb = 1 + b * nblk
    q = _arr((b, h, d), dtype)
    kp, vp = _arr((nb, bs, kvh, d), dtype), _arr((nb, bs, kvh, d), dtype)
    bt = jnp.asarray(RNG.permutation(np.arange(1, nb)).reshape(b, nblk),
                     jnp.int32)
    lens = jnp.asarray(RNG.integers(1, nblk * bs + 1, size=(b,)), jnp.int32)
    out = ops.paged_decode_attention(q, kp, vp, bt, lens)
    expect = ref.paged_decode_attention_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=_tol(dtype) * 4, rtol=_tol(dtype))


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,nh,s,dk,dv", [
    (2, 3, 128, 16, 32), (1, 2, 64, 8, 8), (2, 1, 96, 32, 16),
])
def test_ssd_scan_sweep(b, nh, s, dk, dv, dtype):
    q = _arr((b, nh, s, dk), dtype)
    k = _arr((b, nh, s, dk), dtype, scale=0.3)
    v = _arr((b, nh, s, dv), dtype)
    a = -jnp.asarray(RNG.uniform(0.01, 0.5, size=(b, nh, s)), jnp.float32)
    h0 = _arr((b, nh, dk, dv), jnp.float32, scale=0.1)
    y, hf = ops.ssd_scan(q, k, v, a, h0)
    yr, hfr = ref.ssd_scan_ref(q, k, v, a, h0)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(yr, np.float32),
        atol=_tol(dtype) * 8, rtol=_tol(dtype) * 4)
    np.testing.assert_allclose(hf, hfr, atol=_tol(dtype) * 8,
                               rtol=_tol(dtype) * 4)


def test_ssd_scan_matches_training_reference():
    """The Pallas kernel, the chunked jnp path, and the sequential oracle
    agree (train-path consistency)."""
    from repro.models.ssm import chunked_linear_scan
    q = _arr((1, 2, 64, 8), jnp.float32)
    k = _arr((1, 2, 64, 8), jnp.float32, scale=0.3)
    v = _arr((1, 2, 64, 16), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.01, 0.3, size=(1, 2, 64)), jnp.float32)
    h0 = jnp.zeros((1, 2, 8, 16), jnp.float32)
    y1, h1 = ops.ssd_scan(q, k, v, a, h0)
    y2, h2 = chunked_linear_scan(q, k, v, a, h0, chunk=16)
    y3, h3 = ref.ssd_scan_ref(q, k, v, a, h0)
    np.testing.assert_allclose(y1, y3, atol=1e-4)
    np.testing.assert_allclose(y2, y3, atol=1e-4)
    np.testing.assert_allclose(h1, h3, atol=1e-4)
    np.testing.assert_allclose(h2, h3, atol=1e-4)


# ---------------------------------------------------------------------------
# group mean
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("g,m,d", [(4, 5, 512), (8, 3, 96), (2, 2, 2048)])
def test_group_mean_sweep(g, m, d, dtype):
    x = _arr((g, m, d), dtype)
    mask = jnp.asarray(RNG.random((g, m)) < 0.7, jnp.float32)
    out = ops.group_mean(x, mask)
    expect = ref.group_mean_ref(x, mask)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_group_mean_empty_group_keeps_values():
    x = _arr((2, 3, 64), jnp.float32)
    mask = jnp.zeros((2, 3)).at[1].set(1.0)
    out = ops.group_mean(x, mask)
    np.testing.assert_allclose(out[0], x[0], atol=1e-6)


@given(st.integers(1, 4), st.integers(2, 5), st.integers(1, 6),
       st.integers(0, 10 ** 6))
@settings(max_examples=20, deadline=None)
def test_group_mean_property(g, m, dpow, seed):
    """Hypothesis: kernel == oracle for arbitrary shapes/masks."""
    d = 2 ** dpow
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(g, m, d)), jnp.float32)
    mask = jnp.asarray(r.integers(0, 2, size=(g, m)), jnp.float32)
    out = ops.group_mean(x, mask)
    expect = ref.group_mean_ref(x, mask)
    np.testing.assert_allclose(out, expect, atol=1e-5)


# ---------------------------------------------------------------------------
# the jnp flash custom-vjp (training attention) vs oracle incl. grads
# ---------------------------------------------------------------------------

def test_flash_custom_vjp_grads():
    from repro.models.attention_flash import flash_attention
    b, s, h, kvh, d = 1, 64, 4, 2, 16
    q, k, v = (_arr((b, s, h, d), jnp.float32),
               _arr((b, s, kvh, d), jnp.float32),
               _arr((b, s, kvh, d), jnp.float32))

    def loss_flash(q, k, v):
        return jnp.sum(flash_attention(q, k, v, True, 16, 32) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(ref.flash_attention_ref(q, k, v, True)
                       .astype(jnp.float32) ** 2)

    gf = jax.grad(loss_flash, (0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, (0, 1, 2))(q, k, v)
    for a, b_ in zip(gf, gr):
        np.testing.assert_allclose(a, b_, atol=5e-4, rtol=1e-3)
