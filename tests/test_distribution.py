"""Sharding rules + mesh distribution — run in subprocesses so the forced
host-device count never leaks into the rest of the suite."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharding_rules_megatron_layout():
    out = _run("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.runtime.sharding import make_shard_plan, state_shardings
        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4),
                    ("data", "model"))
        plan = make_shard_plan(mesh, ("data",))
        tree = {
            "wq": jnp.zeros((2, 64, 128)),     # peer-stacked col-parallel
            "wo": jnp.zeros((2, 128, 64)),     # row-parallel
            "wd": jnp.zeros((2, 8, 16, 64)),   # MoE [P, E, ff, d]
            "norm1": jnp.zeros((2, 64)),
            "tok": jnp.zeros((2, 256, 64)),
        }
        sh = state_shardings(tree, plan, head_dim=32, num_heads=4,
                             num_kv_heads=4)
        print("wq", sh["wq"].spec)
        print("wo", sh["wo"].spec)
        print("wd", sh["wd"].spec)
        print("norm1", sh["norm1"].spec)
        print("tok", sh["tok"].spec)
    """)
    assert "wq PartitionSpec('data', None, 'model')" in out
    assert "wo PartitionSpec('data', 'model'" in out
    assert "wd PartitionSpec('data', 'model'" in out       # EP on E=8%4==0
    assert "tok PartitionSpec('data', 'model'" in out      # vocab-parallel


def test_fl_step_on_mesh_matches_single_device():
    """The sharded FL train step produces the same loss as unsharded."""
    out = _run("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.configs.registry import get_smoke_config
        from repro.core.fl_device import init_fl_state, make_fl_train_step
        from repro.core.moshpit import mesh_grid_plan
        from repro.models.model import Model
        from repro.runtime.sharding import (make_shard_plan,
                                            state_shardings,
                                            batch_shardings)
        cfg = get_smoke_config("granite-8b")
        model = Model(cfg)
        mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2),
                    ("data", "model"))
        plan = make_shard_plan(mesh, ("data",))
        grid = mesh_grid_plan([4])
        state = init_fl_state(model, 4, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4,1,1,2,32)),
                           jnp.int32)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, -1)}
        step = make_fl_train_step(model, grid, lr=0.01)
        # unsharded reference
        s1, m1 = jax.jit(step)(state, batch)
        # sharded
        in_sh = (state_shardings(state, plan, head_dim=cfg.head_dim,
                                 num_heads=cfg.num_heads,
                                 num_kv_heads=cfg.num_kv_heads),
                 batch_shardings(batch, plan))
        with mesh:
            s2, m2 = jax.jit(step, in_shardings=in_sh)(state, batch)
        print("loss1", float(m1["loss"]))
        print("loss2", float(m2["loss"]))
        d = abs(float(m1["loss"]) - float(m2["loss"]))
        assert d < 1e-3, d
        print("PARITY OK")
    """)
    assert "PARITY OK" in out


def test_mar_device_collective_pattern():
    """MAR on a mesh lowers to replica-grouped all-reduces whose group
    size matches the grid dims (not a full all-reduce per round)."""
    out = _run("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.core import mar_allreduce as mar
        from repro.core.moshpit import GridPlan
        mesh = Mesh(np.asarray(jax.devices()).reshape(8), ("data",))
        plan = GridPlan(8, (2, 4))
        x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
        sh = NamedSharding(mesh, P("data", None))
        with mesh:
            c = jax.jit(lambda s: mar.mar_aggregate_device({"x": s}, plan),
                        in_shardings={"x": sh} if False else sh,
                        out_shardings=sh).lower(x).compile()
        txt = c.as_text()
        import re
        groups = re.findall(r"replica_groups=\\[(\\d+),(\\d+)\\]", txt)
        print("groups:", groups)
        from repro.runtime.hlo_analysis import analyze_text
        r = analyze_text(txt)
        print("collective counts:",
              {k: v for k, v in r["collective_counts"].items() if v})
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_smallest_cell_512():
    """Full-scale dry-run of one cell on the 512-device multi-pod mesh."""
    out = _run("""
        from repro.launch.dryrun import dryrun_cell
        rec = dryrun_cell("xlstm-350m", "decode_32k", True, verbose=False)
        assert rec["status"] == "ok", rec
        print("STATUS", rec["status"], rec["chips"])
    """, devices=512, timeout=1800)
    assert "STATUS ok 512" in out
