"""Peer lifecycle runtime: churn models, event flow, trace replay, and
mid-run elastic regrouping (grow 8->12, shrink 16->9) without restart."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.federation import Federation, FederationConfig
from repro.runtime.fault import failure_impact
from repro.runtime.fault import HealthTracker, StragglerPolicy
from repro.runtime.lifecycle import (CHURN_MODELS, MembershipEvent,
                                     PeerLifecycle, build_churn_model,
                                     build_lifecycle, load_trace,
                                     save_trace)


# ---------------------------------------------------------------------------
# churn models
# ---------------------------------------------------------------------------

def test_registry_contents():
    assert {"bernoulli", "sessions", "correlated", "wireless",
            "trace"} <= set(CHURN_MODELS)
    with pytest.raises(ValueError):
        build_churn_model("carrier-pigeon", 8)


def test_bernoulli_replays_legacy_sample_masks():
    """The degenerate case is bit-identical to the retired
    Federation.sample_masks — pre-lifecycle runs replay exactly."""
    cfg = FederationConfig(n_peers=16, technique="mar", task="text",
                           participation_rate=0.6, dropout_rate=0.3,
                           seed=9)
    fed = Federation(cfg)
    for t in range(6):
        u0, a0 = fed.sample_masks(
            np.random.default_rng(cfg.seed * 100003 + t))
        tick = fed.lifecycle.tick(t)
        np.testing.assert_array_equal(u0, tick.u)
        np.testing.assert_array_equal(a0, tick.a)


def test_sessions_availability_is_time_correlated():
    """Markov sessions flip far less often than i.i.d. masks at the
    same long-run availability — the whole point of the model."""
    n, iters = 32, 200
    sess = build_churn_model("sessions", n, seed=3, mean_up=10.0,
                             mean_down=5.0)
    rate = 10.0 / 15.0
    iid = build_churn_model("bernoulli", n, seed=3,
                            participation_rate=rate)

    def flips(model):
        prev, total, up = None, 0, 0.0
        for t in range(iters):
            u = model.tick(t).u
            if prev is not None:
                total += int(np.sum(prev != u))
            up += float(u.mean())
            prev = u
        return total, up / iters

    sess_flips, sess_avail = flips(sess)
    iid_flips, _ = flips(iid)
    assert sess_flips < 0.5 * iid_flips
    assert 0.4 < sess_avail < 0.9          # near mean_up/(mean_up+down)


def test_correlated_outages_take_whole_regions_down():
    model = build_churn_model("correlated", 16, seed=5, n_regions=4,
                              outage_rate=0.5, mean_outage=2.0,
                              base_dropout=0.0)
    region = model.region_of()
    saw_outage = False
    for t in range(30):
        u = model.tick(t).u
        if u.sum() == 1.0:
            continue  # all regions out: the >=1-peer fallback fired
        for r in range(4):
            vals = u[region == r]
            assert vals.min() == vals.max()   # region fails as one unit
            if vals.max() == 0.0:
                saw_outage = True
    assert saw_outage


def test_wireless_stragglers_update_but_miss_aggregation():
    model = build_churn_model("wireless", 16, seed=2, slow_frac=0.25,
                              slow_factor=6.0, jitter=0.05)
    saw_straggler = False
    for t in range(10):
        tick = model.tick(t)
        assert tick.u.all()                   # everyone ran the update
        assert tick.durations is not None
        if (tick.a == 0).any():
            saw_straggler = True
            slow = np.flatnonzero(tick.a == 0)
            assert tick.durations[slow].min() > \
                np.median(tick.durations[tick.a > 0])
    assert saw_straggler


def test_trace_roundtrip_and_replay(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    events = [MembershipEvent(0, "down", (1, 2)),
              MembershipEvent(2, "up", (1,)),
              MembershipEvent(3, "straggle", (0,)),
              MembershipEvent(4, "join", (8, 9))]
    save_trace(path, events)
    assert load_trace(path) == events
    with open(path) as f:                     # plain JSONL on disk
        assert json.loads(f.readline())["kind"] == "down"

    lc = build_lifecycle("trace", 8, churn_params={"path": path})
    t0 = lc.tick(0)
    np.testing.assert_array_equal(t0.u[[1, 2]], [0.0, 0.0])
    t2 = lc.tick(2)
    assert t2.u[1] == 1.0 and t2.u[2] == 0.0
    t3 = lc.tick(3)
    assert t3.u[0] == 1.0 and t3.a[0] == 0.0  # straggle: U_t yes, A_t no
    t4 = lc.tick(4)
    assert t4.resize_to == 10 and t4.u.shape == (10,)


def test_lifecycle_recorded_run_replays_identically(tmp_path):
    """Record a sessions run's event stream, replay it through the
    trace model: identical masks at every iteration."""
    n, iters = 12, 25
    rec = build_lifecycle("sessions", n, seed=7,
                          churn_params={"mean_up": 5.0, "mean_down": 2.0})
    recorded = [rec.tick(t) for t in range(iters)]
    path = str(tmp_path / "rec.jsonl")
    save_trace(path, rec.event_log)
    rep = build_lifecycle("trace", n, churn_params={"path": path})
    for t in range(iters):
        tick = rep.tick(t)
        np.testing.assert_array_equal(recorded[t].u, tick.u, err_msg=str(t))


@pytest.mark.parametrize("scenario,params,health_timeout", [
    ("bernoulli", {"participation_rate": 0.6, "dropout_rate": 0.3}, None),
    ("sessions", {"mean_up": 4.0, "mean_down": 3.0}, 3.0),
])
def test_event_log_is_canonical_replayable(tmp_path, scenario, params,
                                           health_timeout):
    """Regression: the event_log records deltas of the FINAL masks —
    i.i.d. models and health-tracked runs (DEAD suppression included)
    replay exactly, not just session models."""
    n, iters = 10, 20
    health = (HealthTracker(n, timeout_s=health_timeout)
              if health_timeout else None)
    rec = build_lifecycle(scenario, n, seed=5, churn_params=params,
                          health=health)
    ticks = [rec.tick(t) for t in range(iters)]
    path = str(tmp_path / "c.jsonl")
    save_trace(path, rec.event_log)
    rep = build_lifecycle("trace", n, churn_params={"path": path})
    for t in range(iters):
        tick = rep.tick(t)
        np.testing.assert_array_equal(ticks[t].u, tick.u, err_msg=str(t))
        np.testing.assert_array_equal(ticks[t].a, tick.a, err_msg=str(t))


def test_correlated_resize_below_region_count():
    """Regression: shrinking under n_regions used to leave _remaining
    at the old length and crash the next tick on a broadcast error."""
    lc = build_lifecycle("correlated", 16,
                         churn_params={"n_regions": 4, "outage_rate": 0.3},
                         schedule=((2, 3),))
    for t in range(6):
        tick = lc.tick(t)
    assert lc.n_peers == 3 and tick.u.shape == (3,)


def test_joiners_not_swept_dead_on_arrival():
    """Regression: joining peers' heartbeat baseline is the join time,
    not iteration 0 — a late joiner must not be timeout-dead at birth."""
    lc = build_lifecycle("bernoulli", 4, participation_rate=0.5,
                         health=HealthTracker(4, timeout_s=5.0),
                         schedule=((20, 6),))
    for t in range(25):
        tick = lc.tick(t)
        assert not any(e.kind == "dead" and any(p >= 4 for p in e.peers)
                       for e in tick.events), t


# ---------------------------------------------------------------------------
# lifecycle runtime: health + deadlines as event consumers
# ---------------------------------------------------------------------------

def test_health_sweep_marks_silent_peer_dead():
    """A peer the model keeps down longer than the timeout is DEAD; it
    revives once it heartbeats again."""
    path_events = [MembershipEvent(0, "down", (3,)),
                   MembershipEvent(6, "up", (3,))]
    lc = build_lifecycle("trace", 6, churn_params={"events": path_events},
                         health=HealthTracker(6, timeout_s=3.0))
    kinds = []
    for t in range(8):
        tick = lc.tick(t)
        kinds.extend(e.kind for e in tick.events)
        if t in (4, 5):
            assert tick.u[3] == 0.0
        if t == 7:
            assert tick.u[3] == 1.0           # heartbeat revived it
    assert "dead" in kinds


def test_straggler_policy_consumes_reported_durations():
    class _SlowPeer(CHURN_MODELS["bernoulli"]):
        def tick(self, t):
            tick = super().tick(t)
            dur = np.ones(self.n_peers)
            dur[2] = 50.0
            tick.durations = dur
            return tick

    lc = PeerLifecycle(_SlowPeer(8, seed=0),
                       straggler=StragglerPolicy(k_std=2.0,
                                                 min_deadline_s=0.0))
    tick = lc.tick(0)
    assert tick.u[2] == 1.0 and tick.a[2] == 0.0
    assert any(e.kind == "straggle" and 2 in e.peers
               for e in tick.events)


def test_lifecycle_never_goes_fully_silent():
    lc = build_lifecycle("bernoulli", 4, participation_rate=0.0,
                         dropout_rate=1.0)
    for t in range(5):
        tick = lc.tick(t)
        assert tick.u.sum() >= 1 and tick.a.sum() >= 1


# ---------------------------------------------------------------------------
# mid-run elastic regrouping (the acceptance scenarios)
# ---------------------------------------------------------------------------

def _leaf0(tree):
    return jax.tree.leaves(tree)[0]


def _assert_peer_axis(tree, n):
    for leaf in jax.tree.leaves(tree):
        assert leaf.shape[0] == n, leaf.shape


def test_elastic_grow_8_to_12_midrun():
    cfg = FederationConfig(n_peers=8, technique="mar", task="text",
                           resize_schedule=((3, 12),),
                           async_aggregation=True, compress="int8_ef",
                           seed=0)
    fed = Federation(cfg)
    state = fed.init_state()
    for _ in range(3):
        state = fed.step(state)
    state = fed.step(state)                    # iteration 3: resize fires

    assert fed.cfg.n_peers == 12
    assert fed.plan.n_peers == 12 and fed.plan.capacity >= 12
    _assert_peer_axis(state.params, 12)
    _assert_peer_axis(state.momentum, 12)
    # wire-stage state resized in place alongside
    _assert_peer_axis(state.pipe["int8_ef"]["ref"], 12)
    _assert_peer_axis(state.pipe["async"]["pending"]["agg"]["p"], 12)
    assert fed.data_x.shape[0] == 12

    # failure impact reflects the new plan's geometry
    impact = failure_impact(fed.plan, [0])
    assert set(impact) == {f"round_{g}_groups_touched"
                           for g in range(fed.plan.depth)}

    for _ in range(3):                         # converges post-resize
        state = fed.step(state)
    for leaf in jax.tree.leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_elastic_shrink_16_to_9_midrun_preserves_survivors():
    cfg = FederationConfig(n_peers=16, technique="mar", task="text",
                           seed=1)
    fed = Federation(cfg)
    state = fed.init_state()
    for _ in range(3):
        state = fed.step(state)
    before = jax.tree.map(np.asarray, state.params)
    before_m = jax.tree.map(np.asarray, state.momentum)

    resized = fed.resize(state, 9)             # direct mid-run call
    assert fed.cfg.n_peers == 9
    assert fed.plan.dims == (3, 3)             # elastic_replan refactored
    _assert_peer_axis(resized.params, 9)
    _assert_peer_axis(resized.momentum, 9)
    assert fed.data_x.shape[0] == 9

    # surviving peers' params/momentum are preserved BIT-EXACT
    for b, a in zip(jax.tree.leaves(before),
                    jax.tree.leaves(resized.params)):
        np.testing.assert_array_equal(b[:9], np.asarray(a))
    for b, a in zip(jax.tree.leaves(before_m),
                    jax.tree.leaves(resized.momentum)):
        np.testing.assert_array_equal(b[:9], np.asarray(a))

    impact = failure_impact(fed.plan, [4])
    assert impact["round_0_groups_touched"] == pytest.approx(1 / 3)
    assert impact["round_1_groups_touched"] == pytest.approx(1 / 3)

    state = resized
    for _ in range(3):
        state = fed.step(state)
    for leaf in jax.tree.leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_elastic_grow_bootstraps_new_peers_from_group_mean():
    cfg = FederationConfig(n_peers=8, technique="mar", task="text",
                           seed=2)
    fed = Federation(cfg)
    state = fed.init_state()
    for _ in range(2):
        state = fed.step(state)
    mean = jax.tree.map(lambda x: np.asarray(jnp.mean(x, 0)),
                        state.params)
    old = jax.tree.map(np.asarray, state.params)
    resized = fed.resize(state, 12)
    for m, o, a in zip(jax.tree.leaves(mean), jax.tree.leaves(old),
                       jax.tree.leaves(resized.params)):
        np.testing.assert_array_equal(o, np.asarray(a)[:8])
        for p in range(8, 12):
            np.testing.assert_allclose(np.asarray(a)[p], m, rtol=1e-6)


def test_elastic_resize_with_dp_stage_resets_bot_marker():
    cfg = FederationConfig(n_peers=8, technique="mar", task="text",
                           use_dp=True, seed=3)
    fed = Federation(cfg)
    state = fed.init_state()
    state = fed.step(state)
    resized = fed.resize(state, 12)
    dp = resized.pipe["dp"]
    assert dp["has_delta"].shape == (12,)
    np.testing.assert_array_equal(np.asarray(dp["has_delta"][8:]),
                                  np.zeros(4))
    _assert_peer_axis(dp["last_global"], 12)
    state = fed.step(resized)                  # still steps cleanly
    for leaf in jax.tree.leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_run_federation_all_builtin_scenarios_complete():
    from repro.core.federation import run_federation
    scenarios = {
        "bernoulli": dict(churn=None, participation_rate=0.7,
                          dropout_rate=0.2),
        "sessions": dict(churn="sessions"),
        "correlated": dict(churn="correlated",
                           churn_params={"n_regions": 2,
                                         "outage_rate": 0.2}),
    }
    for name, kw in scenarios.items():
        cfg = FederationConfig(n_peers=8, technique="mar", task="text",
                               seed=4, **kw)
        hist = run_federation(cfg, 4, eval_every=2)
        assert np.isfinite(hist["accuracy"][-1]), name
        assert hist["comm_bytes"][-1] > 0, name


def test_run_federation_trace_scenario_completes(tmp_path):
    from repro.core.federation import run_federation
    path = str(tmp_path / "t.jsonl")
    save_trace(path, [MembershipEvent(1, "down", (0, 1)),
                      MembershipEvent(3, "up", (0,))])
    cfg = FederationConfig(n_peers=8, technique="mar", task="text",
                           churn="trace", churn_params={"path": path},
                           seed=5)
    hist = run_federation(cfg, 4, eval_every=2)
    assert np.isfinite(hist["accuracy"][-1])
