"""Heap-vs-vectorized engine parity (ISSUE 6 acceptance).

The ``vector_sim`` backend must be a drop-in for the heap ``sim``
backend, not an approximation of it: for every registered technique at
every overlapping N the two produce byte-exact transcripts (totals,
per-round, per-link) and *equal* — not merely close — round and
per-peer finish times, including under churn masks, seeded loss +
demotion, MKD prefix rounds and compute skew. The suite also pins the
array-form planners to the ``Message``-list planners element by
element, the lossless closed-form O(N^2) engines to the materialized
engine, and the aggregated large-N link accounting to the exact mode.
"""
import numpy as np
import pytest

from repro.core import transport
from repro.core.aggregation import TECHNIQUES, build_pipeline, \
    make_aggregator
from repro.core.federation import Federation, FederationConfig
from repro.core.moshpit import plan_grid
from repro.core.transport import (ArrayMessagePlan, build_array_plan,
                                  with_mkd_traffic_arrays)
from repro.runtime.network import NetworkSim, build_link_model
from repro.runtime.transport_base import (LINK_DETAIL_MAX_PEERS,
                                          LinkAccounting, TRANSPORTS,
                                          Transcript, build_transport)
from repro.runtime.vector_network import (VectorNetworkSim,
                                          all_to_all_seconds,
                                          ring_seconds)

MB = 10_000   # model-state bytes per transfer (small, exact in float)

PARITY_NS = (8, 27, 64, 125)


def _plans(tech, n, mask=None, model_bytes=MB):
    plan = plan_grid(n)
    agg = make_aggregator(tech, plan)
    if mask is None:
        mask = np.ones(n, np.float32)
    mplan = agg.message_plan(mask, model_bytes)
    aplan = build_array_plan(tech, plan, mask, model_bytes,
                             num_rounds=agg.num_rounds)
    return mplan, aplan


def _assert_equal_transcripts(th: Transcript, tv: Transcript):
    """Byte-exact AND time-equal — the drop-in contract."""
    assert tv.technique == th.technique
    assert tv.n_messages == th.n_messages
    assert tv.total_bytes == th.total_bytes
    assert tv.bytes_by_round == th.bytes_by_round
    assert tv.bytes_by_link == th.bytes_by_link
    assert tv.kd_bytes == th.kd_bytes
    assert tv.round_s == th.round_s                 # exact, not approx
    assert np.array_equal(tv.peer_finish_s, th.peer_finish_s)
    assert tv.link_time_stats == th.link_time_stats  # seconds, bitwise
    assert np.array_equal(np.asarray(tv.tx_seconds_by_peer),
                          np.asarray(th.tx_seconds_by_peer))
    assert np.array_equal(np.asarray(tv.rx_seconds_by_peer),
                          np.asarray(th.rx_seconds_by_peer))
    assert tv.iteration_s == th.iteration_s
    assert np.array_equal(tv.lost_senders, th.lost_senders)
    assert (sorted((m.src, m.dst, m.nbytes) for m in tv.dropped)
            == sorted((m.src, m.dst, m.nbytes) for m in th.dropped))


def _run_both(mplan, aplan, n, profile="wireless", seed=0,
              link_params=None, compute_s=None, iters=1):
    heap = NetworkSim(n, profile=profile, seed=seed,
                      link_params=link_params)
    vec = VectorNetworkSim(n, profile=profile, seed=seed,
                           link_params=link_params)
    out = []
    for _ in range(iters):
        out.append((heap.run(mplan, compute_s=compute_s),
                    vec.run(aplan, compute_s=compute_s)))
    return out


# ---------------------------------------------------------------------------
# array planners == list planners, message for message
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", PARITY_NS)
@pytest.mark.parametrize("tech", sorted(TECHNIQUES))
def test_array_plan_equals_list_plan(tech, n):
    mplan, aplan = _plans(tech, n)
    back = aplan.to_plan()
    assert len(back.rounds) == len(mplan.rounds)
    for r in range(len(mplan.rounds)):
        assert ([(m.src, m.dst, m.nbytes) for m in back.rounds[r]]
                == [(m.src, m.dst, m.nbytes) for m in mplan.rounds[r]])
    assert aplan.n_nodes == mplan.n_nodes
    assert aplan.total_bytes == pytest.approx(mplan.total_bytes)


@pytest.mark.parametrize("tech", sorted(TECHNIQUES))
def test_array_plan_mask_aware(tech):
    rng = np.random.default_rng(5)
    for seed in range(4):
        mask = (rng.random(27) < 0.6).astype(np.float32)
        if mask.sum() < 2:
            continue
        mplan, aplan = _plans(tech, 27, mask=mask)
        assert ([(m.src, m.dst, m.nbytes)
                 for r in aplan.to_plan().rounds for m in r]
                == [(m.src, m.dst, m.nbytes)
                    for r in mplan.rounds for m in r])


def test_array_plan_roundtrip_lossless():
    mplan, _ = _plans("mar", 27)
    ap = ArrayMessagePlan.from_plan(mplan)
    back = ap.to_plan()
    assert back.kd_rounds == mplan.kd_rounds
    assert back.n_messages == mplan.n_messages
    for ra, rb in zip(back.rounds, mplan.rounds):
        assert [(m.src, m.dst, m.nbytes) for m in ra] \
            == [(m.src, m.dst, m.nbytes) for m in rb]


def test_array_plan_mkd_prefix_matches_list():
    plan = plan_grid(27)
    pipe = build_pipeline("mar", plan)
    mask = np.ones(27, np.float32)
    mplan = pipe.message_plan(mask, MB, 27, use_kd=True,
                              kd_logit_bytes=256)
    aplan = with_mkd_traffic_arrays(
        build_array_plan("mar", plan, mask, MB), plan, mask, MB, 256)
    assert aplan.kd_rounds == mplan.kd_rounds == plan.depth
    assert ([(m.src, m.dst, m.nbytes)
             for r in aplan.to_plan().rounds for m in r]
            == [(m.src, m.dst, m.nbytes)
                for r in mplan.rounds for m in r])


# ---------------------------------------------------------------------------
# heap-vs-vector transcript parity (the acceptance property)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", PARITY_NS)
@pytest.mark.parametrize("tech", sorted(TECHNIQUES))
def test_engines_agree_full_participation(tech, n):
    mplan, aplan = _plans(tech, n)
    for th, tv in _run_both(mplan, aplan, n, iters=2):
        _assert_equal_transcripts(th, tv)


@pytest.mark.parametrize("profile", ["uniform", "wireless", "regions"])
def test_engines_agree_across_profiles(profile):
    mplan, aplan = _plans("mar", 64)
    (th, tv), = _run_both(mplan, aplan, 64, profile=profile, seed=3)
    _assert_equal_transcripts(th, tv)


@pytest.mark.parametrize("tech", sorted(TECHNIQUES))
def test_engines_agree_under_churn(tech):
    rng = np.random.default_rng(11)
    for _ in range(3):
        mask = (rng.random(27) < 0.7).astype(np.float32)
        if mask.sum() < 2:
            continue
        mplan, aplan = _plans(tech, 27, mask=mask)
        (th, tv), = _run_both(mplan, aplan, 27, seed=1)
        _assert_equal_transcripts(th, tv)


@pytest.mark.parametrize("tech", sorted(TECHNIQUES))
def test_engines_agree_seeded_loss_and_demotion(tech):
    """Same seed -> same Bernoulli stream -> identical dropped
    messages and identical demoted-sender flags."""
    mplan, aplan = _plans(tech, 27)
    runs = _run_both(mplan, aplan, 27, profile="uniform", seed=2,
                     link_params={"loss": 0.3}, iters=3)
    assert any(th.n_dropped > 0 for th, _ in runs)
    for th, tv in runs:
        _assert_equal_transcripts(th, tv)


def test_engines_agree_mkd_prefix_rounds():
    plan = plan_grid(27)
    pipe = build_pipeline("mar", plan)
    mask = np.ones(27, np.float32)
    mplan = pipe.message_plan(mask, MB, 27, use_kd=True,
                              kd_logit_bytes=256)
    aplan = ArrayMessagePlan.from_plan(mplan)
    (th, tv), = _run_both(mplan, aplan, 27)
    assert th.kd_bytes > 0
    _assert_equal_transcripts(th, tv)


def test_engines_agree_compute_skew():
    mplan, aplan = _plans("mar", 8)
    slow = np.zeros(8)
    slow[5] = 100.0
    (th, tv), = _run_both(mplan, aplan, 8, compute_s=slow)
    assert th.iteration_s > 100.0
    _assert_equal_transcripts(th, tv)


def test_vector_accepts_list_plan_directly():
    mplan, _ = _plans("mar", 8)
    th = NetworkSim(8, "uniform", seed=0).run(mplan)
    tv = VectorNetworkSim(8, "uniform", seed=0).run(mplan)
    _assert_equal_transcripts(th, tv)


# ---------------------------------------------------------------------------
# closed-form O(N^2) engines vs the materialized engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [8, 64, 125])
def test_all_to_all_closed_form_matches_materialized(n):
    mplan, aplan = _plans("ar", n)
    vec = VectorNetworkSim(n, "wireless", seed=0)
    tr = vec.run(aplan)
    it_s, finish = all_to_all_seconds(vec.links, MB)
    assert it_s == pytest.approx(tr.iteration_s, rel=1e-9)
    np.testing.assert_allclose(finish, tr.peer_finish_s, rtol=1e-9)


@pytest.mark.parametrize("n", [8, 64, 125])
def test_ring_closed_form_matches_materialized(n):
    mplan, aplan = _plans("rdfl", n)
    vec = VectorNetworkSim(n, "wireless", seed=0)
    tr = vec.run(aplan)
    it_s, finish = ring_seconds(vec.links, MB)
    assert it_s == pytest.approx(tr.iteration_s, rel=1e-9)
    np.testing.assert_allclose(finish, tr.peer_finish_s, rtol=1e-9)


def test_closed_form_respects_masks():
    mask = np.ones(27, np.float32)
    mask[[3, 9, 20]] = 0.0
    mplan, aplan = _plans("ar", 27, mask=mask)
    vec = VectorNetworkSim(27, "wireless", seed=4)
    tr = vec.run(aplan)
    it_s, _ = all_to_all_seconds(vec.links, MB, mask=mask)
    assert it_s == pytest.approx(tr.iteration_s, rel=1e-9)


def test_closed_form_rejects_lossy_links():
    links = build_link_model("uniform", 8, loss=0.2)
    with pytest.raises(ValueError, match="lossless"):
        all_to_all_seconds(links, MB)
    with pytest.raises(ValueError, match="lossless"):
        ring_seconds(links, MB)


# ---------------------------------------------------------------------------
# aggregated link accounting above the peer-count threshold
# ---------------------------------------------------------------------------

def test_link_accounting_exact_mode_below_threshold():
    acct = LinkAccounting(10, 10)
    assert acct.exact
    acct.add(0, 1, 5.0)
    acct.add_batch(np.array([0, 2]), np.array([1, 3]),
                   np.array([7.0, 2.0]))
    tr = Transcript(technique="mar")
    acct.finalize(tr)
    assert tr.link_mode == "exact"
    assert tr.bytes_by_link == {(0, 1): 12.0, (2, 3): 2.0}


def test_link_accounting_peer_mode_totals_and_topk():
    n = LINK_DETAIL_MAX_PEERS + 4
    rng = np.random.default_rng(0)
    src = rng.integers(0, n, 4000)
    dst = rng.integers(0, n, 4000)
    nb = rng.integers(1, 100, 4000).astype(float)
    acct = LinkAccounting(n, n, top_k=8)
    assert not acct.exact
    for lo in range(0, 4000, 500):       # several "rounds"
        sl = slice(lo, lo + 500)
        acct.add_batch(src[sl], dst[sl], nb[sl])
    tr = Transcript(technique="mar")
    acct.finalize(tr)
    assert tr.link_mode == "peer"
    # per-peer totals are exact
    np.testing.assert_allclose(
        tr.tx_bytes_by_peer,
        np.bincount(src, weights=nb, minlength=n))
    np.testing.assert_allclose(
        tr.rx_bytes_by_peer,
        np.bincount(dst, weights=nb, minlength=n))
    # the top-k dict is the true heaviest links, exactly summed
    exact = {}
    for s, d, b in zip(src, dst, nb):
        exact[(int(s), int(d))] = exact.get((int(s), int(d)), 0.0) + b
    want = dict(sorted(exact.items(), key=lambda kv: -kv[1])[:8])
    assert len(tr.bytes_by_link) == 8
    assert set(tr.bytes_by_link) <= set(exact)
    assert sorted(tr.bytes_by_link.values(), reverse=True) \
        == pytest.approx(sorted(want.values(), reverse=True))


def test_link_accounting_compaction_keeps_heavy_links():
    """Past ``compact_at`` the deferred buffer is compacted; heavy
    links must survive with their full totals."""
    n = LINK_DETAIL_MAX_PEERS + 4
    acct = LinkAccounting(n, n, top_k=4, compact_at=100)
    heavy = (np.array([1]), np.array([2]), np.array([1e9]))
    for _ in range(10):
        acct.add_batch(*heavy)
        acct.add_batch(np.arange(60), np.arange(60) + 1,
                       np.ones(60))
    tr = Transcript(technique="mar")
    acct.finalize(tr)
    assert tr.bytes_by_link[(1, 2)] == pytest.approx(1e10)


def test_vector_sim_switches_to_peer_mode_at_large_n():
    n = LINK_DETAIL_MAX_PEERS * 2
    plan = plan_grid(n)
    aplan = build_array_plan("mar", plan, None, MB)
    tr = VectorNetworkSim(n, "uniform", seed=0).run(aplan)
    assert tr.link_mode == "peer"
    assert tr.bytes_by_link and len(tr.bytes_by_link) <= 32
    assert tr.tx_bytes_by_peer.sum() == pytest.approx(tr.total_bytes)
    assert tr.rx_bytes_by_peer.sum() == pytest.approx(tr.total_bytes)
    # totals still match the analytic shape: every peer sends G models
    assert tr.total_bytes == plan.capacity * sum(
        m - 1 for m in plan.dims) * MB


# ---------------------------------------------------------------------------
# transport registry + federation seam
# ---------------------------------------------------------------------------

def test_vector_sim_registered_and_buildable():
    assert "vector_sim" in TRANSPORTS
    t = build_transport("vector_sim", 16, profile="wireless", seed=7)
    assert isinstance(t, VectorNetworkSim)
    assert t.n_peers == 16
    t.resize(32)
    assert t.n_peers == 32


def test_vector_sim_clock_accumulates():
    mplan, aplan = _plans("mar", 8)
    vec = VectorNetworkSim(8, "uniform", seed=0)
    t1 = vec.run(aplan)
    t2 = vec.run(aplan)
    assert vec.iterations == 2
    assert vec.clock == pytest.approx(t1.iteration_s + t2.iteration_s)


def test_federation_runs_on_vector_transport():
    """FederationConfig(transport="vector_sim") is a drop-in: same
    measured bytes and simulated seconds as the heap backend."""
    outs = {}
    for backend in ("sim", "vector_sim"):
        cfg = FederationConfig(n_peers=8, technique="mar", task="text",
                               link_profile="wireless",
                               transport=backend, seed=3)
        fed = Federation(cfg)
        state = fed.init_state()
        for _ in range(2):
            state = fed.step(state)
        outs[backend] = (fed.comm_bytes, fed.sim_seconds,
                         fed.last_transcript.n_messages)
    assert outs["vector_sim"] == outs["sim"]
