"""Cluster bootstrap env parsing (multi-host glue)."""
import os

import pytest

from repro.launch.cluster import (ClusterInfo, _first_host,
                                  assert_mesh_feasible, detect_topology,
                                  initialize_cluster)


def test_single_host_default(monkeypatch):
    for k in ("REPRO_NUM_PROCESSES", "SLURM_NTASKS"):
        monkeypatch.delenv(k, raising=False)
    info = detect_topology()
    assert info.num_processes == 1 and info.process_id == 0
    assert initialize_cluster().initialized is False  # no-op


def test_explicit_env(monkeypatch):
    monkeypatch.setenv("REPRO_NUM_PROCESSES", "128")
    monkeypatch.setenv("REPRO_PROCESS_ID", "17")
    monkeypatch.setenv("REPRO_COORDINATOR", "h0:8476")
    info = detect_topology()
    assert info.num_processes == 128
    assert info.process_id == 17
    assert info.coordinator == "h0:8476"
    assert not info.is_coordinator


def test_slurm_env(monkeypatch):
    monkeypatch.delenv("REPRO_NUM_PROCESSES", raising=False)
    monkeypatch.setenv("SLURM_NTASKS", "64")
    monkeypatch.setenv("SLURM_PROCID", "0")
    monkeypatch.setenv("SLURM_STEP_NODELIST", "tpu[003-066]")
    info = detect_topology()
    assert info.num_processes == 64
    assert info.coordinator == "tpu003:8476"
    assert info.is_coordinator


def test_first_host_forms():
    assert _first_host("node[003-008]") == "node003"
    assert _first_host("node7") == "node7"
    assert _first_host("a001,a002") == "a001"


def test_mesh_feasibility_guard():
    assert_mesh_feasible(128, 4, (2, 16, 16))        # 512 == 512
    with pytest.raises(RuntimeError):
        assert_mesh_feasible(64, 4, (2, 16, 16))     # 256 < 512
