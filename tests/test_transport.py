"""The pluggable transport seam (ISSUE 4): sim-vs-socket parity, loss
injection, MKD traffic through the transport, and the tightened
hierarchical oracle.

The contract under test: a :class:`SocketTransport` run of any
MessagePlan emits a transcript *byte-identical* to the simulator's in
the no-loss case — same totals, per-round split, per-link split — and
its loss semantics (billed airtime, flagged senders, receiver-only
demotion) match :func:`demote_lost_senders` exactly, so every consumer
of the transcript (ledger, churn demotion, benchmarks) is
backend-agnostic.
"""
import numpy as np
import pytest

from repro.core import topology
from repro.core.aggregation import (CommLedger, TECHNIQUES,
                                    build_pipeline, make_aggregator)
from repro.core.federation import Federation, FederationConfig
from repro.core.moshpit import plan_grid
from repro.runtime.network import NetworkSim
from repro.runtime.socket_transport import (SocketTransport,
                                            encode_state_payloads)
from repro.runtime.transport_base import (TRANSPORTS, Transport,
                                          build_transport,
                                          demote_lost_senders)

MB = 10_000   # state bytes per transfer (integral -> float sums exact)


def _both(mplan, n, seed=0, **socket_kw):
    sim = NetworkSim(n, profile="uniform", seed=seed).run(mplan)
    sock = SocketTransport(n, seed=seed, **socket_kw).run(mplan)
    return sim, sock


# ---------------------------------------------------------------------------
# registry + interface
# ---------------------------------------------------------------------------

def test_transport_registry():
    assert {"sim", "socket"} <= set(TRANSPORTS)
    assert all(issubclass(c, Transport) for c in TRANSPORTS.values())
    with pytest.raises(ValueError, match="unknown transport"):
        build_transport("carrier-pigeon", 4)


def test_build_transport_maps_link_knobs():
    sim = build_transport("sim", 8, profile="wireless", seed=3)
    assert sim.name == "sim" and sim.links.name == "wireless"
    sock = build_transport("socket", 8, profile="wireless", seed=3,
                           link_params={"loss": 0.25})
    # the socket backend has real loopback links: only loss survives
    assert sock.name == "socket" and sock.loss == 0.25
    assert not sock.lossless
    assert sim.lossless       # wireless profile defaults to loss 0


# ---------------------------------------------------------------------------
# the acceptance property: sim-vs-socket transcript byte equality
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [4, 8])
@pytest.mark.parametrize("tech", ["mar", "fedavg", "ar", "gossip"])
def test_sim_vs_socket_byte_exact(tech, n):
    plan = plan_grid(n)
    agg = make_aggregator(tech, plan)
    mplan = agg.message_plan(np.ones(n, np.float32), MB)
    sim, sock = _both(mplan, n)
    assert sock.total_bytes == sim.total_bytes
    assert sock.n_messages == sim.n_messages
    assert sock.bytes_by_round == sim.bytes_by_round
    assert sock.bytes_by_link == sim.bytes_by_link
    assert sock.n_dropped == 0
    # same transcript *shape*: the time axis exists on both, only its
    # meaning differs (modeled vs measured wall-clock)
    assert len(sock.round_s) == len(sim.round_s)
    assert sock.peer_finish_s.shape == sim.peer_finish_s.shape
    assert sock.iteration_s > 0.0
    # the socket really moved the scheduled octets
    assert sock.payload_bytes == sum(
        int(np.ceil(m.nbytes)) for r in mplan.rounds for m in r
        if m.src != m.dst)


@pytest.mark.parametrize("tech", ["mar", "hierarchical", "rdfl"])
def test_sim_vs_socket_byte_exact_under_churn(tech):
    plan = plan_grid(8)
    agg = make_aggregator(tech, plan)
    for seed in range(4):
        rng = np.random.default_rng(seed)
        mask = (rng.random(8) < 0.6).astype(np.float32)
        mplan = agg.message_plan(mask, MB)
        sim, sock = _both(mplan, 8)
        assert sock.total_bytes == sim.total_bytes
        assert sock.bytes_by_link == sim.bytes_by_link


def test_socket_payloads_carry_real_tensors():
    state = {"w": np.arange(4 * 32, dtype=np.float32).reshape(4, 32),
             "b": np.ones((4, 3), np.float32)}
    blobs = encode_state_payloads(state)
    assert len(blobs) == 4
    # int8 codes + one f32 scale per leaf per peer
    assert all(len(b) == 32 + 4 + 3 + 4 for b in blobs)
    mplan = make_aggregator("mar", plan_grid(4)).message_plan(
        np.ones(4, np.float32), MB)
    tr = SocketTransport(4, seed=0).run(mplan, payloads=blobs)
    assert tr.total_bytes == mplan.total_bytes
    assert tr.payload_bytes > 0


# ---------------------------------------------------------------------------
# loss semantics: injected send failure == modeled drop
# ---------------------------------------------------------------------------

def test_socket_injected_failure_demotes_receiver_only():
    plan = plan_grid(8)
    mplan = make_aggregator("mar", plan).message_plan(
        np.ones(8, np.float32), MB)
    victim = mplan.rounds[0][0]
    st = SocketTransport(8, seed=0,
                         fail_sends={(0, victim.src, victim.dst)})
    assert not st.lossless
    tr = st.run(mplan)
    assert [(m.src, m.dst) for m in tr.dropped] == \
        [(victim.src, victim.dst)]
    # lost frames consumed airtime: billed exactly like the simulator
    assert tr.total_bytes == mplan.total_bytes
    u = np.ones(8, np.float32)
    a = demote_lost_senders(u.copy(), u, tr)
    assert a[victim.src] == 0.0 and a.sum() == 7


def test_socket_bernoulli_loss_flags_senders_deterministically():
    mplan = make_aggregator("mar", plan_grid(8)).message_plan(
        np.ones(8, np.float32), MB)
    tr1 = SocketTransport(8, seed=2, loss=0.5).run(mplan)
    tr2 = SocketTransport(8, seed=2, loss=0.5).run(mplan)
    assert tr1.n_dropped > 0
    assert tr1.total_bytes == mplan.total_bytes
    assert ({m.src for m in tr1.dropped}
            == set(np.flatnonzero(tr1.lost_senders)))
    # the drop pattern is deterministic in (seed, iteration)
    assert ([(m.src, m.dst) for m in tr1.dropped]
            == [(m.src, m.dst) for m in tr2.dropped])


def test_federation_trains_over_socket_transport():
    cfg = FederationConfig(n_peers=4, technique="mar", task="text",
                           transport="socket", seed=3)
    fed = Federation(cfg)
    state = fed.init_state()
    for _ in range(2):
        state = fed.step(state)
    analytic = 2 * topology.iteration_bytes("mar", 4, fed.model_bytes,
                                            fed.plan)
    assert fed.comm_bytes == pytest.approx(analytic)
    assert fed.sim_seconds > 0.0          # wall-clock on this backend
    assert fed.last_transcript.payload_bytes > 0


# ---------------------------------------------------------------------------
# MKD traffic rides the transport (satellite)
# ---------------------------------------------------------------------------

def test_mkd_rounds_ride_the_transport():
    plan = plan_grid(8)
    pipe = build_pipeline("mar", plan)
    mask = np.ones(8, np.float32)
    mplan = pipe.message_plan(mask, MB, 8, use_kd=True,
                              kd_logit_bytes=256)
    assert mplan.kd_rounds == plan.depth
    sim, sock = _both(mplan, 8)
    full = topology.iteration_bytes("mar", 8, MB, plan, use_kd=True,
                                    kd_logit_bytes=256)
    base = topology.iteration_bytes("mar", 8, MB, plan)
    assert sim.total_bytes == pytest.approx(full)
    assert sim.kd_bytes == pytest.approx(full - base)
    assert sock.total_bytes == sim.total_bytes
    assert sock.kd_bytes == sim.kd_bytes
    # the ledger splits measured KD back out per source
    ledger = CommLedger()
    pipe.record_transcript(ledger, sim, 8, MB)
    assert ledger.by_source["kd"] == pytest.approx(full - base)
    assert ledger.by_source["agg/mar"] == pytest.approx(base)


def test_mkd_traffic_mask_aware_under_churn():
    """Under churn the measured KD bytes follow the mask-aware oracle:
    pulls are active-pair exact, logits bill one message per active
    student per round."""
    plan = plan_grid(8)
    pipe = build_pipeline("mar", plan)
    for seed in range(4):
        rng = np.random.default_rng(seed)
        mask = (rng.random(8) < 0.6).astype(np.float32)
        n_act = int(mask.sum())
        mplan = pipe.message_plan(mask, MB, n_act, use_kd=True,
                                  kd_logit_bytes=256)
        tr = NetworkSim(8, profile="uniform", seed=0).run(mplan)
        pulls = topology.mar_bytes(n_act, plan, MB // 2, mask=mask)
        logits = n_act * plan.depth * 256
        assert tr.kd_bytes == pytest.approx(pulls + logits)


# ---------------------------------------------------------------------------
# hierarchical oracle under churn (satellite)
# ---------------------------------------------------------------------------

def test_hierarchical_mask_aware_parity_under_churn():
    for n in (10, 16, 27):
        plan = plan_grid(n)
        agg = make_aggregator("hierarchical", plan)
        for seed in range(5):
            rng = np.random.default_rng(seed)
            mask = (rng.random(n) < 0.5).astype(np.float32)
            tr = NetworkSim(n, profile="uniform", seed=0).run(
                agg.message_plan(mask, MB))
            exact = topology.iteration_bytes(
                "hierarchical", int(mask.sum()), MB, plan, mask=mask)
            assert tr.total_bytes == pytest.approx(exact)


def test_hierarchical_countonly_is_lower_bound():
    """Without the mask, ceil(n/M) is the *minimum* possible nonempty
    leaf-group count — the count-only oracle lower-bounds the measured
    bytes and coincides at full participation."""
    plan = plan_grid(27)
    agg = make_aggregator("hierarchical", plan)
    saw_gap = False
    for seed in range(8):
        rng = np.random.default_rng(seed)
        mask = (rng.random(27) < 0.4).astype(np.float32)
        n_act = int(mask.sum())
        tr = NetworkSim(27, profile="uniform", seed=0).run(
            agg.message_plan(mask, MB))
        lower = topology.iteration_bytes("hierarchical", n_act, MB, plan)
        assert lower <= tr.total_bytes + 1e-9
        saw_gap |= lower < tr.total_bytes
    assert saw_gap          # spread-out actives really cost more
    full = np.ones(27, np.float32)
    tr = NetworkSim(27, profile="uniform", seed=0).run(
        agg.message_plan(full, MB))
    assert tr.total_bytes == pytest.approx(
        topology.iteration_bytes("hierarchical", 27, MB, plan))
