"""Moshpit-KD (Alg. 2/3) and decentralized DP (Alg. 4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.federation import Federation, FederationConfig
from repro.core.mkd import kl_divergence, select_teachers, student_loss
from repro.core.dp import epsilon_estimate


# ---------------------------------------------------------------------------
# MKD units
# ---------------------------------------------------------------------------

def test_kl_divergence_basics():
    p = jnp.asarray([[0.5, 0.5]])
    assert float(kl_divergence(p, p)[0]) == pytest.approx(0.0, abs=1e-6)
    q = jnp.asarray([[0.9, 0.1]])
    assert float(kl_divergence(p, q)[0]) > 0


def test_select_teachers_lowest_kl():
    """Alg. 3: the selected teachers are the rho_l lowest-KL candidates."""
    rng = np.random.default_rng(0)
    my = jnp.asarray(rng.normal(size=(8, 10)), jnp.float32)
    cands = jnp.stack([my + 0.01 * rng.normal(size=(8, 10)),   # close
                       my + 3.0 * rng.normal(size=(8, 10)),    # far
                       my + 0.02 * rng.normal(size=(8, 10)),   # close
                       my + 5.0 * rng.normal(size=(8, 10))])   # far
    mask = jnp.ones((4,))
    w = select_teachers(my, cands, mask, tau=3.0, rho=0.5)
    assert float(w[0]) > 0 and float(w[2]) > 0
    assert float(w[1]) == 0 and float(w[3]) == 0
    assert float(jnp.sum(w)) == pytest.approx(1.0, abs=1e-6)


def test_select_teachers_respects_mask():
    my = jnp.zeros((4, 6))
    cands = jnp.zeros((3, 4, 6))
    mask = jnp.asarray([0.0, 1.0, 0.0])
    w = select_teachers(my, cands, mask, tau=3.0, rho=0.9)
    assert float(w[1]) == pytest.approx(1.0)
    assert float(w[0]) == 0.0 and float(w[2]) == 0.0


def test_student_loss_anneal():
    """alpha=0 -> pure CE; alpha=1 -> pure (scaled) KL."""
    rng = np.random.default_rng(1)
    s = jnp.asarray(rng.normal(size=(4, 5)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(4, 5)), jnp.float32)
    y = jnp.asarray([0, 1, 2, 3], jnp.int32)
    l_ce = student_loss(s, z, y, tau=3.0, alpha=jnp.asarray(0.0))
    l_kl = student_loss(s, z, y, tau=3.0, alpha=jnp.asarray(1.0))
    ce = -jnp.mean(jnp.take_along_axis(jax.nn.log_softmax(s), y[:, None], 1))
    assert float(l_ce) == pytest.approx(float(ce), rel=1e-5)
    l_same = student_loss(s, s, y, tau=3.0, alpha=jnp.asarray(1.0))
    assert float(l_same) == pytest.approx(0.0, abs=1e-5)
    assert float(l_kl) > 0


def test_mkd_accelerates_early_convergence():
    """Fig. 2: with KD, higher accuracy in the early iterations."""
    accs = {}
    for use_kd in (False, True):
        cfg = FederationConfig(n_peers=8, technique="mar", task="text",
                               use_kd=use_kd, kd_iterations=4,
                               local_batches=2, seed=5)
        fed = Federation(cfg)
        state = fed.init_state()
        for _ in range(8):
            state = fed.step(state)
        accs[use_kd] = fed.evaluate(state)
    assert accs[True] > accs[False]


def test_mkd_comm_overhead_accounted():
    cfgs = [FederationConfig(n_peers=8, technique="mar", task="text",
                             use_kd=kd, kd_iterations=4, seed=5)
            for kd in (False, True)]
    comms = []
    for cfg in cfgs:
        fed = Federation(cfg)
        state = fed.init_state()
        for _ in range(4):
            state = fed.step(state)
        comms.append(fed.comm_bytes)
    assert comms[1] > comms[0]


# ---------------------------------------------------------------------------
# DP (Alg. 4)
# ---------------------------------------------------------------------------

def test_dp_training_runs_and_adapts_clip():
    cfg = FederationConfig(n_peers=8, technique="mar", task="text",
                           use_dp=True, noise_multiplier=0.3, seed=7)
    fed = Federation(cfg)
    state = fed.init_state()
    clip0 = float(state.dp["clip"])
    for _ in range(6):
        state = fed.step(state)
    assert bool(jnp.all(jnp.isfinite(jax.tree.leaves(state.params)[0])))
    assert float(state.dp["clip"]) != clip0  # gamma-quantile tracking


def test_dp_noise_hurts_at_high_sigma():
    accs = {}
    for sigma in (0.1, 3.0):
        cfg = FederationConfig(n_peers=8, technique="mar", task="text",
                               use_dp=True, noise_multiplier=sigma,
                               local_batches=4, seed=7)
        fed = Federation(cfg)
        state = fed.init_state()
        for _ in range(15):
            state = fed.step(state)
        accs[sigma] = fed.evaluate(state)
    assert accs[0.1] > accs[3.0]


def test_epsilon_estimates():
    # more noise -> lower epsilon; more iterations -> higher epsilon
    assert epsilon_estimate(100, 1.0) < epsilon_estimate(100, 0.3)
    assert epsilon_estimate(200, 1.0) > epsilon_estimate(100, 1.0)
    # subsampling reduces epsilon
    assert epsilon_estimate(100, 1.0, sampling_rate=0.1) \
        < epsilon_estimate(100, 1.0)
    assert epsilon_estimate(10, 0.0) == float("inf")
