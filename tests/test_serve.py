"""Serving tier: paged KV kernel parity, prefill->decode handoff,
continuous-batching scheduler invariants, checkpoint hot-swap.

Parity tests run float32 + xla attention so the paged pool path and the
dense cache path are structurally identical einsums — the ISSUE-8 gate
is logit agreement <= 1e-5 (observed: bit-exact on CPU).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.core.fl_device import (make_paged_serve_step, make_prefill_step,
                                  make_serve_step)
from repro.kernels import ref
from repro.kernels.paged_attention import (gather_dense_decode,
                                           paged_decode_attention_fwd)
from repro.models.model import Model
from repro.serve import (BlockAllocator, DecodeServer, ServeConfig,
                         gather_session_cache, run_sequential,
                         serving_params_from_checkpoint, session_table,
                         write_prefill_to_pages)

MAX_NEW = 6


def _dense_model():
    cfg = get_smoke_config("starcoder2-3b")
    cfg = dataclasses.replace(cfg, attn_impl="xla", dtype="float32")
    return Model(cfg)


@pytest.fixture(scope="module")
def dense():
    model = _dense_model()
    return model, model.init(jax.random.PRNGKey(0))


def _prompts(model, n, lo=1, hi=12, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, model.cfg.vocab_size,
                         rng.integers(lo, hi + 1)).tolist()
            for _ in range(n)]


# ---------------------------------------------------------------------------
# Paged kernel parity
# ---------------------------------------------------------------------------

def _paged_inputs(seed=0, b=3, nblk=4, bs=8, kvh=2, g=4, d=16):
    rng = np.random.default_rng(seed)
    nb = 1 + b * nblk
    q = jnp.asarray(rng.normal(size=(b, kvh * g, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(nb, bs, kvh, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nb, bs, kvh, d)), jnp.float32)
    bt = jnp.asarray(rng.permutation(np.arange(1, nb))
                     .reshape(b, nblk), jnp.int32)
    lens = jnp.asarray([1, bs * nblk, bs * 2 + 3][:b], jnp.int32)
    return q, kp, vp, bt, lens


def test_paged_kernel_interpret_matches_ref():
    q, kp, vp, bt, lens = _paged_inputs()
    out = paged_decode_attention_fwd(q, kp, vp, bt, lens, interpret=True)
    want = ref.paged_decode_attention_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_gather_dense_fallback_matches_ref():
    q, kp, vp, bt, lens = _paged_inputs(seed=1)
    out = gather_dense_decode(q, kp, vp, bt, lens)
    want = ref.paged_decode_attention_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_paged_decode_logits_match_dense(dense):
    """Full-model parity: paged pool vs dense cache, greedy chains."""
    model, params = dense
    b, bs, nblk = 2, 4, 4
    cache = model.init_cache(b, max_len=bs * nblk)
    pages = model.init_paged_cache(num_blocks=1 + b * nblk, block_size=bs)
    bt = jnp.asarray([[1 + i * nblk + j for j in range(nblk)]
                      for i in range(b)], jnp.int32)
    pos = jnp.zeros((b,), jnp.int32)
    paged = jax.jit(make_paged_serve_step(model))
    serve = jax.jit(make_serve_step(model))
    tok = jnp.asarray([3, 7], jnp.int32)
    for _ in range(bs * nblk):
        ntok, logits, pages = paged(params, pages, bt, pos, tok)
        logits_d, cache = model.decode_step(params, cache, tok)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(logits_d), atol=1e-5)
        tok, pos = ntok, pos + 1


def test_write_prefill_roundtrip(dense):
    """Scattered prefill KV gathers back identically (incl. a ragged
    last block)."""
    model, params = dense
    s, bs = 11, 4                                    # 3 blocks, ragged
    toks = jnp.asarray(np.arange(2 * s).reshape(2, s) % 50, jnp.int32)
    _, _, cache = model.forward(params, toks, collect_cache=True)
    pages = model.init_paged_cache(num_blocks=7, block_size=bs)
    bt = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    pages = write_prefill_to_pages(pages, cache["k"], cache["v"], bt)
    got = gather_session_cache(pages, [4, 5, 6])
    np.testing.assert_array_equal(np.asarray(got["k"][:, 0, :s]),
                                  np.asarray(cache["k"][:, 1]))


# ---------------------------------------------------------------------------
# Prefill -> decode handoff (satellite: no prompt replay)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["starcoder2-3b", "xlstm-350m",
                                  "zamba2-2.7b", "moonshot-v1-16b-a3b"])
def test_prefill_handoff_matches_replay(arch):
    """make_prefill_step(max_len=...) returns a decode-ready cache whose
    continuation equals token-by-token replay from scratch."""
    cfg = get_smoke_config(arch)
    cfg = dataclasses.replace(cfg, attn_impl="xla", dtype="float32")
    if cfg.family == "moe":
        # capacity drops differ between a 12-token prefill and 1-token
        # decode steps; lift the cap so routing is drop-free both ways
        # (the established idiom for MoE exactness tests)
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    model = Model(cfg)
    rng = np.random.default_rng(3)
    params = model.init(jax.random.PRNGKey(3))
    S, MAXLEN = 6, 10
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, MAXLEN)),
                       jnp.int32)

    cache = model.init_cache(2, max_len=MAXLEN)
    replay = []
    for i in range(MAXLEN):
        lg, cache = model.decode_step(params, cache, toks[:, i])
        replay.append(lg)

    prefill = jax.jit(make_prefill_step(model, max_len=MAXLEN))
    lg, dcache = prefill(params, {"tokens": toks[:, :S]})
    outs = [lg]
    for i in range(S, MAXLEN):
        lg, dcache = model.decode_step(params, dcache, toks[:, i])
        outs.append(lg)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(jnp.stack(replay[S - 1:], 1)),
                               atol=2e-4, rtol=2e-4)


def test_hybrid_handoff_ring_layout():
    """Long-prompt hybrid handoff (prompt > window): the converted ring
    holds position p at slot p % w with bit-exact K/V, conv and ssm
    states (forward's full-causal vs decode's windowed attention is a
    separate, pre-existing semantic gap — layout is what the handoff
    owns)."""
    cfg = get_smoke_config("zamba2-2.7b")
    cfg = dataclasses.replace(cfg, attn_impl="xla", dtype="float32",
                              shared_attn_window=4)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(np.arange(12).reshape(2, 6), jnp.int32)
    S, MAXLEN = 6, 12
    _, raw = jax.jit(make_prefill_step(model))(params, {"tokens": toks})
    _, conv = jax.jit(make_prefill_step(model, max_len=MAXLEN))(
        params, {"tokens": toks})
    w_f, w_d = raw["attn_k"].shape[2], conv["attn_k"].shape[2]
    assert w_d == 4
    for j in range(w_f):                  # raw index j holds pos S-w_f+j
        slot = (S - w_f + j) % w_d
        np.testing.assert_array_equal(
            np.asarray(conv["attn_k"][:, :, slot]),
            np.asarray(raw["attn_k"][:, :, j]))
    np.testing.assert_array_equal(np.asarray(conv["conv"]),
                                  np.asarray(raw["conv"]))
    np.testing.assert_array_equal(np.asarray(conv["ssm"]),
                                  np.asarray(raw["ssm"]))


# ---------------------------------------------------------------------------
# Allocator / scheduler invariants
# ---------------------------------------------------------------------------

def test_block_allocator_invariants():
    al = BlockAllocator(6)
    assert al.free_blocks == 5                     # block 0 reserved
    got = al.alloc(3)
    assert 0 not in got and len(set(got)) == 3
    with pytest.raises(RuntimeError):
        al.alloc(3)                                # only 2 left
    al.free(got)
    with pytest.raises(RuntimeError):
        al.free([got[0]])                          # double free
    assert al.free_blocks == 5
    assert session_table([1, 2], 4) == [1, 2, 0, 0]


def test_engine_matches_sequential_mixed_lengths(dense):
    """Heterogeneous-length continuous batch produces the exact greedy
    tokens of the one-at-a-time baseline."""
    model, params = dense
    scfg = ServeConfig(max_batch=3, block_size=4, num_blocks=40,
                       pad_len=12, max_new=MAX_NEW)
    prompts = _prompts(model, 7)
    srv = DecodeServer(model, params, scfg)
    for p in prompts:
        srv.enqueue(p)
    srv.run()
    srv.assert_quiescent()
    seq = run_sequential(model, params, prompts, max_new=MAX_NEW,
                         pad_len=12)
    eng = {s.sid: s.generated for s in srv.finished}
    assert all(eng[s.sid] == s.generated for s in seq)


def test_no_block_leak_under_pressure(dense):
    """A pool far smaller than the offered load still drains every
    session and reclaims every block."""
    model, params = dense
    scfg = ServeConfig(max_batch=4, block_size=4, num_blocks=11,
                       pad_len=12, max_new=MAX_NEW)
    srv = DecodeServer(model, params, scfg)
    for p in _prompts(model, 8, seed=1):
        srv.enqueue(p)
    peak_free = srv.alloc.free_blocks
    srv.run(max_steps=500)
    assert len(srv.finished) == 8
    srv.assert_quiescent()
    assert srv.alloc.free_blocks == peak_free


def test_fifo_head_of_line(dense):
    """Admission is FIFO: while the (large) queue head doesn't fit, a
    small later arrival must not overtake it."""
    model, params = dense
    scfg = ServeConfig(max_batch=3, block_size=4, num_blocks=10,
                       pad_len=12, max_new=MAX_NEW)
    srv = DecodeServer(model, params, scfg)
    big_a = srv.enqueue([1] * 12)     # needs ceil(18/4)=5 of 9 blocks
    big_b = srv.enqueue([2] * 12)     # head-of-line once A runs
    small = srv.enqueue([3])          # would fit beside A — must wait
    srv.step()
    assert big_a.state == "running"
    assert big_b.state == "queued" and small.state == "queued"
    srv.run()
    srv.assert_quiescent()
    assert [s.sid for s in srv.finished] == [big_a.sid, big_b.sid,
                                             small.sid]


def test_enqueue_rejects_impossible(dense):
    model, params = dense
    scfg = ServeConfig(max_batch=2, block_size=4, num_blocks=4,
                       pad_len=12, max_new=MAX_NEW)
    srv = DecodeServer(model, params, scfg)
    with pytest.raises(ValueError):
        srv.enqueue([1] * 13)                      # > pad_len
    with pytest.raises(ValueError):
        srv.enqueue([1] * 12)                      # footprint > pool
    srv.assert_quiescent()


def test_recurrent_family_rejected():
    cfg = get_smoke_config("xlstm-350m")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        DecodeServer(model, params, ServeConfig())
    with pytest.raises(ValueError):
        run_sequential(model, params, [[1, 2]], max_new=2, pad_len=4)


# ---------------------------------------------------------------------------
# Checkpoint hot-swap
# ---------------------------------------------------------------------------

def test_identity_hot_swap_is_deterministic(dense):
    """Swapping identical weights mid-decode changes nothing and drops
    nothing."""
    model, params = dense
    scfg = ServeConfig(max_batch=3, block_size=4, num_blocks=40,
                       pad_len=12, max_new=MAX_NEW)
    prompts = _prompts(model, 6, seed=2)

    def drain(swap):
        srv = DecodeServer(model, params, scfg)
        for p in prompts:
            srv.enqueue(p)
        if swap:
            for _ in range(3):
                srv.step()
            assert srv.running                     # mid-decode
            srv.swap_params(jax.tree.map(lambda x: x + 0, params),
                            tag="identity")
        srv.run()
        srv.assert_quiescent()
        return srv

    base, swapped = drain(False), drain(True)
    assert len(swapped.finished) == len(prompts)   # zero dropped
    assert {s.sid: s.generated for s in base.finished} == \
           {s.sid: s.generated for s in swapped.finished}
    (entry,) = swapped.swap_log
    assert entry["tag"] == "identity" and entry["in_flight"]


def test_serving_params_peer_mean(dense):
    """FL checkpoints carry a peer axis; serving weights are its mean."""
    model, params = dense
    stacked = jax.tree.map(
        lambda x: jnp.stack([x, 3 * x]), params)   # mean = 2x
    got = serving_params_from_checkpoint(
        {"params": stacked, "momentum": stacked}, params)
    want = jax.tree.map(lambda x: 2 * x, params)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6)
    # raw (unstacked) params pass through unchanged
    same = serving_params_from_checkpoint(params, params)
    for a, b in zip(jax.tree.leaves(same), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_hot_swap_mid_run(dense, tmp_path):
    """The engine picks up a newer checkpoint mid-drain, switches its
    token stream to the new weights, and finishes every session."""
    from repro.checkpoint.checkpointer import Checkpointer
    model, params = dense
    other = model.init(jax.random.PRNGKey(99))
    scfg = ServeConfig(max_batch=2, block_size=4, num_blocks=40,
                       pad_len=12, max_new=MAX_NEW)
    prompts = _prompts(model, 4, seed=4)

    ckpt = Checkpointer(str(tmp_path), keep=2)
    ckpt.save(1, {"params": jax.tree.map(
        lambda x: jnp.stack([x, x]), params)}, metadata={"n_peers": 2})

    srv = DecodeServer(model, params, scfg)
    srv.attach_checkpointer(ckpt, params, every=1)
    for p in prompts:
        srv.enqueue(p)
    for _ in range(2):
        srv.step()
    assert not srv.swap_log                        # step 1 already seen
    ckpt.save(2, {"params": jax.tree.map(
        lambda x: jnp.stack([x, x]), other)}, metadata={"n_peers": 2})
    srv.run()
    srv.assert_quiescent()
    assert len(srv.finished) == len(prompts)
    (entry,) = srv.swap_log
    assert entry["tag"] == "ckpt:2"
    # the installed weights are checkpoint 2's peer mean (== other)
    for a, b in zip(jax.tree.leaves(srv.params), jax.tree.leaves(other)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6)
