"""End-to-end FL behaviour: parity, learning, churn, communication."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.federation import Federation, FederationConfig, TECHNIQUES


def _run(cfg, iters):
    fed = Federation(cfg)
    state = fed.init_state()
    for _ in range(iters):
        state = fed.step(state)
    return fed, state


def test_mar_equals_fedavg_exact():
    """Fig. 5 qualitative identity: exact MAR == client-server FedAvg ==
    all-to-all, bit-for-bit (same seeds, full participation)."""
    results = {}
    for tech in ("mar", "fedavg", "ar"):
        cfg = FederationConfig(n_peers=8, technique=tech, task="text",
                               seed=3)
        fed, state = _run(cfg, 6)
        results[tech] = jax.tree.leaves(state.params)[0]
    np.testing.assert_allclose(results["mar"], results["fedavg"], atol=2e-7)
    np.testing.assert_allclose(results["mar"], results["ar"], atol=2e-7)


def test_peers_agree_after_aggregation():
    cfg = FederationConfig(n_peers=8, technique="mar", task="text")
    fed, state = _run(cfg, 3)
    x = jax.tree.leaves(state.params)[0]
    spread = float(jnp.max(jnp.abs(x - jnp.mean(x, 0, keepdims=True))))
    assert spread < 1e-5


def test_learning_progress():
    cfg = FederationConfig(n_peers=8, technique="mar", task="text",
                           local_batches=4)
    fed = Federation(cfg)
    state = fed.init_state()
    acc0 = fed.evaluate(state)
    for _ in range(25):
        state = fed.step(state)
    acc1 = fed.evaluate(state)
    assert acc1 > acc0 + 0.1, (acc0, acc1)


def test_partial_participation_still_trains():
    cfg = FederationConfig(n_peers=8, technique="mar", task="text",
                           participation_rate=0.5, local_batches=4, seed=1)
    fed = Federation(cfg)
    state = fed.init_state()
    acc0 = fed.evaluate(state)
    for _ in range(25):
        state = fed.step(state)
    assert fed.evaluate(state) > acc0 + 0.05


def test_dropout_churn_no_nans():
    """Paper Fig. 3: dropouts (update done, aggregation missed) don't
    break training."""
    cfg = FederationConfig(n_peers=27, technique="mar", task="text",
                           dropout_rate=0.2, seed=2)
    fed, state = _run(cfg, 8)
    for leaf in jax.tree.leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_communication_ordering():
    """MAR comm sits between FedAvg (O(N)) and AR/RDFL (O(N^2))."""
    comm = {}
    for tech in ("mar", "fedavg", "ar", "rdfl"):
        cfg = FederationConfig(n_peers=27, technique=tech, task="text")
        fed, _ = _run(cfg, 2)
        comm[tech] = fed.comm_bytes
    assert comm["fedavg"] < comm["mar"] < comm["ar"]
    assert comm["ar"] == comm["rdfl"]


def test_paper_headline_10x_at_125():
    """Fig. 1: at N=125 (5^3), MAR needs ~10x less comm than AR/RDFL."""
    from repro.core import topology
    from repro.core.moshpit import plan_grid
    plan = plan_grid(125)
    mb = 1000
    ar = topology.iteration_bytes("ar", 125, mb)
    mar_b = topology.iteration_bytes("mar", 125, mb, plan)
    assert 9.0 < ar / mar_b < 12.0


def test_unknown_technique_rejected():
    # "gossip" graduated into the aggregator registry; use a name that
    # stays fictional
    with pytest.raises(ValueError):
        Federation(FederationConfig(technique="carrier-pigeon"))


def test_new_techniques_reach_global_mean():
    """Registry additions: gossip (power-of-two ring) and hierarchical
    match the exact-mean family under full participation."""
    results = {}
    for tech in ("mar", "gossip", "hierarchical"):
        cfg = FederationConfig(n_peers=8, technique=tech, task="text",
                               seed=3)
        fed, state = _run(cfg, 4)
        results[tech] = jax.tree.leaves(state.params)[0]
    np.testing.assert_allclose(results["gossip"], results["mar"], atol=1e-5)
    np.testing.assert_allclose(results["hierarchical"], results["mar"],
                               atol=1e-5)


def test_peer_disagreement_is_per_parameter_mean():
    """Regression: the normalization is N * total-params (the docstring's
    per-parameter mean), so hand-planted spread gives an exact value."""
    cfg = FederationConfig(n_peers=4, technique="mar", task="text")
    fed = Federation(cfg)
    state = fed.init_state()
    # peers at +delta/-delta around their mean in every coordinate
    delta = 0.5
    state.params = jax.tree.map(
        lambda x: jnp.where(
            (jnp.arange(x.shape[0]) % 2 == 0).reshape(
                (-1,) + (1,) * (x.ndim - 1)),
            jnp.full_like(x, delta), jnp.full_like(x, -delta)),
        state.params)
    # every parameter contributes delta^2 to the squared distance
    assert fed.peer_disagreement(state) == pytest.approx(delta ** 2,
                                                         rel=1e-5)


def test_rng_reproducibility():
    a = _run(FederationConfig(n_peers=8, task="text", seed=11), 3)[1]
    b = _run(FederationConfig(n_peers=8, task="text", seed=11), 3)[1]
    np.testing.assert_array_equal(jax.tree.leaves(a.params)[0],
                                  jax.tree.leaves(b.params)[0])
