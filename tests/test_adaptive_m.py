"""Adaptive group sizing: controller registry, regroup semantics,
federation wiring, and the ISSUE-5 planner regressions."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.adaptive import (CONTROLLERS, ScheduleController,
                                 StaticController, TailAwareController,
                                 build_controller, candidate_grids,
                                 validate_proposal)
from repro.core.federation import Federation, FederationConfig
from repro.core.moshpit import GridPlan, plan_grid
from repro.runtime.transport_base import Transcript


def _transcript(finish):
    return Transcript(technique="mar",
                      peer_finish_s=np.asarray(finish, float))


# ---------------------------------------------------------------------------
# registry round-trips
# ---------------------------------------------------------------------------

def test_controller_registry_roundtrip():
    assert {"static", "tail_aware", "schedule"} <= set(CONTROLLERS)
    plan = plan_grid(27)
    for name, cls in CONTROLLERS.items():
        c = build_controller(name, plan)
        assert isinstance(c, cls)
        assert c.name == name
        assert c.plan is plan


def test_unknown_controller_rejected():
    with pytest.raises(ValueError, match="carrier-pigeon"):
        build_controller("carrier-pigeon", plan_grid(8))


def test_candidate_grids_ladder():
    dims = [p.dims for p in candidate_grids(125)]
    assert (5, 5, 5) in dims
    assert dims == sorted(dims, key=lambda d: d[0])  # ordered by M
    for p in candidate_grids(125):
        assert p.capacity >= 125
    for p in candidate_grids(8, exact_only=True):
        assert p.is_exact


def test_validate_proposal_rejects_resize_and_padding():
    with pytest.raises(ValueError, match="regroup"):
        validate_proposal(plan_grid(12), 8)
    with pytest.raises(ValueError, match="capacity"):
        validate_proposal(GridPlan(8, (2, 2)), 8)
    with pytest.raises(ValueError, match="exact"):
        validate_proposal(plan_grid(10, group_size=4), 10,
                          exact_only=True)


# ---------------------------------------------------------------------------
# controller policies (unit level, synthetic transcripts)
# ---------------------------------------------------------------------------

def test_static_never_regroups():
    c = StaticController(plan_grid(125))
    for t in range(10):
        assert c.observe(t, _transcript([1.0] * 124 + [9.0]),
                         c.plan) is None


def test_tail_aware_shrinks_then_recovers_capped_at_home():
    c = TailAwareController(plan_grid(125), window=2, cooldown=0)
    home = c.plan.dims
    plan = c.plan
    # dominant tail: walk down the ladder
    seen = []
    for t in range(20):
        p = c.observe(t, _transcript([1.0] * 124 + [8.0]), plan)
        if p is not None:
            assert max(p.dims) < max(plan.dims)   # shrink only
            plan, seen = p, seen + [p.dims]
    assert seen, "tail never triggered a shrink"
    # flat profile: grow back toward — but never past — the home plan
    for t in range(20, 60):
        p = c.observe(t, _transcript([1.0] * 125), plan)
        if p is not None:
            plan = p
    assert plan.dims == home


def test_tail_aware_flat_profile_is_a_noop():
    """On flat finish times at the planner's own grid the controller
    proposes nothing — adaptive == static (the parity the federation
    test pins end to end)."""
    c = TailAwareController(plan_grid(64), window=2, cooldown=0)
    for t in range(12):
        assert c.observe(t, _transcript([1.0] * 64), c.plan) is None


def test_schedule_controller_fires_once_at_iteration():
    c = ScheduleController(plan_grid(125),
                           schedule=((3, (5, 25)),))
    plan = plan_grid(125)
    assert c.observe(0, _transcript([1.0] * 125), plan) is None
    p = c.observe(3, _transcript([1.0] * 125), plan)
    assert p is not None and p.dims == (5, 25) and p.n_peers == 125
    # already on the scheduled dims -> no-op
    assert c.observe(3, _transcript([1.0] * 125), p) is None


# ---------------------------------------------------------------------------
# federation wiring
# ---------------------------------------------------------------------------

def _leaves_equal(a, b):
    return all(bool((x == y).all())
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_tail_aware_matches_static_on_uniform_profile():
    """Flat-latency uniform links: the controller stays at the planner's
    grid and the run is bit-identical to the fixed-M federation."""
    base = FederationConfig(n_peers=8, technique="mar", task="text",
                            link_profile="uniform", seed=3)
    runs = {}
    for name, cfg in (("static", base),
                      ("tail", dataclasses.replace(
                          base, adaptive_m="tail_aware"))):
        fed = Federation(cfg)
        state = fed.init_state()
        for _ in range(5):
            state = fed.step(state)
        runs[name] = (fed, state)
    fed_t, state_t = runs["tail"]
    assert fed_t.regroup_log == []
    assert fed_t.plan.dims == runs["static"][0].plan.dims
    assert _leaves_equal(state_t.params, runs["static"][1].params)
    assert _leaves_equal(state_t.momentum, runs["static"][1].momentum)


def test_noop_regroup_is_bit_exact():
    cfg = FederationConfig(n_peers=8, technique="mar", task="text",
                           compress="int8_ef", seed=1)
    fed = Federation(cfg)
    state = fed.init_state()
    state = fed.step(state)
    # same dims: identity, same object
    assert fed.regroup(state, GridPlan(8, fed.plan.dims)) is state
    # different exact dims: peer state passes through bit-exact
    before = jax.tree.leaves((state.params, state.momentum, state.pipe))
    out = fed.regroup(state, GridPlan(8, (8,)))
    assert fed.plan.dims == (8,)
    after = jax.tree.leaves((out.params, out.momentum, out.pipe))
    for x, y in zip(before, after):
        assert bool((x == y).all())
    fed.step(out)                       # still steps cleanly


def test_regroup_rejects_membership_changes():
    fed = Federation(FederationConfig(n_peers=8, technique="mar",
                                      task="text"))
    state = fed.init_state()
    with pytest.raises(ValueError, match="regroup"):
        fed.regroup(state, plan_grid(12))


def test_scheduled_regroup_5cubed_to_5_25_survivor_parity():
    """The ISSUE acceptance scenario: 125 = 5^3 regroups to (5, 25)
    mid-run with no membership change; full participation keeps every
    exact grid at the exact global mean, so the regrouped run tracks
    the static one, and the transcript bytes match the mask-aware
    oracle on the new grid."""
    from repro.core import topology
    base = FederationConfig(n_peers=125, technique="mar", task="text",
                            seed=5)
    sched = dataclasses.replace(
        base, adaptive_m="schedule",
        adaptive_m_params={"schedule": ((0, (5, 25)),)})
    feds, states = {}, {}
    for name, cfg in (("static", base), ("sched", sched)):
        fed = Federation(cfg)
        state = fed.init_state()
        for _ in range(3):
            state = fed.step(state)
        feds[name], states[name] = fed, state
    fed = feds["sched"]
    assert fed.plan.dims == (5, 25)
    assert fed.regroup_log == [(0, (5, 5, 5), (5, 25))]
    # survivor parity: same exact global mean as the never-regrouped run
    for a, b in zip(jax.tree.leaves(states["sched"].params),
                    jax.tree.leaves(states["static"].params)):
        np.testing.assert_allclose(a, b, atol=2e-6)
    # byte accounting on the regrouped grid still matches the oracle
    mask = np.ones(125, np.float32)
    oracle = topology.mar_bytes(125, fed.plan, fed.model_bytes,
                                mask=mask)
    assert abs(fed.last_transcript.total_bytes - oracle) < 1.0


def test_tail_aware_regroups_under_wireless_tail():
    """End-to-end: heterogeneous wireless links trigger a shrink and
    byte parity holds on the post-regroup grid."""
    from repro.core import topology
    cfg = FederationConfig(
        n_peers=27, technique="mar", task="text", seed=2,
        link_profile="wireless", adaptive_m="tail_aware",
        adaptive_m_params={"window": 2, "cooldown": 0})
    fed = Federation(cfg)
    state = fed.init_state()
    for _ in range(6):
        state = fed.step(state)
    assert fed.regroup_log, "wireless tail never triggered a regroup"
    t, old, new = fed.regroup_log[0]
    assert max(new) < max(old)
    mask = np.ones(27, np.float32)
    oracle = topology.mar_bytes(27, fed.plan, fed.model_bytes,
                                mask=mask)
    assert abs(fed.last_transcript.total_bytes - oracle) < 1.0


# ---------------------------------------------------------------------------
# planner regressions (ISSUE 5 bugfix)
# ---------------------------------------------------------------------------

def test_plan_grid_rejects_undersized_explicit_grid():
    with pytest.raises(ValueError, match="capacity"):
        plan_grid(10, group_size=3, depth=2)     # 9 < 10
    with pytest.raises(ValueError, match="capacity"):
        plan_grid(125, group_size=5, depth=2)    # 25 < 125


def test_plan_grid_honors_explicit_grid():
    assert plan_grid(8, group_size=2, depth=3).dims == (2, 2, 2)
    assert plan_grid(125, group_size=5, depth=3).dims == (5, 5, 5)
    # padding is fine as long as the capacity holds N
    p = plan_grid(10, group_size=4, depth=2)
    assert p.dims == (4, 4) and p.capacity == 16


def test_plan_grid_depth_zero_is_explicit_not_unset():
    with pytest.raises(ValueError, match="depth"):
        plan_grid(8, group_size=2, depth=0)
    with pytest.raises(ValueError, match="depth"):
        plan_grid(8, depth=0)


def test_plan_grid_group_size_alone_still_autodeepens():
    assert plan_grid(125, group_size=5).dims == (5, 5, 5)
    assert plan_grid(125, group_size=3).dims == (3,) * 5


# ---------------------------------------------------------------------------
# launch-path validation (ISSUE 5 bugfix)
# ---------------------------------------------------------------------------

def test_planned_resizes_from_schedule():
    from repro.runtime.lifecycle import build_lifecycle
    lc = build_lifecycle(None, 8, schedule=((5, 12), (9, 6)))
    assert lc.planned_resizes(0, 20) == [(5, 12), (9, 6)]
    assert lc.planned_resizes(0, 5) == []
    assert lc.planned_resizes(6, 20) == [(9, 6)]


def test_planned_resizes_from_trace_is_pure(tmp_path):
    from repro.runtime.lifecycle import (MembershipEvent, build_lifecycle,
                                         save_trace)
    path = str(tmp_path / "trace.jsonl")
    save_trace(path, [MembershipEvent(2, "join", (8, 9)),
                      MembershipEvent(4, "leave", (9,))])
    lc = build_lifecycle("trace", 8, churn_params={"path": path})
    assert lc.planned_resizes(0, 10) == [(2, 10), (4, 9)]
    # pure look-ahead: the live model state is untouched
    assert lc.planned_resizes(0, 10) == [(2, 10), (4, 9)]
    assert lc.model.n_peers == 8
