"""Checkpointer (atomic, keep-k, elastic) and fault-tolerance policies."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.moshpit import GridPlan, plan_grid
from repro.runtime.fault import (HealthTracker, StragglerPolicy,
                                 elastic_replan, failure_impact)


def _tree(n_peers=4, seed=0):
    r = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(r.normal(size=(n_peers, 8, 4)),
                                    jnp.bfloat16),
                   "b": jnp.asarray(r.normal(size=(n_peers, 4)),
                                    jnp.float32)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(5, t, metadata={"n_peers": 4, "step": 5})
    got, meta = ck.restore(like=t)
    assert meta["step"] == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype  # bf16 preserved through npz


def test_keep_last_k(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree())
    assert ck.steps() == [3, 4]


def test_restore_without_like(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(), metadata={"n_peers": 4})
    got, _ = ck.restore()
    assert got["params"]["w"].shape == (4, 8, 4)


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(9, _tree(), blocking=False)
    ck.wait()
    assert ck.latest_step() == 9


def test_elastic_shrink_and_grow(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree(n_peers=4)
    ck.save(3, t, metadata={"n_peers": 4, "step": 3})
    small, _ = ck.restore_elastic(2)
    assert small["params"]["w"].shape[0] == 2
    big, _ = ck.restore_elastic(6)
    assert big["params"]["w"].shape[0] == 6
    # grown peers replicate existing ones cyclically
    np.testing.assert_array_equal(
        np.asarray(big["params"]["w"][4], np.float32),
        np.asarray(t["params"]["w"][0], np.float32))


def test_atomic_no_partial_dirs(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree())
    names = os.listdir(tmp_path)
    assert all(not n.startswith(".tmp") for n in names)


# ---------------------------------------------------------------------------
# fault policies
# ---------------------------------------------------------------------------

def test_health_tracker_timeout():
    h = HealthTracker(4, timeout_s=10.0)
    now = time.monotonic()
    h.heartbeat(0, now=now)
    h.heartbeat(1, now=now - 100)  # stale
    h.peers[1].last_heartbeat = now - 100
    dead = h.sweep(now=now)
    assert 1 in dead
    mask = h.alive_mask()
    assert mask[0] == 1.0 and mask[1] == 0.0


def test_straggler_policy():
    sp = StragglerPolicy(k_std=2.0)
    d = np.array([1.0, 1.1, 0.9, 1.0, 9.0], np.float32)
    mask = sp.mask(d)
    assert mask[-1] == 0.0 and mask[:4].all()


def test_elastic_replan_keeps_group_size():
    old = GridPlan(27, (3, 3, 3))
    new = elastic_replan(old, 81)
    assert new.dims == (3, 3, 3, 3)
    other = elastic_replan(old, 100)
    assert other.capacity >= 100


def test_failure_impact_single_group():
    """Paper claim: one dropout touches exactly one group per round."""
    p = GridPlan(125, (5, 5, 5))
    impact = failure_impact(p, [7])
    for g in range(3):
        assert impact[f"round_{g}_groups_touched"] == pytest.approx(1 / 25)
