"""Scan-aware HLO cost analysis: the foundations of §Roofline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.hlo_analysis import analyze_text, parse_module
from repro.runtime.roofline import (LINK_BW, PEAK_FLOPS, RooflineReport,
                                    model_flops_estimate)


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_raw_cost_analysis_misses_scan_trips():
    """Documents the defect that motivates hlo_analysis: XLA's own
    cost_analysis counts a scanned body once."""
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def f(x):
        def body(h, _):
            return h @ x, None
        return jax.lax.scan(body, x, None, length=10)[0]

    comp = _compile(f, a)
    ca = comp.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    one = 2 * 256 ** 3
    assert ca["flops"] == pytest.approx(one, rel=0.05)      # NOT 10x


def test_analyzer_multiplies_scan_trips():
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def f(x):
        def body(h, _):
            return h @ x, None
        return jax.lax.scan(body, x, None, length=10)[0]

    comp = _compile(f, a)
    r = analyze_text(comp.as_text())
    assert r["flops"] == pytest.approx(10 * 2 * 256 ** 3, rel=0.05)


def test_analyzer_counts_remat_recompute():
    """grad of checkpointed scan: fwd + recompute + 2 bwd matmuls per
    layer ~= 4x forward FLOPs — the 'useful fraction' denominator."""
    a = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def g(w, x):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(jax.checkpoint(body), x, None, length=8)
        return jnp.sum(h)

    comp = _compile(jax.grad(g), a, a)
    r = analyze_text(comp.as_text())
    assert r["flops"] == pytest.approx(4 * 8 * 2 * 128 ** 3, rel=0.15)


def test_nested_scan_trips_multiply():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(x):
        def outer(h, _):
            def inner(hh, _):
                return hh @ x, None
            h, _ = jax.lax.scan(inner, h, None, length=3)
            return h, None
        return jax.lax.scan(outer, x, None, length=5)[0]

    comp = _compile(f, a)
    r = analyze_text(comp.as_text())
    assert r["flops"] == pytest.approx(15 * 2 * 64 ** 3, rel=0.1)


def test_parse_module_finds_computations():
    a = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    comp = _compile(lambda x: jnp.tanh(x @ x), a)
    comps = parse_module(comp.as_text())
    assert any("main" in n for n in comps)
    n_instr = sum(len(c.instrs) for c in comps.values())
    assert n_instr > 0


def test_roofline_report_terms():
    rep = RooflineReport(
        arch="x", shape="train_4k", mesh="single", chips=256,
        hlo_flops_per_chip=PEAK_FLOPS,       # exactly 1 second of compute
        hlo_bytes_per_chip=0.0,
        collective_bytes_per_chip=LINK_BW * 2.0,   # 2 seconds of comms
        collective_detail={}, model_flops=PEAK_FLOPS * 256 * 0.5,
        memory_per_chip={})
    assert rep.compute_s == pytest.approx(1.0)
    assert rep.collective_s == pytest.approx(2.0)
    assert rep.dominant == "collective"
    assert rep.step_time_s == pytest.approx(2.0)
    assert rep.mfu == pytest.approx(0.25)
    assert rep.useful_flops_fraction == pytest.approx(0.5)


def test_model_flops_estimate():
    from repro.configs.base import SHAPES
    from repro.configs.registry import get_config
    cfg = get_config("granite-8b")
    t = model_flops_estimate(cfg, SHAPES["train_4k"], "train")
    assert t == pytest.approx(6 * cfg.param_count() * 256 * 4096, rel=0.05)
    d = model_flops_estimate(cfg, SHAPES["decode_32k"], "decode")
    assert d == pytest.approx(2 * cfg.param_count() * 128, rel=0.05)
