"""The unified elastic-membership contract (core/replan.py, DESIGN.md
§16): one MembershipChange from lifecycle signal to device backend, and
the SocketTransport address-book (multi-host) mode it rewires.
"""
import json
import os
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import build_pipeline
from repro.core.moshpit import GridPlan, plan_grid
from repro.core.replan import (MembershipChange, plan_membership_change,
                               regroup_change, resize_peer_axis,
                               resize_state_tree, select_survivors,
                               validate_membership_schedule)


# ---------------------------------------------------------------------------
# the contract itself
# ---------------------------------------------------------------------------

def test_plan_membership_change_replans_grid():
    change = plan_membership_change(plan_grid(16), 9, iteration=7)
    assert change.old_n == 16 and change.new_n == 9
    assert tuple(change.new_plan.dims) == (3, 3)
    assert change.new_plan.is_exact
    assert change.iteration == 7
    assert change.survivors == tuple(range(9))
    assert change.contiguous and change.n_joiners == 0

    grow = plan_membership_change(plan_grid(8), 12)
    assert tuple(grow.new_plan.dims) == (3, 2, 2)
    assert grow.n_joiners == 4 and grow.survivors == tuple(range(8))


def test_plan_membership_change_exact_only():
    # 10 has no exact grid (best factorization caps at 12)
    with pytest.raises(ValueError, match="no exact grid for 10"):
        plan_membership_change(plan_grid(8), 10, exact_only=True)
    # without the constraint the inexact plan is allowed (sim backend)
    change = plan_membership_change(plan_grid(8), 10)
    assert change.new_n == 10


def test_membership_change_validates_survivors():
    plan = plan_grid(4)
    with pytest.raises(ValueError):
        MembershipChange(old_n=6, new_n=4, new_plan=plan,
                         survivors=(0, 1, 2, 6))      # 6 not an old id
    with pytest.raises(ValueError):
        MembershipChange(old_n=6, new_n=4, new_plan=plan,
                         survivors=(0, 1, 2, 2))      # duplicate
    with pytest.raises(ValueError):
        MembershipChange(old_n=6, new_n=4, new_plan=plan,
                         survivors=(0, 1, 2, 3, 4))   # > new_n


def test_apply_to_tree_shrink_is_bit_exact():
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.normal(size=(16, 5)), jnp.float32)}
    change = plan_membership_change(plan_grid(16), 9)
    out = change.apply_to_tree(tree)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"])[:9])


def test_apply_to_tree_grow_bootstraps_joiners_from_mean():
    rng = np.random.default_rng(1)
    tree = {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)}
    change = plan_membership_change(plan_grid(8), 12)
    out = change.apply_to_tree(tree)
    np.testing.assert_array_equal(np.asarray(out["w"])[:8],
                                  np.asarray(tree["w"]))
    mean = np.asarray(tree["w"]).mean(0)
    for j in range(8, 12):
        np.testing.assert_allclose(np.asarray(out["w"])[j], mean,
                                   rtol=1e-6)


def test_apply_to_tree_non_contiguous_survivors():
    rng = np.random.default_rng(2)
    tree = {"w": jnp.asarray(rng.normal(size=(6, 3)), jnp.float32)}
    change = MembershipChange(old_n=6, new_n=4, new_plan=plan_grid(4),
                              survivors=(0, 2, 3, 5))
    assert not change.contiguous
    out = change.apply_to_tree(tree)
    np.testing.assert_array_equal(
        np.asarray(out["w"]), np.asarray(tree["w"])[[0, 2, 3, 5]])


def test_select_survivors_contiguous_fast_path():
    x = jnp.arange(12.0).reshape(6, 2)
    got = select_survivors({"x": x}, 6, (0, 1, 2))
    np.testing.assert_array_equal(np.asarray(got["x"]),
                                  np.asarray(x)[:3])


def test_resize_state_tree_zero_keys():
    own = {"err": jnp.ones((4, 3)), "scale": jnp.full((4,), 2.0)}
    out = resize_state_tree(own, 4, 6, zero_keys=("err",))
    np.testing.assert_array_equal(np.asarray(out["err"])[4:],
                                  np.zeros((2, 3)))
    np.testing.assert_allclose(np.asarray(out["scale"])[4:],
                               np.full((2,), 2.0))


def test_validate_membership_schedule_chains_plans():
    # 16 -> 9 -> 12 are all exact: fine
    validate_membership_schedule(plan_grid(16), [(3, 9), (7, 12)])
    # the second hop lands on 10 (inexact): the error names the step
    with pytest.raises(ValueError, match="step 7"):
        validate_membership_schedule(plan_grid(16), [(3, 9), (7, 10)])


def test_regroup_change_same_n():
    old = plan_grid(4)
    new = GridPlan(4, (4,))
    change = regroup_change(old, new)
    assert change.same_n and change.n_joiners == 0
    with pytest.raises(ValueError):
        regroup_change(old, plan_grid(9))


# ---------------------------------------------------------------------------
# per-stage wire-state semantics through the contract
# ---------------------------------------------------------------------------

def _pipe_pipelines(n, dims):
    plan = GridPlan(n, dims)
    kwargs = dict(async_aggregation=True, use_dp=True,
                  compress="int8_ef", noise_multiplier=0.0)
    return (build_pipeline("mar", plan, backend="device", **kwargs),
            build_pipeline("mar", plan, **kwargs))


def test_stage_roundtrip_16_12_16_device_matches_sim():
    """Shrink-then-regrow through every wire stage (async/dp/int8_ef):
    the device-backend pipeline applies the same per-stage rules as the
    sim pipeline — survivors' wire state rides bit-exact, joiners get
    the stage's bootstrap (EF residuals zero, DP markers zero, async
    buffers mean)."""
    dev, sim = _pipe_pipelines(16, (2, 2, 2, 2))
    rng = np.random.default_rng(3)
    leaves = {"p": {"w": jnp.asarray(rng.normal(size=(16, 5)),
                                     jnp.float32)}}
    pipe16 = dev.init_state(leaves)
    # put recognizable non-zero wire state everywhere
    pipe16 = jax.tree.map(
        lambda x: x + jnp.arange(x.shape[0], dtype=x.dtype).reshape(
            (-1,) + (1,) * (x.ndim - 1)) if x.ndim else x, pipe16)
    d12 = dev.resize_state(pipe16, 16, 12)
    s12 = sim.resize_state(pipe16, 16, 12)
    for a, b in zip(jax.tree.leaves(d12), jax.tree.leaves(s12)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # shrink is a pure prefix slice on every peer-stacked stage leaf
    # (scalar leaves — DP clip, async counters — carry over untouched)
    for before, after in zip(jax.tree.leaves(pipe16),
                             jax.tree.leaves(d12)):
        b, a = np.asarray(before), np.asarray(after)
        np.testing.assert_array_equal(b[:12] if b.ndim else b, a)
    # regrow: survivors exact, EF residuals of joiners zero
    d16 = dev.resize_state(d12, 12, 16)
    for mid, back in zip(jax.tree.leaves(d12), jax.tree.leaves(d16)):
        m, k = np.asarray(mid), np.asarray(back)
        np.testing.assert_array_equal(m, k[:12] if k.ndim else k)
    err16 = d16["int8_ef"]["err"]["w"]
    np.testing.assert_array_equal(np.asarray(err16)[12:],
                                  np.zeros((4, 5)))
    dp16 = d16["dp"]["has_delta"]
    np.testing.assert_array_equal(np.asarray(dp16)[12:], np.zeros(4))


# ---------------------------------------------------------------------------
# device backend: mid-run membership through the contract
# ---------------------------------------------------------------------------

class _ToyModel:
    """Duck-typed stand-in for models.model.Model: linear regression."""

    def __init__(self, dim=3):
        self.dim = dim

    def init(self, key):
        return {"w": jax.random.normal(key, (self.dim,), jnp.float32)}

    def loss(self, params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean(jnp.square(pred - batch["y"]))


def _toy_batch(n, rng):
    return {
        "x": jnp.asarray(rng.normal(size=(n, 2, 1, 8, 3)), jnp.float32),
        "y": jnp.asarray(rng.normal(size=(n, 2, 1, 8)), jnp.float32),
    }


def test_device_apply_membership_mid_run():
    """Scheduled shrink+grow on the device backend, no relaunch: state
    maps through the contract, the step re-jits for each new exact
    grid, and training continues."""
    from repro.core.fl_device import (apply_membership, init_fl_state,
                                      make_fl_train_step)
    model = _ToyModel()
    grid = GridPlan(4, (2, 2))
    pipeline = build_pipeline("mar", grid, backend="device",
                              compress="int8_ef")
    state = init_fl_state(model, 4, jax.random.PRNGKey(0),
                          pipeline=pipeline)
    step = jax.jit(make_fl_train_step(model, grid, lr=0.05,
                                      pipeline=pipeline))
    rng = np.random.default_rng(0)
    state, _ = step(state, _toy_batch(4, rng))

    # grow 4 -> 6 (exact grid (3, 2))
    change = plan_membership_change(grid, 6, iteration=1,
                                    exact_only=True)
    before = np.asarray(state["params"]["w"])
    state, pipeline = apply_membership(state, change, pipeline)
    grid = change.new_plan
    assert grid.is_exact and grid.n_peers == 6
    got = np.asarray(state["params"]["w"])
    np.testing.assert_array_equal(got[:4], before)        # survivors
    np.testing.assert_allclose(
        got[4:], np.broadcast_to(before.mean(0), (2, 3)),
        rtol=1e-6)                                         # joiners
    step = jax.jit(make_fl_train_step(model, grid, lr=0.05,
                                      pipeline=pipeline))
    state, metrics = step(state, _toy_batch(6, rng))
    assert bool(jnp.isfinite(metrics["loss"]))

    # shrink 6 -> 4: survivors bit-exact again, next step still runs
    change = plan_membership_change(grid, 4, iteration=2,
                                    exact_only=True)
    before = np.asarray(state["params"]["w"])
    state, pipeline = apply_membership(state, change, pipeline)
    grid = change.new_plan
    np.testing.assert_array_equal(np.asarray(state["params"]["w"]),
                                  before[:4])
    step = jax.jit(make_fl_train_step(model, grid, lr=0.05,
                                      pipeline=pipeline))
    state, metrics = step(state, _toy_batch(4, rng))
    assert bool(jnp.isfinite(metrics["loss"]))


def test_device_apply_membership_checks_old_n():
    from repro.core.fl_device import apply_membership, init_fl_state
    state = init_fl_state(_ToyModel(), 4, jax.random.PRNGKey(0))
    change = plan_membership_change(plan_grid(6), 4)
    with pytest.raises(ValueError, match="planned for 6"):
        apply_membership(state, change)


# ---------------------------------------------------------------------------
# checkpoint restore across a peer-axis mismatch
# ---------------------------------------------------------------------------

def test_checkpointer_restore_remaps_peer_axis(tmp_path):
    from repro.checkpoint.checkpointer import Checkpointer
    rng = np.random.default_rng(4)
    saved = {"params": {"w": jnp.asarray(rng.normal(size=(4, 3)),
                                         jnp.float32)},
             "step": jnp.zeros((), jnp.int32)}
    ckpt = Checkpointer(os.fspath(tmp_path))
    ckpt.save(10, saved, metadata={"step": 10, "n_peers": 4})
    like = {"params": {"w": jnp.zeros((6, 3), jnp.float32)},
            "step": jnp.zeros((), jnp.int32)}
    tree, meta = ckpt.restore(like=like)
    got = np.asarray(tree["params"]["w"])
    np.testing.assert_array_equal(got[:4],
                                  np.asarray(saved["params"]["w"]))
    np.testing.assert_allclose(
        got[4:],
        np.broadcast_to(np.asarray(saved["params"]["w"]).mean(0),
                        (2, 3)), rtol=1e-6)
    assert meta["n_peers"] == 4


# ---------------------------------------------------------------------------
# the transport registry + address book
# ---------------------------------------------------------------------------

def test_build_transport_unknown_name_lists_registry():
    from repro.runtime.transport_base import (available_transports,
                                              build_transport)
    names = available_transports()
    assert {"sim", "socket", "vector_sim", "super_sim"} <= set(names)
    with pytest.raises(ValueError, match="registered"):
        build_transport("quantum_tunnel", 4)


def test_address_book_json_roundtrip(tmp_path):
    from repro.runtime.socket_transport import AddressBook
    book = AddressBook(hosts=("10.0.0.1", "10.0.0.2"),
                       ports=(9101, 9101), ranks=(0, 1))
    path = os.fspath(tmp_path / "book.json")
    book.to_json(path)
    assert AddressBook.from_json(path) == book
    # compact string entries parse too
    doc = {"nodes": ["10.0.0.1:9101:0", "10.0.0.2:9101"]}
    got = AddressBook.from_dict(doc)
    assert got.hosts == ("10.0.0.1", "10.0.0.2")
    assert got.ranks == (0, 0)
    assert book.world_size == 2 and book.owned(1) == (1,)


def test_socket_book_resize_rejects_growth_past_book():
    from repro.runtime.socket_transport import (AddressBook,
                                                SocketTransport)
    book = AddressBook.loopback(4, world_size=1)
    t = SocketTransport(4, address_book=book, rank=0)
    t.resize(3)               # shrink: fine, survivors keep endpoints
    with pytest.raises(ValueError, match="extend the book"):
        t.resize(5)
    with pytest.raises(ValueError, match="extend"):
        SocketTransport(6, address_book=book, rank=0)


def test_socket_two_rank_book_byte_exact_vs_sim():
    """Two SocketTransport ranks (own event loops, cross-rank TCP on
    fixed book ports) merge byte-exact vs the simulator — the in-process
    version of the two-process calibration gate."""
    from repro.core.transport import build_message_plan
    from repro.runtime.network import NetworkSim
    from repro.runtime.socket_transport import (AddressBook,
                                                SocketTransport,
                                                merge_transcripts)
    n = 4
    grid = plan_grid(n)
    plans = [build_message_plan(t, grid, None, 1000.0)
             for t in ("mar", "ar", "fedavg")]
    n_nodes = max(max(p.n_nodes for p in plans), n)
    book = AddressBook.loopback(n_nodes, world_size=2)
    t0 = SocketTransport(n, seed=0, address_book=book, rank=0)
    t1 = SocketTransport(n, seed=0, address_book=book, rank=1)
    sim = NetworkSim.from_config(n, profile="uniform", seed=0)
    try:
        for p in plans:
            with ThreadPoolExecutor(2) as ex:
                parts = [ex.submit(t0.run, p), ex.submit(t1.run, p)]
                merged = merge_transcripts([f.result() for f in parts])
            ref = sim.run(p)
            assert merged.total_bytes == ref.total_bytes
            assert merged.bytes_by_round == ref.bytes_by_round
            assert merged.bytes_by_link == ref.bytes_by_link
            assert merged.n_messages == ref.n_messages
    finally:
        t0.close()
        t1.close()


def test_resize_peer_axis_reexport_unchanged():
    # the historical import path still works (aggregation re-exports)
    from repro.core.aggregation import resize_peer_axis as via_agg
    assert via_agg is resize_peer_axis
    x = {"w": jnp.arange(8.0).reshape(4, 2)}
    out = resize_peer_axis(x, 4, 2)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.arange(4.0).reshape(2, 2))
