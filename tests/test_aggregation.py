"""Composable aggregation pipeline: registry, wire-stage parity,
CommLedger regression against the analytic topology models, and
sim/device backend parity under masks + compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import mar_allreduce as mar
from repro.core import topology
from repro.core.aggregation import (AGGREGATORS, TECHNIQUES,
                                    AggregationPipeline, AsyncStage,
                                    CommLedger, DPStage, Int8EFStage,
                                    MarAggregator, build_pipeline,
                                    finalize_masked_mean, make_aggregator)
from repro.core.federation import Federation, FederationConfig
from repro.core.moshpit import GridPlan, plan_grid


def _state(n, dim=7, seed=0):
    x = np.random.default_rng(seed).normal(size=(n, dim)).astype(np.float32)
    return {"p": jnp.asarray(x), "m": jnp.asarray(0.1 * x)}


# ---------------------------------------------------------------------------
# strategy layer: registry
# ---------------------------------------------------------------------------

def test_registry_contents():
    assert {"mar", "fedavg", "ar", "rdfl", "gossip",
            "hierarchical"} <= set(AGGREGATORS)
    assert TECHNIQUES == tuple(AGGREGATORS)


def test_make_aggregator_rejects_unknown():
    with pytest.raises(ValueError):
        make_aggregator("carrier-pigeon", plan_grid(8))


def test_device_backend_gated_to_supported():
    with pytest.raises(ValueError):
        make_aggregator("gossip", plan_grid(8), backend="device")
    agg = make_aggregator("mar", plan_grid(16), backend="device")
    assert agg.backend == "device"


def test_exact_mean_family_agrees_under_churn():
    """The global-mean family returns the same masked global mean (MAR
    is only exact under full participation, so it is tested below)."""
    p = plan_grid(16)
    s = _state(16)
    mask = jnp.asarray(np.random.default_rng(1).integers(0, 2, 16),
                       jnp.float32).at[0].set(1.0)
    want = make_aggregator("ar", p)(s, mask)["p"]
    for name in ("fedavg", "rdfl", "hierarchical"):
        got = make_aggregator(name, p)(s, mask)["p"]
        np.testing.assert_allclose(got, want, atol=1e-5, err_msg=name)


def test_all_techniques_exact_under_full_participation():
    p = plan_grid(16)
    s = _state(16)
    mask = jnp.ones((16,), jnp.float32)
    gm = jnp.mean(s["p"], 0, keepdims=True)
    for name in TECHNIQUES:
        got = make_aggregator(name, p)(s, mask)["p"]
        np.testing.assert_allclose(got, jnp.broadcast_to(gm, got.shape),
                                   atol=1e-5, err_msg=name)


def test_gossip_exact_for_power_of_two():
    s = _state(16)
    out = mar.gossip_aggregate_sim(s)
    gm = jnp.mean(s["p"], 0, keepdims=True)
    np.testing.assert_allclose(out["p"], jnp.broadcast_to(gm, (16, 7)),
                               atol=1e-5)


@given(st.integers(3, 30), st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_gossip_masked_convexity(n, seed):
    """Push-sum gossip outputs stay inside the input hull (any N)."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 3)).astype(np.float32)
    mask = (rng.random(n) < 0.7).astype(np.float32)
    out = mar.gossip_aggregate_sim({"p": jnp.asarray(x)},
                                   jnp.asarray(mask))["p"]
    assert float(jnp.max(out)) <= x.max() + 1e-4
    assert float(jnp.min(out)) >= x.min() - 1e-4
    assert bool(jnp.all(jnp.isfinite(out)))


def test_finalize_masked_mean_empty_group_keeps_own():
    num = jnp.zeros((4, 2))
    den = jnp.asarray([0.0, 2.0, 0.0, 1.0]).reshape(-1, 1)
    own = jnp.arange(8.0).reshape(4, 2)
    out = finalize_masked_mean(num, den, own)
    np.testing.assert_allclose(out[0], own[0])
    np.testing.assert_allclose(out[2], own[2])
    np.testing.assert_allclose(out[1], 0.0)


# ---------------------------------------------------------------------------
# wire-stage layer: composition parity under full participation
# ---------------------------------------------------------------------------

def _run_pipeline_twice(pipeline, s):
    """Apply a (possibly stateful/delayed) pipeline twice on a static
    state; the second output has absorbed any staleness-1 delay."""
    n = s["p"].shape[0]
    mask = jnp.ones((n,), jnp.float32)
    pipe = pipeline.init_state(jax.tree.map(jnp.zeros_like, s))
    out, pipe = pipeline(s, pipe, mask, jax.random.PRNGKey(0))
    out, pipe = pipeline(s, pipe, mask, jax.random.PRNGKey(1))
    return out


@pytest.mark.parametrize("stages", [
    ("int8_ef",), ("async",), ("async", "int8_ef")])
def test_stage_composition_matches_plain(stages):
    """Each wire-stage composition matches the plain aggregator within
    tolerance under full participation (quantization error bounded by
    the int8 grid; staleness absorbed by a repeated static state)."""
    p = plan_grid(16)
    s = _state(16, seed=2)
    plain = MarAggregator(p)(s, jnp.ones((16,), jnp.float32))
    mk = {"int8_ef": Int8EFStage, "async": AsyncStage}
    pipeline = AggregationPipeline(MarAggregator(p),
                                   [mk[name]() for name in stages])
    out = _run_pipeline_twice(pipeline, s)
    atol = 0.05 if "int8_ef" in stages else 1e-5
    np.testing.assert_allclose(out["p"], plain["p"], atol=atol)
    np.testing.assert_allclose(out["m"], plain["m"], atol=1e-5)


def test_dp_stage_threads_state_and_strips_extras():
    p = plan_grid(8)
    s = _state(8, seed=3)
    pipeline = AggregationPipeline(
        MarAggregator(p), [DPStage(p, noise_multiplier=0.3)])
    pipe = pipeline.init_state(s)
    clip0 = float(pipe["dp"]["clip"])
    out, pipe = pipeline(s, pipe, jnp.ones((8,), jnp.float32),
                         jax.random.PRNGKey(0))
    assert set(out) == {"p", "m"}
    assert float(pipe["dp"]["clip"]) != clip0
    for leaf in jax.tree.leaves(out):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_duplicate_stages_rejected():
    with pytest.raises(ValueError):
        AggregationPipeline(MarAggregator(plan_grid(8)),
                            [Int8EFStage(), Int8EFStage()])


# ---------------------------------------------------------------------------
# previously-asserted-out combinations converge (acceptance criteria)
# ---------------------------------------------------------------------------

def _accuracy(cfg, iters=20):
    fed = Federation(cfg)
    state = fed.init_state()
    for _ in range(iters):
        state = fed.step(state)
    return fed.evaluate(state)


@pytest.mark.slow
def test_compress_dp_composes_and_converges():
    """compress + DP (quantize-after-noising) stays within 2 points of
    the uncompressed DP run in a 20-iteration smoke test."""
    base = dict(n_peers=8, technique="mar", task="text", local_batches=4,
                use_dp=True, noise_multiplier=0.3, seed=3)
    acc_dp = _accuracy(FederationConfig(**base))
    acc_both = _accuracy(FederationConfig(**base, compress="int8_ef"))
    assert acc_both >= acc_dp - 0.02


@pytest.mark.slow
def test_async_compress_composes_and_converges():
    base = dict(n_peers=8, technique="mar", task="text", local_batches=4,
                async_aggregation=True, seed=3)
    acc_async = _accuracy(FederationConfig(**base))
    acc_both = _accuracy(FederationConfig(**base, compress="int8_ef"))
    assert acc_both >= acc_async - 0.02


# ---------------------------------------------------------------------------
# accounting layer: CommLedger vs analytic topology models
# ---------------------------------------------------------------------------

def test_ledger_basic_bookkeeping():
    led = CommLedger()
    led.record("a", 10)
    led.record("a", 5)
    led.record("b", 1)
    assert led.total_bytes == 16
    assert led.by_source == {"a": 15.0, "b": 1.0}
    led.reset()
    assert led.total_bytes == 0 and led.by_source == {}


@pytest.mark.parametrize("tech", ["mar", "fedavg", "ar", "rdfl", "gossip",
                                  "hierarchical"])
def test_ledger_matches_analytic_on_legacy_paths(tech):
    """Regression (acceptance): reported comm bytes come from the
    CommLedger and equal topology.iteration_bytes on legacy paths."""
    cfg = FederationConfig(n_peers=8, technique=tech, task="text", seed=1)
    fed = Federation(cfg)
    state = fed.init_state()
    for _ in range(3):
        state = fed.step(state)
    analytic = 3 * topology.iteration_bytes(tech, 8, fed.model_bytes,
                                            fed.plan)
    assert fed.comm_bytes == pytest.approx(analytic)
    assert sum(fed.ledger.by_source.values()) == pytest.approx(analytic)


def test_ledger_async_kd_regression():
    """Regression for the seed bug: _step_async dropped use_kd /
    kd_logit_bytes from its accounting, undercounting KD iterations.
    The CommLedger path must charge async+KD exactly like sync+KD."""
    kw = dict(n_peers=8, technique="mar", task="text", use_kd=True,
              kd_iterations=2, seed=5)
    comms = {}
    for mode in (False, True):
        cfg = FederationConfig(**kw, async_aggregation=mode)
        fed = Federation(cfg)
        state = fed.init_state()
        for _ in range(3):          # 2 KD iterations + 1 plain
            state = fed.step(state)
        comms[mode] = fed.comm_bytes
        analytic = (
            2 * topology.iteration_bytes(
                "mar", 8, fed.model_bytes, fed.plan, use_kd=True,
                kd_logit_bytes=fed._kd_logit_bytes())
            + topology.iteration_bytes("mar", 8, fed.model_bytes,
                                       fed.plan))
        assert fed.comm_bytes == pytest.approx(analytic)
        assert fed.ledger.by_source["kd"] > 0
    assert comms[True] == pytest.approx(comms[False])


def test_gossip_ledger_rounds_independent_of_churn():
    """Regression: gossip's ring covers all N peers regardless of how
    many participate, so the byte model must use ceil(log2 N) rounds —
    not a round count derived from the (smaller) active set."""
    cfg = FederationConfig(n_peers=16, technique="gossip", task="text",
                           participation_rate=0.5, seed=2)
    fed = Federation(cfg)
    state = fed.init_state()
    state = fed.step(state)
    u, a = fed.sample_masks(
        np.random.default_rng(cfg.seed * 100003 + 0))
    n_active = int(a.sum())
    assert n_active < 16                 # churn actually happened
    analytic = topology.iteration_bytes(
        "gossip", n_active, fed.model_bytes, fed.plan, num_rounds=4)
    assert fed.comm_bytes == pytest.approx(analytic)


def test_one_shot_all_dropped_keeps_state():
    """Regression: the fused one-shot device mean shares the
    finalize_masked_mean churn fallback — an all-dropped aggregation
    carries peer state forward instead of zeroing it."""
    p = GridPlan(4, (2, 2))
    s = _state(4, seed=7)
    out = mar.mar_aggregate_device(s, p, jnp.zeros((4,), jnp.float32),
                                   one_shot=True)
    np.testing.assert_allclose(out["p"], s["p"], atol=1e-6)
    np.testing.assert_allclose(out["m"], s["m"], atol=1e-6)


def test_ledger_compression_ratio():
    from repro.core.compression import INT8_RATIO
    p = plan_grid(16)
    plain = AggregationPipeline(MarAggregator(p))
    comp = AggregationPipeline(MarAggregator(p), [Int8EFStage()])
    assert comp.iteration_bytes(16, 1000) == pytest.approx(
        plain.iteration_bytes(16, 1000) / INT8_RATIO)


# ---------------------------------------------------------------------------
# execution layer: sim/device parity under masks + compression
# ---------------------------------------------------------------------------

@given(st.integers(2, 4), st.integers(1, 3), st.integers(0, 100))
@settings(max_examples=15, deadline=None)
def test_device_sim_parity_under_masks(m, d, seed):
    """Acceptance: the device backend accepts a participation mask and
    matches the sim backend on the same grid."""
    n = m ** d
    p = GridPlan(n, (m,) * d)
    rng = np.random.default_rng(seed)
    s = {"p": jnp.asarray(rng.normal(size=(n, 5)), jnp.float32),
         "m": jnp.asarray(rng.normal(size=(n, 5)), jnp.float32)}
    mask = (rng.random(n) < 0.7).astype(np.float32)
    if mask.sum() == 0:
        mask[0] = 1.0
    mask = jnp.asarray(mask)
    sim = MarAggregator(p, backend="sim")(s, mask)
    dev = MarAggregator(p, backend="device")(s, mask)
    np.testing.assert_allclose(sim["p"], dev["p"], atol=1e-5)
    np.testing.assert_allclose(sim["m"], dev["m"], atol=1e-5)


def test_device_sim_parity_with_compression():
    p = GridPlan(16, (4, 4))
    s = _state(16, seed=4)
    mask = jnp.asarray(np.random.default_rng(4).random(16) < 0.8,
                       jnp.float32).at[0].set(1.0)
    outs = {}
    for backend in ("sim", "device"):
        pipeline = AggregationPipeline(
            MarAggregator(p, backend=backend), [Int8EFStage()])
        pipe = pipeline.init_state(jax.tree.map(jnp.zeros_like, s))
        outs[backend], _ = pipeline(s, pipe, mask, jax.random.PRNGKey(0))
    np.testing.assert_allclose(outs["sim"]["p"], outs["device"]["p"],
                               atol=1e-5)


@pytest.mark.parametrize("n,dims", [(16, (4, 4)), (27, (3, 3, 3)),
                                    (12, (4, 4))])  # last one: padded grid
def test_pallas_group_mean_kernel_parity(n, dims):
    """The fused Pallas group_mean kernel matches the jnp segment-sum
    path on the aggregation output — exact and virtual-slot grids,
    churn masks, mixed-rank leaves."""
    p = GridPlan(n, dims)
    rng = np.random.default_rng(n)
    s = {"p": jnp.asarray(rng.normal(size=(n, 5, 3)), jnp.float32),
         "m": jnp.asarray(rng.normal(size=(n,)), jnp.float32)}
    mask = (rng.random(n) < 0.7).astype(np.float32)
    mask[0] = 1.0
    mask = jnp.asarray(mask)
    ref = mar.mar_aggregate_sim(s, p, mask)
    ker = mar.mar_aggregate_sim(s, p, mask, use_kernel=True)
    np.testing.assert_allclose(ker["p"], ref["p"], atol=1e-6)
    np.testing.assert_allclose(ker["m"], ref["m"], atol=1e-6)


def test_pallas_group_mean_in_federation_hot_path():
    """FederationConfig(pallas_group_mean=True) routes sim MAR through
    the kernel and trains to the same parameters as the jnp path."""
    results = {}
    for flag in (False, True):
        cfg = FederationConfig(n_peers=8, technique="mar", task="text",
                               pallas_group_mean=flag, seed=6)
        fed = Federation(cfg)
        assert fed.pipeline.aggregator.use_kernel is flag
        state = fed.init_state()
        for _ in range(2):
            state = fed.step(state)
        results[flag] = jax.tree.leaves(state.params)[0]
    np.testing.assert_allclose(results[True], results[False], atol=1e-5)


class _ToyModel:
    """Duck-typed stand-in for models.model.Model: linear regression."""

    def __init__(self, dim=3):
        self.dim = dim

    def init(self, key):
        return {"w": jax.random.normal(key, (self.dim,), jnp.float32)}

    def loss(self, params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean(jnp.square(pred - batch["y"]))


def test_fl_train_step_mask_and_compression():
    """Acceptance: make_fl_train_step accepts a participation mask and
    compress="int8_ef"; masked-out peers carry state forward."""
    from repro.core.fl_device import init_fl_state, make_fl_train_step
    model = _ToyModel()
    grid = GridPlan(4, (2, 2))
    pipeline = build_pipeline("mar", grid, backend="device",
                              compress="int8_ef")
    state = init_fl_state(model, 4, jax.random.PRNGKey(0),
                          pipeline=pipeline)
    assert "pipe" in state and "int8_ef" in state["pipe"]
    step = make_fl_train_step(model, grid, lr=0.05, pipeline=pipeline)
    rng = np.random.default_rng(0)
    batch = {
        "x": jnp.asarray(rng.normal(size=(4, 2, 1, 8, 3)), jnp.float32),
        "y": jnp.asarray(rng.normal(size=(4, 2, 1, 8)), jnp.float32),
    }
    mask = jnp.asarray([1.0, 1.0, 0.0, 1.0])
    state1, metrics = step(state, batch, mask)
    assert bool(jnp.isfinite(metrics["loss"]))
    # masked-out peer contributed nothing, but received its group mean
    assert int(state1["step"]) == 1
    for leaf in jax.tree.leaves(state1["params"]):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    # second step exercises the carried EF residual structure
    state2, _ = step(state1, batch, mask)
    assert int(state2["step"]) == 2


def test_fl_train_step_requires_pipe_state_for_stages():
    from repro.core.fl_device import init_fl_state, make_fl_train_step
    model = _ToyModel()
    grid = GridPlan(4, (2, 2))
    pipeline = build_pipeline("mar", grid, backend="device",
                              compress="int8_ef")
    state = init_fl_state(model, 4, jax.random.PRNGKey(0))  # no pipe
    step = make_fl_train_step(model, grid, pipeline=pipeline)
    batch = {"x": jnp.zeros((4, 1, 1, 2, 3)), "y": jnp.zeros((4, 1, 1, 2))}
    with pytest.raises(ValueError):
        step(state, batch)
