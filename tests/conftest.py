"""Shared fixtures. NOTE: no XLA device-count forcing here — smoke tests
run on the single real CPU device; mesh-dependent tests spawn
subprocesses that set XLA_FLAGS before importing jax."""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running (dry-run scale)")
