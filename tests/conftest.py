"""Shared fixtures. NOTE: no XLA device-count forcing here — smoke tests
run on the single real CPU device; mesh-dependent tests spawn
subprocesses that set XLA_FLAGS before importing jax.

Also provides a conftest-level fallback for ``hypothesis`` (declared as
an optional test dependency in pyproject.toml): when the real library is
absent, a deterministic mini-shim is installed into ``sys.modules`` so
the property-test modules still *collect and run* — each ``@given`` test
executes over a fixed-seed sample of its strategies instead of erroring
out at import (the importorskip-style alternative would silently drop
every non-property test in those modules too).
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import hypothesis  # noqa: F401
except ImportError:
    import types

    _SHIM_MAX_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value, max_value):
        return _Strategy(
            lambda r: int(r.integers(min_value, max_value + 1)))

    def _floats(min_value, max_value, **_kw):
        return _Strategy(lambda r: float(r.uniform(min_value, max_value)))

    def _booleans():
        return _Strategy(lambda r: bool(r.integers(0, 2)))

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda r: elements[int(r.integers(len(elements)))])

    def _just(value):
        return _Strategy(lambda r: value)

    def _given(*strategies, **kw_strategies):
        def decorate(fn):
            # deliberately zero-arg (and no functools.wraps): the
            # drawn parameters must not look like pytest fixtures
            def wrapper():
                n = getattr(wrapper, "_shim_max_examples",
                            getattr(fn, "_shim_max_examples",
                                    _SHIM_MAX_EXAMPLES))
                rng = np.random.default_rng(0)
                for _ in range(min(n, _SHIM_MAX_EXAMPLES)):
                    pos = tuple(s.draw(rng) for s in strategies)
                    kws = {k: s.draw(rng)
                           for k, s in kw_strategies.items()}
                    fn(*pos, **kws)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return decorate

    def _settings(**kw):
        def decorate(fn):
            fn._shim_max_examples = kw.get("max_examples",
                                           _SHIM_MAX_EXAMPLES)
            return fn
        return decorate

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.booleans = _booleans
    _st.sampled_from = _sampled_from
    _st.just = _just

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__shim__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running (dry-run scale)")
