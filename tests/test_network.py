"""Discrete-event network layer: transport plans, link models, and the
transcript-vs-analytic parity contract (ISSUE 3 acceptance).

The closed-form byte models in ``core/topology.py`` are *oracles* now:
the ledger is fed from measured transcripts, and this suite pins the
two to each other in the no-loss case — for every registered technique,
at several peer counts, under full participation (and, for the
mask-aware MAR model, under churn masks too).
"""
import numpy as np
import pytest

from repro.core import topology, transport
from repro.core.aggregation import (AggregationPipeline, Int8EFStage,
                                    MarAggregator, TECHNIQUES,
                                    make_aggregator)
from repro.core.federation import Federation, FederationConfig
from repro.core.moshpit import plan_grid
from repro.runtime.lifecycle import build_churn_model
from repro.runtime.network import (LINK_MODELS, NetworkSim,
                                   build_link_model)

MB = 10_000   # model-state bytes per transfer (small, exact in float)


# ---------------------------------------------------------------------------
# transcript-vs-analytic parity (the acceptance property)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [8, 16, 27, 64])
@pytest.mark.parametrize("tech", sorted(TECHNIQUES))
def test_transcript_matches_analytic_full_participation(tech, n):
    """loss=0, full participation: NetworkSim measured bytes equal
    topology.iteration_bytes for every registered technique."""
    plan = plan_grid(n)
    agg = make_aggregator(tech, plan)
    mplan = agg.message_plan(np.ones(n, np.float32), MB)
    tr = NetworkSim(n, profile="uniform", seed=0).run(mplan)
    analytic = topology.iteration_bytes(tech, n, MB, plan,
                                        num_rounds=agg.num_rounds)
    assert tr.total_bytes == pytest.approx(analytic)
    assert tr.n_dropped == 0
    assert tr.iteration_s > 0.0


@pytest.mark.parametrize("n", [16, 27, 64])
def test_mar_mask_aware_parity_under_churn(n):
    """The mask-aware topology.mar_bytes fix: exact per-group analytic
    accounting equals the transcript for arbitrary churn masks."""
    plan = plan_grid(n)
    agg = make_aggregator("mar", plan)
    for seed in range(6):
        rng = np.random.default_rng(seed)
        mask = (rng.random(n) < 0.6).astype(np.float32)
        tr = NetworkSim(n, profile="uniform", seed=0).run(
            agg.message_plan(mask, MB))
        analytic = topology.iteration_bytes(
            "mar", int(mask.sum()), MB, plan, mask=mask)
        assert tr.total_bytes == pytest.approx(analytic)


def test_mar_bytes_countonly_no_longer_overbills():
    """Regression (satellite): with a churn-reduced active count the
    count-only formula must not bill senders for dropped group mates;
    it now scales by the active-pair fraction and upper-bounds at the
    full-participation constant."""
    plan = plan_grid(27)
    full = topology.mar_bytes(27, plan, MB)
    half = topology.mar_bytes(14, plan, MB)
    old_half = 14 * 2 * 3 * MB          # 14 senders x (M-1) x G rounds
    assert full == 27 * 2 * 3 * MB      # paper constant unchanged
    assert half < old_half              # the fix: fewer active pairs
    assert half == pytest.approx(old_half * 13 / 26, rel=0.01)


def test_mar_mask_parity_padded_grid():
    """Non-exact grids (capacity > N) pad with virtual slots; the
    mask-aware analytic and the transcript agree there too."""
    plan = plan_grid(10)                 # (3, 2, 2): 12 slots, 10 peers
    assert plan.capacity > plan.n_peers
    mask = np.ones(10, np.float32)
    agg = make_aggregator("mar", plan)
    tr = NetworkSim(10, profile="uniform", seed=0).run(
        agg.message_plan(mask, MB))
    analytic = topology.iteration_bytes("mar", 10, MB, plan, mask=mask)
    assert tr.total_bytes == pytest.approx(analytic)


def test_compression_shrinks_time_and_bytes():
    plan = plan_grid(16)
    plain = AggregationPipeline(MarAggregator(plan))
    comp = AggregationPipeline(MarAggregator(plan), [Int8EFStage()])
    mask = np.ones(16, np.float32)
    t_plain = NetworkSim(16, "wireless", seed=1).run(
        plain.message_plan(mask, MB, 16))
    t_comp = NetworkSim(16, "wireless", seed=1).run(
        comp.message_plan(mask, MB, 16))
    assert t_comp.total_bytes == pytest.approx(t_plain.total_bytes / 4)
    assert t_comp.iteration_s < t_plain.iteration_s


# ---------------------------------------------------------------------------
# link models
# ---------------------------------------------------------------------------

def test_link_registry_and_unknown_profile():
    assert {"uniform", "wireless", "regions"} <= set(LINK_MODELS)
    with pytest.raises(ValueError, match="unknown link profile"):
        build_link_model("dialup", 8)


def test_wireless_links_heterogeneous_and_deterministic():
    a = build_link_model("wireless", 32, seed=3)
    b = build_link_model("wireless", 32, seed=3)
    np.testing.assert_array_equal(a.up, b.up)
    assert a.up.std() > 0 and a.lat.std() > 0
    c = build_link_model("wireless", 32, seed=4)
    assert not np.array_equal(a.up, c.up)


def test_region_links_tiered():
    m = build_link_model("regions", 12, seed=0, n_regions=3, jitter=0.0)
    region = m.region_of()
    assert set(region) == {0, 1, 2}
    # within a region links are identical (jitter 0); tiers differ
    for r in range(3):
        assert np.allclose(m.up[region == r], m.up[region == r][0])
    assert m.up[0] != m.up[-1]


def test_link_resize_keeps_survivors():
    m = build_link_model("wireless", 16, seed=5)
    up8 = m.up[:8].copy()
    m.resize(8)
    np.testing.assert_array_equal(m.up, up8)
    m.resize(16)
    np.testing.assert_array_equal(m.up[:8], up8)
    assert len(m.up) == 16


# ---------------------------------------------------------------------------
# the event-driven simulator
# ---------------------------------------------------------------------------

def test_sim_deterministic_and_clock_accumulates():
    plan = plan_grid(16)
    mplan = make_aggregator("mar", plan).message_plan(
        np.ones(16, np.float32), MB)
    net = NetworkSim(16, "wireless", seed=7)
    t1 = net.run(mplan)
    assert net.clock == pytest.approx(t1.iteration_s)
    net.run(mplan)
    assert net.clock > t1.iteration_s
    # an identically-seeded sim replays the first iteration exactly
    t1b = NetworkSim(16, "wireless", seed=7).run(mplan)
    assert t1b.iteration_s == pytest.approx(t1.iteration_s)
    assert t1b.round_s == pytest.approx(t1.round_s)


def test_slow_uplink_dominates_finish_time():
    """A 100x slower uplink shows up in that peer's finish time — the
    signal the lifecycle's deadline policy cuts on."""
    plan = plan_grid(8)
    mplan = make_aggregator("mar", plan).message_plan(
        np.ones(8, np.float32), 10_000_000)
    base = NetworkSim(8, "uniform", seed=0).run(mplan)
    links = build_link_model("uniform", 8, seed=0)
    links.up[3] /= 100.0
    tr = NetworkSim(8, links=links).run(mplan)
    # peer 3's serialized slow sends dominate its own finish, its group
    # mates finish just after it, and the whole iteration slows >20x
    assert tr.peer_finish_s.max() == pytest.approx(
        tr.peer_finish_s[3], rel=0.05)
    assert tr.iteration_s > 20 * base.iteration_s


def test_lossy_links_drop_and_flag_senders():
    plan = plan_grid(16)
    mplan = make_aggregator("mar", plan).message_plan(
        np.ones(16, np.float32), MB)
    tr = NetworkSim(16, "uniform", seed=2,
                    link_params={"loss": 0.5}).run(mplan)
    assert tr.n_dropped > 0
    assert tr.lost_senders.any()
    # lost messages consumed airtime: bytes are billed as transmitted
    assert tr.total_bytes == pytest.approx(mplan.total_bytes)
    # dropped messages' senders are exactly the flagged ones
    assert ({m.src for m in tr.dropped}
            == set(np.flatnonzero(tr.lost_senders)))


def test_compute_seeds_finish_times():
    plan = plan_grid(8)
    mplan = make_aggregator("mar", plan).message_plan(
        np.ones(8, np.float32), MB)
    slow = np.zeros(8)
    slow[5] = 100.0
    tr = NetworkSim(8, "uniform", seed=0).run(mplan, compute_s=slow)
    assert tr.iteration_s > 100.0


def test_infrastructure_nodes_are_free():
    """FedAvg's server (node id >= n) is infinitely provisioned: the
    transfer is bounded by client links only."""
    plan = plan_grid(8)
    mplan = transport.fedavg_plan(plan, np.ones(8, np.float32), MB)
    assert mplan.n_nodes == 9
    tr = NetworkSim(8, "uniform", seed=0).run(mplan)
    links = build_link_model("uniform", 8)
    expect = 2 * (MB / links.up[0] + links.lat[0])   # up + down, serial
    assert tr.iteration_s == pytest.approx(expect, rel=1e-6)


# ---------------------------------------------------------------------------
# the wall-clock scaling claim (acceptance criterion)
# ---------------------------------------------------------------------------

def test_mar_wallclock_sublinear_ar_linear():
    """On the same lognormal-wireless links, MAR's per-iteration
    simulated seconds grow ~log N while AR's grow ~N."""
    secs = {}
    for n in (8, 64):
        plan = plan_grid(n)
        mask = np.ones(n, np.float32)
        for tech in ("mar", "ar"):
            mplan = make_aggregator(tech, plan).message_plan(mask, 1e6)
            secs[(tech, n)] = NetworkSim(
                n, "wireless", seed=0).run(mplan).iteration_s
    mar_growth = secs[("mar", 64)] / secs[("mar", 8)]
    ar_growth = secs[("ar", 64)] / secs[("ar", 8)]
    assert secs[("mar", 64)] < secs[("ar", 64)]
    assert ar_growth > 0.8 * (64 / 8)          # ~linear in N
    assert mar_growth < 0.5 * (64 / 8)         # clearly sub-linear
    assert mar_growth < ar_growth / 2


# ---------------------------------------------------------------------------
# federation + lifecycle integration
# ---------------------------------------------------------------------------

def test_federation_ledger_fed_from_transcript():
    cfg = FederationConfig(n_peers=8, technique="mar", task="text",
                           link_profile="wireless", seed=3)
    fed = Federation(cfg)
    state = fed.init_state()
    for _ in range(2):
        state = fed.step(state)
    # parity: full participation, no loss — measured equals analytic
    analytic = 2 * topology.iteration_bytes("mar", 8, fed.model_bytes,
                                            fed.plan)
    assert fed.comm_bytes == pytest.approx(analytic)
    assert fed.sim_seconds > 0.0
    assert fed.ledger.total_seconds == pytest.approx(fed.sim_seconds)
    assert fed.last_transcript is not None
    assert fed.last_transcript.n_messages == 24   # 3 rounds x 8 x (2-1)


def test_federation_lossy_links_demote_and_train():
    import jax
    import jax.numpy as jnp
    cfg = FederationConfig(n_peers=8, technique="mar", task="text",
                           link_profile="wireless",
                           link_params={"loss": 0.4}, seed=4)
    fed = Federation(cfg)
    state = fed.init_state()
    for _ in range(3):
        state = fed.step(state)
    assert fed.last_transcript.n_dropped > 0
    for leaf in jax.tree.leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_link_churn_model_cuts_slow_uplinks():
    """The lifecycle's link-bound straggler model: the deadline is
    missed *because* the modeled uplink is slow."""
    model = build_churn_model("link", 32, seed=1, profile="wireless",
                              model_bytes=2e6, jitter=0.05)
    tick = model.tick(0)
    assert tick.durations is not None
    stragglers = np.flatnonzero(tick.a == 0)
    assert stragglers.size > 0
    # every straggler's link-time exceeds the median peer's
    comm = model.comm_s()
    assert (comm[stragglers] > np.median(comm)).all()


def test_federation_link_churn_end_to_end():
    cfg = FederationConfig(
        n_peers=16, technique="mar", task="text", churn="link",
        churn_params=dict(profile="wireless", model_bytes=2e6), seed=2)
    fed = Federation(cfg)
    state = fed.init_state()
    for _ in range(3):
        state = fed.step(state)
    from repro.runtime.lifecycle import STRAGGLE
    kinds = {e.kind for e in fed.lifecycle.event_log}
    assert STRAGGLE in kinds
    assert fed.comm_bytes > 0
