"""MAR grid math: coordinates, group keys, schedules (unit + property)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.moshpit import (GridPlan, bytes_per_iteration,
                                exchanges_per_iteration, mesh_grid_plan,
                                plan_grid)


def test_plan_grid_exact_powers():
    assert plan_grid(125).dims == (5, 5, 5)
    assert plan_grid(16).dims == (2, 2, 2, 2)
    assert plan_grid(64).dims == (4, 4, 4) or plan_grid(64).is_exact
    assert plan_grid(27).dims == (3, 3, 3)
    for n in (125, 64, 27, 16, 8):
        assert plan_grid(n).is_exact


def test_plan_grid_explicit():
    p = plan_grid(125, group_size=5)
    assert p.dims == (5, 5, 5)
    # paper Fig. 11: group size 3, d=5 covers 125 with padding
    p3 = plan_grid(125, group_size=3)
    assert p3.capacity >= 125 and all(d == 3 for d in p3.dims)


def test_plan_grid_non_power():
    p = plan_grid(100)
    assert p.capacity >= 100
    assert p.depth >= 2


def test_coords_roundtrip():
    p = GridPlan(24, (2, 3, 4))
    peers = np.arange(24)
    assert np.array_equal(p.index(p.coords(peers)), peers)


def test_group_key_strikes_axis():
    p = GridPlan(125, (5, 5, 5))
    for rnd in range(3):
        groups = p.groups_for_round(rnd)
        assert len(groups) == 25
        # each group differs only in coordinate `rnd`
        for g in groups:
            c = p.coords(g)
            for ax in range(3):
                n_unique = len(np.unique(c[:, ax]))
                assert n_unique == (5 if ax == rnd else 1)


def test_no_pair_revisited_across_rounds():
    """The paper's key-update property: within one FL iteration no two
    peers meet twice (for exact grids)."""
    p = GridPlan(27, (3, 3, 3))
    met = set()
    for rnd in range(p.depth):
        for g in p.groups_for_round(rnd):
            for i in g:
                for j in g:
                    if i < j:
                        assert (i, j) not in met, (rnd, i, j)
                        met.add((i, j))


def test_partner_matrix_consistency():
    p = GridPlan(16, (4, 4))
    for rnd in range(2):
        pm = p.partner_matrix(rnd)
        keys = p.group_key(np.arange(16), rnd)
        for peer in range(16):
            assert peer in pm[peer]
            assert np.all(keys[pm[peer]] == keys[peer])


def test_mesh_grid_plan():
    assert mesh_grid_plan([16]).dims == (4, 4)
    assert mesh_grid_plan([2, 16]).dims == (2, 4, 4)
    assert mesh_grid_plan([2]).dims == (2,)


def test_mesh_grid_plan_factor_hints():
    """factor_hints override the balanced factorization per DP axis."""
    assert mesh_grid_plan([16], {0: (2, 8)}).dims == (2, 8)
    assert mesh_grid_plan([16], {0: (2, 2, 2, 2)}).dims == (2, 2, 2, 2)
    # hint on one axis leaves the others balanced
    p = mesh_grid_plan([2, 16], {1: (8, 2)})
    assert p.dims == (2, 8, 2)
    assert p.capacity == 32 and p.n_peers == 32
    # a hint that doesn't multiply out to the axis size is rejected
    with pytest.raises(AssertionError):
        mesh_grid_plan([16], {0: (3, 5)})


def test_mesh_grid_plan_hinted_plans_stay_exact():
    for hints in (None, {0: (2, 8)}, {0: (4, 4)}):
        p = mesh_grid_plan([16], hints)
        assert p.is_exact
        for rnd in range(p.depth):
            groups = p.groups_for_round(rnd)
            flat = np.sort(np.concatenate(groups))
            assert np.array_equal(flat, np.arange(p.capacity))


def test_partner_matrix_ordered_by_struck_coordinate():
    """partner_matrix row k holds the group mate whose struck-out
    coordinate equals k (the ordering secagg's pairwise masks rely on)."""
    p = GridPlan(24, (2, 3, 4))
    for rnd in range(p.depth):
        pm = p.partner_matrix(rnd)
        assert pm.shape == (24, p.dims[rnd])
        c = p.coords(np.arange(24))
        for peer in range(24):
            for k in range(p.dims[rnd]):
                cc = p.coords(pm[peer, k])
                assert cc[rnd] == k
                struck = np.delete(cc, rnd)
                assert np.array_equal(struck, np.delete(c[peer], rnd))
        # the diagonal: every peer appears in its own row at its own
        # struck coordinate
        own = pm[np.arange(24), c[:, rnd]]
        assert np.array_equal(own, np.arange(24))


@given(st.integers(2, 5), st.integers(1, 3))
@settings(max_examples=20, deadline=None)
def test_partner_matrix_rows_are_groups(m, d):
    p = GridPlan(m ** d, (m,) * d)
    for rnd in range(d):
        pm = p.partner_matrix(rnd)
        keys = p.group_key(np.arange(p.capacity), rnd)
        # every row is exactly its peer's group (same key, all members)
        for peer in range(p.capacity):
            assert len(set(pm[peer])) == m
            assert np.all(keys[pm[peer]] == keys[peer])


def test_exchange_and_byte_counts():
    p = GridPlan(125, (5, 5, 5))
    assert exchanges_per_iteration(p) == 125 * 3 * 4
    naive = bytes_per_iteration(p, 100, allreduce="naive")
    butterfly = bytes_per_iteration(p, 100, allreduce="butterfly")
    assert naive == 125 * 3 * 4 * 100
    assert butterfly < naive


@given(st.integers(2, 6), st.integers(1, 4))
@settings(max_examples=30, deadline=None)
def test_groups_partition_property(m, d):
    """Every round's groups partition the full peer set."""
    if m ** d > 1296:
        return
    p = GridPlan(m ** d, (m,) * d)
    for rnd in range(d):
        groups = p.groups_for_round(rnd)
        flat = np.sort(np.concatenate(groups))
        assert np.array_equal(flat, np.arange(p.capacity))
        assert all(len(g) == m for g in groups)


@given(st.integers(2, 500))
@settings(max_examples=50, deadline=None)
def test_plan_grid_always_covers(n):
    p = plan_grid(n)
    assert p.capacity >= n
    assert p.n_peers == n
    assert all(m >= 2 for m in p.dims)
