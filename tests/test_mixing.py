"""Eq. 1 mixing dynamics: theory vs simulation."""
import numpy as np
import pytest

from repro.core.mixing import (contraction_factor, distortion,
                               empirical_contraction,
                               predicted_distortion)


def test_contraction_factor_values():
    # r=1 (single global group) -> factor = 1/N^2 (near-exact in 1 iter)
    assert contraction_factor(100, 1) == pytest.approx(1e-4)
    # more groups mix slower
    assert contraction_factor(100, 10) > contraction_factor(100, 2)


def test_empirical_matches_eq1():
    """Random-partition averaging contracts at the Eq. 1 rate (within
    stochastic tolerance)."""
    emp, theory = empirical_contraction(n_peers=64, n_groups=8,
                                        iterations=4, trials=24)
    assert emp == pytest.approx(theory, rel=0.35)


def test_distortion_decays_monotonically():
    from repro.core.mixing import random_group_average
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(27, 16)).astype(np.float32))
    prev = distortion(x)
    for _ in range(5):
        x = random_group_average(x, 3, rng)
        cur = distortion(x)
        assert cur <= prev + 1e-9
        prev = cur


def test_deterministic_schedule_beats_random():
    """Paper §2.3: the key-rotation schedule reaches the exact mean in d
    rounds while random grouping is only in expectation."""
    import jax.numpy as jnp
    from repro.core import mar_allreduce as mar
    from repro.core.moshpit import GridPlan
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(27, 8)).astype(np.float32))
    p = GridPlan(27, (3, 3, 3))
    out = mar.mar_aggregate_sim({"x": x}, p)["x"]
    det = distortion(out)
    assert det < 1e-10
    expected_random = (contraction_factor(27, 9) ** 3) * distortion(x)
    assert det < expected_random
