"""Async (staleness-1) aggregation + MoE dispatch consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.federation import Federation, FederationConfig


def test_async_aggregation_converges():
    """Staleness-1 delayed averaging still trains (slower than sync —
    the overlap/utility trade-off is the point, EXPERIMENTS.md)."""
    accs = {}
    for mode in (False, True):
        cfg = FederationConfig(n_peers=8, technique="mar", task="text",
                               local_batches=4, async_aggregation=mode,
                               seed=3)
        fed = Federation(cfg)
        state = fed.init_state()
        for _ in range(25):
            state = fed.step(state)
        accs[mode] = fed.evaluate(state)
    assert accs[True] > 0.3          # converges
    assert accs[False] >= accs[True]  # sync is the quality ceiling


def test_async_comm_bytes_match_sync():
    cfgs = [FederationConfig(n_peers=8, technique="mar", task="text",
                             async_aggregation=m, seed=1) for m in
            (False, True)]
    comms = []
    for cfg in cfgs:
        fed = Federation(cfg)
        s = fed.init_state()
        for _ in range(3):
            s = fed.step(s)
        comms.append(fed.comm_bytes)
    assert comms[0] == comms[1]      # same bytes, different schedule


def test_async_dp_composes():
    """async + DP — asserted out before the composable pipeline — now
    runs: the staleness-1 schedule wraps the privatized aggregation."""
    cfg = FederationConfig(n_peers=8, use_dp=True, async_aggregation=True,
                           task="text", seed=4)
    fed = Federation(cfg)
    assert fed.pipeline.stage_names == ("async", "dp")
    state = fed.init_state()
    for _ in range(3):
        state = fed.step(state)
    for leaf in jax.tree.leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    assert state.dp is not None and state.pending is not None


# ---------------------------------------------------------------------------
# MoE: capacity-dispatch block vs all-experts oracle
# ---------------------------------------------------------------------------

def test_moe_block_matches_dense_oracle():
    from repro.configs.registry import get_smoke_config
    from repro.models.moe import (moe_block, moe_block_dense_oracle,
                                  moe_init)
    cfg = get_smoke_config("moonshot-v1-16b-a3b")
    # generous capacity so no token drops -> exact match expected
    cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(2, 16, cfg.d_model)), jnp.float32).astype(jnp.dtype(cfg.dtype))
    got = moe_block(params, x, cfg)
    want = moe_block_dense_oracle(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=5e-2, rtol=5e-2)


def test_moe_capacity_drops_are_bounded():
    """With capacity factor 1.0 and balanced-ish routing, the dispatch
    output stays close to the oracle (drops only at the margin)."""
    from repro.configs.registry import get_smoke_config
    from repro.models.moe import moe_block, moe_block_dense_oracle, moe_init
    cfg = get_smoke_config("kimi-k2-1t-a32b")
    params = moe_init(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(np.random.default_rng(1).normal(
        size=(2, 32, cfg.d_model)), jnp.float32).astype(jnp.dtype(cfg.dtype))
    got = moe_block(params, x, cfg)
    want = moe_block_dense_oracle(params, x, cfg)
    # relative Frobenius error from capacity drops stays moderate
    err = float(jnp.linalg.norm((got - want).astype(jnp.float32))
                / jnp.linalg.norm(want.astype(jnp.float32)))
    assert err < 0.5, err
