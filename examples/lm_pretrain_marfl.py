"""End-to-end driver: MAR-FL local-SGD pretraining of a ~100M-param LM
(reduced glm4 family config) for a few hundred steps on CPU.

This is the device-backend path the production mesh runs (fl_train_step
= B local steps + MAR aggregation), at laptop scale: 4 peers on a (2,2)
MAR grid, synthetic Zipf token stream, checkpoint every 50 steps.

    PYTHONPATH=src python examples/lm_pretrain_marfl.py --steps 200
"""
import sys, os, argparse, dataclasses, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.registry import get_smoke_config
from repro.core.fl_device import init_fl_state, make_fl_train_step
from repro.core.moshpit import plan_grid
from repro.data.synthetic import lm_token_stream
from repro.models.model import Model

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--peers", type=int, default=4)
ap.add_argument("--local-steps", type=int, default=2)
ap.add_argument("--ckpt", default="/tmp/marfl_lm_ckpt")
args = ap.parse_args()

# ~100M params: widen the glm4 smoke config
cfg = dataclasses.replace(
    get_smoke_config("glm4-9b"), name="glm4-100m",
    num_layers=8, d_model=512, num_heads=8, num_kv_heads=2, head_dim=64,
    d_ff=2048, vocab_size=32_000)
model = Model(cfg)
print(f"model: {cfg.name}, params={cfg.param_count():,}")

grid = plan_grid(args.peers)
step = jax.jit(make_fl_train_step(model, grid, lr=0.05))
state = init_fl_state(model, args.peers, jax.random.PRNGKey(0))
ck = Checkpointer(args.ckpt, keep=2)

B, S = 4, 128
stream = lm_token_stream(cfg.vocab_size, args.peers * args.local_steps * B,
                         S, seed=0)
t0 = time.time()
for t in range(args.steps):
    raw = next(stream)
    batch = {k: v.reshape(args.peers, args.local_steps, 1, B, S)
             for k, v in raw.items()}
    state, metrics = step(state, batch)
    if (t + 1) % 20 == 0:
        print(f"step {t+1:4d}: loss={float(metrics['loss']):.4f} "
              f"({(time.time()-t0)/(t+1)*1e3:.0f} ms/step)")
    if (t + 1) % 50 == 0:
        ck.save(t + 1, state, metadata={"step": t + 1,
                                        "n_peers": args.peers},
                blocking=False)
ck.wait()
print(f"done: final loss {float(metrics['loss']):.4f}; "
      f"checkpoints at {args.ckpt}")
