"""Differentially private MAR-FL (Alg. 4): adaptive clipping + noise,
with the RDP privacy ledger.

    PYTHONPATH=src python examples/private_federation.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.dp import epsilon_estimate
from repro.core.federation import Federation, FederationConfig

for sigma in (0.1, 0.5):
    cfg = FederationConfig(n_peers=8, technique="mar", task="text",
                           use_dp=True, noise_multiplier=sigma,
                           local_batches=2)
    fed = Federation(cfg)
    state = fed.init_state()
    for t in range(15):
        state = fed.step(state)
    eps = epsilon_estimate(15, sigma)
    print(f"sigma={sigma}: acc={fed.evaluate(state):.3f} "
          f"clip bound C_t={float(state.dp['clip']):.3f} "
          f"epsilon(delta=1e-5)={eps:.1f}")

print("\nLower sigma -> better utility, higher epsilon; the clipping "
      "bound C_t adapts toward the gamma=0.5 quantile (Alg. 4 line 17).")
