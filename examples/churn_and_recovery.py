"""Churn + fault tolerance: peers drop mid-training, a straggler gets
masked, the federation checkpoints and restarts with a different peer
count (elastic re-mesh).

    PYTHONPATH=src python examples/churn_and_recovery.py
"""
import sys, os, tempfile
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.federation import Federation, FederationConfig
from repro.runtime.fault import (HealthTracker, StragglerPolicy,
                                 elastic_replan, failure_impact)

cfg = FederationConfig(n_peers=16, technique="mar", task="text",
                       dropout_rate=0.2, local_batches=2)
fed = Federation(cfg)
state = fed.init_state()
health = HealthTracker(cfg.n_peers, timeout_s=5.0)
straggler = StragglerPolicy(k_std=2.0)

print(f"grid={fed.plan.dims}; simulated 20% dropout per iteration")
print("failure impact of peers {3, 7}:",
      failure_impact(fed.plan, [3, 7]))

for t in range(10):
    # fleet health -> participation mask (dead peers excluded from MAR)
    durations = np.abs(np.random.default_rng(t).normal(1.0, 0.1, 16))
    if t == 4:
        durations[5] = 9.0          # straggler at iteration 4
        health.mark_failed(11)      # hard failure at iteration 4
    u = health.alive_mask() * straggler.mask(durations)
    a = u.copy()
    state = fed.step(state, masks=(u, a))
print(f"after churn: acc={fed.evaluate(state):.3f}")

# checkpoint, then restart ELASTICALLY with 9 peers (16 -> 9)
with tempfile.TemporaryDirectory() as d:
    ck = Checkpointer(d)
    ck.save(10, {"params": state.params, "momentum": state.momentum},
            metadata={"n_peers": 16, "step": 10})
    new_plan = elastic_replan(fed.plan, 9)
    print(f"elastic replan 16->{9}: new grid={new_plan.dims}")
    cfg9 = FederationConfig(n_peers=9, technique="mar", task="text",
                            local_batches=2)
    fed9 = Federation(cfg9)
    state9 = fed9.init_state()
    restored, meta = ck.restore_elastic(9)
    state9.params = type(state9.params)(restored["params"]) \
        if not isinstance(restored["params"], dict) else restored["params"]
    state9 = type(state9)(params=restored["params"],
                          momentum=restored["momentum"],
                          iteration=meta["step"], rng=state9.rng)
    for _ in range(5):
        state9 = fed9.step(state9)
    print(f"resumed with 9 peers from step {meta['step']}: "
          f"acc={fed9.evaluate(state9):.3f}")
