"""Churn + fault tolerance on the peer lifecycle runtime.

A 16-peer federation trains through session churn (Markov on/off
availability), correlated region outages, and deadline stragglers; a
silent peer is caught by the HealthTracker sweep; then the fleet
permanently shrinks 16 -> 9 and grows back 9 -> 12 *mid-run* — elastic
regrouping via ``Federation.resize`` (grid re-factorized, pipeline
rebuilt, peer state resized in place), no checkpoint/restart round-trip.
The whole membership history is saved as a replayable trace.

    PYTHONPATH=src python examples/churn_and_recovery.py
"""
import sys, os, tempfile
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.federation import Federation, FederationConfig
from repro.runtime.fault import (HealthTracker, StragglerPolicy,
                                 failure_impact)
from repro.runtime.lifecycle import (PeerLifecycle, build_churn_model,
                                     build_lifecycle, load_trace,
                                     save_trace)

# --- phase 1: session churn + health tracking --------------------------
cfg = FederationConfig(n_peers=16, technique="mar", task="text",
                       churn="sessions",
                       churn_params={"mean_up": 6.0, "mean_down": 2.0},
                       local_batches=2, seed=0)
lifecycle = PeerLifecycle(
    build_churn_model("sessions", 16, seed=0, mean_up=6.0, mean_down=2.0),
    health=HealthTracker(16, timeout_s=4.0),     # 4 iterations silent
    straggler=StragglerPolicy(k_std=2.0))
fed = Federation(cfg, lifecycle=lifecycle)
state = fed.init_state()

print(f"grid={fed.plan.dims}; session churn "
      f"(mean_up=6 it, mean_down=2 it)")
print("failure impact of peers {3, 7}:", failure_impact(fed.plan, [3, 7]))

for t in range(10):
    if t == 4:
        lifecycle.health.mark_failed(11)   # hard failure at iteration 4
    state = fed.step(state)
print(f"after churn: acc={fed.evaluate(state):.3f}, "
      f"{len(lifecycle.event_log)} membership events")

# --- phase 2: mid-run elastic shrink 16 -> 9 ---------------------------
state = fed.resize(state, 9)
print(f"elastic shrink 16->9 (no restart): grid={fed.plan.dims}, "
      f"impact of peer 3 now {failure_impact(fed.plan, [3])}")
for _ in range(5):
    state = fed.step(state)
print(f"resumed with 9 peers: acc={fed.evaluate(state):.3f}")

# --- phase 3: mid-run elastic grow 9 -> 12 -----------------------------
state = fed.resize(state, 12)
print(f"elastic grow 9->12: grid={fed.plan.dims} "
      f"(capacity {fed.plan.capacity}, virtual slots masked)")
for _ in range(5):
    state = fed.step(state)
print(f"resumed with 12 peers: acc={fed.evaluate(state):.3f}")

# --- phase 4: the membership history is a replayable trace -------------
with tempfile.TemporaryDirectory() as d:
    path = os.path.join(d, "membership.jsonl")
    save_trace(path, lifecycle.event_log)
    replay = build_lifecycle("trace", 16,
                             churn_params={"events": load_trace(path)})
    tick = replay.tick(0)
    print(f"saved {len(lifecycle.event_log)} events; replay tick(0): "
          f"{int(tick.u.sum())}/16 peers up")
