"""Quickstart: train a 27-peer MAR-FL federation on the text task.

Shows the core public API: FederationConfig -> Federation -> step/eval,
the MAR grid behind it, and the communication ledger.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.federation import Federation, FederationConfig

cfg = FederationConfig(
    n_peers=27,          # 27 = 3^3 -> exact MAR grid, 3 rounds of size-3
    technique="mar",
    task="text",         # 20-class frozen-encoder features (20NG analogue)
    local_batches=2,     # B local Momentum-SGD steps per FL iteration
    lr=0.1, momentum=0.9,
)
fed = Federation(cfg)
print(f"MAR grid: {fed.plan.dims} (exact={fed.plan.is_exact}), "
      f"model bytes={fed.model_bytes:,}")

state = fed.init_state()
for t in range(20):
    state = fed.step(state)
    if (t + 1) % 5 == 0:
        print(f"iter {t+1:3d}: acc={fed.evaluate(state):.3f} "
              f"comm={fed.comm_bytes/1e6:,.0f} MB "
              f"peer-disagreement={fed.peer_disagreement(state):.2e}")

print("\nEvery peer holds the collaboratively trained global model "
      "(Alg. 1 returns theta^T).")
