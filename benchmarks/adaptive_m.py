"""Adaptive-M vs fixed-M: the group-size controller in the timing loop.

The ROADMAP's "Adaptive M" item, measured: the ``tail_aware``
:class:`~repro.core.adaptive.GroupSizeController` consumes each
iteration's discrete-event transcript (``runtime/network.py``) and
regroups the MAR grid mid-run, against the static ``plan_grid``
factorization the ``wallclock_scaling`` baselines use — same links,
same seed, same model bytes, N in {8, 16, 64, 125} under the
``wireless`` and ``regions`` profiles.

Expected shape: on heterogeneous links the slowest peer's uplink chain
bounds the iteration at ``depth * (M-1)`` serialized model sends, so
the controller walks down the candidate ladder (125: 5^3 -> 3^5 ->
2^7, i.e. 12 -> 10 -> 7 sends on the slow chain) and the steady-state
iteration time drops below the fixed grid's; on flat links it stays at
the planner's choice and matches the baseline exactly.

Byte accounting stays honest throughout: after *every* iteration —
including every post-regroup one — the transcript's total bytes are
cross-checked against the mask-aware analytic oracle
(``topology.mar_bytes``); any mismatch fails the benchmark (transports
bill scheduled sizes, so the parity holds even under per-tier loss).

Emits CSV rows plus ``BENCH_adaptive_m.json`` and exits nonzero if the
controller loses to fixed-M at the largest wireless cell or any byte
cross-check fails.
"""
from __future__ import annotations

import json
import sys
from typing import Optional

import numpy as np

from benchmarks.common import emit, std_argparser
from repro.core import topology
from repro.core.adaptive import GroupSizeController, build_controller
from repro.core.aggregation import make_aggregator
from repro.core.moshpit import plan_grid
from repro.runtime.network import NetworkSim

PROFILES = ("wireless", "regions")


def run_cell(n: int, profile: str, seed: int, iters: int,
             model_bytes: float,
             controller: Optional[GroupSizeController]) -> dict:
    """One (N, profile) cell: ``iters`` MAR iterations over one
    NetworkSim, optionally with the controller in the loop. Links are
    drawn from (profile, n, seed) alone, so the fixed and adaptive
    cells of a pair time their messages over identical links."""
    net = NetworkSim(n, profile=profile, seed=seed)
    plan = plan_grid(n)
    mask = np.ones(n, np.float32)
    per_iter, regroups = [], []
    parity_ok = True
    for t in range(iters):
        agg = make_aggregator("mar", plan)
        tr = net.run(agg.message_plan(mask, model_bytes))
        per_iter.append(tr.iteration_s)
        # no-loss byte accounting vs the mask-aware analytic oracle —
        # checked after every iteration, i.e. after every regroup too
        oracle = topology.mar_bytes(n, plan, model_bytes, mask=mask)
        if abs(tr.total_bytes - oracle) >= 1.0:
            parity_ok = False
        if controller is not None:
            proposal = controller.observe(t, tr, plan)
            if proposal is not None:
                regroups.append({"t": t, "from": list(plan.dims),
                                 "to": list(proposal.dims)})
                plan = proposal
    steady_k = max(iters // 3, 1)
    return {
        "n_peers": n, "profile": profile,
        "dims_final": list(plan.dims),
        "iters": iters,
        "mean_s": float(np.mean(per_iter)),
        "steady_s": float(np.mean(per_iter[-steady_k:])),
        "total_s": float(np.sum(per_iter)),
        "regroups": regroups,
        "byte_parity": parity_ok,
    }


def main(argv=None) -> int:
    ap = std_argparser(__doc__)
    ap.add_argument("--model-mb", type=float, default=10.0,
                    help="state bytes per transfer (theta + momentum)")
    ap.add_argument("--iters", type=int, default=None,
                    help="iterations per cell (controller needs a few "
                         "windows to converge)")
    ap.add_argument("--controller", default="tail_aware",
                    help="GroupSizeController to race against fixed-M")
    ap.add_argument("--out", default="BENCH_adaptive_m.json")
    args = ap.parse_args(argv)

    if args.smoke:
        peer_counts, iters = (8, 16), args.iters or 10
    elif args.full:
        peer_counts, iters = (8, 16, 64, 125), args.iters or 60
    else:
        peer_counts, iters = (8, 16, 64, 125), args.iters or 24
    model_bytes = args.model_mb * 1e6

    results, summary = [], {}
    rc = 0
    for profile in PROFILES:
        for n in peer_counts:
            fixed = run_cell(n, profile, args.seed, iters, model_bytes,
                             controller=None)
            ctrl = build_controller(args.controller, plan_grid(n))
            adapt = run_cell(n, profile, args.seed, iters, model_bytes,
                             controller=ctrl)
            speedup = (fixed["steady_s"] / adapt["steady_s"]
                       if adapt["steady_s"] > 0 else 1.0)
            parity = fixed["byte_parity"] and adapt["byte_parity"]
            row = dict(profile=profile, n_peers=n,
                       fixed_dims=str(tuple(fixed["dims_final"])),
                       adaptive_dims=str(tuple(adapt["dims_final"])),
                       n_regroups=len(adapt["regroups"]),
                       fixed_steady_s=round(fixed["steady_s"], 4),
                       adaptive_steady_s=round(adapt["steady_s"], 4),
                       adaptive_total_s=round(adapt["total_s"], 4),
                       fixed_total_s=round(fixed["total_s"], 4),
                       speedup=round(speedup, 3),
                       byte_parity=parity)
            emit("adaptive_m", **row)
            results.append({"fixed": fixed, "adaptive": adapt,
                            "speedup": speedup})
            summary[f"{profile}_n{n}_speedup"] = round(speedup, 3)
            if not parity:
                print(f"# FAIL byte parity at n={n} {profile}",
                      flush=True)
                rc = 1

    # acceptance: beat-or-match fixed-M at the largest wireless cell
    # (1.0 within noise; the controller must never *lose* steady-state)
    n_hi = peer_counts[-1]
    key = f"wireless_n{n_hi}_speedup"
    if summary.get(key, 1.0) < 0.98:
        print(f"# FAIL adaptive loses to fixed-M at N={n_hi} wireless "
              f"(speedup {summary[key]})", flush=True)
        rc = 1
    emit("adaptive_m_summary", controller=args.controller,
         iters=iters, **summary)

    with open(args.out, "w") as f:
        json.dump({"benchmark": "adaptive_m",
                   "controller": args.controller,
                   "model_bytes": model_bytes,
                   "iters": iters, "seed": args.seed,
                   "summary": summary,
                   "results": results}, f, indent=2)
    print(f"# wrote {args.out}", flush=True)
    return rc


if __name__ == "__main__":
    sys.exit(main())
