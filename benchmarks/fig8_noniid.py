"""Fig. 8 — i.i.d. vs non-i.i.d. (Dirichlet alpha) local data splits."""
from __future__ import annotations

import sys

import numpy as np

from benchmarks.common import emit, scale, std_argparser
from repro.core.federation import FederationConfig, run_federation
from repro.data.partition import dirichlet_partition, partition_stats
from repro.data.synthetic import classification_task


def main(argv=None) -> int:
    ap = std_argparser(__doc__)
    args = ap.parse_args(argv)
    s = scale(args.full)

    # partition heterogeneity diagnostics
    _, train, _ = classification_task("text", seed=args.seed)
    for alpha in (0.1, 1.0, 100.0):
        shards = dirichlet_partition(train["y"], s["peers"], alpha,
                                     seed=args.seed)
        st = partition_stats(shards, train["y"])
        emit("fig8_partition", alpha=alpha, **st)

    for task in ("text", "vision"):
        for alpha in (None, 1.0, 0.1):
            cfg = FederationConfig(
                n_peers=s["peers"], technique="mar", task=task,
                alpha=alpha, batch_size=64 if task == "vision" else 16,
                local_batches=s["local_batches"], seed=args.seed)
            hist = run_federation(cfg, s["iters"],
                                  eval_every=s["eval_every"])
            emit("fig8_noniid", task=task,
                 alpha=("iid" if alpha is None else alpha),
                 final_acc=round(hist["accuracy"][-1], 4))
    return 0


if __name__ == "__main__":
    sys.exit(main())
