"""Fig. 5 — qualitative identity: MAR-FL == FedAvg == AR-FL == RDFL test
accuracy under exact aggregation (and max param divergence)."""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp

from benchmarks.common import emit, scale, std_argparser
from repro.core.federation import Federation, FederationConfig


def main(argv=None) -> int:
    ap = std_argparser(__doc__)
    args = ap.parse_args(argv)
    s = scale(args.full)

    params = {}
    for tech in ("mar", "fedavg", "ar", "rdfl"):
        cfg = FederationConfig(n_peers=s["peers"], technique=tech,
                               task="text",
                               local_batches=s["local_batches"],
                               seed=args.seed)
        fed = Federation(cfg)
        state = fed.init_state()
        for _ in range(s["iters"] // 2):
            state = fed.step(state)
        acc = fed.evaluate(state)
        params[tech] = jax.tree.leaves(state.params)[0]
        emit("fig5_parity", technique=tech, acc=round(acc, 4))
    base = params["fedavg"]
    for tech in ("mar", "ar", "rdfl"):
        d = float(jnp.max(jnp.abs(params[tech] - base)))
        emit("fig5_divergence", technique=tech, vs="fedavg",
             max_param_diff=f"{d:.2e}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
