"""Benchmark harness: one module per paper table/figure.

``python -m benchmarks.run``           — fast settings, all figures
``python -m benchmarks.run --full``    — paper-scale (125 peers, slow)
``python -m benchmarks.run --only fig1_perf_gap fig4_dp``

Each module prints ``name,key=value,...`` CSV rows. The roofline table
(§Roofline) is produced by the dry-run instead:
``python -m repro.launch.dryrun --all --mesh both --out dryrun.json``.
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = [
    "fig1_perf_gap",
    "fig2_mkd",
    "fig3_churn",
    "fig4_dp",
    "fig5_parity",
    "fig8_noniid",
    "fig11_approx_agg",
    "wire_ladder",
    "wallclock_scaling",
    "adaptive_m",
    "placement",
    "transport_calibration",
    "kernel_bench",
    "serving",
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args(argv)

    mods = args.only if args.only else MODULES
    rc = 0
    for name in mods:
        print(f"# ---- {name} ----", flush=True)
        t0 = time.time()
        mod = __import__(f"benchmarks.{name}", fromlist=["main"])
        try:
            rc |= mod.main(["--full"] if args.full else [])
        except Exception as e:  # keep the harness going; report at end
            print(f"{name},ERROR={type(e).__name__}: {e}", flush=True)
            rc |= 1
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    return rc


if __name__ == "__main__":
    sys.exit(main())
