"""Fig. 3/6/7 — partial participation and network churn.

Sweeps participation rate x dropout likelihood for MAR-FL (and FedAvg as
the reference pattern): accuracy degrades with participation but is
robust to dropouts; MAR keeps its communication edge throughout.
"""
from __future__ import annotations

import sys

from benchmarks.common import emit, scale, std_argparser
from repro.core.federation import FederationConfig, run_federation


def main(argv=None) -> int:
    ap = std_argparser(__doc__)
    args = ap.parse_args(argv)
    s = scale(args.full)

    for tech in ("mar", "fedavg"):
        for part in (1.0, 0.5):
            for drop in (0.0, 0.2):
                cfg = FederationConfig(
                    n_peers=s["peers"], technique=tech, task="text",
                    participation_rate=part, dropout_rate=drop,
                    local_batches=s["local_batches"], seed=args.seed)
                hist = run_federation(cfg, s["iters"],
                                      eval_every=s["eval_every"])
                emit("fig3_churn", technique=tech, participation=part,
                     dropout=drop,
                     final_acc=round(hist["accuracy"][-1], 4),
                     comm_mb=round(hist["comm_bytes"][-1] / 1e6, 1),
                     disagreement=f"{hist['disagreement'][-1]:.2e}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
