"""Fig. 3/6/7 — churn-scenario robustness matrix.

The paper's churn claim, stressed beyond i.i.d. masks: MAR-FL (and
FedAvg as the reference pattern) trains under four availability models
from the peer lifecycle runtime —

* ``iid``        — per-iteration Bernoulli participation + dropout
                   (the paper's Fig. 3 setting);
* ``sessions``   — Markov on/off sessions with dwell times (time-
                   correlated availability);
* ``correlated`` — region-level outages (whole MAR groups vanish
                   together);
* ``trace``      — a recorded sessions run replayed from its event
                   file (replayability check: same masks, same curve).

Each cell reports final accuracy, peer disagreement (Eq. 1), and
CommLedger data-plane bytes. An extra ``elastic`` row runs iid churn
with a mid-run shrink and grow (no-restart regrouping).
"""
from __future__ import annotations

import os
import sys
import tempfile

from benchmarks.common import emit, scale, std_argparser
from repro.core.federation import FederationConfig, run_federation
from repro.runtime.lifecycle import build_lifecycle, save_trace


def _scenarios(s):
    n = s["peers"]
    return {
        "iid": dict(churn=None, participation_rate=0.7, dropout_rate=0.2),
        "sessions": dict(churn="sessions",
                         churn_params={"mean_up": 8.0, "mean_down": 3.0}),
        "correlated": dict(churn="correlated",
                           churn_params={"n_regions": max(2, n // 4),
                                         "outage_rate": 0.1,
                                         "mean_outage": 3.0}),
    }


def _record_trace(s, seed, iters, path):
    """Run the sessions model standalone and save its event stream."""
    lc = build_lifecycle("sessions", s["peers"], seed=seed,
                         churn_params={"mean_up": 8.0, "mean_down": 3.0})
    for t in range(iters):
        lc.tick(t)
    save_trace(path, lc.event_log)


def main(argv=None) -> int:
    ap = std_argparser(__doc__)
    args = ap.parse_args(argv)
    s = scale(args.full, args.smoke)

    techniques = ("mar",) if args.smoke else ("mar", "fedavg")
    with tempfile.TemporaryDirectory() as tmp:
        trace_path = os.path.join(tmp, "sessions.jsonl")
        _record_trace(s, args.seed, s["iters"], trace_path)
        scenarios = _scenarios(s)
        scenarios["trace"] = dict(churn="trace",
                                  churn_params={"path": trace_path})
        third = max(1, s["iters"] // 3)
        scenarios["elastic"] = dict(
            churn=None, participation_rate=0.9, dropout_rate=0.1,
            resize_schedule=((third, max(2, s["peers"] // 2)),
                             (2 * third, s["peers"] - 1)))

        for tech in techniques:
            for name, kw in scenarios.items():
                cfg = FederationConfig(
                    n_peers=s["peers"], technique=tech, task="text",
                    local_batches=s["local_batches"], seed=args.seed,
                    **kw)
                hist = run_federation(cfg, s["iters"],
                                      eval_every=s["eval_every"])
                emit("fig3_churn", technique=tech, scenario=name,
                     final_acc=round(hist["accuracy"][-1], 4),
                     comm_mb=round(hist["comm_bytes"][-1] / 1e6, 1),
                     disagreement=f"{hist['disagreement'][-1]:.2e}",
                     peers_end=hist["n_peers"][-1],
                     events=hist["events"][-1])
    return 0


if __name__ == "__main__":
    sys.exit(main())
