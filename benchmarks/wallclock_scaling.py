"""Wall-clock scaling: the O(N log N) vs O(N^2) gap in *seconds*.

The paper's headline (Fig. 1) compares techniques by bytes; the
wireless-FL literature argues per-round *timing* over heterogeneous
links is what actually limits scale. This benchmark unrolls one FL
iteration of every registered technique into messages
(``core/transport.py``), times them over the lognormal-wireless link
profile with the discrete-event simulator (``runtime/network.py``),
and reports measured bytes + simulated seconds per iteration across
N in {8, 16, 64, 125}.

Expected shape, from uplink serialization alone: MAR sends G*(M-1)
models per peer, so its per-iteration wall-clock grows ~log N, while
AR's N-1 sends per peer grow ~N — the byte gap becomes a time gap on
the *same* links. Measured bytes are cross-checked against the
analytic oracles (``core/topology.py``) row by row (loss=0 parity).

Emits CSV rows plus ``BENCH_comm.json`` (bytes + simulated seconds per
technique per N) so the perf trajectory has machine-readable data
points.
"""
from __future__ import annotations

import json
import sys

import numpy as np

from benchmarks.common import emit, std_argparser
from repro.core import topology
from repro.core.aggregation import TECHNIQUES, make_aggregator
from repro.core.moshpit import plan_grid
from repro.runtime.network import NetworkSim

ORDER = ("fedavg", "hierarchical", "mar", "gossip", "rdfl", "ar")


def main(argv=None) -> int:
    ap = std_argparser(__doc__)
    ap.add_argument("--profile", default="wireless",
                    help="link model (uniform | wireless | regions)")
    ap.add_argument("--model-mb", type=float, default=10.0,
                    help="state bytes per transfer (theta + momentum)")
    ap.add_argument("--iters", type=int, default=3,
                    help="simulated iterations to average over")
    ap.add_argument("--out", default="BENCH_comm.json")
    args = ap.parse_args(argv)

    if args.smoke:
        peer_counts = (8, 16)
    elif args.full:
        peer_counts = (8, 16, 64, 125, 512)
    else:
        peer_counts = (8, 16, 64, 125)
    model_bytes = args.model_mb * 1e6

    techniques = [t for t in ORDER if t in TECHNIQUES] + \
        sorted(set(TECHNIQUES) - set(ORDER))
    results = []
    per_iter_s = {}           # (technique, n) -> mean seconds
    for n in peer_counts:
        plan = plan_grid(n)
        mask = np.ones(n, np.float32)
        for tech in techniques:
            agg = make_aggregator(tech, plan)
            mplan = agg.message_plan(mask, model_bytes)
            net = NetworkSim(n, profile=args.profile, seed=args.seed)
            # links are fixed per sim and loss only matters on lossy
            # profiles, so the last transcript serves for bytes too
            transcripts = [net.run(mplan) for _ in range(args.iters)]
            tr = transcripts[-1]
            analytic = topology.iteration_bytes(
                tech, n, model_bytes, plan, num_rounds=agg.num_rounds)
            sim_s = float(np.mean([t.iteration_s for t in transcripts]))
            per_iter_s[(tech, n)] = sim_s
            row = dict(technique=tech, n_peers=n, grid=str(plan.dims),
                       messages=mplan.n_messages,
                       bytes=int(tr.total_bytes),
                       analytic_bytes=int(analytic),
                       parity=abs(tr.total_bytes - analytic) < 1.0,
                       sim_s=round(sim_s, 4))
            emit("wallclock", **row)
            results.append(row)

    # acceptance summary: growth factor from the smallest to the
    # largest N — MAR should track ~log N, AR ~N, on identical links
    lo, hi = peer_counts[0], peer_counts[-1]
    summary = {}
    for tech in ("mar", "ar"):
        if (tech, lo) in per_iter_s and per_iter_s[(tech, lo)] > 0:
            summary[f"{tech}_growth"] = round(
                per_iter_s[(tech, hi)] / per_iter_s[(tech, lo)], 2)
    summary["n_growth"] = round(hi / lo, 2)
    summary["logn_growth"] = round(np.log2(hi) / np.log2(lo), 2)
    emit("wallclock_summary", profile=args.profile, n_lo=lo, n_hi=hi,
         **summary)

    with open(args.out, "w") as f:
        json.dump({"benchmark": "wallclock_scaling",
                   "profile": args.profile,
                   "model_bytes": model_bytes,
                   "seed": args.seed,
                   "summary": summary,
                   "results": results}, f, indent=2)
    print(f"# wrote {args.out}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
