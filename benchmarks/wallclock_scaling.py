"""Wall-clock scaling: the O(N log N) vs O(N^2) gap in *seconds*.

The paper's headline (Fig. 1) compares techniques by bytes; the
wireless-FL literature argues per-round *timing* over heterogeneous
links is what actually limits scale. This benchmark unrolls one FL
iteration of every registered technique into messages
(``core/transport.py``), times them over the lognormal-wireless link
profile, and reports measured bytes + simulated seconds per iteration
across N in {8 .. 2^20}, plus the process peak RSS after each row.

Four engines cover the range:

- ``heap``   — per-message discrete-event sim (``runtime/network.py``);
  run alongside the vector engine at N <= 125 as a byte- and
  time-exact parity cross-check.
- ``vector`` — batched segment-op sim (``runtime/vector_network.py``)
  over ``ArrayMessagePlan``; the default whenever the plan
  materializes under the message budget.
- ``super``  — the hybrid closed-form/vectorized engine
  (``runtime/super_network.py``) consuming symbolic
  ``SuperMessagePlan`` recipes: O(rounds) vector ops instead of
  O(messages), transcript-identical on this profile. Cross-checked
  against the vector engine at N=1024, the only engine that reaches
  N=2^20 (one MAR iteration there is ~21M messages — never built).
- ``closed`` — O(N)/O(N * chunk) closed forms for the two O(N^2)
  baselines (``all_to_all_seconds`` / ``ring_seconds``) past the
  budget; above N=65536 even those loops are skipped (an O(N^2)
  baseline at N=2^20 is the point of the plot, not a row to wait on).

Expected shape, from uplink serialization alone: MAR sends G*(M-1)
models per peer, so its per-iteration wall-clock grows ~log N, while
AR's N-1 sends per peer grow ~N — the byte gap becomes a time gap on
the *same* links. Measured bytes are cross-checked against the
analytic oracles (``core/topology.py``) row by row (loss=0 parity).

Speedup rows: heap-vs-vector on one MAR iteration at N=1024 (the
ISSUE-6 acceptance number) and vector-vs-super at N=65536 — the
latter is *gated*: the run reports FAIL unless super is >= 10x. A
``plan_cache`` row reports the per-step planning time the
``Federation`` plan memo saves at N=65536 (array and symbolic
builds; a cache hit is a dict lookup).

Emits CSV rows plus ``BENCH_comm.json`` (bytes + simulated seconds per
technique per N, MAR-vs-AR growth ratios at large N) so the perf
trajectory has machine-readable data points.
"""
from __future__ import annotations

import json
import resource
import sys
import time

import numpy as np

from benchmarks.common import emit, std_argparser
from repro.core import topology
from repro.core.aggregation import TECHNIQUES, make_aggregator
from repro.core.moshpit import plan_grid
from repro.core.transport import build_array_plan, build_super_plan
from repro.runtime.network import NetworkSim
from repro.runtime.super_network import SuperNetworkSim
from repro.runtime.vector_network import (VectorNetworkSim,
                                          all_to_all_seconds,
                                          ring_seconds)

ORDER = ("fedavg", "hierarchical", "mar", "gossip", "rdfl", "ar")

#: above this many messages a plan is not materialized; the O(N^2)
#: baselines switch to their closed-form engines instead
MSG_BUDGET = 2_000_000
#: at or below this N the heap engine re-runs every plan as an exact
#: parity cross-check against the vector engine
PARITY_MAX_N = 125
#: the N at which the super engine is cross-checked against vector
SUPER_PARITY_N = 1024
#: largest N any plan is materialized at; past it the super engine
#: (symbolic plans) carries every structured technique
MAT_MAX_N = 65536
#: the acceptance-criterion speedup measurement points
SPEEDUP_N = 1024
SUPER_SPEEDUP_N = 65536
SUPER_SPEEDUP_GATE = 10.0


def _est_messages(tech: str, plan) -> int:
    """Message-count upper bound, cheap enough to decide the engine
    *before* building anything."""
    n = plan.n_peers
    if tech in ("ar", "rdfl"):
        return n * (n - 1)
    if tech == "gossip":
        return n * max(1, int(np.ceil(np.log2(max(n, 2)))))
    if tech == "mar":
        return plan.capacity * sum(m - 1 for m in plan.dims)
    return 2 * n                          # fedavg / hierarchical


def _rss_mb() -> int:
    """Process peak RSS in MB (ru_maxrss is KB on Linux)."""
    return int(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
               // 1024)


def _measure_speedup(n: int, profile: str, model_bytes: float,
                     seed: int, reps: int = 3):
    """Best-of-``reps`` wall time for one MAR iteration, heap vs
    vector, on identical links + plans."""
    plan = plan_grid(n)
    agg = make_aggregator("mar", plan)
    mplan = agg.message_plan(None, model_bytes)
    aplan = build_array_plan("mar", plan, None, model_bytes,
                             num_rounds=agg.num_rounds)
    heap = NetworkSim(n, profile=profile, seed=seed)
    vec = VectorNetworkSim(n, profile=profile, seed=seed)
    t_heap = min(_timed(heap.run, mplan) for _ in range(reps))
    t_vec = min(_timed(vec.run, aplan) for _ in range(reps))
    return t_heap, t_vec


def _measure_super_speedup(n: int, profile: str, model_bytes: float,
                           seed: int, reps: int = 5):
    """Best-of-``reps`` wall time for one MAR iteration, vector vs
    super, on identical links + plans (plan build timed separately —
    that's the ``plan_cache`` row)."""
    plan = plan_grid(n)
    agg = make_aggregator("mar", plan)
    t_build_array = time.perf_counter()
    aplan = build_array_plan("mar", plan, None, model_bytes,
                             num_rounds=agg.num_rounds)
    t_build_array = time.perf_counter() - t_build_array
    t_build_super = time.perf_counter()
    splan = build_super_plan("mar", plan, None, model_bytes,
                             num_rounds=agg.num_rounds)
    t_build_super = time.perf_counter() - t_build_super
    vec = VectorNetworkSim(n, profile=profile, seed=seed)
    sup = SuperNetworkSim(n, profile=profile, seed=seed)
    t_vec = min(_timed(vec.run, aplan) for _ in range(reps))
    t_sup = min(_timed(sup.run, splan) for _ in range(reps))
    return t_vec, t_sup, t_build_array, t_build_super


def _timed(fn, *a):
    t0 = time.perf_counter()
    fn(*a)
    return time.perf_counter() - t0


def main(argv=None) -> int:
    ap = std_argparser(__doc__)
    ap.add_argument("--profile", default="wireless",
                    help="link model (uniform | wireless | regions)")
    ap.add_argument("--model-mb", type=float, default=10.0,
                    help="state bytes per transfer (theta + momentum)")
    ap.add_argument("--iters", type=int, default=3,
                    help="simulated iterations to average over")
    ap.add_argument("--out", default="BENCH_comm.json")
    args = ap.parse_args(argv)

    if args.smoke:
        # one super parity row (N=1024) + the N=2^20 MAR headline
        peer_counts = (8, 16, 1024, 1 << 20)
    elif args.full:
        peer_counts = (8, 16, 64, 125, 512, 1024, 8192, 65536,
                       1 << 17, 1 << 18, 1 << 20)
    else:
        peer_counts = (8, 16, 64, 125, 1024, 8192, 65536,
                       1 << 17, 1 << 18, 1 << 20)
    model_bytes = args.model_mb * 1e6

    techniques = [t for t in ORDER if t in TECHNIQUES] + \
        sorted(set(TECHNIQUES) - set(ORDER))
    results = []
    per_iter_s = {}           # (technique, n) -> mean seconds
    for n in peer_counts:
        plan = plan_grid(n)
        mask = np.ones(n, np.float32)
        for tech in techniques:
            if n > MAT_MAX_N and args.smoke and tech != "mar":
                continue      # smoke: only the MAR headline up there
            agg = make_aggregator(tech, plan)
            analytic = topology.iteration_bytes(
                tech, n, model_bytes, plan, num_rounds=agg.num_rounds)
            est = _est_messages(tech, plan)
            if tech in ("ar", "rdfl") and (est > MSG_BUDGET
                                           or n > MAT_MAX_N):
                if n > MAT_MAX_N:
                    emit("wallclock_skip", technique=tech, n_peers=n,
                         reason="o_n2_baseline_above_materialized_tier")
                    continue
                # O(N^2) baseline past the budget: closed-form engine
                closed = {"ar": all_to_all_seconds,
                          "rdfl": ring_seconds}[tech]
                links = VectorNetworkSim(
                    n, profile=args.profile, seed=args.seed).links
                if getattr(links, "has_pair_terms", False):
                    # pairwise WAN terms (regions) are per-(src, dst);
                    # the closed forms model per-peer costs only
                    emit("wallclock_skip", technique=tech, n_peers=n,
                         reason="pair_terms_need_materialized_plan")
                    continue
                sim_s, _ = closed(links, model_bytes)
                row = dict(technique=tech, n_peers=n,
                           grid=str(plan.dims), engine="closed",
                           messages=est, bytes=int(analytic),
                           analytic_bytes=int(analytic), parity=True,
                           sim_s=round(sim_s, 4))
            elif est > MSG_BUDGET or n > MAT_MAX_N:
                # structured technique past the materialized tier:
                # symbolic plan through the super engine — O(rounds),
                # bytes still cross-checked against the oracle
                sup = SuperNetworkSim(n, profile=args.profile,
                                      seed=args.seed)
                splan = build_super_plan(tech, plan, mask, model_bytes,
                                         num_rounds=agg.num_rounds)
                transcripts = [sup.run(splan)
                               for _ in range(args.iters)]
                tr = transcripts[-1]
                parity = abs(tr.total_bytes - analytic) < 1.0
                sim_s = float(np.mean([t.iteration_s
                                       for t in transcripts]))
                row = dict(technique=tech, n_peers=n,
                           grid=str(plan.dims), engine="super",
                           messages=tr.n_messages,
                           bytes=int(tr.total_bytes),
                           analytic_bytes=int(analytic), parity=parity,
                           sim_s=round(sim_s, 4))
            else:
                aplan = build_array_plan(tech, plan, mask, model_bytes,
                                         num_rounds=agg.num_rounds)
                vec = VectorNetworkSim(n, profile=args.profile,
                                       seed=args.seed)
                transcripts = [vec.run(aplan)
                               for _ in range(args.iters)]
                tr = transcripts[-1]
                parity = abs(tr.total_bytes - analytic) < 1.0
                engine = "vector"
                if n <= PARITY_MAX_N:
                    # heap cross-check: byte-exact AND time-equal
                    heap = NetworkSim(n, profile=args.profile,
                                      seed=args.seed)
                    mplan = agg.message_plan(mask, model_bytes)
                    for t_vec in transcripts:
                        t_heap = heap.run(mplan)
                        same = (t_heap.total_bytes == t_vec.total_bytes
                                and t_heap.round_s == t_vec.round_s
                                and np.array_equal(t_heap.peer_finish_s,
                                                   t_vec.peer_finish_s))
                        parity = parity and same
                    engine = "vector+heap"
                if n == SUPER_PARITY_N:
                    # super cross-check: transcript-equal on this
                    # profile (bytes, per-round times, finish vector)
                    sup = SuperNetworkSim(n, profile=args.profile,
                                          seed=args.seed)
                    splan = build_super_plan(
                        tech, plan, mask, model_bytes,
                        num_rounds=agg.num_rounds)
                    for t_vec in transcripts:
                        t_sup = sup.run(splan)
                        same = (t_sup.total_bytes == t_vec.total_bytes
                                and t_sup.round_s == t_vec.round_s
                                and np.array_equal(t_sup.peer_finish_s,
                                                   t_vec.peer_finish_s))
                        parity = parity and same
                    engine += "+super"
                sim_s = float(np.mean([t.iteration_s
                                       for t in transcripts]))
                row = dict(technique=tech, n_peers=n,
                           grid=str(plan.dims), engine=engine,
                           messages=aplan.n_messages,
                           bytes=int(tr.total_bytes),
                           analytic_bytes=int(analytic), parity=parity,
                           sim_s=round(sim_s, 4))
            row["peak_rss_mb"] = _rss_mb()
            per_iter_s[(tech, n)] = row["sim_s"]
            emit("wallclock", **row)
            results.append(row)

    # acceptance summary: growth factor from the smallest to the
    # largest N each technique reached — MAR should track ~log N, AR
    # ~N, on identical links — plus the AR/MAR wall-clock ratio at
    # every large N where both engines produced rows
    lo, hi = peer_counts[0], peer_counts[-1]
    summary = {}
    for tech in ("mar", "ar"):
        ns = sorted(nn for (t2, nn) in per_iter_s if t2 == tech)
        if len(ns) >= 2 and per_iter_s[(tech, ns[0])] > 0:
            summary[f"{tech}_growth"] = round(
                per_iter_s[(tech, ns[-1])] / per_iter_s[(tech, ns[0])],
                2)
            summary[f"{tech}_growth_n_hi"] = ns[-1]
    summary["n_growth"] = round(hi / lo, 2)
    summary["logn_growth"] = round(np.log2(hi) / np.log2(lo), 2)
    for n in peer_counts:
        if (n >= 1024 and per_iter_s.get(("mar", n), 0) > 0
                and ("ar", n) in per_iter_s):
            summary[f"ar_over_mar_n{n}"] = round(
                per_iter_s[("ar", n)] / per_iter_s[("mar", n)], 2)

    if SPEEDUP_N in peer_counts:
        t_heap, t_vec = _measure_speedup(
            SPEEDUP_N, args.profile, model_bytes, args.seed)
        speedup = round(t_heap / t_vec, 1)
        summary[f"mar_n{SPEEDUP_N}_speedup"] = speedup
        emit("speedup", n_peers=SPEEDUP_N, technique="mar",
             heap_ms=round(t_heap * 1e3, 2),
             vector_ms=round(t_vec * 1e3, 2), speedup=speedup)

    if SUPER_SPEEDUP_N in peer_counts:
        t_vec, t_sup, t_ba, t_bs = _measure_super_speedup(
            SUPER_SPEEDUP_N, args.profile, model_bytes, args.seed)
        speedup = round(t_vec / t_sup, 1)
        gate = speedup >= SUPER_SPEEDUP_GATE
        summary[f"mar_n{SUPER_SPEEDUP_N}_super_speedup"] = speedup
        summary["super_speedup_gate_10x"] = (
            "pass" if gate else "FAIL")
        emit("super_speedup", n_peers=SUPER_SPEEDUP_N,
             technique="mar", vector_ms=round(t_vec * 1e3, 2),
             super_ms=round(t_sup * 1e3, 2), speedup=speedup,
             gate_10x="pass" if gate else "FAIL")
        # the planning time the Federation plan memo saves per step
        # once the (grid, mask, parity) key repeats: the whole build
        # (a cache hit is a dict lookup)
        summary["plan_build_array_ms"] = round(t_ba * 1e3, 2)
        summary["plan_build_super_ms"] = round(t_bs * 1e3, 2)
        emit("plan_cache", n_peers=SUPER_SPEEDUP_N, technique="mar",
             array_build_ms=round(t_ba * 1e3, 2),
             super_build_ms=round(t_bs * 1e3, 2),
             saved_per_hit_vector_ms=round(t_ba * 1e3, 2),
             saved_per_hit_super_ms=round(t_bs * 1e3, 2))

    emit("wallclock_summary", profile=args.profile, n_lo=lo, n_hi=hi,
         peak_rss_mb=_rss_mb(), **summary)

    with open(args.out, "w") as f:
        json.dump({"benchmark": "wallclock_scaling",
                   "profile": args.profile,
                   "model_bytes": model_bytes,
                   "seed": args.seed,
                   "summary": summary,
                   "results": results}, f, indent=2)
    print(f"# wrote {args.out}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
