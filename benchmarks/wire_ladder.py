"""Wire-stage ladder — the scenario matrix the composable pipeline opens.

Crosses aggregation techniques with wire-stage compositions (plain /
int8-EF / async / DP and their previously-asserted-out combinations)
on the sim backend and reports accuracy plus the CommLedger's per-source
byte split for each cell (EXPERIMENTS.md §Perf C-ladder, sim view).
"""
from __future__ import annotations

import sys

from benchmarks.common import emit, scale, std_argparser
from repro.core.federation import Federation, FederationConfig

STAGES = {
    "plain": {},
    "int8_ef": dict(compress="int8_ef"),
    "async": dict(async_aggregation=True),
    "dp": dict(use_dp=True),
    "async+int8_ef": dict(async_aggregation=True, compress="int8_ef"),
    "dp+int8_ef": dict(use_dp=True, compress="int8_ef"),
    "async+dp": dict(async_aggregation=True, use_dp=True),
}


def main(argv=None) -> int:
    ap = std_argparser(__doc__)
    ap.add_argument("--techniques", nargs="+",
                    default=["mar", "gossip", "hierarchical"])
    args = ap.parse_args(argv)
    s = scale(args.full)

    for tech in args.techniques:
        for label, flags in STAGES.items():
            cfg = FederationConfig(
                n_peers=s["peers"], technique=tech, task="text",
                local_batches=s["local_batches"], seed=args.seed, **flags)
            fed = Federation(cfg)
            state = fed.init_state()
            for _ in range(s["iters"]):
                state = fed.step(state)
            by_source = "|".join(f"{k}:{v/1e6:.1f}"
                                 for k, v in fed.ledger.by_source.items())
            emit("wire_ladder", technique=tech, stages=label,
                 acc=round(fed.evaluate(state), 4),
                 comm_mb=round(fed.comm_bytes / 1e6, 1),
                 by_source_mb=by_source)
    return 0


if __name__ == "__main__":
    sys.exit(main())
