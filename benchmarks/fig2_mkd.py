"""Fig. 2 / Fig. 9 — MKD: communication to reach target accuracy with and
without Moshpit-KD (text = 20NG analogue; --task vision = MNIST)."""
from __future__ import annotations

import sys

from benchmarks.common import emit, scale, std_argparser
from repro.core.federation import FederationConfig, run_federation


def main(argv=None) -> int:
    ap = std_argparser(__doc__)
    ap.add_argument("--task", default="text", choices=["text", "vision"])
    ap.add_argument("--target", type=float, default=0.30)
    args = ap.parse_args(argv)
    s = scale(args.full)

    for use_kd, kd_iters in ((False, 0), (True, 6), (True, 12)):
        cfg = FederationConfig(
            n_peers=s["peers"], technique="mar", task=args.task,
            batch_size=64 if args.task == "vision" else 16,
            local_batches=s["local_batches"],
            use_kd=use_kd, kd_iterations=kd_iters, seed=args.seed)
        hist = run_federation(cfg, s["iters"], eval_every=s["eval_every"])
        reached = next((c for a, c in zip(hist["accuracy"],
                                          hist["comm_bytes"])
                        if a >= args.target), None)
        emit("fig2_mkd", task=args.task, use_kd=use_kd, kd_iters=kd_iters,
             final_acc=round(hist["accuracy"][-1], 4),
             comm_mb=round(hist["comm_bytes"][-1] / 1e6, 1),
             mb_to_target=(round(reached / 1e6, 1)
                           if reached else "not_reached"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
