"""Fig. 4/10 — differentially private training: noise multiplier sweep
with adaptive clipping (Alg. 4) + RDP epsilon estimates."""
from __future__ import annotations

import sys

from benchmarks.common import emit, scale, std_argparser
from repro.core.dp import epsilon_estimate
from repro.core.federation import FederationConfig, run_federation


def main(argv=None) -> int:
    ap = std_argparser(__doc__)
    args = ap.parse_args(argv)
    s = scale(args.full)

    for sigma in (0.0, 0.1, 0.3, 1.0):
        cfg = FederationConfig(
            n_peers=s["peers"], technique="mar", task="text",
            use_dp=sigma > 0, noise_multiplier=sigma,
            local_batches=s["local_batches"], seed=args.seed)
        hist = run_federation(cfg, s["iters"], eval_every=s["eval_every"])
        eps = (epsilon_estimate(s["iters"], sigma)
               if sigma > 0 else float("inf"))
        emit("fig4_dp", noise_multiplier=sigma,
             final_acc=round(hist["accuracy"][-1], 4),
             epsilon=(round(eps, 1) if eps != float("inf") else "inf"),
             comm_mb=round(hist["comm_bytes"][-1] / 1e6, 1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
