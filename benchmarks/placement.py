"""Placement-aware vs random grid coordinates, in simulated seconds.

The ROADMAP's topology-aware placement item, measured: the
``clustered`` :class:`~repro.core.placement.PlacementPolicy` learns
network regions from landmark probe rounds over the live links and
regroups the MAR grid so each region fills contiguous coordinates —
against ``random`` coordinates (the misalignment control) and
``identity`` (today's raw-index behavior) on identical links.

The regions cells run with ``shuffle=True`` — peers joined in
arbitrary order, so raw indices interleave regions and every one of
the d rounds crosses the 5 Mbit/s WAN; aligned placement collapses
cross-region traffic into the top axes. Two N=125 grids are reported:
the planner's (5, 5, 5), where 4 regions cannot tile 25-slot blocks
and the mixed block bounds the win (~1.15x), and (2,)*7 — the grid
``tail_aware`` adaptive-M converges to at N=125 (BENCH_adaptive_m) —
where alignment is structurally possible and the acceptance gate
(clustered >= 1.3x over random) applies. The wireless profile has no
pair structure, so placement is provably neutral there (per-peer-only
costs make iteration time permutation-invariant) — those rows document
that placement never *hurts*.

Byte accounting stays honest throughout: placement changes *when*
traffic crosses the WAN, never *how much*, so after every iteration —
including every post-regroup one — the transcript's total bytes are
cross-checked against ``topology.mar_bytes``; any mismatch fails the
benchmark. Probe traffic is billed separately (``probe_bytes`` /
``probe_s`` columns), never hidden in the steady-state numbers.

A combined cell runs ``clustered`` placement and the ``tail_aware``
group-size controller in the same loop (the federation's composition
order) and must at least match adaptive-M alone.

Emits CSV rows plus ``BENCH_placement.json``; exits nonzero on any
byte-parity failure, a sub-1.3x gate cell, or a combined run that
loses to adaptive-M alone.
"""
from __future__ import annotations

import json
import sys
from typing import Optional

import numpy as np

from benchmarks.common import emit, std_argparser
from repro.core import topology
from repro.core.adaptive import build_controller
from repro.core.aggregation import make_aggregator
from repro.core.moshpit import GridPlan, plan_grid
from repro.core.placement import build_placement
from repro.runtime.network import NetworkSim

PROFILES = ("regions", "wireless")
GATE_SPEEDUP = 1.3
#: regions cells scatter region assignment over peer indices — the
#: misaligned world placement exists for (aligned raw indices would
#: make identity coincidentally optimal and the benchmark vacuous)
REGION_PARAMS = {"shuffle": True}


def run_cell(n: int, profile: str, seed: int, iters: int,
             model_bytes: float, placement: Optional[str] = None,
             dims: Optional[tuple] = None,
             adaptive: bool = False) -> dict:
    """One cell: ``iters`` MAR iterations over one NetworkSim, with an
    optional placement policy (and optional tail_aware controller) in
    the loop. Links are drawn from (profile, n, seed) alone, so every
    arm of a cell times its messages over identical links."""
    link_params = REGION_PARAMS if profile == "regions" else None
    net = NetworkSim(n, profile=profile, seed=seed,
                     link_params=link_params)
    plan = plan_grid(n) if dims is None else GridPlan(n, tuple(dims))
    mask = np.ones(n, np.float32)
    probe = {"bytes": 0.0, "s": 0.0}

    def prober(mplan):
        tr = net.run(mplan)
        probe["bytes"] += tr.total_bytes
        probe["s"] += tr.iteration_s
        return tr

    policy = None
    if placement is not None:
        policy = build_placement(placement, plan, seed=seed)
        policy.bind_prober(prober)
    controller = build_controller("tail_aware", plan) if adaptive \
        else None

    per_iter, moves, regroups = [], 0, 0
    parity_ok = True
    for t in range(iters):
        agg = make_aggregator("mar", plan)
        tr = net.run(agg.message_plan(mask, model_bytes))
        per_iter.append(tr.iteration_s)
        # any permutation preserves bytes — checked vs the analytic
        # oracle after every iteration, post-regroup included
        oracle = topology.mar_bytes(n, plan, model_bytes, mask=mask)
        if abs(tr.total_bytes - oracle) >= 1.0:
            parity_ok = False
        if controller is not None:
            proposal = controller.observe(t, tr, plan)
            if proposal is not None and \
                    tuple(proposal.dims) != tuple(plan.dims):
                plan = proposal
                regroups += 1
                if policy is not None:
                    policy.rebind(plan)
        if policy is not None:
            target = policy.observe(t, tr, plan)
            if target is not None and target != plan:
                plan = target
                moves += 1
    steady_k = max(iters // 3, 1)
    out = {
        "n_peers": n, "profile": profile,
        "placement": placement or "identity",
        "dims_final": list(plan.dims),
        "iters": iters,
        "steady_s": float(np.mean(per_iter[-steady_k:])),
        "total_s": float(np.sum(per_iter)),
        "probe_bytes": probe["bytes"], "probe_s": probe["s"],
        "placement_moves": moves, "regroups": regroups,
        "byte_parity": parity_ok,
    }
    labels = getattr(policy, "labels", None)
    truth = net.links.peer_attrs().get("region")
    if labels is not None and truth is not None:
        purity = sum(int(np.bincount(truth[labels == c]).max())
                     for c in np.unique(labels))
        out["purity"] = purity / n
    return out


def main(argv=None) -> int:
    ap = std_argparser(__doc__)
    ap.add_argument("--model-mb", type=float, default=10.0,
                    help="state bytes per transfer (theta + momentum)")
    ap.add_argument("--iters", type=int, default=None,
                    help="iterations per cell")
    ap.add_argument("--out", default="BENCH_placement.json")
    args = ap.parse_args(argv)

    if args.smoke:
        peer_counts, iters = (8, 16), args.iters or 8
    else:
        peer_counts, iters = (27, 64, 125), args.iters or 24
    model_bytes = args.model_mb * 1e6

    # (n, dims) cells: the planner's grid everywhere, plus the
    # adaptive-M converged (2,)*7 grid at N=125 — the acceptance gate
    # cell (on (5, 5, 5), 4 regions cannot tile 25-slot blocks, so the
    # mixed block structurally bounds the win; reported honestly)
    cells = [(n, None) for n in peer_counts]
    gate_cell = None
    if 125 in peer_counts:
        gate_cell = (125, (2,) * 7)
        cells.append(gate_cell)

    results, summary = [], {}
    rc = 0
    for profile in PROFILES:
        for n, dims in cells:
            if profile == "wireless" and dims is not None:
                continue                  # gate grid is a regions cell
            arms = {
                name: run_cell(n, profile, args.seed, iters,
                               model_bytes, placement=name, dims=dims)
                for name in (None, "random", "clustered")
            }
            ident, rand, clust = (arms[None], arms["random"],
                                  arms["clustered"])
            vs_random = (rand["steady_s"] / clust["steady_s"]
                         if clust["steady_s"] > 0 else 1.0)
            vs_ident = (ident["steady_s"] / clust["steady_s"]
                        if clust["steady_s"] > 0 else 1.0)
            parity = all(a["byte_parity"] for a in arms.values())
            tag = f"{profile}_n{n}" + ("_pow2" if dims else "")
            row = dict(profile=profile, n_peers=n,
                       grid=str(tuple(clust["dims_final"])),
                       identity_steady_s=round(ident["steady_s"], 4),
                       random_steady_s=round(rand["steady_s"], 4),
                       clustered_steady_s=round(clust["steady_s"], 4),
                       clustered_vs_random=round(vs_random, 3),
                       clustered_vs_identity=round(vs_ident, 3),
                       probe_mb=round(clust["probe_bytes"] / 1e6, 2),
                       probe_s=round(clust["probe_s"], 3),
                       purity=round(clust.get("purity", 0.0), 3),
                       byte_parity=parity)
            emit("placement", **row)
            results.append({"cell": tag, "arms": arms})
            summary[f"{tag}_clustered_vs_random"] = round(vs_random, 3)
            summary[f"{tag}_clustered_vs_identity"] = round(vs_ident, 3)
            if "purity" in clust:
                summary[f"{tag}_purity"] = round(clust["purity"], 3)
            if not parity:
                print(f"# FAIL byte parity at n={n} {profile}",
                      flush=True)
                rc = 1
            if profile == "regions" and (n, dims) == gate_cell \
                    and vs_random < GATE_SPEEDUP:
                print(f"# FAIL clustered placement below the "
                      f"{GATE_SPEEDUP}x gate vs random at N={n} "
                      f"regions {tuple(clust['dims_final'])} "
                      f"(got {vs_random:.3f}x)", flush=True)
                rc = 1

    # composition: clustered placement + tail_aware adaptive-M must at
    # least match adaptive-M alone on the same links
    n_hi = peer_counts[-1]
    adapt = run_cell(n_hi, "regions", args.seed, iters, model_bytes,
                     adaptive=True)
    combined = run_cell(n_hi, "regions", args.seed, iters, model_bytes,
                        placement="clustered", adaptive=True)
    combo = (adapt["total_s"] / combined["total_s"]
             if combined["total_s"] > 0 else 1.0)
    emit("placement_combined", n_peers=n_hi, profile="regions",
         adaptive_total_s=round(adapt["total_s"], 3),
         combined_total_s=round(combined["total_s"], 3),
         combined_vs_adaptive=round(combo, 3),
         adaptive_dims=str(tuple(adapt["dims_final"])),
         combined_dims=str(tuple(combined["dims_final"])))
    results.append({"cell": f"combined_n{n_hi}",
                    "arms": {"adaptive": adapt, "combined": combined}})
    summary[f"combined_n{n_hi}_vs_adaptive"] = round(combo, 3)
    if not (adapt["byte_parity"] and combined["byte_parity"]):
        print("# FAIL byte parity in the combined cell", flush=True)
        rc = 1
    if combo < 0.98:
        print(f"# FAIL clustered+tail_aware loses to tail_aware alone "
              f"at N={n_hi} regions ({combo:.3f}x)", flush=True)
        rc = 1
    emit("placement_summary", iters=iters, **summary)

    with open(args.out, "w") as f:
        json.dump({"benchmark": "placement",
                   "model_bytes": model_bytes,
                   "iters": iters, "seed": args.seed,
                   "region_params": REGION_PARAMS,
                   "summary": summary,
                   "results": results}, f, indent=2)
    print(f"# wrote {args.out}", flush=True)
    return rc


if __name__ == "__main__":
    sys.exit(main())
